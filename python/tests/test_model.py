"""L2 correctness: stage decomposition, pipeline-chain gradients vs
end-to-end autodiff, pallas/jnp path equivalence, Adam, and a short
training-loss sanity run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.CONFIGS["lm1m"]


def make_params(n_stages, seed=0):
    kinds, blocks = M.stage_layout(CFG, n_stages)
    return kinds, blocks, [M.init_stage(CFG, k, nb, seed) for k, nb in zip(kinds, blocks)]


def data(b=2, seed=0):
    rng = np.random.default_rng(seed)
    tok = jnp.asarray(rng.integers(0, CFG.vocab, (b, CFG.seq)), jnp.int32)
    tgt = jnp.asarray(rng.integers(0, CFG.vocab, (b, CFG.seq)), jnp.int32)
    return tok, tgt


def test_split_blocks_even_and_total():
    assert M.split_blocks(8, 4) == [2, 2, 2, 2]
    assert sum(M.split_blocks(12, 5)) == 12
    # extras land on middle stages first
    c = M.split_blocks(7, 3)
    assert sum(c) == 7 and c[1] >= c[0] and c[1] >= c[2]


def test_stage_layout_kinds():
    kinds, blocks = M.stage_layout(CFG, 4)
    assert kinds == ["first", "mid", "mid", "last"]
    assert sum(blocks) == CFG.n_layers
    with pytest.raises(ValueError):
        M.stage_layout(CFG, 1)


def test_init_shapes_match_specs():
    kinds, blocks, params = make_params(3)
    for kind, nb, p in zip(kinds, blocks, params):
        specs = M.stage_param_specs(CFG, kind, nb)
        assert len(p) == len(specs)
        for arr, (_, shape) in zip(p, specs):
            assert arr.shape == shape


def test_initial_loss_near_log_vocab():
    kinds, blocks, params = make_params(2)
    tok, tgt = data()
    loss = float(M.full_forward_loss(CFG, kinds, blocks, params, tok, tgt))
    assert abs(loss - np.log(CFG.vocab)) < 0.5, loss


def test_pallas_and_jnp_paths_agree():
    kinds, blocks, params = make_params(2)
    tok, tgt = data()
    l_ref = float(M.full_forward_loss(CFG, kinds, blocks, params, tok, tgt, use_pallas=False))
    l_pal = float(M.full_forward_loss(CFG, kinds, blocks, params, tok, tgt, use_pallas=True))
    assert abs(l_ref - l_pal) < 1e-3


@pytest.mark.parametrize("n_stages", [2, 3, 4])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_pipeline_chain_grads_match_autodiff(n_stages, use_pallas):
    """fwd through the chain, bwd back through the chain == jax.grad of the
    composed loss — the invariant the rust engine relies on."""
    kinds, blocks, params = make_params(n_stages)
    tok, tgt = data()
    # forward chain, stashing stage inputs
    xs = [tok]
    for kind, nb, p in zip(kinds[:-1], blocks[:-1], params[:-1]):
        xs.append(M.stage_fwd(CFG, kind, nb, use_pallas, p, xs[-1]))
    # backward chain with zero accumulators
    grads = [None] * n_stages
    acc = [jnp.zeros_like(a) for a in params[-1]]
    out = M.stage_bwd(CFG, "last", blocks[-1], use_pallas, params[-1], acc, xs[-1], tgt)
    grads[-1], gx = out[:-1], out[-1]
    for i in range(n_stages - 2, -1, -1):
        acc = [jnp.zeros_like(a) for a in params[i]]
        out = M.stage_bwd(CFG, kinds[i], blocks[i], use_pallas, params[i], acc, xs[i], gx)
        if kinds[i] == "first":
            grads[i] = out
        else:
            grads[i], gx = out[:-1], out[-1]
    # oracle
    gref = jax.grad(
        lambda ps: M.full_forward_loss(CFG, kinds, blocks, ps, tok, tgt, use_pallas=False)
    )(params)
    tol = 5e-3 if use_pallas else 5e-4
    for gs, rs in zip(grads, gref):
        for a, b in zip(gs, rs):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


def test_bwd_accumulates():
    """Calling bwd twice with the same inputs doubles the accumulator."""
    kinds, blocks, params = make_params(2)
    tok, tgt = data()
    x1 = M.stage_fwd(CFG, "first", blocks[0], False, params[0], tok)
    acc = [jnp.zeros_like(a) for a in params[1]]
    out1 = M.stage_bwd(CFG, "last", blocks[1], False, params[1], acc, x1, tgt)
    out2 = M.stage_bwd(CFG, "last", blocks[1], False, params[1], out1[:-1], x1, tgt)
    for once, twice in zip(out1[:-1], out2[:-1]):
        np.testing.assert_allclose(2 * np.asarray(once), np.asarray(twice), rtol=1e-4, atol=1e-5)


def test_adam_moves_params_against_gradient():
    p = [jnp.ones(4)]
    g = [jnp.ones(4)]
    m = [jnp.zeros(4)]
    v = [jnp.zeros(4)]
    new_p, new_m, new_v = M.adam_update(p, g, m, v, step=1.0, lr=0.1, grad_scale=1.0)
    assert np.all(np.asarray(new_p[0]) < 1.0)
    assert np.all(np.asarray(new_m[0]) > 0.0)
    # grad_scale=0 is a no-op
    same_p, _, _ = M.adam_update(p, g, m, v, step=1.0, lr=0.1, grad_scale=0.0)
    np.testing.assert_allclose(same_p[0], p[0])


def test_short_training_run_reduces_loss():
    """20 full-model Adam steps on a fixed batch must cut the loss."""
    kinds, blocks, params = make_params(2)
    tok, tgt = data(b=4)
    flat = [a for p in params for a in p]
    sizes = [len(p) for p in params]

    def unflatten(flat):
        out, i = [], 0
        for s in sizes:
            out.append(flat[i : i + s])
            i += s
        return out

    loss_fn = jax.jit(
        lambda fl: M.full_forward_loss(CFG, kinds, blocks, unflatten(fl), tok, tgt)
    )
    grad_fn = jax.jit(jax.grad(lambda fl: M.full_forward_loss(CFG, kinds, blocks, unflatten(fl), tok, tgt)))
    m = [jnp.zeros_like(a) for a in flat]
    v = [jnp.zeros_like(a) for a in flat]
    l0 = float(loss_fn(flat))
    for step in range(1, 21):
        g = grad_fn(flat)
        flat, m, v = M.adam_update(flat, g, m, v, step=float(step), lr=1e-3, grad_scale=1.0)
    l1 = float(loss_fn(flat))
    assert l1 < l0 - 0.5, f"{l0} -> {l1}"
