"""L1 correctness: every Pallas kernel vs the pure-jnp oracle, across a
hypothesis-swept shape/seed space, plus gradient checks for the
custom-vjp wrappers. This is the CORE correctness signal for layer 1."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import autodiff as AD
from compile.kernels import ref as R

DIMS = st.sampled_from([1, 2, 4, 8, 16, 32, 48, 64, 96, 128, 192, 256])
SMALL = st.sampled_from([2, 4, 8, 16, 32])


def arr(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = arr(rng, m, k), arr(rng, k, n)
    np.testing.assert_allclose(K.matmul(x, y), R.matmul(x, y), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(m=SMALL, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_fused_linear_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, b = arr(rng, m, k), arr(rng, k, n), arr(rng, n)
    np.testing.assert_allclose(
        K.linear_bias_gelu(x, w, b), R.linear_bias_gelu(x, w, b), rtol=5e-4, atol=5e-4
    )


@settings(max_examples=15, deadline=None)
@given(r=DIMS, d=DIMS, seed=st.integers(0, 2**31 - 1))
def test_layernorm_matches_ref(r, d, seed):
    rng = np.random.default_rng(seed)
    x, s, b = arr(rng, r, d), arr(rng, d), arr(rng, d)
    np.testing.assert_allclose(K.layernorm(x, s, b), R.layernorm(x, s, b), rtol=5e-4, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(bh=SMALL, s=st.sampled_from([4, 8, 16, 32, 64]), dh=st.sampled_from([4, 8, 16, 32, 64]),
       seed=st.integers(0, 2**31 - 1))
def test_attention_matches_ref(bh, s, dh, seed):
    rng = np.random.default_rng(seed)
    q, k, v = arr(rng, bh, s, dh), arr(rng, bh, s, dh), arr(rng, bh, s, dh)
    got = K.causal_attention(q, k, v)
    want = jax.vmap(R.causal_attention)(q, k, v)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(r=SMALL, v=st.sampled_from([16, 64, 512, 1000, 4096]), seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_matches_ref(r, v, seed):
    rng = np.random.default_rng(seed)
    lg = arr(rng, r, v)
    t = jnp.asarray(rng.integers(0, v, r), jnp.int32)
    np.testing.assert_allclose(K.softmax_xent(lg, t), R.softmax_xent(lg, t), rtol=5e-4, atol=5e-4)


def test_attention_is_causal():
    """Changing future tokens must not change earlier outputs."""
    rng = np.random.default_rng(3)
    q = arr(rng, 1, 16, 8)
    k1, v1 = arr(rng, 1, 16, 8), arr(rng, 1, 16, 8)
    k2 = k1.at[:, 12:].set(99.0)
    v2 = v1.at[:, 12:].set(-99.0)
    o1 = K.causal_attention(q, k1, v1)
    o2 = K.causal_attention(q, k2, v2)
    np.testing.assert_allclose(o1[:, :12], o2[:, :12], rtol=1e-5, atol=1e-5)
    assert not np.allclose(o1[:, 12:], o2[:, 12:])


# ------------------------------------------------------------ grad checks

@settings(max_examples=10, deadline=None)
@given(m=SMALL, k=st.sampled_from([8, 16, 64]), n=st.sampled_from([8, 16, 64]),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_grad_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = arr(rng, m, k), arr(rng, k, n)
    gx1, gy1 = jax.grad(lambda a, b: AD.matmul(a, b).sum(), argnums=(0, 1))(x, y)
    gx2, gy2 = jax.grad(lambda a, b: R.matmul(a, b).sum(), argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx1, gx2, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(gy1, gy2, rtol=5e-4, atol=5e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fused_linear_grad_matches_ref(seed):
    rng = np.random.default_rng(seed)
    x, w, b = arr(rng, 8, 16), arr(rng, 16, 32), arr(rng, 32)
    g1 = jax.grad(lambda a, c, d: AD.linear_bias_gelu(a, c, d).sum(), argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(lambda a, c, d: R.linear_bias_gelu(a, c, d).sum(), argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g1, g2):
        np.testing.assert_allclose(a, c, rtol=1e-3, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_layernorm_grad_matches_ref(seed):
    rng = np.random.default_rng(seed)
    x, s, b = arr(rng, 8, 32), arr(rng, 32), arr(rng, 32)
    g1 = jax.grad(lambda a, c, d: (AD.layernorm(a, c, d) ** 2).sum(), argnums=(0, 1, 2))(x, s, b)
    g2 = jax.grad(lambda a, c, d: (R.layernorm(a, c, d) ** 2).sum(), argnums=(0, 1, 2))(x, s, b)
    for a, c in zip(g1, g2):
        np.testing.assert_allclose(a, c, rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_attention_grad_matches_ref(seed):
    rng = np.random.default_rng(seed)
    q, k, v = arr(rng, 2, 8, 4), arr(rng, 2, 8, 4), arr(rng, 2, 8, 4)
    g1 = jax.grad(lambda a, c, d: (AD.causal_attention(a, c, d) ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    ref_fn = lambda a, c, d: (jax.vmap(R.causal_attention)(a, c, d) ** 2).sum()
    g2 = jax.grad(ref_fn, argnums=(0, 1, 2))(q, k, v)
    for a, c in zip(g1, g2):
        np.testing.assert_allclose(a, c, rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_grad_matches_ref(seed):
    rng = np.random.default_rng(seed)
    lg = arr(rng, 8, 64)
    t = jnp.asarray(rng.integers(0, 64, 8), jnp.int32)
    g1 = jax.grad(lambda a: AD.softmax_xent(a, t).mean())(lg)
    g2 = jax.grad(lambda a: R.softmax_xent(a, t).mean())(lg)
    np.testing.assert_allclose(g1, g2, rtol=1e-3, atol=1e-3)


def test_xent_of_uniform_logits_is_log_v():
    v = 128
    lg = jnp.zeros((4, v))
    t = jnp.asarray([0, 1, 2, 3], jnp.int32)
    np.testing.assert_allclose(K.softmax_xent(lg, t), np.log(v) * np.ones(4), rtol=1e-5)


def test_vmem_and_mxu_estimates():
    from compile.kernels.matmul import mxu_utilization, vmem_bytes
    # 128³ tiles: 3 tiles of 64 KiB = 192 KiB — far under the 16 MB VMEM budget
    assert vmem_bytes(128, 128, 128) == 4 * 3 * 128 * 128
    assert mxu_utilization(128, 128, 128) == 1.0
    assert mxu_utilization(64, 128, 128) == 0.5
