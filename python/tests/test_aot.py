"""AOT path: artifacts build, the manifest is complete, and the emitted
HLO text is parseable (header + parameter arity spot checks)."""

import json
import os

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts") / "lm1m-s2-b2")
    aot.build("lm1m", n_stages=2, micro=2, use_pallas=False, out_dir=out)
    return out


def test_manifest_fields(built):
    with open(os.path.join(built, "manifest.json")) as f:
        man = json.load(f)
    cfg = M.CONFIGS["lm1m"]
    assert man["model"] == "lm1m"
    assert man["d_model"] == cfg.d_model
    assert man["n_stages"] == 2
    assert man["micro_batch"] == 2
    assert len(man["stages"]) == 2
    assert man["stages"][0]["kind"] == "first"
    assert man["stages"][1]["kind"] == "last"
    assert man["stages"][0]["in_dtype"] == "i32"
    assert man["stages"][1]["in_dtype"] == "f32"


def test_all_artifacts_exist_and_are_hlo(built):
    with open(os.path.join(built, "manifest.json")) as f:
        man = json.load(f)
    for st in man["stages"]:
        for name in ("init", "fwd", "bwd", "opt"):
            path = os.path.join(built, st["files"][name])
            assert os.path.exists(path), path
            with open(path) as f:
                head = f.read(200)
            assert head.startswith("HloModule"), f"{path}: {head[:40]}"


def test_param_specs_match_model(built):
    with open(os.path.join(built, "manifest.json")) as f:
        man = json.load(f)
    cfg = M.CONFIGS["lm1m"]
    kinds, blocks = M.stage_layout(cfg, 2)
    for st, kind, nb in zip(man["stages"], kinds, blocks):
        specs = M.stage_param_specs(cfg, kind, nb)
        assert len(st["params"]) == len(specs)
        for got, (name, shape) in zip(st["params"], specs):
            assert got["name"] == name
            assert tuple(got["shape"]) == shape


def test_fwd_param_count_in_hlo(built):
    """fwd takes P params + 1 input (+1 targets for last stage)."""
    with open(os.path.join(built, "manifest.json")) as f:
        man = json.load(f)
    for st in man["stages"]:
        with open(os.path.join(built, st["files"]["fwd"])) as f:
            text = f.read()
        entry = [l for l in text.splitlines() if "ENTRY" in l][0]
        n_args = entry.count("parameter(") or entry.count(": ")  # fallback
        expect = len(st["params"]) + (2 if st["kind"] == "last" else 1)
        # count parameter declarations across the entry computation
        n_params = text.count("parameter(")
        assert n_params >= expect, f"{st['kind']}: {n_params} < {expect}"
