"""L2: the decoder-only transformer LM as *pipeline stages* in JAX.

Each stage is a pure function over a flat list of parameter arrays (HLO
takes positional args, so pytrees are flattened in a fixed, manifest-
recorded order). The backward pass recomputes the stage forward via
`jax.vjp` at the stashed stage *input* — so the rust engine stashes only
stage inputs per in-flight micro-batch, matching the 1F1B activation
accounting (`(N-i)·a`).

Stage kinds:
  first : tok_emb + pos_emb + K blocks          (tokens i32[B,S] → f32[B,S,D])
  mid   : K blocks                              (f32[B,S,D] → f32[B,S,D])
  last  : K blocks + ln_f + untied lm head +    (x, targets → scalar mean loss)
          fused softmax-xent

`use_pallas=True` routes every gemm / layernorm / attention / loss through
the L1 Pallas kernels (via their custom-vjp wrappers); `False` uses the
pure-jnp reference ops — numerics must match either way (tested).
"""

import dataclasses
import functools
from typing import List

import jax
import jax.numpy as jnp

from .kernels import autodiff as AD
from .kernels import ref as R


@dataclasses.dataclass(frozen=True)
class Config:
    """Transformer hyper-parameters (mirrors rust `TransformerCfg`)."""

    d_model: int
    n_layers: int
    n_heads: int
    vocab: int
    seq: int

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


CONFIGS = {
    "lm1m": Config(d_model=128, n_layers=4, n_heads=4, vocab=512, seq=32),
    "lm10m": Config(d_model=256, n_layers=8, n_heads=8, vocab=4096, seq=64),
    "lm100m": Config(d_model=768, n_layers=12, n_heads=12, vocab=8192, seq=64),
}


def split_blocks(n_layers: int, n_stages: int) -> List[int]:
    """Distribute transformer blocks over stages as evenly as possible,
    biasing the *extra* blocks toward middle stages (first/last also carry
    embedding / head work)."""
    base = n_layers // n_stages
    extra = n_layers % n_stages
    counts = [base] * n_stages
    order = sorted(range(n_stages), key=lambda i: (i == 0 or i == n_stages - 1, i))
    for i in range(extra):
        counts[order[i % n_stages]] += 1
    assert sum(counts) == n_layers
    return counts


# ---------------------------------------------------------------- params

def block_param_specs(cfg: Config, prefix: str):
    """(name, shape) pairs for one transformer block, in flattened order."""
    d = cfg.d_model
    return [
        (f"{prefix}.ln1_s", (d,)),
        (f"{prefix}.ln1_b", (d,)),
        (f"{prefix}.wqkv", (d, 3 * d)),
        (f"{prefix}.bqkv", (3 * d,)),
        (f"{prefix}.wo", (d, d)),
        (f"{prefix}.bo", (d,)),
        (f"{prefix}.ln2_s", (d,)),
        (f"{prefix}.ln2_b", (d,)),
        (f"{prefix}.w1", (d, 4 * d)),
        (f"{prefix}.b1", (4 * d,)),
        (f"{prefix}.w2", (4 * d, d)),
        (f"{prefix}.b2", (d,)),
    ]


def stage_param_specs(cfg: Config, kind: str, n_blocks: int):
    """(name, shape) pairs for a whole stage."""
    specs = []
    if kind == "first":
        specs.append(("tok_emb", (cfg.vocab, cfg.d_model)))
        specs.append(("pos_emb", (cfg.seq, cfg.d_model)))
    for b in range(n_blocks):
        specs.extend(block_param_specs(cfg, f"blk{b}"))
    if kind == "last":
        specs.append(("lnf_s", (cfg.d_model,)))
        specs.append(("lnf_b", (cfg.d_model,)))
        specs.append(("w_out", (cfg.d_model, cfg.vocab)))
    return specs


def init_stage(cfg: Config, kind: str, n_blocks: int, seed):
    """Initialize one stage's parameter list from an i32 seed (traceable)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for i, (name, shape) in enumerate(stage_param_specs(cfg, kind, n_blocks)):
        sub = jax.random.fold_in(key, i)
        base = name.split(".")[-1]
        if base in ("ln1_s", "ln2_s", "lnf_s"):
            out.append(jnp.ones(shape, jnp.float32))
        elif base in ("ln1_b", "ln2_b", "lnf_b", "bqkv", "bo", "b1", "b2"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            std = 0.02
            if base in ("wo", "w2"):  # residual-branch outputs scaled down
                std = 0.02 / jnp.sqrt(2.0 * cfg.n_layers)
            out.append(std * jax.random.normal(sub, shape, jnp.float32))
    return out


# ---------------------------------------------------------------- forward

def _attention(cfg: Config, x2d, wqkv, bqkv, wo, bo, b, s, use_pallas):
    mm = AD.matmul if use_pallas else R.matmul
    qkv = mm(x2d, wqkv) + bqkv  # [B*S, 3D]
    qkv = qkv.reshape(b, s, 3, cfg.n_heads, cfg.d_head)
    q, k, v = (
        qkv[:, :, i].transpose(0, 2, 1, 3).reshape(b * cfg.n_heads, s, cfg.d_head)
        for i in range(3)
    )
    if use_pallas:
        ctx = AD.causal_attention(q, k, v)
    else:
        ctx = jax.vmap(R.causal_attention)(q, k, v)
    ctx = (
        ctx.reshape(b, cfg.n_heads, s, cfg.d_head)
        .transpose(0, 2, 1, 3)
        .reshape(b * s, cfg.d_model)
    )
    return mm(ctx, wo) + bo


def block_fwd(cfg: Config, p12, x, use_pallas):
    """One pre-norm transformer block. x: [B, S, D]."""
    b, s, d = x.shape
    ln = AD.layernorm if use_pallas else R.layernorm
    mm = AD.matmul if use_pallas else R.matmul
    flg = AD.linear_bias_gelu if use_pallas else R.linear_bias_gelu
    (ln1_s, ln1_b, wqkv, bqkv, wo, bo, ln2_s, ln2_b, w1, b1, w2, b2) = p12
    x2d = x.reshape(b * s, d)
    h = ln(x2d, ln1_s, ln1_b)
    x2d = x2d + _attention(cfg, h, wqkv, bqkv, wo, bo, b, s, use_pallas)
    h = ln(x2d, ln2_s, ln2_b)
    h = flg(h, w1, b1)
    x2d = x2d + mm(h, w2) + b2
    return x2d.reshape(b, s, d)


def stage_fwd(cfg: Config, kind: str, n_blocks: int, use_pallas, params, x, targets=None):
    """Run one stage. `x` is tokens (first) or activations; `targets` only
    for the last stage. Returns activations, or the scalar mean loss."""
    params = list(params)
    if kind == "first":
        tok_emb, pos_emb = params[0], params[1]
        params = params[2:]
        x = tok_emb[x] + pos_emb[None, :, :]
    for bi in range(n_blocks):
        x = block_fwd(cfg, params[bi * 12 : (bi + 1) * 12], x, use_pallas)
    if kind == "last":
        lnf_s, lnf_b, w_out = params[n_blocks * 12 :]
        b, s, d = x.shape
        ln = AD.layernorm if use_pallas else R.layernorm
        mm = AD.matmul if use_pallas else R.matmul
        sx = AD.softmax_xent if use_pallas else R.softmax_xent
        h = ln(x.reshape(b * s, d), lnf_s, lnf_b)
        logits = mm(h, w_out)  # [B*S, V]
        losses = sx(logits, targets.reshape(b * s))
        return jnp.mean(losses)
    return x


# --------------------------------------------------------------- backward

def stage_bwd(cfg: Config, kind: str, n_blocks: int, use_pallas, params, acc, x, gy_or_targets):
    """Backward with gradient accumulation: recomputes the stage forward
    (`jax.vjp` at the stashed input), returns `(acc + grads, gx)`.

    * first : gy_or_targets is gy [B,S,D]; returns (acc', ) — tokens have
      no gradient.
    * mid   : gy_or_targets is gy; returns (acc', gx).
    * last  : gy_or_targets is targets i32; dLoss = 1; returns (acc', gx).
    """
    params = list(params)
    acc = list(acc)
    if kind == "last":
        f = lambda p, xx: stage_fwd(cfg, kind, n_blocks, use_pallas, p, xx, gy_or_targets)
        _, vjp = jax.vjp(f, params, x)
        gp, gx = vjp(jnp.float32(1.0))
        return [a + g for a, g in zip(acc, gp)] + [gx]
    f = lambda p, xx: stage_fwd(cfg, kind, n_blocks, use_pallas, p, xx)
    _, vjp = jax.vjp(f, params, x)
    gp, gx = vjp(gy_or_targets)
    out = [a + g for a, g in zip(acc, gp)]
    if kind != "first":
        out.append(gx)
    return out


# -------------------------------------------------------------- optimizer

def adam_update(params, grads, m, v, step, lr, grad_scale,
                beta1=0.9, beta2=0.999, eps=1e-8):
    """One Adam step over flat lists; `grad_scale` divides the accumulated
    gradient by the number of micro-batches. Returns (params', m', v')."""
    b1t = 1.0 - beta1**step
    b2t = 1.0 - beta2**step
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        g = g * grad_scale
        mi = beta1 * mi + (1.0 - beta1) * g
        vi = beta2 * vi + (1.0 - beta2) * g * g
        mh = mi / b1t
        vh = vi / b2t
        new_p.append(p - lr * mh / (jnp.sqrt(vh) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


# ------------------------------------------------------- whole-model refs

def full_forward_loss(cfg: Config, stage_kinds, stage_blocks, all_params, tokens, targets,
                      use_pallas=False):
    """Compose all stages — the oracle the pipeline engine must match."""
    x = tokens
    for i, (kind, nb, p) in enumerate(zip(stage_kinds, stage_blocks, all_params)):
        if kind == "last":
            return stage_fwd(cfg, kind, nb, use_pallas, p, x, targets)
        x = stage_fwd(cfg, kind, nb, use_pallas, p, x)
    raise AssertionError("no last stage")


def stage_layout(cfg: Config, n_stages: int):
    """(kinds, blocks) describing the pipeline decomposition."""
    blocks = split_blocks(cfg.n_layers, n_stages)
    if n_stages == 1:
        kinds = ["last"]  # single stage carries embed too — see stage_fwd
        raise ValueError("n_stages must be >= 2 (first/last are distinct)")
    kinds = ["first"] + ["mid"] * (n_stages - 2) + ["last"]
    return kinds, blocks
