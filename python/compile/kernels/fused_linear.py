"""Fused linear + bias + GELU Pallas kernel — the transformer MLP's first
half fused into one VMEM-resident pass (the fusion CUDA kernels do with
shared memory, re-expressed as a BlockSpec schedule; DESIGN.md
§Hardware-Adaptation)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick_block


def _kernel(x_ref, w_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ w_ref[...]

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _():
        h = o_ref[...] + b_ref[...]
        o_ref[...] = 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def linear_bias_gelu(x, w, b, bm: int = 128, bn: int = 128, bk: int = 128):
    """GELU(x @ w + b) in one fused kernel. x: [M, K], w: [K, N], b: [N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((bn,), lambda i, j, l: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)
