"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

Each function here is the mathematical definition the corresponding kernel
in this package must match to float32 tolerance; pytest (and hypothesis
sweeps) assert `assert_allclose(kernel(...), ref(...))`.
"""

import jax
import jax.numpy as jnp


def matmul(x, y):
    """Plain matrix product."""
    return x @ y


def gelu(h):
    """tanh-approx GELU."""
    return 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))


def linear_bias_gelu(x, w, b):
    """x @ w + b then GELU (the transformer MLP's first half)."""
    return gelu(x @ w + b)


def layernorm(x, scale, bias, eps=1e-5):
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def causal_attention(q, k, v):
    """Single-head causal attention for [S, Dh] blocks (vmapped upstream)."""
    s = q.shape[0]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, jnp.asarray(-1e30, q.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    return probs @ v


def softmax_xent(logits, targets):
    """Per-position cross-entropy: logits [R, V], targets [R] → [R]."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return logz - gold
