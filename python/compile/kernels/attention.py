"""Causal self-attention Pallas kernel.

Grid = (batch × heads); each step owns one head's full [S, Dh] Q/K/V tiles
in VMEM (S ≤ 128, Dh ≤ 128 here, so scores are a [S, S] on-chip tile —
the flash-attention outer loop is unnecessary at these shapes, which is
itself a VMEM-budget decision: 128·128·4B ≈ 64 KiB per tensor)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    s = q.shape[0]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    scores = jnp.where(row >= col, scores, jnp.asarray(-1e30, q.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    o_ref[0] = probs @ v


@jax.jit
def causal_attention(q, k, v):
    """q, k, v: [BH, S, Dh] (batch×heads flattened) → [BH, S, Dh]."""
    bh, s, dh = q.shape
    return pl.pallas_call(
        _kernel,
        grid=(bh,),
        in_specs=[pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0))] * 3,
        out_specs=pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=True,
    )(q, k, v)
