"""Tiled Pallas matmul — the L1 compute hot-spot of every stage's gemms.

TPU mapping (DESIGN.md §Hardware-Adaptation): blocks are sized for the MXU
(multiples of 128 on M/N when the operand allows) and for VMEM — the three
resident tiles `bm×bk + bk×bn + bm×bn` stay well under the ~16 MB budget.
The k-loop is the innermost grid dimension; the output block is revisited
across it and accumulated in place (dimension_semantics would mark i/j
"parallel" and k "arbitrary" on real hardware).

`interpret=True` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ y_ref[...]


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is ≤ target (prefer MXU-friendly sizes)."""
    for cand in (target, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= target and dim % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, bm: int = 128, bn: int = 128, bk: int = 128):
    """`x @ y` via the tiled Pallas kernel. Shapes must be divisible by the
    chosen blocks (blocks shrink automatically to divisors)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """VMEM footprint of one grid step (x, y and output tiles resident)."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(bm: int, bn: int, bk: int) -> float:
    """Fraction of 128×128 MXU lanes a (bm, bn, bk) tiling keeps busy —
    the §Perf structural estimate for real-TPU efficiency."""
    use_m = min(bm, 128) / 128.0
    use_n = min(bn, 128) / 128.0
    return use_m * use_n
