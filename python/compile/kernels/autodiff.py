"""Differentiable wrappers: forward = the Pallas kernel, backward =
hand-derived VJP whose large gemms route through the Pallas matmul again
(so the backward pass exercises the same L1 hot path). `interpret=True`
pallas_call has no AD rule, and on real hardware you want explicit
backward kernels anyway."""

import jax
import jax.numpy as jnp

from .attention import causal_attention as _attn
from .fused_linear import linear_bias_gelu as _flg
from .layernorm import layernorm as _ln
from .matmul import matmul as _mm
from .softmax_xent import softmax_xent as _sx

_C = 0.7978845608028654  # sqrt(2/pi)
_A = 0.044715


def _gelu_grad(h):
    u = _C * (h + _A * h**3)
    t = jnp.tanh(u)
    return 0.5 * (1.0 + t) + 0.5 * h * (1.0 - t * t) * _C * (1.0 + 3.0 * _A * h * h)


@jax.custom_vjp
def matmul(x, y):
    """Differentiable tiled-Pallas matmul."""
    return _mm(x, y)


def _mm_fwd(x, y):
    return _mm(x, y), (x, y)


def _mm_bwd(res, g):
    x, y = res
    return _mm(g, y.T), _mm(x.T, g)


matmul.defvjp(_mm_fwd, _mm_bwd)


@jax.custom_vjp
def linear_bias_gelu(x, w, b):
    """Differentiable fused GELU(x @ w + b)."""
    return _flg(x, w, b)


def _flg_fwd(x, w, b):
    return _flg(x, w, b), (x, w, b)


def _flg_bwd(res, g):
    x, w, b = res
    h = _mm(x, w) + b  # recompute pre-activation (rematerialization)
    dg = g * _gelu_grad(h)
    return _mm(dg, w.T), _mm(x.T, dg), dg.sum(axis=0)


linear_bias_gelu.defvjp(_flg_fwd, _flg_bwd)


@jax.custom_vjp
def layernorm(x, scale, bias):
    """Differentiable Pallas LayerNorm (last axis)."""
    return _ln(x, scale, bias)


def _ln_fwd(x, scale, bias):
    return _ln(x, scale, bias), (x, scale)


def _ln_bwd(res, g):
    x, scale = res
    eps = 1e-5
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    inv = 1.0 / jnp.sqrt(var + eps)
    xhat = (x - mu) * inv
    gx_hat = g * scale
    gx = inv * (
        gx_hat
        - jnp.mean(gx_hat, axis=-1, keepdims=True)
        - xhat * jnp.mean(gx_hat * xhat, axis=-1, keepdims=True)
    )
    return gx, (g * xhat).sum(axis=0), g.sum(axis=0)


layernorm.defvjp(_ln_fwd, _ln_bwd)


@jax.custom_vjp
def causal_attention(q, k, v):
    """Differentiable Pallas causal attention ([BH, S, Dh])."""
    return _attn(q, k, v)


def _attn_fwd(q, k, v):
    return _attn(q, k, v), (q, k, v)


def _attn_bwd(res, g):
    q, k, v = res
    s = q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bsd,btd->bst", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, jnp.asarray(-1e30, q.dtype))
    p = jax.nn.softmax(scores, axis=-1)
    gv = jnp.einsum("bst,bsd->btd", p, g)
    gp = jnp.einsum("bsd,btd->bst", g, v)
    # softmax backward
    gs = p * (gp - jnp.sum(gp * p, axis=-1, keepdims=True))
    gs = jnp.where(mask, gs, 0.0) * scale
    gq = jnp.einsum("bst,btd->bsd", gs, k)
    gk = jnp.einsum("bst,bsd->btd", gs, q)
    return gq, gk, gv


causal_attention.defvjp(_attn_fwd, _attn_bwd)


@jax.custom_vjp
def softmax_xent(logits, targets):
    """Differentiable fused cross-entropy ([R, V], [R] → [R])."""
    return _sx(logits, targets)


def _sx_fwd(logits, targets):
    return _sx(logits, targets), (logits, targets)


def _sx_bwd(res, g):
    logits, targets = res
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    return (g[:, None] * (p - onehot), None)


softmax_xent.defvjp(_sx_fwd, _sx_bwd)
