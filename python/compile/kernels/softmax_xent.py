"""Fused softmax cross-entropy Pallas kernel: per row-block, the whole
vocab row stays in VMEM and log-sum-exp + gold-logit gather happen in one
pass (V ≤ 8192 floats/row ≈ 32 KiB — fine)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick_block


def _kernel(lg_ref, t_ref, o_ref):
    lg = lg_ref[...]
    t = t_ref[...]
    m = jnp.max(lg, axis=-1, keepdims=True)
    logz = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[:, 0]
    gold = jnp.take_along_axis(lg, t[:, None], axis=-1)[:, 0]
    o_ref[...] = logz - gold


@functools.partial(jax.jit, static_argnames=("br",))
def softmax_xent(logits, targets, br: int = 64):
    """Per-position cross-entropy: logits [R, V] f32, targets [R] i32 → [R]."""
    r, v = logits.shape
    br = _pick_block(r, br)
    return pl.pallas_call(
        _kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, v), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), logits.dtype),
        interpret=True,
    )(logits, targets)
