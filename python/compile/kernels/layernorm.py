"""LayerNorm Pallas kernel: rows are tiled across the grid, the feature
axis stays whole in VMEM (D ≤ a few thousand floats — far under budget),
so each row's mean/variance reduce entirely on-chip."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick_block


def _kernel(x_ref, s_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) / jnp.sqrt(var + eps) * s_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("br",))
def layernorm(x, scale, bias, br: int = 128, eps: float = 1e-5):
    """LayerNorm over the last axis of x: [R, D]; scale/bias: [D]."""
    r, d = x.shape
    br = _pick_block(r, br)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=True,
    )(x, scale, bias)
