"""L1 Pallas kernels (interpret=True for CPU-PJRT execution) + jnp oracle."""

from . import ref  # noqa: F401
from .attention import causal_attention  # noqa: F401
from .fused_linear import linear_bias_gelu  # noqa: F401
from .layernorm import layernorm  # noqa: F401
from .matmul import matmul, mxu_utilization, vmem_bytes  # noqa: F401
from .softmax_xent import softmax_xent  # noqa: F401
