"""AOT lowering: per-stage JAX programs → HLO **text** + manifest.json.

Run once by `make artifacts`; python never executes on the training path.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the rust `xla` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Artifacts per stage k:
  stage<k>_init.hlo.txt : (seed i32[])                       → params…
  stage<k>_fwd.hlo.txt  : (params…, x[, targets])            → y | loss
  stage<k>_bwd.hlo.txt  : (params…, acc…, x, gy|targets)     → acc'…[, gx]
  stage<k>_opt.hlo.txt  : (params…, acc…, m…, v…, step, lr, gscale)
                                                             → params'…, m'…, v'…
plus manifest.json describing shapes, arg counts and file names.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple so multi-output
    programs unwrap uniformly on the rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stage(cfg, kind, n_blocks, micro, use_pallas):
    """Lower the four per-stage programs; returns {name: hlo_text} plus
    the parameter spec list."""
    specs = M.stage_param_specs(cfg, kind, n_blocks)
    p_structs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    b, s, d = micro, cfg.seq, cfg.d_model
    x_struct = (
        jax.ShapeDtypeStruct((b, s), jnp.int32)
        if kind == "first"
        else jax.ShapeDtypeStruct((b, s, d), jnp.float32)
    )
    gy_struct = jax.ShapeDtypeStruct((b, s, d), jnp.float32)
    tgt_struct = jax.ShapeDtypeStruct((b, s), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    seed = jax.ShapeDtypeStruct((), jnp.int32)

    out = {}

    def init_fn(sd):
        return tuple(M.init_stage(cfg, kind, n_blocks, sd))

    out["init"] = to_hlo_text(jax.jit(init_fn, keep_unused=True).lower(seed))

    if kind == "last":
        def fwd_fn(*args):
            p, x, t = list(args[:-2]), args[-2], args[-1]
            return (M.stage_fwd(cfg, kind, n_blocks, use_pallas, p, x, t),)

        out["fwd"] = to_hlo_text(jax.jit(fwd_fn, keep_unused=True).lower(*p_structs, x_struct, tgt_struct))

        def bwd_fn(*args):
            np_ = len(p_structs)
            p = list(args[:np_])
            acc = list(args[np_ : 2 * np_])
            x, t = args[-2], args[-1]
            return tuple(M.stage_bwd(cfg, kind, n_blocks, use_pallas, p, acc, x, t))

        out["bwd"] = to_hlo_text(
            jax.jit(bwd_fn, keep_unused=True).lower(*p_structs, *p_structs, x_struct, tgt_struct)
        )
    else:
        def fwd_fn(*args):
            p, x = list(args[:-1]), args[-1]
            return (M.stage_fwd(cfg, kind, n_blocks, use_pallas, p, x),)

        out["fwd"] = to_hlo_text(jax.jit(fwd_fn, keep_unused=True).lower(*p_structs, x_struct))

        def bwd_fn(*args):
            np_ = len(p_structs)
            p = list(args[:np_])
            acc = list(args[np_ : 2 * np_])
            x, gy = args[-2], args[-1]
            return tuple(M.stage_bwd(cfg, kind, n_blocks, use_pallas, p, acc, x, gy))

        out["bwd"] = to_hlo_text(
            jax.jit(bwd_fn, keep_unused=True).lower(*p_structs, *p_structs, x_struct, gy_struct)
        )

    def opt_fn(*args):
        np_ = len(p_structs)
        p = list(args[:np_])
        g = list(args[np_ : 2 * np_])
        m = list(args[2 * np_ : 3 * np_])
        v = list(args[3 * np_ : 4 * np_])
        step, lr, gscale = args[-3], args[-2], args[-1]
        new_p, new_m, new_v = M.adam_update(p, g, m, v, step, lr, gscale)
        return tuple(new_p + new_m + new_v)

    out["opt"] = to_hlo_text(
        jax.jit(opt_fn, keep_unused=True).lower(*(p_structs * 4), scalar, scalar, scalar)
    )
    return out, specs


def build(model_name: str, n_stages: int, micro: int, use_pallas: bool, out_dir: str):
    """Build all artifacts for one (model, n_stages, micro) configuration."""
    cfg = M.CONFIGS[model_name]
    kinds, blocks = M.stage_layout(cfg, n_stages)
    os.makedirs(out_dir, exist_ok=True)
    stages_meta = []
    for k, (kind, nb) in enumerate(zip(kinds, blocks)):
        print(f"  lowering stage {k} ({kind}, {nb} blocks)...", flush=True)
        hlos, specs = lower_stage(cfg, kind, nb, micro, use_pallas)
        files = {}
        for name, text in hlos.items():
            fname = f"stage{k}_{name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            files[name] = fname
        stages_meta.append(
            {
                "kind": kind,
                "blocks": nb,
                "files": files,
                "params": [
                    {"name": n, "shape": list(s)} for n, s in specs
                ],
                "in_shape": [micro, cfg.seq] if kind == "first" else [micro, cfg.seq, cfg.d_model],
                "in_dtype": "i32" if kind == "first" else "f32",
            }
        )
    manifest = {
        "model": model_name,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "vocab": cfg.vocab,
        "seq": cfg.seq,
        "micro_batch": micro,
        "n_stages": n_stages,
        "use_pallas": use_pallas,
        "stages": stages_meta,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {out_dir}/manifest.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="lm10m", choices=sorted(M.CONFIGS))
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--micro", type=int, default=4, help="micro-batch size (static)")
    ap.add_argument("--no-pallas", action="store_true",
                    help="use pure-jnp ops instead of the Pallas kernels")
    ap.add_argument("--out-dir", default=None,
                    help="default: ../artifacts/<model>-s<stages>-b<micro>[-jnp]")
    args = ap.parse_args()
    suffix = "-jnp" if args.no_pallas else ""
    out_dir = args.out_dir or os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts",
        f"{args.model}-s{args.stages}-b{args.micro}{suffix}",
    )
    print(f"AOT: {args.model} stages={args.stages} micro={args.micro} "
          f"pallas={not args.no_pallas} -> {out_dir}")
    build(args.model, args.stages, args.micro, not args.no_pallas, out_dir)


if __name__ == "__main__":
    main()
