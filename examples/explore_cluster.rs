//! Sweep BaPipe's auto-exploration across the paper's workloads and GPU
//! cluster sizes — a compact view of the Table-3 decision surface: which
//! schedule wins where, and when the explorer falls back to DP.
//!
//! Run: `cargo run --release --example explore_cluster`

use bapipe::cluster::presets;
use bapipe::explorer::{self, Choice, Options};
use bapipe::model::zoo;
use bapipe::profile::analytical;
use bapipe::util::benchkit::print_table;

fn main() {
    let mut rows = Vec::new();
    for model in ["vgg16", "resnet50", "gnmt8", "gnmt16", "alexnet"] {
        let net = zoo::by_name(model).unwrap();
        for n in [2usize, 4, 8] {
            let cl = presets::v100_cluster(n);
            let prof = analytical::profile(&net, &cl);
            let opts = Options {
                batch_per_device: 32.0,
                samples_per_epoch: 50_000,
                ..Default::default()
            };
            let plan = explorer::explore(&net, &cl, &prof, &opts);
            let choice = match &plan.choice {
                Choice::Pipeline { kind, m, partition, .. } => {
                    format!("{} M={m} {}", kind.label(), partition.describe())
                }
                Choice::DataParallel => "DP".to_string(),
            };
            rows.push(vec![
                model.to_string(),
                format!("{n}x V100"),
                format!("{:.2}x", plan.speedup_over_dp),
                choice,
            ]);
        }
    }
    print_table(
        "BaPipe exploration across workloads x cluster sizes",
        &["model", "cluster", "speedup vs DP", "chosen plan"],
        &rows,
    );
}
