//! Sweep BaPipe's auto-exploration across the paper's workloads and GPU
//! cluster sizes — a compact view of the Table-3 decision surface: which
//! schedule wins where, and when the explorer falls back to DP — then
//! emit the flagship scenario as a machine-readable `plan.json`.
//!
//! Run: `cargo run --release --example explore_cluster`

use bapipe::cluster::presets;
use bapipe::model::zoo;
use bapipe::planner::{self, Choice, Options};
use bapipe::profile::analytical;
use bapipe::util::benchkit::print_table;

fn main() {
    let mut rows = Vec::new();
    let opts = Options {
        batch_per_device: 32.0,
        samples_per_epoch: 50_000,
        jobs: 4,
        ..Default::default()
    };
    for model in ["vgg16", "resnet50", "gnmt8", "gnmt16", "alexnet"] {
        let net = zoo::by_name(model).unwrap();
        for n in [2usize, 4, 8] {
            let cl = presets::v100_cluster(n);
            let prof = analytical::profile(&net, &cl);
            let plan = planner::explore(&net, &cl, &prof, &opts);
            let choice = match &plan.choice {
                Choice::Pipeline { kind, m, partition, .. } => {
                    format!("{} M={m} {}", kind.label(), partition.describe())
                }
                Choice::DataParallel => "DP".to_string(),
            };
            rows.push(vec![
                model.to_string(),
                format!("{n}x V100"),
                format!("{:.2}x", plan.speedup_over_dp),
                format!(
                    "{choice} ({} DES, {} pruned)",
                    plan.report.simulated_count, plan.report.pruned_count
                ),
            ]);
        }
    }
    print_table(
        "BaPipe exploration across workloads x cluster sizes",
        &["model", "cluster", "speedup vs DP", "chosen plan"],
        &rows,
    );

    // The plan artifact: serialize the flagship scenario. `emit_json`
    // verifies the document round-trips before returning the text (the
    // same helper `bapipe explore --emit plan.json` uses).
    let net = zoo::vgg16(224);
    let cl = presets::v100_cluster(4);
    let prof = analytical::profile(&net, &cl);
    let plan = planner::explore(&net, &cl, &prof, &opts);
    let text = plan.emit_json().expect("plan.json must round-trip");
    std::fs::write("plan.json", &text).expect("write plan.json");
    println!("\nwrote plan.json ({} bytes, round-trip verified)", text.len());
}
