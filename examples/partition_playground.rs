//! Watch the Fig.-3 balanced-partition flow stage by stage on any zoo
//! model: Eq.-1 seed → iterative refinement → DP-optimal → (coarse pass if
//! communication-bound) → memory fine-tune.
//!
//! Run: `cargo run --release --example partition_playground -- \
//!         --model gnmt8 --cluster v100 --n 4 --micro 8`

use bapipe::cluster::presets;
use bapipe::model::zoo;
use bapipe::partition::{balanced_partition, coarse, interlayer, stage_costs};
use bapipe::profile::analytical;
use bapipe::schedule::ScheduleKind;
use bapipe::util::cli::Args;

fn main() -> bapipe::Result<()> {
    let args = Args::from_env();
    let model = args.get_str("model", "gnmt8");
    let n = args.get_usize("n", 4);
    let micro = args.get_f64("micro", 8.0);
    let net = zoo::by_name(&model).ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let cl = match args.get_str("cluster", "v100").as_str() {
        "v100" => presets::v100_cluster(n),
        "vcu118" => presets::fpga_cluster(&vec!["VCU118"; n]),
        other => anyhow::bail!("unknown cluster {other}"),
    };
    let prof = analytical::profile(&net, &cl);
    let cuts = net.legal_cuts();

    println!("{} on {}, micro-batch {micro}", net.describe(), cl.describe());
    println!("\nEq. 1 ideal stage time T = {:.3} ms", interlayer::eq1_ideal_time(&prof) * micro * 1e3);

    let seed = interlayer::seed_partition(&prof, &cl, &cuts, micro)?;
    println!(
        "\n1. seed:        {}  (max stage {:.3} ms)",
        seed.describe(),
        interlayer::max_stage_time(&prof, &seed, micro, None) * 1e3
    );
    let refined = interlayer::refine(&prof, seed, &cuts, micro);
    println!(
        "2. refined:     {}  (max stage {:.3} ms)",
        refined.describe(),
        interlayer::max_stage_time(&prof, &refined, micro, None) * 1e3
    );
    let dp = interlayer::dp_optimal(&prof, &cl, &cuts, micro, None)?;
    println!(
        "3. DP-optimal:  {}  (max stage {:.3} ms)",
        dp.describe(),
        interlayer::max_stage_time(&prof, &dp, micro, None) * 1e3
    );

    // Coarse view: how many cut points survive each threshold decade.
    println!("\ncut points by activation-size threshold:");
    for a_th in [64e3, 256e3, 1e6, 4e6, f64::INFINITY] {
        let kept = coarse::allowed_cuts(&prof, &cuts, a_th);
        println!("  a_th ≤ {:>9}: {} of {} cuts",
            if a_th.is_finite() { format!("{:.0} KB", a_th / 1e3) } else { "inf".into() },
            kept.len(),
            cuts.len()
        );
    }

    // Full flow.
    let plan = balanced_partition(&net, &cl, &prof, ScheduleKind::OneFOneBSno, micro, 16)?;
    println!("\nfull Fig.-3 flow:");
    for note in &plan.notes {
        println!("  {note}");
    }
    let costs = stage_costs(&prof, &cl, &plan.partition, micro);
    println!("\nfinal stage times:");
    for (i, (f, b)) in costs.iter().enumerate() {
        println!("  stage {i}: F {:.3} ms + B {:.3} ms = {:.3} ms", f * 1e3, b * 1e3, (f + b) * 1e3);
    }
    Ok(())
}
