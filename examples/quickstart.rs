//! Quickstart: BaPipe's planner in five calls — describe a workload,
//! describe the cluster, profile, explore, read the plan.
//!
//! Run: `cargo run --release --example quickstart`

use bapipe::cluster::presets;
use bapipe::model::zoo;
use bapipe::planner::{self, Choice, Options};
use bapipe::profile::analytical;
use bapipe::sim::{engine, timeline};

fn main() {
    // 1. The workload: VGG-16 at 224x224 (the paper's Table 3 headliner).
    let net = zoo::vgg16(224);
    println!("workload: {}", net.describe());

    // 2. The cluster: 4x NVIDIA V100 (16 GB) on PCIe gen3, GLOO transport.
    let cluster = presets::v100_cluster(4);
    println!("cluster:  {}", cluster.describe());

    // 3. Profile (analytical here; `measured` profiles real executables).
    let profile = analytical::profile(&net, &cluster);

    // 4. Explore schedules x partitions x micro-batching (Fig. 3) —
    //    branch-and-bound pruned, over 4 worker threads.
    let opts = Options {
        batch_per_device: 32.0,
        samples_per_epoch: 50_000,
        jobs: 4,
        ..Default::default()
    };
    let plan = planner::explore(&net, &cluster, &profile, &opts);

    // 5. Read the plan. The typed report also serializes: `plan.to_json()`
    //    is exactly what `bapipe explore --emit plan.json` writes.
    println!("\n{}", plan.summary());
    println!("\nexploration log:");
    for line in plan.report.log_lines() {
        println!("  {line}");
    }

    // Bonus: visualize the chosen schedule.
    if let Choice::Pipeline { kind, m, micro, partition } = &plan.choice {
        let spec = planner::build_spec(&profile, &cluster, partition, *kind, *micro, *m);
        let r = engine::simulate(&spec);
        println!("\n{} timeline (one mini-batch):", kind.label());
        print!("{}", timeline::render(&r, partition.n_stages(), 110));
    }
}
