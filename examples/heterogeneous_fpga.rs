//! Heterogeneous FPGA clusters (the Table-6 setting): BaPipe balances
//! ResNet-50 across mixed VCU129/VCU118 boards — inter-layer partition
//! proportional to DSP counts, intra-layer fractional refinement, FBP-AS
//! scheduling, and the on-chip-weight residency check.
//!
//! Run: `cargo run --release --example heterogeneous_fpga`

use bapipe::cluster::presets;
use bapipe::explorer::build_spec;
use bapipe::model::zoo;
use bapipe::partition::{balanced_partition, stage_costs};
use bapipe::profile::analytical;
use bapipe::schedule::ScheduleKind;
use bapipe::sim::engine::simulate;
use bapipe::util::benchkit::print_table;
use bapipe::util::fmt_bytes;

fn main() -> bapipe::Result<()> {
    let net = zoo::resnet50(224);
    println!("workload: {}", net.describe());
    for boards in [
        vec!["VCU118"; 4],
        vec!["VCU129", "VCU129", "VCU118", "VCU118"],
        vec!["VCU129"; 4],
    ] {
        let cl = presets::fpga_cluster(&boards);
        let prof = analytical::profile(&net, &cl);
        let m = 128;
        let plan = balanced_partition(&net, &cl, &prof, ScheduleKind::FbpAs, 1.0, m)?;
        println!("\n=== {} ===", cl.describe());
        for note in &plan.notes {
            println!("  flow: {note}");
        }
        let costs = stage_costs(&prof, &cl, &plan.partition, 1.0);
        let mut rows = Vec::new();
        for (i, (f, b)) in costs.iter().enumerate() {
            let r = plan.partition.stage(i);
            let w = prof.param_bytes(r.start, r.end);
            let onchip = cl.devices[i].onchip_capacity;
            rows.push(vec![
                format!("stage {i} ({})", cl.devices[i].name),
                format!("{}..{}", r.start, r.end),
                format!("{:.3} ms", (f + b) * 1e3),
                fmt_bytes(w),
                if (w as f64) < 0.75 * onchip as f64 { "on-chip" } else { "DDR spill" }.into(),
            ]);
        }
        print_table(
            "balanced stages (micro-batch 1, FBP-AS)",
            &["stage", "layers", "F+B", "stage weights", "residency"],
            &rows,
        );
        if let Some(fp) = &plan.frac {
            println!(
                "  intra-layer refinement: imbalance {:.2}% -> {:.2}%",
                fp.imbalance_before * 100.0,
                fp.imbalance_after * 100.0
            );
        }
        let spec = build_spec(&prof, &cl, &plan.partition, ScheduleKind::FbpAs, 1.0, m);
        let r = simulate(&spec);
        println!(
            "  mini-batch (M={m}): {:.2} ms, bubble {:.1}%",
            r.makespan * 1e3,
            r.bubble_fraction * 100.0
        );
    }
    Ok(())
}
