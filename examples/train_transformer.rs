//! E11 — the end-to-end driver: train a transformer LM through the REAL
//! pipeline engine (compiled XLA stage programs, worker threads, channel
//! interconnect) on a synthetic Markov corpus, logging the loss curve
//! against the corpus' entropy floor and comparing schedules.
//!
//! Default workload: the lm10m bundle (≈10M params, 4 stages) for a few
//! hundred steps — sized for this single-core CPU host. Build
//! `make artifacts-lm100m` and pass `--artifacts artifacts/lm100m-s4-b2`
//! for the paper-scale (~100M-param) run.
//!
//! Run: `cargo run --release --example train_transformer -- \
//!         --artifacts artifacts/lm10m-s4-b4 --steps 300 --m 8`

use bapipe::config::TrainConfig;
use bapipe::pipeline::training;
use bapipe::runtime::{Manifest, Runtime};
use bapipe::util::cli::Args;

fn main() -> bapipe::Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_str("artifacts", "artifacts/lm10m-s4-b4");
    let schedule = args.get_str("schedule", "1f1b");
    let steps = args.get_usize("steps", 300);
    let m = args.get_usize("m", 8);

    let man = Manifest::load(&artifacts)?;
    println!(
        "model {}: {} params, {} stages, micro-batch {}, seq {}, pallas kernels: {}",
        man.model,
        bapipe::util::fmt_params(man.total_params() as u64),
        man.n_stages,
        man.micro_batch,
        man.seq,
        man.use_pallas
    );
    man.crosscheck_zoo()?;

    // Planner first: measured profile of the real stage executables.
    {
        let rt = Runtime::load(&artifacts)?;
        let times = training::measure_stage_times(&rt, 3)?;
        println!("\nmeasured per-stage times (micro-batch {}):", man.micro_batch);
        for (i, (f, b)) in times.iter().enumerate() {
            println!("  stage {i}: fwd {:6.2} ms, bwd {:6.2} ms", f * 1e3, b * 1e3);
        }
        let imbalance = {
            let tot: Vec<f64> = times.iter().map(|(f, b)| f + b).collect();
            let max = tot.iter().cloned().fold(0.0, f64::max);
            let min = tot.iter().cloned().fold(f64::INFINITY, f64::min);
            max / min
        };
        println!("  stage imbalance (max/min): {imbalance:.2}x");
    }

    let cfg = TrainConfig {
        artifacts: artifacts.clone(),
        schedule: schedule.clone(),
        m,
        steps,
        lr: args.get_f64("lr", 1e-3) as f32,
        seed: args.get_u64("seed", 0),
        branch: args.get_usize("branch", 8),
        noise: args.get_f64("noise", 0.1),
        log_every: args.get_usize("log-every", 10),
    };
    println!("\ntraining: schedule={} M={} steps={} lr={}", cfg.schedule, cfg.m, steps, cfg.lr);
    let t0 = std::time::Instant::now();
    let rep = training::train(&cfg)?;
    println!("\nloss curve:");
    print!("{}", rep.render_curve());
    println!(
        "\nfirst loss {:.4} (ln V = {:.4}), final loss {:.4}, floor {:.4}",
        rep.first_loss,
        (man.vocab as f64).ln(),
        rep.final_loss,
        rep.entropy_floor
    );
    println!(
        "throughput {:.1} tokens/s over {:.1}s wall-clock",
        rep.tokens_per_sec,
        t0.elapsed().as_secs_f64()
    );
    println!("\nper-stage mean seconds/step (fwd | bwd | opt | stall):");
    for (i, (f, b, o, s)) in rep.per_stage_means.iter().enumerate() {
        println!(
            "  stage {i}: {:7.1} ms | {:7.1} ms | {:6.1} ms | {:7.1} ms",
            f * 1e3,
            b * 1e3,
            o * 1e3,
            s * 1e3
        );
    }
    Ok(())
}
