//! Minimal, offline-friendly stand-in for the `anyhow` crate.
//!
//! The bapipe repository builds against an offline crate set, so this
//! vendored implementation provides the (small) `anyhow` surface the
//! codebase actually uses:
//!
//! * [`Error`] — an opaque, `Send + Sync` error value built from any
//!   message or any `std::error::Error`;
//! * [`Result`] — `std::result::Result` defaulted to [`Error`];
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the three construction macros.
//!
//! Mirroring the real crate, [`Error`] deliberately does **not**
//! implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion (and therefore `?`) possible.

use std::fmt;

/// An opaque error: a rendered message, optionally with the `Display`
/// chain of the source error it was converted from.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything printable (the real crate's
    /// `Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Append context, rendered as `context: original` like the real
    /// crate's single-line `{:#}` format.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `std::result::Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(::std::format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] when the condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: `",
                ::std::stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(!flag, "flag was {}", flag);
        Ok(7)
    }

    fn bails() -> Result<()> {
        bail!("bailed with {}", 42);
    }

    fn io_err() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn macros_and_conversions() {
        assert_eq!(fails(false).unwrap(), 7);
        let e = fails(true).unwrap_err();
        assert_eq!(e.to_string(), "flag was true");
        assert_eq!(bails().unwrap_err().to_string(), "bailed with 42");
        assert!(io_err().is_err());
        let e = anyhow!("plain {}", "fmt");
        assert_eq!(format!("{e:?}"), "plain fmt");
        assert_eq!(e.context("while testing").to_string(), "while testing: plain fmt");
    }
}
