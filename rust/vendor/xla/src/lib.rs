//! Placeholder for the real `xla` (xla-rs) PJRT bindings.
//!
//! The `bapipe` crate gates every XLA/PJRT-dependent module (`runtime`,
//! `pipeline`) behind the off-by-default `pjrt` cargo feature so the
//! planner/simulator stack builds and tests on machines without a PJRT
//! toolchain. Enabling `pjrt` pulls in this package; since the container
//! image does not ship the real bindings, that is a hard error with a
//! pointer at the fix rather than hundreds of confusing resolve errors.
//!
//! To actually enable the real engine, replace this directory with a
//! checkout of xla-rs (github.com/LaurentMazare/xla-rs) — the `bapipe`
//! sources compile against its public API unchanged — and build with
//! `cargo build --release --features pjrt`.

compile_error!(
    "the `pjrt` feature requires the real xla-rs bindings and a PJRT toolchain; \
     replace rust/vendor/xla with an xla-rs checkout, or build without `--features pjrt`"
);
