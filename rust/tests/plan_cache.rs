//! Cross-scenario plan cache (`bapipe explore --plan-cache`), end to end:
//!
//! * a cache persisted after one exploration and restored for an
//!   identical `(model, cluster)` scenario answers **every** phase-A
//!   partition request from memory (zero misses — phase A is skipped),
//!   and the exploration selects a bit-identical plan;
//! * the `(model, cluster)` fingerprint gates reuse: a different model
//!   (or cluster, or device-order space) rejects the cache instead of
//!   silently poisoning the search.

use bapipe::cluster::presets;
use bapipe::model::zoo;
use bapipe::planner::{self, store, EvalCache, Options, SearchSpace};
use bapipe::profile::analytical;

#[test]
fn plan_cache_skips_phase_a_on_reuse() {
    let net = zoo::vgg16(224);
    let cl = presets::v100_cluster(4);
    let prof = analytical::profile(&net, &cl);
    let opts =
        Options { batch_per_device: 32.0, samples_per_epoch: 8192, ..Default::default() };
    let fp = store::fingerprint(&net, &cl, &prof);
    let space = SearchSpace::bapipe(&net, &cl, &prof, &opts);

    let path = std::env::temp_dir().join("bapipe-plan-cache-test.json");
    let path = path.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&path);

    // First run: cold cache — phase A computes the seeds and fine-tunes.
    let mut cold = EvalCache::new();
    let first = planner::explore_with_cache(&net, &cl, &prof, &opts, &mut cold);
    assert!(cold.misses > 0, "cold run must run partition passes");
    store::save(&path, &cold, &fp, &space.device_orders).unwrap();

    // Second run: the restored cache answers every phase-A request.
    let mut warm = match store::load(&path, &fp, &space.device_orders) {
        store::CacheLoad::Loaded(cache) => cache,
        store::CacheLoad::Fresh(why) => panic!("expected the cache to load: {why}"),
    };
    let second = planner::explore_with_cache(&net, &cl, &prof, &opts, &mut warm);
    assert_eq!(warm.misses, 0, "phase A must be skipped entirely on reuse");
    assert!(warm.hits > 0);
    assert_eq!(first.choice, second.choice);
    assert_eq!(first.epoch_time, second.epoch_time);
    assert_eq!(first.minibatch_time, second.minibatch_time);
    assert_eq!(first.stage_memory, second.stage_memory);
    assert_eq!(
        first.report.evaluations, second.report.evaluations,
        "per-candidate outcomes must be bit-identical across cache reuse"
    );

    // A different scenario computes a different fingerprint and rejects
    // the persisted cache.
    let net2 = zoo::resnet50(224);
    let prof2 = analytical::profile(&net2, &cl);
    let fp2 = store::fingerprint(&net2, &cl, &prof2);
    assert_ne!(fp, fp2, "distinct scenarios must fingerprint differently");
    match store::load(&path, &fp2, &space.device_orders) {
        store::CacheLoad::Fresh(reason) => {
            assert!(reason.contains("stale"), "unexpected reason: {reason}")
        }
        store::CacheLoad::Loaded(_) => panic!("a stale cache must not load"),
    }

    let _ = std::fs::remove_file(&path);
}

#[test]
fn plan_cache_round_trips_heterogeneous_permuted_scenario() {
    // Permutation search stores per-`perm` entries; the persisted
    // device-order list pins their meaning. A run with a different
    // --permute setting (different order space) must reject the cache.
    let net = zoo::vgg16(224);
    let cl = presets::fpga_cluster(&["VCU129", "VCU118"]);
    let prof = analytical::profile(&net, &cl);
    let opts = Options {
        batch_per_device: 4.0,
        samples_per_epoch: 8192,
        consider_dp: false,
        permute_devices: true,
        ..Default::default()
    };
    let fp = store::fingerprint(&net, &cl, &prof);
    let space = SearchSpace::bapipe(&net, &cl, &prof, &opts);
    assert!(space.device_orders.len() > 1, "heterogeneous pair has 2 orderings");

    let path = std::env::temp_dir().join("bapipe-plan-cache-perm-test.json");
    let path = path.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&path);

    let mut cold = EvalCache::new();
    let first = planner::explore_with_cache(&net, &cl, &prof, &opts, &mut cold);
    store::save(&path, &cold, &fp, &space.device_orders).unwrap();

    let mut warm = match store::load(&path, &fp, &space.device_orders) {
        store::CacheLoad::Loaded(cache) => cache,
        store::CacheLoad::Fresh(why) => panic!("expected the cache to load: {why}"),
    };
    let second = planner::explore_with_cache(&net, &cl, &prof, &opts, &mut warm);
    assert_eq!(warm.misses, 0);
    assert_eq!(first.choice, second.choice);
    assert_eq!(first.device_order, second.device_order);
    assert_eq!(first.epoch_time, second.epoch_time);

    // identity-only run (no --permute): different order space → fresh
    let identity_space =
        SearchSpace::bapipe(&net, &cl, &prof, &Options { permute_devices: false, ..opts });
    match store::load(&path, &fp, &identity_space.device_orders) {
        store::CacheLoad::Fresh(reason) => {
            assert!(reason.contains("stale"), "unexpected reason: {reason}")
        }
        store::CacheLoad::Loaded(_) => panic!("mismatched order space must not load"),
    }

    let _ = std::fs::remove_file(&path);
}
