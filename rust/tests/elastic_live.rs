//! The elastic loop, closed live and end to end: a synthetic timing
//! sample stream — no scripted scenario anywhere — drives
//! `cluster::detect` → `planner::elastic::run_scenario` →
//! `planner::migrate`, the detector emits exactly the expected events,
//! the chosen migration schedule never stalls longer than its
//! drain-and-copy fallback, the mid-epoch amortization keeps the
//! degraded incumbent for a late-epoch event while switching for the
//! same event early in the epoch, and the whole loop is bit-identical
//! across planner worker counts.

use bapipe::cluster::detect::{detect, DetectorConfig, SampleStream};
use bapipe::cluster::mutate::ClusterEvent;
use bapipe::cluster::presets;
use bapipe::model::zoo;
use bapipe::planner::elastic::{epoch_micro_batches, run_scenario, ReplanRun};
use bapipe::planner::{self, Choice, Options, Plan};
use bapipe::profile::analytical;
use bapipe::util::json::Json;

const VICTIM: usize = 1;
const STEP_AT: usize = 10;
const TICKS: usize = 24;
/// Default config (window 5, dwell 3): the EWMA crosses `enter` at
/// `STEP_AT + 2` and the dwell completes at `STEP_AT + 4`.
const EMIT_TICK: usize = STEP_AT + 4;

fn opts(jobs: usize, samples_per_epoch: usize) -> Options {
    Options {
        batch_per_device: 8.0,
        samples_per_epoch,
        m_candidates: vec![4, 8],
        consider_dp: false,
        jobs,
        ..Options::default()
    }
}

/// A clean 4-device / 3-link sample stream in the CLI's JSON shape:
/// constant per-channel baselines, with device `VICTIM` stepping to 2x
/// its baseline from tick `STEP_AT` on — one persistent straggler, zero
/// jitter, nothing else.
fn stream_json(mb_per_tick: Option<u64>) -> String {
    let mut ticks = Vec::with_capacity(TICKS);
    for t in 0..TICKS {
        let dev: Vec<String> = (0..4)
            .map(|d| {
                let base = 1e-3 * (d + 1) as f64;
                let v = if d == VICTIM && t >= STEP_AT { 2.0 * base } else { base };
                format!("{v:e}")
            })
            .collect();
        ticks.push(format!(
            r#"{{"device_times":[{}],"link_times":[2e-4,2e-4,2e-4]}}"#,
            dev.join(",")
        ));
    }
    let mb = match mb_per_tick {
        Some(k) => format!(r#","mb_per_tick":{k}"#),
        None => String::new(),
    };
    format!(r#"{{"name":"live-straggler"{mb},"ticks":[{}]}}"#, ticks.join(","))
}

fn parse_stream(mb_per_tick: Option<u64>) -> SampleStream {
    let doc = Json::parse(&stream_json(mb_per_tick)).unwrap();
    SampleStream::from_json(&doc).unwrap()
}

/// Detect on a positioned stream and replay the synthesized scenario
/// against `incumbent`. The detector itself is exercised on every call —
/// each run goes JSON → detect → scenario → replan, never a script.
fn run_live(
    incumbent: &Plan,
    mb_per_tick: u64,
    o: &Options,
) -> (bapipe::cluster::mutate::Scenario, ReplanRun) {
    let net = zoo::vgg16(224);
    let cl = presets::gpu_mixed_cluster(4);
    let prof = analytical::profile(&net, &cl);
    let stream = parse_stream(Some(mb_per_tick));
    let det = detect(&stream, &DetectorConfig::default()).unwrap();
    let scenario = det.to_scenario(&stream);
    let run = run_scenario(&net, &cl, &prof, incumbent, &scenario, o).unwrap();
    (scenario, run)
}

/// Micro-batches per tick that puts the emission at `frac` of the epoch
/// (capped strictly inside it).
fn mb_for_fraction(total_mb: u64, frac: f64) -> u64 {
    let mb = ((frac * total_mb as f64) / EMIT_TICK as f64).round().max(1.0) as u64;
    // stay strictly before the boundary: past it the keep is trivial
    mb.min(((0.92 * total_mb as f64) / EMIT_TICK as f64).max(1.0) as u64).max(1)
}

#[test]
fn live_stream_detects_replans_and_amortizes_mid_epoch() {
    let net = zoo::vgg16(224);
    let cl = presets::gpu_mixed_cluster(4);
    let prof = analytical::profile(&net, &cl);

    // --- the detector half: exactly one event, on the right device,
    // with the exact step factor, at the predicted tick ---
    let stream = parse_stream(None);
    let det = detect(&stream, &DetectorConfig::default()).unwrap();
    assert_eq!(det.events.len(), 1, "{:?}", det.events);
    assert_eq!(det.events[0].tick, EMIT_TICK);
    match &det.events[0].event {
        ClusterEvent::Straggler { device, slowdown } => {
            assert_eq!(*device, VICTIM);
            assert!((slowdown - 2.0).abs() < 1e-9, "median ratio is the step size: {slowdown}");
        }
        other => panic!("expected a straggler, got {other:?}"),
    }

    // --- probe run: measure the migration stall and the epoch gap
    // between the degraded incumbent and the challenger at a known
    // early position ---
    let s_probe = 8192usize;
    let o = opts(1, s_probe);
    let incumbent = planner::explore(&net, &cl, &prof, &o);
    assert!(matches!(incumbent.choice, Choice::Pipeline { .. }));
    let total_probe = epoch_micro_batches(&incumbent, cl.len(), &o).unwrap();
    let (scenario, probe) = run_live(&incumbent, mb_for_fraction(total_probe, 0.10), &o);
    assert_eq!(scenario.events.len(), 1, "the live scenario is the detection, nothing else");
    assert!(scenario.events[0].at_mb.is_some(), "mb_per_tick positions the event");
    assert_eq!(probe.steps.len(), 1);
    let step = &probe.steps[0];
    assert!(step.event.contains("straggler"), "{}", step.event);
    assert!(step.event.contains("at micro-batch"), "{}", step.event);

    // the challenger's transfers were scheduled against the drain, and
    // overlapping into bubbles never loses to stop-the-world copying
    let sched = step.schedule.as_ref().expect("pipeline-to-pipeline step has a schedule");
    assert!(
        sched.stall <= sched.drain_stall + 1e-9,
        "overlap {} vs drain-and-copy {}",
        sched.stall,
        sched.drain_stall
    );
    assert!(sched.stall > 0.0, "a 2x straggler must move layers (stall 0 cannot amortize)");
    let dec = step.decision.as_ref().expect("positioned event with a draining incumbent");
    let r = dec.position.remaining_fraction();
    assert!(r > 0.0);
    let inc_epoch = dec.remaining_incumbent / r;
    let chal_epoch = (dec.remaining_challenger - dec.stall) / r;
    let gap = inc_epoch - chal_epoch;
    assert!(
        gap > 0.0,
        "the challenger must beat the degraded incumbent over a full epoch (gap {gap})"
    );

    // --- pick an epoch length that lands the stall inside the
    // amortization window: stall ≈ 0.4 x gap, so an event at 10% of the
    // epoch switches (0.4 < 0.9) and the same event at 85% keeps
    // (0.4 > 0.15). The gap scales ~linearly with samples_per_epoch;
    // the 6x-wide window absorbs the nonlinearity, and the power-of-two
    // neighbours catch a probe that lands off-centre. ---
    let s_star = ((s_probe as f64) * dec.stall / (0.4 * gap)).round().max(64.0) as usize;
    let mut found = None;
    for s in [s_star, s_star / 2, s_star * 2, s_star / 4, s_star * 4] {
        if s < 64 {
            continue;
        }
        let o = opts(1, s);
        let inc = planner::explore(&net, &cl, &prof, &o);
        let total = match epoch_micro_batches(&inc, cl.len(), &o) {
            Some(t) if t > 2 * EMIT_TICK as u64 => t,
            _ => continue,
        };
        let (_, early) = run_live(&inc, mb_for_fraction(total, 0.10), &o);
        let (_, late) = run_live(&inc, mb_for_fraction(total, 0.85), &o);
        let ed = early.steps[0].decision.as_ref().unwrap().clone();
        let ld = late.steps[0].decision.as_ref().unwrap().clone();
        if ed.switched && !ld.switched {
            found = Some((s, o, inc, early, late, ed, ld));
            break;
        }
    }
    let (s, o, inc, early, late, ed, ld) =
        found.expect("no epoch length separates early-switch from late-keep");

    // early in the epoch the stall amortizes: the challenger is adopted
    assert!(ed.switched, "{}", ed.describe());
    assert!(ed.remaining_challenger < ed.remaining_incumbent);
    let em = early.steps[0].migration.as_ref().unwrap();
    assert!(em.bytes > 0, "switching moves the reassigned layers' state");

    // late in the epoch it cannot pay before the boundary: the degraded
    // incumbent is kept, nothing moves, and the plan honestly reports
    // the *degraded* epoch time
    assert!(!ld.switched, "{}", ld.describe());
    assert!(ld.position.at_mb > ed.position.at_mb);
    let lstep = &late.steps[0];
    assert_eq!(lstep.plan.choice, inc.choice, "kept incumbent, same choice");
    assert_eq!(lstep.migration.as_ref().unwrap().bytes, 0, "a kept incumbent moves nothing");
    assert!(
        lstep.plan.epoch_time > inc.epoch_time,
        "the kept plan carries the straggler-degraded timing"
    );
    assert!(
        lstep.provenance.iter().any(|l| l.contains("keeping the degraded incumbent")),
        "{:?}",
        lstep.provenance
    );

    // --- the whole live loop is bit-identical across worker counts ---
    let total = epoch_micro_batches(&inc, cl.len(), &o).unwrap();
    for frac in [0.10, 0.85] {
        let mb = mb_for_fraction(total, frac);
        let (_, a) = run_live(&inc, mb, &opts(1, s));
        let (_, b) = run_live(&inc, mb, &opts(8, s));
        assert_eq!(a.steps.len(), b.steps.len());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert_eq!(sa.plan.choice, sb.plan.choice, "event {}", sa.event);
            assert_eq!(sa.plan.epoch_time, sb.plan.epoch_time, "event {}", sa.event);
            assert_eq!(sa.plan.device_order, sb.plan.device_order, "event {}", sa.event);
            assert_eq!(sa.plan.report.evaluations, sb.plan.report.evaluations);
            assert_eq!(sa.migration, sb.migration, "event {}", sa.event);
            assert_eq!(sa.schedule, sb.schedule, "event {}", sa.event);
            assert_eq!(sa.decision, sb.decision, "event {}", sa.event);
            assert_eq!(sa.provenance, sb.provenance, "event {}", sa.event);
        }
    }
}
