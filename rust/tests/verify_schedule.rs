//! Property harness for the static schedule verifier (`bapipe::verify`).
//!
//! Three claims, each load-bearing for `bapipe check` and the planner's
//! debug gate:
//!
//! 1. **Soundness on real programs** — every generated schedule (all 7
//!    kinds, both exec modes, the whole M grid) certifies clean: the
//!    verifier never rejects a program the DES would happily run.
//! 2. **Sensitivity to seeded mutations** — swapped ops, dropped
//!    transfers, FIFO reorders, duplicated/dropped ops, off-by-one stash
//!    depths, under-declared weight versions and hand-built deadlock
//!    cycles are each rejected with the *expected* typed [`VerifyError`]
//!    variant carrying coordinates.
//! 3. **Artifact round-trip** — a plan explored under each shipped train
//!    config's (schedule, M), serialized with `emit_json` and re-loaded
//!    with `Plan::from_json`, audits clean (exit 0, the `bapipe check`
//!    contract), identically under `--jobs 1` and `--jobs 8`.

use bapipe::cluster::{presets, ExecMode};
use bapipe::config::TrainConfig;
use bapipe::model::zoo;
use bapipe::partition::memfit::StageBytes;
use bapipe::planner;
use bapipe::profile::analytical;
use bapipe::schedule::{Op, ScheduleKind};
use bapipe::sim::engine::SimSpec;
use bapipe::util::json::Json;
use bapipe::verify::{self, program, VerifyError};

const M_GRID: [usize; 6] = [1, 2, 3, 4, 8, 16];

/// Materialized per-stage programs for one (kind, n, m) shape.
fn programs(kind: ScheduleKind, n: usize, m: usize) -> Vec<Vec<Op>> {
    (0..n).map(|i| verify::materialize(kind, n, i, m)).collect()
}

// ---------------------------------------------------------------- claim 1

#[test]
fn all_kinds_exec_modes_and_m_certify_clean() {
    for kind in ScheduleKind::all() {
        for exec in [ExecMode::Sync, ExecMode::Async] {
            for n in [1usize, 2, 3, 4, 6] {
                for m in M_GRID {
                    let spec = SimSpec::uniform(kind, n, m, 1.0, 2.0, 0.25, exec);
                    let r = verify::check_spec(&spec);
                    assert!(
                        r.is_clean(),
                        "{} {exec:?} N={n} M={m}: {}",
                        kind.label(),
                        r.render("spec")
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------- claim 2

#[test]
fn mutation_swapped_ops_is_dependency_order() {
    // Move micro-batch 0's backward in front of its forward at stage 0.
    let kind = ScheduleKind::OneFOneBSno;
    let mut progs = programs(kind, 2, 4);
    let fwd = progs[0].iter().position(|o| matches!(o, Op::Fwd { mb: 0 })).unwrap();
    let bwd = progs[0].iter().position(|o| matches!(o, Op::Bwd { mb: 0 })).unwrap();
    progs[0].swap(fwd, bwd);
    let r = verify::check_stage_programs(kind, 2, 4, &progs);
    assert_eq!(r.exit_code(), 2);
    assert!(
        r.violations
            .iter()
            .any(|v| matches!(v, VerifyError::DependencyOrder { stage: 0, micro: 0, .. })),
        "{}",
        r.render("swapped")
    );
}

#[test]
fn mutation_dropped_transfer_is_missing_producer() {
    // Stage 0 never forwards micro-batch 2: stage 1 consumes a tensor
    // nobody sent. The producer stage also gets its own MissingOp.
    let kind = ScheduleKind::GPipe;
    let mut progs = programs(kind, 2, 4);
    progs[0].retain(|o| !matches!(o, Op::Fwd { mb: 2 }));
    let r = verify::check_stage_programs(kind, 2, 4, &progs);
    assert_eq!(r.exit_code(), 2);
    assert!(
        r.violations
            .iter()
            .any(|v| matches!(v, VerifyError::MissingProducer { stage: 1, micro: 2, .. })),
        "{}",
        r.render("dropped transfer")
    );
    assert!(r
        .violations
        .iter()
        .any(|v| matches!(v, VerifyError::MissingOp { stage: 0, micro: 2, .. })));
}

#[test]
fn mutation_fifo_reorder_is_transfer_order() {
    // The consumer stage reads micro-batch 1 before 0 while the producer
    // emits 0 before 1 — the channel would deliver the wrong tensor.
    let kind = ScheduleKind::GPipe;
    let mut progs = programs(kind, 2, 4);
    let p0 = progs[1].iter().position(|o| matches!(o, Op::Fwd { mb: 0 })).unwrap();
    let p1 = progs[1].iter().position(|o| matches!(o, Op::Fwd { mb: 1 })).unwrap();
    progs[1].swap(p0, p1);
    let r = verify::check_stage_programs(kind, 2, 4, &progs);
    assert_eq!(r.exit_code(), 2);
    assert!(
        r.violations
            .iter()
            .any(|v| matches!(v, VerifyError::TransferOrder { stage: 1, .. })),
        "{}",
        r.render("fifo reorder")
    );
}

#[test]
fn mutation_duplicate_and_dropped_ops_are_typed() {
    let kind = ScheduleKind::OneFOneBSo;
    // Duplicate a forward…
    let mut dup = programs(kind, 2, 4);
    let f = dup[0].iter().position(|o| matches!(o, Op::Fwd { mb: 1 })).unwrap();
    let op = dup[0][f];
    dup[0].insert(f + 1, op);
    let r = verify::check_stage_programs(kind, 2, 4, &dup);
    assert!(
        r.violations
            .iter()
            .any(|v| matches!(v, VerifyError::DuplicateOp { stage: 0, micro: 1, .. })),
        "{}",
        r.render("duplicate")
    );
    // …and drop a backward.
    let mut dropped = programs(kind, 2, 4);
    dropped[1].retain(|o| !matches!(o, Op::Bwd { mb: 3 }));
    let r = verify::check_stage_programs(kind, 2, 4, &dropped);
    assert!(
        r.violations
            .iter()
            .any(|v| matches!(v, VerifyError::MissingOp { stage: 1, micro: 3, .. })),
        "{}",
        r.render("dropped")
    );
}

#[test]
fn mutation_off_by_one_stash_depth_is_stash_depth() {
    // The program genuinely needs 4 concurrent micro-batches; a memory
    // model that budgeted 3 is under-provisioned by exactly one slot.
    let kind = ScheduleKind::GPipe;
    let ops = verify::materialize(kind, 2, 0, 4);
    let derived = program::peak_occupancy(&ops);
    assert_eq!(derived, 4, "GPipe stage 0 stashes all M");
    let bytes =
        [StageBytes { static_bytes: 100, per_mb_stash: 10, stash_depth: derived - 1 }];
    let r = verify::check_memory(&[derived], &bytes, None, None);
    assert!(
        matches!(
            r.violations.as_slice(),
            [VerifyError::StashDepth { stage: 0, derived: 4, declared: 3 }]
        ),
        "{}",
        r.render("stash")
    );
}

#[test]
fn mutation_underdeclared_weight_versions_is_staleness_bound() {
    // PipeDream stage 0 at N=4 genuinely needs shadow versions; declaring
    // one fewer than required breaks the staleness certificate.
    let kind = ScheduleKind::PipeDream;
    let ops = verify::materialize(kind, 4, 0, 8);
    let required = program::required_weight_versions(&ops, kind.intra_batch());
    assert!(required > 0, "PipeDream stage 0 is stale by construction");
    let errs = program::check_weight_versions(0, &ops, kind.intra_batch(), required - 1);
    assert!(
        matches!(errs.as_slice(), [VerifyError::StalenessBound { stage: 0, .. }]),
        "{errs:?}"
    );
    // Declared exactly right: accepted.
    assert!(program::check_weight_versions(0, &ops, kind.intra_batch(), required).is_empty());
}

#[test]
fn mutation_cyclic_programs_are_deadlock() {
    // Stage 0 waits for micro-batch 0's error before forwarding it;
    // stage 1 waits for the activation before backwarding. Neither can
    // start — a send/recv cycle the topological pass must find.
    let progs = vec![
        vec![Op::Bwd { mb: 0 }, Op::Fwd { mb: 0 }, Op::Update],
        vec![Op::Fwd { mb: 0 }, Op::Bwd { mb: 0 }, Op::Update],
    ];
    let errs = program::check_deadlock(&progs);
    assert!(
        errs.iter()
            .any(|v| matches!(v, VerifyError::DeadlockCycle { stages } if stages[..] == [0, 1])),
        "{errs:?}"
    );
}

// ---------------------------------------------------------------- claim 3

/// Explore a plan constrained to one (kind, M) pair — the shape every
/// shipped train config pins — at a given parallelism.
fn explore_pinned(kind: ScheduleKind, m: usize, jobs: usize) -> planner::Plan {
    let net = zoo::vgg16(224);
    let cl = presets::v100_cluster(4);
    let prof = analytical::profile(&net, &cl);
    let opts = planner::Options { jobs, ..Default::default() };
    let mut space = planner::SearchSpace::bapipe(&net, &cl, &prof, &opts);
    space.kinds = vec![kind];
    space.m_grid = vec![m];
    let mut cache = planner::EvalCache::new();
    planner::explore_with_cache_in_space(&net, &cl, &prof, &space, &opts, &mut cache)
}

#[test]
fn config_pinned_plans_round_trip_and_audit_clean() {
    for name in ["train_lm10m.json", "train_lm100m.json"] {
        let path = format!("{}/configs/{name}", env!("CARGO_MANIFEST_DIR"));
        let cfg = TrainConfig::load(&path).unwrap();
        let kind = cfg
            .schedule_kind()
            .unwrap()
            .expect("shipped configs pin a pipeline schedule");
        let plan = explore_pinned(kind, cfg.m, 1);
        // Serialize exactly like `explore --emit`, re-load exactly like
        // `bapipe check`, and require the audit's exit-0 contract.
        let text = plan.emit_json().unwrap();
        let loaded = planner::Plan::from_json(&Json::parse(&text).unwrap()).unwrap();
        let cl = presets::v100_cluster(4);
        let audit = verify::plan_audit(&loaded, Some(&cl));
        assert_eq!(audit.exit_code(), 0, "{name}: {}", audit.render("plan"));
    }
}

#[test]
fn audit_diagnostics_agree_across_jobs() {
    // The same pinned exploration under jobs=1 and jobs=8 must produce
    // plans whose audits render identically — the verifier's coordinate
    // sort makes diagnostics independent of evaluation order.
    let plan1 = explore_pinned(ScheduleKind::OneFOneBSno, 8, 1);
    let plan8 = explore_pinned(ScheduleKind::OneFOneBSno, 8, 8);
    let a1 = verify::plan_audit(&plan1, None);
    let a8 = verify::plan_audit(&plan8, None);
    assert_eq!(a1.render("plan"), a8.render("plan"));
    assert_eq!(a1.exit_code(), 0, "{}", a1.render("plan"));
}

#[test]
fn report_ordering_is_insertion_order_independent() {
    // Feed the same violations in two different orders; after sort() the
    // rendered diagnostics are byte-identical.
    let errs = [
        VerifyError::UpdateCount { stage: 1, found: 0, expected: 1 },
        VerifyError::DependencyOrder { stage: 0, pc: 5, micro: 2 },
        VerifyError::PlanStructure { what: "x".into() },
        VerifyError::TransferOrder { stage: 1, pc: 2, micro: 3 },
    ];
    let mut fwd = verify::VerifyReport::default();
    fwd.violations.extend(errs.iter().cloned());
    let mut rev = verify::VerifyReport::default();
    rev.violations.extend(errs.iter().rev().cloned());
    fwd.sort();
    rev.sort();
    assert_eq!(fwd.render("r"), rev.render("r"));
}
