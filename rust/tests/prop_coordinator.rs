//! Property tests on the coordinator's core invariants (via the in-repo
//! `util::prop` mini-framework — the offline crate set has no proptest):
//! partitions, schedule programs, DES conservation laws and analytical
//! agreement over randomized inputs.

use bapipe::cluster::{presets, ExecMode};
use bapipe::model::zoo;
use bapipe::partition::interlayer;
use bapipe::profile::analytical;
use bapipe::schedule::{analytical as closed, generators, Op, ScheduleKind};
use bapipe::sim::engine::{simulate, SimSpec};
use bapipe::util::prop::{check, ensure, Config};

const KINDS: [ScheduleKind; 7] = [
    ScheduleKind::OneFOneBAs,
    ScheduleKind::FbpAs,
    ScheduleKind::OneFOneBSno,
    ScheduleKind::OneFOneBSo,
    ScheduleKind::GPipe,
    ScheduleKind::PipeDream,
    ScheduleKind::TwoBW,
];

#[test]
fn prop_partition_covers_and_respects_cuts() {
    // Random per-layer times on random models → the DP partitioner always
    // returns contiguous, covering, legal-cut partitions.
    check(
        &Config { cases: 80, ..Default::default() },
        |g| {
            let model = ["vgg16", "resnet50", "gnmt8", "alexnet"][g.usize_in(0, 4)];
            let n = g.usize_in(2, 7);
            let micro = g.f64_in(1.0, 32.0);
            (model, n, micro)
        },
        |&(model, n, micro)| {
            let net = zoo::by_name(model).unwrap();
            let cl = presets::v100_cluster(n);
            let prof = analytical::profile(&net, &cl);
            let cuts = net.legal_cuts();
            let p = interlayer::dp_optimal(&prof, &cl, &cuts, micro, None)
                .map_err(|e| e.to_string())?;
            ensure(p.n_stages() == n, "stage count")?;
            ensure(p.bounds[0] == 0 && *p.bounds.last().unwrap() == net.len(), "coverage")?;
            for &b in &p.bounds[1..p.bounds.len() - 1] {
                ensure(cuts.contains(&(b - 1)), format!("illegal cut at {b}"))?;
            }
            // optimality lower bound: max stage ≥ total/n and ≥ biggest
            // un-cuttable segment
            let t = interlayer::max_stage_time(&prof, &p, micro, None);
            let total = prof.fwd_time(0, 0, net.len(), micro)
                + prof.bwd_time(0, 0, net.len(), micro);
            ensure(t >= total / n as f64 - 1e-12, "below mean bound")
        },
    );
}

#[test]
fn prop_schedule_programs_valid_and_balanced() {
    check(
        &Config { cases: 200, ..Default::default() },
        |g| {
            let kind = KINDS[g.usize_in(0, KINDS.len())];
            let n = g.usize_in(1, 10);
            let m = g.usize_in(1, 65);
            (kind, n, m)
        },
        |&(kind, n, m)| {
            for i in 0..n {
                let p = generators::program(kind, n, i, m);
                generators::validate(&p, m, kind.intra_batch())?;
                ensure(p.n_fwd() == m && p.n_bwd() == m, "op counts")?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_des_conservation_and_bounds() {
    // For every schedule on random uniform specs: each stage executes
    // exactly its program, makespan within [bottleneck, serial], peak
    // in-flight ≤ stash_depth bound.
    check(
        &Config { cases: 120, ..Default::default() },
        |g| {
            let kind = KINDS[g.usize_in(0, KINDS.len())];
            let n = g.usize_in(1, 7);
            let m = g.usize_in(1, 33);
            let f = g.f64_in(0.1, 3.0);
            let b = g.f64_in(0.1, 5.0);
            let sr = g.f64_in(0.0, 0.3);
            (kind, n, m, f, b, sr)
        },
        |&(kind, n, m, f, b, sr)| {
            let exec = match kind.required_exec() {
                Some(e) => e,
                None => ExecMode::Sync,
            };
            let spec = SimSpec::uniform(kind, n, m, f, b, sr, exec);
            let r = simulate(&spec);
            let slot = if kind == ScheduleKind::FbpAs { f + b } else { f.max(b) };
            let _ = slot;
            let per_stage_work = if kind == ScheduleKind::FbpAs {
                // every slot costs f+b; a stage has at least m slots
                m as f64 * (f + b)
            } else {
                m as f64 * (f + b)
            };
            ensure(r.makespan >= per_stage_work - 1e-9, "bottleneck bound")?;
            let serial = n as f64 * m as f64 * (f + b) * 3.0 + (n + m) as f64 * 4.0 * sr;
            ensure(r.makespan <= serial + 1e-9, format!("serial bound {} > {serial}", r.makespan))?;
            for i in 0..n {
                ensure(
                    r.peak_in_flight[i] <= kind.stash_depth(n, i, m).max(1),
                    format!("stage {i} in-flight {} > stash bound {}", r.peak_in_flight[i], kind.stash_depth(n, i, m)),
                )?;
            }
            // events per stage = program length
            for i in 0..n {
                let prog = generators::program(kind, n, i, m);
                let evs = r.events.iter().filter(|e| e.stage == i).count();
                ensure(evs == prog.ops.len(), "event count == program length")?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_des_matches_closed_forms_when_comm_small() {
    // With SR ≤ min(F,B)/2, the DES must match the paper's closed forms
    // for 1F1B-AS (exact) and 1F1B-SO (exact).
    check(
        &Config { cases: 80, ..Default::default() },
        |g| {
            let n = g.usize_in(2, 7);
            let m = g.usize_in(n, 48);
            let f = g.f64_in(0.5, 2.0);
            let b = g.f64_in(0.5, 2.0);
            let sr = g.f64_in(0.0, 0.5 * f.min(b) / 2.0);
            (n, m, f, b, sr)
        },
        |&(n, m, f, b, sr)| {
            let syms = closed::Symbols { m, n, f, b, sr, a: 0.0, w: 0.0 };
            let des_as = simulate(&SimSpec::uniform(
                ScheduleKind::OneFOneBAs, n, m, f, b, sr, ExecMode::Async,
            ))
            .makespan;
            let t_as = closed::minibatch_time(ScheduleKind::OneFOneBAs, &syms);
            ensure(
                (des_as - t_as).abs() / t_as < 0.05,
                format!("1F1B-AS: DES {des_as} vs closed {t_as}"),
            )?;
            let des_so = simulate(&SimSpec::uniform(
                ScheduleKind::OneFOneBSo, n, m, f, b, sr, ExecMode::Sync,
            ))
            .makespan;
            let t_so = closed::minibatch_time(ScheduleKind::OneFOneBSo, &syms);
            ensure(
                (des_so - t_so).abs() / t_so < 0.08,
                format!("1F1B-SO: DES {des_so} vs closed {t_so} (n={n} m={m} f={f} b={b} sr={sr})"),
            )
        },
    );
}

#[test]
fn prop_memfit_never_returns_oversubscribed_partition() {
    use bapipe::partition::memfit::{fit_memory, stage_memory_bytes, MemoryModel};
    check(
        &Config { cases: 40, ..Default::default() },
        |g| {
            let l = [32u64, 60, 90][g.usize_in(0, 3)];
            let n = g.usize_in(2, 6);
            let micro = g.f64_in(4.0, 32.0);
            let m = g.usize_in(2, 17);
            (l, n, micro, m)
        },
        |&(l, n, micro, m)| {
            let net = zoo::gnmt_l(l);
            let cl = presets::v100_cluster(n);
            let prof = analytical::profile(&net, &cl);
            let cuts = net.legal_cuts();
            let kind = ScheduleKind::OneFOneBSno;
            let seed = interlayer::dp_optimal(&prof, &cl, &cuts, micro, None)
                .map_err(|e| e.to_string())?;
            match fit_memory(&prof, &cl, seed, kind, false, micro, m, &cuts) {
                Err(_) => Ok(()), // honest failure is allowed
                Ok(r) => {
                    let mm = MemoryModel::default();
                    for i in 0..n {
                        let used = stage_memory_bytes(
                            &prof, &mm, kind, false, n, i, r.partition.stage(i), micro, m,
                        );
                        ensure(
                            used <= mm.usable(cl.devices[i].mem_capacity),
                            format!("stage {i} oversubscribed after fit"),
                        )?;
                    }
                    Ok(())
                }
            }
        },
    );
}
