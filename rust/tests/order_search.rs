//! Device-order neighbourhood search past the 8-device wall, end to end
//! (the acceptance criteria of the `planner::orders` subsystem):
//!
//! * on a heterogeneous ≥16-device cluster, `--permute --order-search`
//!   discovers a non-identity ordering whose *evaluated* (DES) epoch time
//!   beats the identity layout — identity is always enumerated first, so
//!   ties go to it and a non-identity winner strictly beat it;
//! * the search is bit-identical across `--jobs 1` and `--jobs 8`
//!   (probes fan out in first-appearance batches with a deterministic
//!   reduction, like phase A's prewarm);
//! * exhaustive enumeration at ≤ 8 devices is byte-for-byte unchanged,
//!   with or without `--order-search`;
//! * a persisted plan cache whose discovered order set differs from the
//!   current discovery is rejected, never silently reused.

use bapipe::cluster::presets;
use bapipe::model::zoo;
use bapipe::planner::{self, store, EvalCache, Options, SearchSpace};
use bapipe::profile::analytical;

fn search_opts() -> Options {
    Options {
        batch_per_device: 8.0,
        samples_per_epoch: 4096,
        consider_dp: false,
        permute_devices: true,
        order_search: true,
        order_budget: 300,
        ..Default::default()
    }
}

#[test]
fn neighbourhood_search_beats_identity_on_a_16_device_mix() {
    // gpu_mixed alternates V100/P100: VGG's heavy adjacent conv layers
    // cannot all sit on fast boards under the identity layout, so sorted
    // layouts (in the seed portfolio) win decisively.
    let net = zoo::vgg16(224);
    let cl = presets::gpu_mixed_cluster(16);
    let prof = analytical::profile(&net, &cl);
    let plan = planner::explore(&net, &cl, &prof, &search_opts());

    let identity: Vec<usize> = (0..16).collect();
    assert_ne!(
        plan.device_order, identity,
        "ties go to the identity ordering (enumerated first), so a non-identity \
         winner strictly beats it:\n{}",
        plan.report.log_lines().join("\n")
    );
    // the winning order is a permutation of all 16 devices
    let mut sorted = plan.device_order.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, identity);

    // widening the space beats the identity-only exploration outright
    let id_plan = planner::explore(
        &net,
        &cl,
        &prof,
        &Options { permute_devices: false, ..search_opts() },
    );
    assert!(
        plan.epoch_time < id_plan.epoch_time,
        "discovered order must beat identity: {} vs {}",
        plan.epoch_time,
        id_plan.epoch_time
    );

    // the search reports itself: budget usage in the notes, one
    // provenance line per discovered order
    assert!(
        plan.report.notes.iter().any(|n| n.contains("neighbourhood search")),
        "search notes missing: {:?}",
        plan.report.notes
    );
    let n_orders =
        plan.report.evaluations.iter().map(|e| e.candidate.perm).max().unwrap_or(0) + 1;
    assert!(n_orders > 1, "the discovered set must hold more than the identity");
    assert_eq!(
        plan.report.order_provenance.len(),
        n_orders,
        "one provenance line per discovered order: {:?}",
        plan.report.order_provenance
    );
}

#[test]
fn order_search_is_bit_identical_across_job_counts() {
    let net = zoo::vgg16(224);
    let cl = presets::gpu_mixed_cluster(16);
    let prof = analytical::profile(&net, &cl);
    let serial = planner::explore(&net, &cl, &prof, &Options { jobs: 1, ..search_opts() });
    let parallel = planner::explore(&net, &cl, &prof, &Options { jobs: 8, ..search_opts() });
    assert_eq!(serial.choice, parallel.choice);
    assert_eq!(serial.device_order, parallel.device_order);
    assert_eq!(serial.epoch_time, parallel.epoch_time);
    assert_eq!(serial.minibatch_time, parallel.minibatch_time);
    assert_eq!(serial.stage_memory, parallel.stage_memory);
    // the whole search record matches: discovered orders, provenance,
    // notes, per-candidate outcomes and cache statistics
    assert_eq!(serial.report.notes, parallel.report.notes);
    assert_eq!(serial.report.order_provenance, parallel.report.order_provenance);
    assert_eq!(serial.report.evaluations, parallel.report.evaluations);
    assert_eq!(serial.report.cache_hits, parallel.report.cache_hits);
}

#[test]
fn exhaustive_enumeration_unchanged_at_8_or_fewer_devices() {
    // ≤ 8 devices: --order-search must not perturb the exhaustive walk —
    // same orders, same notes, no provenance.
    let net = zoo::vgg16(224);
    let cl = presets::fpga_cluster(&["VCU129", "VCU129", "VCU118", "VCU118"]);
    let prof = analytical::profile(&net, &cl);
    let base = Options { permute_devices: true, ..Default::default() };
    let without = SearchSpace::bapipe(&net, &cl, &prof, &base);
    let with = SearchSpace::bapipe(
        &net,
        &cl,
        &prof,
        &Options { order_search: true, order_budget: 64, ..base },
    );
    assert_eq!(without.device_orders, with.device_orders);
    assert_eq!(without.notes, with.notes);
    assert!(without.order_provenance.is_empty());
    assert!(with.order_provenance.is_empty());
    assert_eq!(without.device_orders.len(), 6, "4!/(2!·2!) distinct layouts");
}

#[test]
fn plan_cache_with_different_discovered_order_set_is_rejected() {
    let net = zoo::vgg16(224);
    let cl = presets::gpu_mixed_cluster(16);
    let prof = analytical::profile(&net, &cl);
    let opts = search_opts();
    let fp = store::fingerprint(&net, &cl, &prof);
    let searched = SearchSpace::bapipe(&net, &cl, &prof, &opts);
    assert!(searched.device_orders.len() > 1, "discovery must widen the order set");

    let path = std::env::temp_dir().join("bapipe-order-search-cache-test.json");
    let path = path.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&path);

    let mut cache = EvalCache::new();
    let first = planner::explore_with_cache(&net, &cl, &prof, &opts, &mut cache);
    store::save(&path, &cache, &fp, &searched.device_orders).unwrap();

    // a run without --order-search discovers a different (identity-only)
    // set: the cached `perm` indices would lie, so the cache is rejected
    let identity_space = SearchSpace::bapipe(
        &net,
        &cl,
        &prof,
        &Options { order_search: false, ..opts.clone() },
    );
    assert_eq!(identity_space.device_orders.len(), 1);
    match store::load(&path, &fp, &identity_space.device_orders) {
        store::CacheLoad::Fresh(reason) => {
            assert!(reason.contains("stale"), "unexpected reason: {reason}")
        }
        store::CacheLoad::Loaded(_) => panic!("a mismatched order set must not load"),
    }

    // the matching discovered set restores and skips phase A entirely
    let mut warm = match store::load(&path, &fp, &searched.device_orders) {
        store::CacheLoad::Loaded(cache) => cache,
        store::CacheLoad::Fresh(why) => panic!("expected the cache to load: {why}"),
    };
    let second = planner::explore_with_cache(&net, &cl, &prof, &opts, &mut warm);
    assert_eq!(warm.misses, 0, "phase A must be skipped on matching discovery");
    assert_eq!(first.choice, second.choice);
    assert_eq!(first.device_order, second.device_order);
    assert_eq!(first.epoch_time, second.epoch_time);

    let _ = std::fs::remove_file(&path);
}
