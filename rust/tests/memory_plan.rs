//! Memory-scalable planning, end to end: the DES per-stage in-flight
//! high-water mark against the paper's closed-form memory rows (Tables
//! 1–2), capacity safety of every memfit-accepted plan, and the
//! (epoch time × simulated peak memory) Pareto front on a
//! capacity-halved cluster — including the 2BW and recomputation axes
//! and worker-count determinism.

use bapipe::cluster::{presets, Cluster, ExecMode};
use bapipe::model::zoo;
use bapipe::partition::memfit::MemoryModel;
use bapipe::planner::{self, Choice, Options, Outcome};
use bapipe::profile::analytical;
use bapipe::schedule::{analytical as closed, ScheduleKind};
use bapipe::sim::engine::{simulate, SimSpec};
use bapipe::util::prop::{check, ensure, Config};

/// Kinds whose generator warm-up *equals* the stash-depth bound, so the
/// simulated high-water mark must hit it exactly (with `m ≥ n` below so
/// PipeDream's unclamped `n-i` depth is reachable). FBP-AS peaks one
/// below its `2(n-i)` bound (the round-trip offset is `2(n-i)-1`) and is
/// covered by the `≤` property in `prop_coordinator`.
const EXACT_KINDS: [ScheduleKind; 6] = [
    ScheduleKind::OneFOneBAs,
    ScheduleKind::OneFOneBSno,
    ScheduleKind::OneFOneBSo,
    ScheduleKind::GPipe,
    ScheduleKind::PipeDream,
    ScheduleKind::TwoBW,
];

#[test]
fn prop_simulated_peak_matches_analytical_memory_oracle() {
    // On uniform chains the DES peak, priced at `a` bytes per stashed
    // micro-batch plus `(2 + versions)·w` for weights, must reproduce the
    // paper's features+weights memory rows *exactly* — the high-water
    // mark is program-structural, independent of op timing.
    check(
        &Config { cases: 150, ..Default::default() },
        |g| {
            let kind = EXACT_KINDS[g.usize_in(0, EXACT_KINDS.len())];
            let n = g.usize_in(1, 7);
            let m = g.usize_in(n, 4 * n + 9);
            let f = g.f64_in(0.2, 2.0);
            let b = g.f64_in(0.2, 3.0);
            let sr = g.f64_in(0.0, 0.2);
            (kind, n, m, f, b, sr)
        },
        |&(kind, n, m, f, b, sr)| {
            let exec = kind.required_exec().unwrap_or(ExecMode::Sync);
            let r = simulate(&SimSpec::uniform(kind, n, m, f, b, sr, exec));
            let s = closed::Symbols {
                m,
                n,
                f,
                b,
                sr,
                a: 3.0 * (1u64 << 20) as f64,
                w: 5.0 * (1u64 << 20) as f64,
            };
            for i in 0..n {
                ensure(
                    r.peak_in_flight[i] == kind.stash_depth(n, i, m),
                    format!(
                        "{kind:?} n={n} i={i} m={m}: peak {} != stash depth {}",
                        r.peak_in_flight[i],
                        kind.stash_depth(n, i, m)
                    ),
                )?;
                let simulated = (2 + kind.weight_versions(n, i)) as f64 * s.w
                    + r.peak_in_flight[i] as f64 * s.a;
                let oracle = closed::weights_memory(kind, &s, i + 1)
                    + closed::features_memory(kind, &s, i + 1);
                ensure(
                    simulated == oracle,
                    format!("{kind:?} n={n} i={i} m={m}: {simulated} bytes != oracle {oracle}"),
                )?;
            }
            Ok(())
        },
    );
}

/// The paper's V100 cluster with every device's memory halved — tight
/// enough that memory-scalable schedules matter, loose enough that the
/// pipeline still trains.
fn halved_v100(n: usize) -> Cluster {
    let mut cl = presets::v100_cluster(n);
    for d in &mut cl.devices {
        d.mem_capacity /= 2;
    }
    cl
}

fn pareto_opts(jobs: usize) -> Options {
    Options {
        samples_per_epoch: 8192,
        consider_dp: false,
        jobs,
        pareto: true,
        recompute: true,
        ..Options::default()
    }
}

#[test]
fn capacity_halved_cluster_yields_memory_scalable_pareto_front() {
    let net = zoo::gnmt_l(64);
    let cl = halved_v100(8);
    let prof = analytical::profile(&net, &cl);
    let plan = planner::explore(&net, &cl, &prof, &pareto_opts(1));
    let front = &plan.pareto_front;
    assert!(
        front.len() >= 2,
        "need >= 2 mutually non-dominated plans, got {}\n{}",
        front.len(),
        plan.summary()
    );

    // Pairwise mutual non-domination: each point beats every other on at
    // least one axis. Combined with the fastest-first sort this means
    // epoch strictly increases and peak strictly decreases along the front.
    for (x, a) in front.iter().enumerate() {
        for b in front.iter().skip(x + 1) {
            assert!(
                a.epoch_time < b.epoch_time || a.peak_memory < b.peak_memory,
                "front point dominated: {a:?} vs {b:?}"
            );
            assert!(
                b.epoch_time < a.epoch_time || b.peak_memory < a.peak_memory,
                "front point dominated: {b:?} vs {a:?}"
            );
        }
    }
    assert!(
        front
            .windows(2)
            .all(|w| w[0].epoch_time < w[1].epoch_time && w[0].peak_memory > w[1].peak_memory),
        "front not sorted fastest-first with decreasing peak\n{}",
        plan.summary()
    );

    // At least one front plan uses a memory-scalable mechanism.
    assert!(
        front
            .iter()
            .any(|p| p.candidate.kind == ScheduleKind::TwoBW || p.candidate.recompute),
        "no 2BW or recompute plan on the front\n{}",
        plan.summary()
    );

    // Simulated peak fits the halved capacity on every front plan — and
    // on every memfit-accepted (simulated) candidate, per device.
    let mm = MemoryModel::default();
    for p in front {
        assert!(
            p.peak_memory <= mm.usable(cl.devices[0].mem_capacity),
            "front plan over capacity: {p:?}"
        );
    }
    for ev in &plan.report.evaluations {
        if let Outcome::Evaluated { peak_memory, .. } = &ev.outcome {
            assert!(!peak_memory.is_empty(), "simulated candidate without peaks");
            for (i, &peak) in peak_memory.iter().enumerate() {
                assert!(
                    peak <= mm.usable(cl.devices[i].mem_capacity),
                    "stage {i} of {:?} oversubscribed: {peak} bytes",
                    ev.candidate
                );
            }
        }
    }

    // The selected plan is still the fastest feasible point — the front
    // widens the report, not the choice.
    assert!(matches!(plan.choice, Choice::Pipeline { .. }), "expected a pipeline winner");
    assert_eq!(plan.epoch_time, front[0].epoch_time, "winner must be the fastest front point");

    // The front survives a plan.json round trip (emit_json re-parses and
    // compares internally).
    let text = plan.emit_json().unwrap();
    assert!(text.contains("\"pareto_front\""));
}

#[test]
fn pareto_front_is_independent_of_worker_count() {
    // With pruning suspended under --pareto every feasible candidate is
    // simulated, so jobs=1 and jobs=8 must agree bit-for-bit: same
    // winner, same per-candidate outcomes, same simulated peaks, same
    // front.
    let net = zoo::gnmt_l(32);
    let cl = halved_v100(4);
    let prof = analytical::profile(&net, &cl);
    let p1 = planner::explore(&net, &cl, &prof, &pareto_opts(1));
    let p8 = planner::explore(&net, &cl, &prof, &pareto_opts(8));
    assert_eq!(p1.choice, p8.choice);
    assert_eq!(p1.epoch_time, p8.epoch_time);
    assert_eq!(p1.stage_memory, p8.stage_memory);
    assert_eq!(p1.pareto_front, p8.pareto_front);
    assert_eq!(p1.report.evaluations, p8.report.evaluations);
    assert!(!p1.pareto_front.is_empty(), "parity check needs a non-trivial front");
}
