//! Elastic replanning, end to end: scenario replay determinism across
//! worker counts, warm-start quality against cold exploration on the
//! same mutated cluster, and graceful degradation to the recompute/2BW
//! axes when a device loss makes the incumbent partition memfit-
//! infeasible.

use bapipe::cluster::mutate::{self, ClusterEvent, Scenario};
use bapipe::cluster::presets;
use bapipe::model::zoo;
use bapipe::planner::elastic::{replan, run_scenario, surviving_order};
use bapipe::planner::{self, Choice, Options};
use bapipe::profile::analytical;
use bapipe::schedule::ScheduleKind;
use bapipe::util::json::Json;

fn opts(jobs: usize) -> Options {
    Options {
        batch_per_device: 8.0,
        samples_per_epoch: 8192,
        m_candidates: vec![4, 8],
        consider_dp: false,
        jobs,
        ..Options::default()
    }
}

/// The CLI scenario-JSON shape drives the replay: loss, join, link
/// degradation and a straggler, parsed from text exactly as `bapipe
/// replan --scenario` would, and bit-identical for any `--jobs` value.
#[test]
fn scenario_replay_is_bit_identical_across_worker_counts() {
    let net = zoo::vgg16(224);
    let cl = presets::gpu_mixed_cluster(6);
    let prof = analytical::profile(&net, &cl);
    let incumbent = planner::explore(&net, &cl, &prof, &opts(1));
    assert!(matches!(incumbent.choice, Choice::Pipeline { .. }));

    let doc = Json::parse(
        r#"{
          "name": "outage-and-recovery",
          "events": [
            {"event": "device-loss", "device": 2},
            {"event": "straggler", "device": 0, "slowdown": 1.5},
            {"event": "device-join", "device_name": "P100", "position": 2},
            {"event": "link-degrade", "link": 1, "bandwidth_factor": 0.5,
             "latency_factor": 2.0}
          ]
        }"#,
    )
    .unwrap();
    let scenario = Scenario::from_json(&doc).unwrap();

    let a = run_scenario(&net, &cl, &prof, &incumbent, &scenario, &opts(1)).unwrap();
    let b = run_scenario(&net, &cl, &prof, &incumbent, &scenario, &opts(8)).unwrap();
    assert_eq!(a.scenario, "outage-and-recovery");
    assert_eq!(a.steps.len(), 4);
    for (sa, sb) in a.steps.iter().zip(&b.steps) {
        assert_eq!(sa.plan.choice, sb.plan.choice, "event {}", sa.event);
        assert_eq!(sa.plan.epoch_time, sb.plan.epoch_time, "event {}", sa.event);
        assert_eq!(sa.plan.device_order, sb.plan.device_order, "event {}", sa.event);
        assert_eq!(
            sa.plan.report.evaluations, sb.plan.report.evaluations,
            "event {}",
            sa.event
        );
        assert_eq!(
            sa.migration.as_ref().map(|m| (m.moved_layers, m.bytes)),
            sb.migration.as_ref().map(|m| (m.moved_layers, m.bytes)),
            "event {}",
            sa.event
        );
        assert_eq!(sa.provenance, sb.provenance, "event {}", sa.event);
    }
    // every event ends with a feasible pipeline on this roomy cluster
    for s in &a.steps {
        assert!(matches!(s.plan.choice, Choice::Pipeline { .. }), "{}", s.event);
    }
    // the loss event must price a migration: the lost device's layers move
    let mig = a.steps[0].migration.as_ref().expect("pipeline-to-pipeline step");
    assert!(mig.moved_layers > 0 && mig.bytes > 0, "{mig:?}");
}

/// Warm-started replanning explores a superset of the cold space, so on
/// every mutated cluster of the scenario the warm plan is at least as
/// fast as a cold `explore` with the same options.
#[test]
fn warm_replan_never_loses_to_cold_exploration() {
    let net = zoo::vgg16(224);
    let cl = presets::gpu_mixed_cluster(6);
    let prof = analytical::profile(&net, &cl);
    let o = opts(1);
    let incumbent = planner::explore(&net, &cl, &prof, &o);
    let scenario = Scenario::scripted(
        "degrade",
        vec![
            ClusterEvent::Straggler { device: 1, slowdown: 2.0 },
            ClusterEvent::DeviceLoss { device: 4 },
            ClusterEvent::LinkDegrade { link: 0, bandwidth_factor: 0.25, latency_factor: 1.0 },
        ],
    );
    let run = run_scenario(&net, &cl, &prof, &incumbent, &scenario, &o).unwrap();

    // replay the mutations independently to rebuild each step's cluster
    let (mut c, mut p) = (cl, prof);
    for (event, step) in scenario.events.iter().zip(&run.steps) {
        let mu = mutate::apply(&net, &c, &p, &event.event).unwrap();
        let cold = planner::explore(&net, &mu.cluster, &mu.profile, &o);
        assert!(
            step.plan.epoch_time <= cold.epoch_time,
            "warm {} slower than cold {} after {}",
            step.plan.epoch_time,
            cold.epoch_time,
            step.event
        );
        c = mu.cluster;
        p = mu.profile;
    }
    // the first replan runs without a prior cache, later ones salvage
    assert!(run.steps[0].provenance.iter().all(|l| !l.contains("cache salvage")));
    assert!(run.steps[1].provenance.iter().any(|l| l.contains("cache salvage")));
}

/// When the post-loss cluster cannot fit any plain-schedule partition,
/// the replanner widens to the recompute/2BW axes instead of giving up,
/// and says so in the provenance.
#[test]
fn infeasible_after_loss_falls_back_to_memory_scalable_axes() {
    let net = zoo::gnmt_l(64);
    let base = Options {
        batch_per_device: 32.0,
        samples_per_epoch: 8192,
        m_candidates: vec![4, 8, 16],
        consider_dp: false,
        ..Options::default()
    };

    // Find a capacity tight enough that no plain schedule fits three
    // devices but the recompute/2BW axes still do — self-validating, so
    // the test never asserts against an infeasible-everywhere cluster.
    let mut found = None;
    for div in [2u64, 3, 4, 6, 8, 12] {
        let mut tight = presets::v100_cluster(3);
        for d in &mut tight.devices {
            d.mem_capacity /= div;
        }
        let tprof = analytical::profile(&net, &tight);
        let plain = planner::explore(&net, &tight, &tprof, &base);
        let wide = planner::explore(
            &net,
            &tight,
            &tprof,
            &Options { pareto: true, recompute: true, ..base.clone() },
        );
        if plain.report.best_evaluation().is_none() && wide.report.best_evaluation().is_some() {
            found = Some((tight, tprof));
            break;
        }
    }
    let (tight, tprof) =
        found.expect("no capacity divisor separates plain from memory-scalable schedules");

    // healthy incumbent at full capacity
    let cl = presets::v100_cluster(3);
    let prof = analytical::profile(&net, &cl);
    let incumbent = planner::explore(&net, &cl, &prof, &base);
    assert!(matches!(incumbent.choice, Choice::Pipeline { .. }));

    // the "mutated" cluster is the capacity-starved one; the incumbent
    // order survives verbatim
    let order = surviving_order(&incumbent.device_order, &[Some(0), Some(1), Some(2)], 3);
    let r = replan(&net, &tight, &tprof, &incumbent, &order, &base, None);
    assert!(
        r.provenance.iter().any(|l| l.contains("widened to the recompute/2BW axes")),
        "{:?}",
        r.provenance
    );
    assert!(
        r.provenance.iter().any(|l| l.contains("recovered a feasible pipeline")),
        "{:?}",
        r.provenance
    );
    match &r.plan.choice {
        Choice::Pipeline { kind, recompute, .. } => assert!(
            *recompute || *kind == ScheduleKind::TwoBW,
            "recovered plan must use a memory-scalable mechanism, got {kind:?} rc={recompute}"
        ),
        Choice::DataParallel => panic!("expected a widened pipeline, got DP\n{:?}", r.provenance),
    }
}
