//! Planner guarantees, end to end:
//!
//! * branch-and-bound pruning is *sound* — the pruned search returns the
//!   exact plan of the exhaustive search while running strictly fewer
//!   discrete-event simulations (VGG-16/4×V100 and the paper's other
//!   preset scenarios);
//! * the parallel evaluator is *deterministic* — `jobs = 1` and
//!   `jobs = 8` select identical plans (property-tested over random
//!   scenarios via `util::prop`), with *both* phases parallel: the
//!   phase-A balance-seed/fine-tune fan-out (including device-order
//!   permutations) and the phase-B DES fan-out, at 64-stage scale;
//! * adaptive M refinement never selects a worse plan than the fixed
//!   grid (zoo models);
//! * the pooled per-worker simulators (one `sim::batch::FamilySim` per
//!   worker, shared by the fixed-grid pass and every adaptive-M round)
//!   keep the jobs=1 ≡ jobs=8 guarantee on the batched DES path;
//! * `plan.json` artifacts round-trip losslessly;
//! * device-order permutation search only ever improves a heterogeneous
//!   plan.

use bapipe::cluster::presets;
use bapipe::model::zoo;
use bapipe::partition::interlayer::{
    dp_optimal_prefix, dp_optimal_rc, dp_optimal_reference, max_stage_time,
};
use bapipe::planner::{self, Options, Outcome};
use bapipe::profile::{analytical, RangeCost};
use bapipe::util::json::Json;
use bapipe::util::prop::{check, ensure, Config};

fn opts(batch: f64) -> Options {
    Options { batch_per_device: batch, samples_per_epoch: 8192, ..Default::default() }
}

#[test]
fn pruned_search_equals_exhaustive_on_vgg16_4xv100() {
    let net = zoo::vgg16(224);
    let cl = presets::v100_cluster(4);
    let prof = analytical::profile(&net, &cl);

    let exhaustive =
        planner::explore(&net, &cl, &prof, &Options { prune: false, ..opts(32.0) });
    let pruned = planner::explore(&net, &cl, &prof, &Options { prune: true, ..opts(32.0) });

    assert_eq!(exhaustive.choice, pruned.choice, "pruning changed the selected plan");
    assert_eq!(exhaustive.epoch_time, pruned.epoch_time);
    assert_eq!(exhaustive.minibatch_time, pruned.minibatch_time);
    assert_eq!(exhaustive.stage_memory, pruned.stage_memory);

    assert_eq!(exhaustive.report.pruned_count, 0);
    assert!(
        pruned.report.pruned_count > 0,
        "expected branch-and-bound to skip some DES runs:\n{}",
        pruned.report.log_lines().join("\n")
    );
    assert!(
        pruned.report.simulated_count < exhaustive.report.simulated_count,
        "pruned search must run strictly fewer simulations ({} vs {})",
        pruned.report.simulated_count,
        exhaustive.report.simulated_count
    );
    // every pruned candidate's bound must exceed the winner's epoch time
    for ev in &pruned.report.evaluations {
        if let Outcome::Pruned { lower_bound } = ev.outcome {
            assert!(
                lower_bound >= pruned.epoch_time,
                "pruned candidate {:?} M={} had bound {lower_bound} below best {}",
                ev.candidate.kind,
                ev.candidate.m,
                pruned.epoch_time
            );
        }
    }
}

#[test]
fn pruned_search_equals_exhaustive_on_paper_presets() {
    // The paper's other preset scenarios: ResNet-50 on 8 V100 (degenerates
    // to DP) and ResNet-50 on the mixed VCU129/VCU118 FPGA testbed.
    let scenarios: Vec<(&str, bapipe::cluster::Cluster, f64, bool)> = vec![
        ("resnet50", presets::v100_cluster(8), 32.0, true),
        (
            "resnet50",
            presets::fpga_cluster(&["VCU129", "VCU129", "VCU118", "VCU118"]),
            4.0,
            false,
        ),
        ("vgg16", presets::fpga_cluster(&["VCU129", "VCU118"]), 4.0, false),
    ];
    for (model, cl, batch, consider_dp) in scenarios {
        let net = zoo::by_name(model).unwrap();
        let prof = analytical::profile(&net, &cl);
        let base = Options { consider_dp, ..opts(batch) };
        let exhaustive =
            planner::explore(&net, &cl, &prof, &Options { prune: false, ..base.clone() });
        let pruned = planner::explore(&net, &cl, &prof, &Options { prune: true, ..base });
        assert_eq!(
            exhaustive.choice,
            pruned.choice,
            "{model} on {}: pruning changed the plan",
            cl.describe()
        );
        assert_eq!(exhaustive.epoch_time, pruned.epoch_time);
        assert!(
            pruned.report.simulated_count <= exhaustive.report.simulated_count,
            "{model} on {}",
            cl.describe()
        );
    }
}

#[test]
fn parallel_jobs_select_identical_plans_property() {
    // util::prop over random (model, cluster size, batch) scenarios: the
    // scoped-thread evaluator's reduction must be interleaving-free.
    let models = ["vgg16", "resnet50", "gnmt8", "alexnet"];
    check(
        &Config { cases: 10, seed: 0xBA_51C0DE, max_size: 8 },
        |g| {
            let model = models[g.usize_in(0, models.len())];
            let n = [2usize, 4][g.usize_in(0, 2)];
            let batch = [16.0, 32.0][g.usize_in(0, 2)];
            (model, n, batch)
        },
        |&(model, n, batch)| {
            let net = zoo::by_name(model).unwrap();
            let cl = presets::v100_cluster(n);
            let prof = analytical::profile(&net, &cl);
            let serial =
                planner::explore(&net, &cl, &prof, &Options { jobs: 1, ..opts(batch) });
            let parallel =
                planner::explore(&net, &cl, &prof, &Options { jobs: 8, ..opts(batch) });
            ensure(
                serial.choice == parallel.choice,
                format!(
                    "{model} on {n} V100 at B={batch}: jobs=1 chose {:?}, jobs=8 chose {:?}",
                    serial.choice, parallel.choice
                ),
            )?;
            ensure(
                serial.epoch_time == parallel.epoch_time,
                format!(
                    "{model} on {n} V100 at B={batch}: epoch {} vs {}",
                    serial.epoch_time, parallel.epoch_time
                ),
            )?;
            ensure(
                serial.report.cache_hits == parallel.report.cache_hits,
                "phase A's prewarm is deterministic; cache hits must match".to_string(),
            )
        },
    );
}

#[test]
fn parallel_phase_a_parity_with_permutations() {
    // Phase A (balance-seed DP + memory fine-tune) fans out over --jobs
    // too; device-order permutations multiply its work list. Everything
    // observable must be independent of the job count — including the
    // cache statistics (the prewarm work lists are in first-appearance
    // order) and the per-candidate feasibility outcomes.
    let net = zoo::vgg16(224);
    let cl = presets::fpga_cluster(&["VCU129", "VCU129", "VCU118", "VCU118"]);
    let prof = analytical::profile(&net, &cl);
    let base = Options { consider_dp: false, permute_devices: true, ..opts(4.0) };
    let serial = planner::explore(&net, &cl, &prof, &Options { jobs: 1, ..base.clone() });
    let parallel = planner::explore(&net, &cl, &prof, &Options { jobs: 8, ..base });
    assert_eq!(serial.choice, parallel.choice);
    assert_eq!(serial.epoch_time, parallel.epoch_time);
    assert_eq!(serial.minibatch_time, parallel.minibatch_time);
    assert_eq!(serial.device_order, parallel.device_order);
    assert_eq!(serial.report.cache_hits, parallel.report.cache_hits);
    // permutation search actually widened phase A (6 distinct orderings)
    assert!(serial.report.evaluations.iter().any(|e| e.candidate.perm > 0));
    // phase-A outcomes (infeasibility) are decided before the DES race
    // and must match candidate-for-candidate
    assert_eq!(serial.report.evaluations.len(), parallel.report.evaluations.len());
    for (a, b) in serial.report.evaluations.iter().zip(&parallel.report.evaluations) {
        assert_eq!(a.candidate, b.candidate);
        assert_eq!(
            matches!(a.outcome, Outcome::Infeasible { .. }),
            matches!(b.outcome, Outcome::Infeasible { .. }),
            "feasibility flipped for {:?} M={}",
            a.candidate.kind,
            a.candidate.m
        );
    }
}

#[test]
fn pooled_batched_path_parity_across_grid_and_adaptive_rounds() {
    // PR 6 moves phase B onto pooled per-worker `sim::batch::FamilySim`
    // instances that survive across the fixed-grid pass and every
    // adaptive-M round (reset via `begin_family` in between). The sparse
    // starting grid forces at least one bisection round, so a worker's
    // simulator serves candidate families of different shapes back to
    // back — and everything observable must still be independent of the
    // job count on a heterogeneous cluster with permutations on.
    let net = zoo::vgg16(224);
    let cl = presets::gpu_mixed_cluster(4); // V100/P100 mix: permutations matter
    let prof = analytical::profile(&net, &cl);
    let base = Options {
        consider_dp: false,
        permute_devices: true,
        adaptive_m: true,
        m_candidates: vec![2, 32], // global batch 32: bisection can reach 1/4/8/16
        ..opts(8.0)
    };
    let serial = planner::explore(&net, &cl, &prof, &Options { jobs: 1, ..base.clone() });
    let parallel = planner::explore(&net, &cl, &prof, &Options { jobs: 8, ..base });
    assert_eq!(serial.choice, parallel.choice);
    assert_eq!(serial.epoch_time, parallel.epoch_time);
    assert_eq!(serial.minibatch_time, parallel.minibatch_time);
    assert_eq!(serial.device_order, parallel.device_order);
    assert_eq!(serial.stage_memory, parallel.stage_memory);
    assert_eq!(serial.report.cache_hits, parallel.report.cache_hits);
    // the sparse grid must produce a feasible incumbent for the
    // bisection to work around (VGG-16 fits this mix comfortably)
    assert!(
        serial
            .report
            .evaluations
            .iter()
            .any(|e| matches!(e.outcome, Outcome::Evaluated { .. })),
        "no feasible candidate on the starting grid:\n{}",
        serial.report.log_lines().join("\n")
    );
    // the refinement actually ran extra rounds through the shared pool
    assert!(
        serial.report.notes.iter().any(|n| n.contains("adaptive-M round")),
        "expected at least one bisection round:\n{:?}",
        serial.report.notes
    );
    // the candidate work list (ascending-lb order) is jobs-independent
    assert_eq!(serial.report.evaluations.len(), parallel.report.evaluations.len());
    for (a, b) in serial.report.evaluations.iter().zip(&parallel.report.evaluations) {
        assert_eq!(a.candidate, b.candidate);
    }
}

#[test]
fn sixty_four_stage_stress_parity() {
    // The ROADMAP "Scale" scenario: a 64-stage synthetic cluster at
    // M=512 (a debug-build-sized slice of `benches/planner_scale.rs`:
    // 70-layer GNMT-L, three M values). Phase A runs one O(N·C²) DP per
    // distinct micro; phase B runs ~65k-op DES traces. jobs=1 and jobs=8
    // must select identical plans (--permute included: on a homogeneous
    // chain it degenerates to the identity ordering, recorded in the
    // notes).
    let net = zoo::by_name("gnmt-l64").unwrap();
    let cl = presets::v100_cluster(64);
    let prof = analytical::profile(&net, &cl);
    let base = Options {
        batch_per_device: 8.0, // global mini-batch 512
        samples_per_epoch: 4096,
        m_candidates: vec![64, 256, 512],
        consider_dp: false,
        permute_devices: true,
        ..Default::default()
    };
    let serial = planner::explore(&net, &cl, &prof, &Options { jobs: 1, ..base.clone() });
    let parallel = planner::explore(&net, &cl, &prof, &Options { jobs: 8, ..base });
    assert_eq!(serial.choice, parallel.choice, "64-stage plans diverged across job counts");
    assert_eq!(serial.epoch_time, parallel.epoch_time);
    assert_eq!(serial.report.cache_hits, parallel.report.cache_hits);
    assert!(
        serial.report.evaluations.iter().any(|e| e.candidate.m == 512),
        "M=512 candidates must be enumerated"
    );
    assert!(
        serial.report.notes.iter().any(|n| n.contains("SKIPPED") || n.contains("identity")),
        "homogeneous permutation request must be noted: {:?}",
        serial.report.notes
    );
}

#[test]
fn adaptive_m_never_worse_than_fixed_grid_on_zoo_models() {
    for (model, n, batch) in
        [("vgg16", 4usize, 32.0), ("resnet50", 4, 32.0), ("alexnet", 2, 16.0), ("gnmt8", 4, 16.0)]
    {
        let net = zoo::by_name(model).unwrap();
        let cl = presets::v100_cluster(n);
        let prof = analytical::profile(&net, &cl);
        let base = Options { consider_dp: false, ..opts(batch) };
        let fixed = planner::explore(&net, &cl, &prof, &base);
        let adaptive =
            planner::explore(&net, &cl, &prof, &Options { adaptive_m: true, ..base });
        assert!(
            adaptive.epoch_time <= fixed.epoch_time,
            "{model} on {n} V100: adaptive {} worse than fixed {}",
            adaptive.epoch_time,
            fixed.epoch_time
        );
    }

    // A non-power-of-two global mini-batch (4 × 12 = 48) over a sparse
    // grid gives the bisection real work: divisors 3, 4, 6, 12, 16, 24
    // sit untried between the grid points.
    let net = zoo::by_name("vgg16").unwrap();
    let cl = presets::v100_cluster(4);
    let prof = analytical::profile(&net, &cl);
    let base = Options {
        batch_per_device: 12.0,
        samples_per_epoch: 8192,
        m_candidates: vec![2, 8, 48],
        consider_dp: false,
        ..Default::default()
    };
    let fixed = planner::explore(&net, &cl, &prof, &base);
    let adaptive =
        planner::explore(&net, &cl, &prof, &Options { adaptive_m: true, ..base });
    assert!(adaptive.epoch_time <= fixed.epoch_time);
    assert!(
        adaptive.report.evaluations.len() > fixed.report.evaluations.len(),
        "bisection should add candidates between the sparse grid points"
    );
    assert!(
        adaptive.report.notes.iter().any(|n| n.contains("adaptive-M")),
        "refinement rounds must be recorded in the notes: {:?}",
        adaptive.report.notes
    );
}

#[test]
fn emitted_plan_round_trips() {
    let net = zoo::vgg16(224);
    let cl = presets::v100_cluster(4);
    let prof = analytical::profile(&net, &cl);
    let plan = planner::explore(&net, &cl, &prof, &Options { jobs: 2, ..opts(32.0) });

    // emit_json is the CLI `--emit` path: serialize + self-verify.
    let text = plan.emit_json().unwrap();
    assert_eq!(text, plan.to_json().to_string_pretty());
    let back = planner::Plan::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.choice, plan.choice);
    assert_eq!(back.device_order, plan.device_order);
    assert_eq!(back.epoch_time, plan.epoch_time);
    assert_eq!(back.stage_memory, plan.stage_memory);
    assert_eq!(back.report, plan.report);
    // and the serialized form is stable (parse → emit → identical text)
    assert_eq!(back.to_json().to_string_pretty(), text);

    // a DataParallel outcome round-trips too (ResNet-50 on 8 V100)
    let net = zoo::resnet50(224);
    let cl = presets::v100_cluster(8);
    let prof = analytical::profile(&net, &cl);
    let plan = planner::explore(&net, &cl, &prof, &opts(32.0));
    assert_eq!(plan.choice, planner::Choice::DataParallel);
    let back =
        planner::Plan::from_json(&Json::parse(&plan.to_json().to_string_compact()).unwrap())
            .unwrap();
    assert_eq!(back.choice, plan.choice);
    assert_eq!(back.report, plan.report);
}

#[test]
fn prefix_monotone_dp_bit_exact_with_reference() {
    // The PR's oracle guarantee, swept across zoo models × homogeneous
    // and heterogeneous clusters × the micro grid × with/without per-cut
    // communication costs:
    //
    // 1. against the retained seed triple loop (`dp_optimal_reference`)
    //    evaluated over the *same* prefix tables, both the prefix scan
    //    and the monotone crossing search select bit-identical partitions
    //    (provable: identical cost values, identical tie-breaking);
    // 2. across cost backings (`Profile` re-summation vs prefix
    //    differences) the selected partitions attain the same optimal
    //    max-stage cost — summation order may break *exact* ties between
    //    equally-optimal partitions (GNMT's uniform layer chain ties
    //    constantly), so the value, not the bounds, is the invariant.
    let clusters = [
        presets::v100_cluster(4),
        presets::v100_cluster(8),
        presets::fpga_cluster(&["VCU129", "VCU118"]),
        presets::fpga_cluster(&["VCU129", "VCU129", "VCU118", "VCU118"]),
    ];
    for model in ["vgg16", "resnet50", "gnmt8", "alexnet", "gnmt-l64"] {
        let net = zoo::by_name(model).unwrap();
        let cuts = net.legal_cuts();
        for cl in &clusters {
            if cuts.len() + 1 < cl.len() {
                continue; // not enough cut points for this many stages
            }
            let prof = analytical::profile(&net, cl);
            let rc = RangeCost::build(&prof);
            for micro in [1.0f64, 4.0, 32.0] {
                for with_cut_cost in [false, true] {
                    let comm = |stage: usize, cut_layer: usize| -> f64 {
                        let bytes = prof.cut_bytes(cut_layer) as f64 * micro;
                        cl.link(stage).xfer_time(bytes) * 2.0
                    };
                    let cc: Option<&dyn Fn(usize, usize) -> f64> =
                        if with_cut_cost { Some(&comm) } else { None };
                    let ctx = format!(
                        "{model} on {} micro={micro} cut_cost={with_cut_cost}",
                        cl.describe()
                    );

                    let oracle = dp_optimal_reference(&rc, cl, &cuts, micro, cc).unwrap();
                    let prefix = dp_optimal_prefix(&rc, cl, &cuts, micro, cc).unwrap();
                    let fast = dp_optimal_rc(&rc, cl, &cuts, micro, cc).unwrap();
                    assert_eq!(oracle.bounds, prefix.bounds, "prefix vs oracle: {ctx}");
                    assert_eq!(oracle.bounds, fast.bounds, "monotone vs oracle: {ctx}");

                    let seed = dp_optimal_reference(&prof, cl, &cuts, micro, cc).unwrap();
                    let t_of = |p: &bapipe::partition::Partition| {
                        let comm_of = |i: usize| {
                            if with_cut_cost {
                                comm(i, p.bounds[i + 1] - 1)
                            } else {
                                0.0
                            }
                        };
                        max_stage_time(&prof, p, micro, Some(&comm_of))
                    };
                    let (t_seed, t_fast) = (t_of(&seed), t_of(&fast));
                    assert!(
                        (t_seed - t_fast).abs() <= 1e-9 * t_seed.max(t_fast),
                        "optimal value diverged across backings: {t_fast} vs {t_seed} ({ctx})"
                    );
                }
            }
        }
    }
}

#[test]
fn permutation_search_only_improves_heterogeneous_plans() {
    let net = zoo::vgg16(224);
    let cl = presets::fpga_cluster(&["VCU118", "VCU129"]);
    let prof = analytical::profile(&net, &cl);
    let base = Options { consider_dp: false, ..opts(4.0) };
    let identity = planner::explore(&net, &cl, &prof, &base);
    let permuted = planner::explore(
        &net,
        &cl,
        &prof,
        &Options { permute_devices: true, jobs: 4, ..base },
    );
    assert!(
        permuted.epoch_time <= identity.epoch_time,
        "widening the space cannot hurt: {} vs {}",
        permuted.epoch_time,
        identity.epoch_time
    );
    // the chosen order is a permutation of the devices
    let mut order = permuted.device_order.clone();
    order.sort_unstable();
    assert_eq!(order, vec![0, 1]);
    // and the permuted search covered both orderings in its report
    assert!(permuted.report.evaluations.iter().any(|e| e.candidate.perm == 1));
}
