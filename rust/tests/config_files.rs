//! The shipped example configs must parse and resolve.

use bapipe::config::TrainConfig;

#[test]
fn shipped_configs_parse() {
    for path in ["configs/train_lm10m.json", "configs/train_lm100m.json"] {
        let full = format!("{}/{}", env!("CARGO_MANIFEST_DIR"), path);
        let c = TrainConfig::load(&full).unwrap_or_else(|e| panic!("{path}: {e}"));
        assert!(c.schedule_kind().unwrap().is_some());
        assert!(c.steps > 0 && c.m > 0);
        assert!(c.lr > 0.0);
    }
}
