//! End-to-end integration: the real pipeline engine over AOT artifacts.
//!
//! The key invariant: all intra-batch schedules (GPipe, 1F1B-SNO,
//! 1F1B-SO, FBP-AS) are *numerically identical* — same gradients, same
//! updates, same loss sequence — because they only reorder work within a
//! mini-batch. PipeDream (inter-batch, stale weights) may differ.
//!
//! Requires `make artifacts` (skips gracefully when absent).

use bapipe::config::TrainConfig;
use bapipe::pipeline::{dp_engine, training};
use bapipe::runtime::Manifest;
use std::path::PathBuf;

fn artifact_dir() -> Option<String> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/lm1m-s2-b2-jnp");
    d.join("manifest.json")
        .exists()
        .then(|| d.to_str().unwrap().to_string())
}

fn cfg(dir: &str, schedule: &str, m: usize, steps: usize) -> TrainConfig {
    TrainConfig {
        artifacts: dir.to_string(),
        schedule: schedule.into(),
        m,
        steps,
        lr: 3e-3,
        seed: 42,
        branch: 4,
        noise: 0.05,
        log_every: 1,
    }
}

#[test]
fn manifest_crosschecks_zoo() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    Manifest::load(&dir).unwrap().crosscheck_zoo().unwrap();
}

#[test]
fn intra_batch_schedules_numerically_identical() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut curves = Vec::new();
    for schedule in ["gpipe", "1f1b", "1f1b-so", "fbp"] {
        let rep = training::train(&cfg(&dir, schedule, 4, 4)).unwrap();
        curves.push((schedule, rep.curve));
    }
    let (ref_name, ref_curve) = &curves[0];
    for (name, curve) in &curves[1..] {
        assert_eq!(curve.len(), ref_curve.len());
        for ((s1, l1), (s2, l2)) in curve.iter().zip(ref_curve.iter()) {
            assert_eq!(s1, s2);
            assert!(
                (l1 - l2).abs() < 1e-4,
                "{name} diverges from {ref_name} at step {s1}: {l1} vs {l2}"
            );
        }
    }
}

#[test]
fn pipeline_loss_decreases() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rep = training::train(&cfg(&dir, "1f1b", 4, 20)).unwrap();
    assert!(
        rep.final_loss < rep.first_loss - 0.1,
        "loss should fall: {} -> {}",
        rep.first_loss,
        rep.final_loss
    );
    // starts near ln(V)
    let ln_v = (Manifest::load(&dir).unwrap().vocab as f32).ln();
    assert!((rep.first_loss - ln_v).abs() < 1.0, "first {} vs lnV {}", rep.first_loss, ln_v);
    assert!(rep.tokens_per_sec > 0.0);
}

#[test]
fn pipedream_trains_too() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rep = training::train(&cfg(&dir, "pipedream", 4, 10)).unwrap();
    assert!(
        rep.final_loss < rep.first_loss,
        "pipedream loss should still fall: {} -> {}",
        rep.first_loss,
        rep.final_loss
    );
}

#[test]
fn dp_engine_trains_and_matches_start() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let c = cfg(&dir, "dp", 1, 14);
    let rep = dp_engine::train_dp(&c, 2).unwrap();
    assert!(rep.curve.len() >= 2);
    let ln_v = (Manifest::load(&dir).unwrap().vocab as f32).ln();
    assert!((rep.curve[0].1 - ln_v).abs() < 1.0);
    assert!(
        rep.final_loss < rep.curve[0].1 - 0.05,
        "dp loss should fall: {} -> {}",
        rep.curve[0].1,
        rep.final_loss
    );
}

#[test]
fn measured_profile_has_sane_shape() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = bapipe::runtime::Runtime::load(&dir).unwrap();
    let times = training::measure_stage_times(&rt, 3).unwrap();
    assert_eq!(times.len(), 2);
    for (f, b) in &times {
        assert!(*f > 0.0 && *b > 0.0);
        // backward (recompute + grads) costs more than forward
        assert!(b > f, "bwd {b} should exceed fwd {f}");
    }
}
