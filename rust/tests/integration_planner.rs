//! Planner integration: profile → partition → schedule → simulate across
//! models and clusters, checking cross-module invariants end to end
//! (no artifacts needed — runs on the analytical profilers).

use bapipe::cluster::presets;
use bapipe::explorer::{self, build_spec, build_spec_plan, Choice, Options};
use bapipe::model::zoo;
use bapipe::partition::{balanced_partition, stage_costs};
use bapipe::profile::analytical;
use bapipe::schedule::ScheduleKind;
use bapipe::sim::engine::simulate;
use bapipe::sim::timeline;

#[test]
fn every_zoo_model_partitions_on_every_gpu_cluster() {
    for model in ["vgg16", "resnet50", "alexnet", "gnmt8", "gnmt16", "lm10m", "lm100m"] {
        let net = zoo::by_name(model).unwrap();
        for n in [2usize, 4] {
            let cl = presets::v100_cluster(n);
            let prof = analytical::profile(&net, &cl);
            let plan = balanced_partition(&net, &cl, &prof, ScheduleKind::OneFOneBSno, 4.0, 8)
                .unwrap_or_else(|e| panic!("{model} on {n} V100: {e}"));
            assert_eq!(plan.partition.n_stages(), n, "{model}");
            assert_eq!(plan.partition.bounds[0], 0);
            assert_eq!(*plan.partition.bounds.last().unwrap(), net.len());
        }
    }
}

#[test]
fn simulated_makespan_between_bottleneck_and_serial() {
    let net = zoo::vgg16(224);
    let cl = presets::v100_cluster(4);
    let prof = analytical::profile(&net, &cl);
    let m = 16;
    let micro = 8.0;
    let plan =
        balanced_partition(&net, &cl, &prof, ScheduleKind::OneFOneBSo, micro, m).unwrap();
    let costs = stage_costs(&prof, &cl, &plan.partition, micro);
    let bottleneck: f64 = costs.iter().map(|(f, b)| f + b).fold(0.0, f64::max);
    let serial: f64 = costs.iter().map(|(f, b)| f + b).sum::<f64>() * m as f64;
    let spec = build_spec(&prof, &cl, &plan.partition, ScheduleKind::OneFOneBSo, false, micro, m);
    let r = simulate(&spec);
    assert!(r.makespan >= bottleneck * m as f64 - 1e-12, "below bottleneck bound");
    assert!(r.makespan <= serial + 1.0, "above serial bound: {} vs {serial}", r.makespan);
}

#[test]
fn explorer_plan_is_reproducible() {
    let net = zoo::by_name("gnmt8").unwrap();
    let cl = presets::v100_cluster(4);
    let prof = analytical::profile(&net, &cl);
    let opts =
        Options { batch_per_device: 32.0, samples_per_epoch: 10_000, ..Default::default() };
    let a = explorer::explore(&net, &cl, &prof, &opts);
    let b = explorer::explore(&net, &cl, &prof, &opts);
    assert_eq!(format!("{:?}", a.choice), format!("{:?}", b.choice));
    assert_eq!(a.epoch_time, b.epoch_time);
}

#[test]
fn fpga_explorer_prefers_async_and_respects_onchip() {
    let net = zoo::resnet50(224);
    let cl = presets::fpga_cluster(&["VCU129", "VCU129", "VCU118", "VCU118"]);
    let prof = analytical::profile(&net, &cl);
    let mut opts = Options { batch_per_device: 4.0, ..Default::default() };
    opts.consider_dp = false;
    let plan = explorer::explore(&net, &cl, &prof, &opts);
    match plan.choice {
        Choice::Pipeline { kind, ref partition, .. } => {
            assert!(matches!(kind, ScheduleKind::OneFOneBAs | ScheduleKind::FbpAs));
            // each stage's weights should be on-chip-resident
            for i in 0..partition.n_stages() {
                let r = partition.stage(i);
                let w = prof.param_bytes(r.start, r.end);
                assert!(
                    (w as f64) < 0.9 * cl.devices[i].onchip_capacity as f64,
                    "stage {i} weights {w} vs on-chip {}",
                    cl.devices[i].onchip_capacity
                );
            }
        }
        Choice::DataParallel => panic!("expected a pipeline plan"),
    }
}

#[test]
fn timeline_render_is_consistent() {
    let net = zoo::vgg16(224);
    let cl = presets::v100_cluster(3);
    let prof = analytical::profile(&net, &cl);
    let plan =
        balanced_partition(&net, &cl, &prof, ScheduleKind::OneFOneBSno, 4.0, 8).unwrap();
    let spec = build_spec_plan(&prof, &cl, &plan, ScheduleKind::OneFOneBSno, false, 4.0, 8);
    let r = simulate(&spec);
    let s = timeline::render(&r, 3, 100);
    assert_eq!(s.lines().count(), 3);
    assert!(s.contains('U') && s.contains("B1"));
}

#[test]
fn heterogeneous_fractional_feeds_simulator() {
    let net = zoo::vgg16(224);
    let cl = presets::fpga_cluster(&["VCU129", "VCU118"]);
    let prof = analytical::profile(&net, &cl);
    let plan = balanced_partition(&net, &cl, &prof, ScheduleKind::FbpAs, 1.0, 32).unwrap();
    let spec_plain = build_spec(&prof, &cl, &plan.partition, ScheduleKind::FbpAs, false, 1.0, 32);
    let spec_frac = build_spec_plan(&prof, &cl, &plan, ScheduleKind::FbpAs, false, 1.0, 32);
    let t_plain = simulate(&spec_plain).makespan;
    let t_frac = simulate(&spec_frac).makespan;
    // fractional rebalancing can only help (or tie) the bottleneck
    assert!(t_frac <= t_plain * 1.001, "frac {t_frac} vs plain {t_plain}");
}

#[test]
fn memory_feasibility_monotone_in_model_size() {
    // if GNMT-L(l) fits, every smaller size fits too (under BaPipe 1F1B-SNO)
    let cl = presets::v100_cluster(4);
    let fit = |l: u64| {
        let net = zoo::gnmt_l(l);
        let prof = analytical::profile(&net, &cl);
        balanced_partition(&net, &cl, &prof, ScheduleKind::OneFOneBSno, 16.0, 8).is_ok()
    };
    let results: Vec<bool> = [16u64, 64, 128, 256, 400].iter().map(|&l| fit(l)).collect();
    // once it stops fitting it never fits again
    let mut seen_false = false;
    for (i, &ok) in results.iter().enumerate() {
        if !ok {
            seen_false = true;
        }
        assert!(!(seen_false && ok), "non-monotone feasibility at index {i}: {results:?}");
    }
    assert!(results[0], "GNMT-16 must fit on 4 V100s");
}
