//! The pipeline engine: spawns one worker thread per stage, wires the
//! forward/backward channels, and drives training mini-batches.

use super::worker::{Ctl, SendLit, StepReport, Worker, WorkerCfg, WorkerIo};
use crate::runtime::{i32_literal, Manifest};
use crate::schedule::{generators, ScheduleKind};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// Outcome of one training step (mini-batch).
#[derive(Debug, Clone)]
pub struct StepStats {
    /// Mean loss over the mini-batch's micro-batches.
    pub loss: f32,
    /// Wall-clock seconds for the mini-batch.
    pub secs: f64,
    /// Per-stage (fwd, bwd, opt, stall) seconds.
    pub per_stage: Vec<(f64, f64, f64, f64)>,
}

/// A running pipeline of worker threads.
pub struct PipelineEngine {
    /// The manifest of the loaded artifacts.
    pub manifest: Manifest,
    /// Schedule being executed.
    pub kind: ScheduleKind,
    /// Micro-batches per mini-batch.
    pub m: usize,
    ctls: Vec<Sender<Ctl>>,
    reports: Receiver<StepReport>,
    handles: Vec<JoinHandle<crate::Result<()>>>,
}

impl PipelineEngine {
    /// Validate the schedule programs, then spawn + initialize the workers
    /// (each compiles its stage on a thread-local PJRT client).
    pub fn launch(
        manifest: Manifest,
        kind: ScheduleKind,
        m: usize,
        lr: f32,
        seed: i32,
    ) -> crate::Result<PipelineEngine> {
        let n = manifest.n_stages;
        anyhow::ensure!(n >= 2, "pipeline needs ≥ 2 stages");
        for i in 0..n {
            let p = generators::program(kind, n, i, m);
            generators::validate(&p, m, kind.intra_batch())
                .map_err(|e| anyhow::anyhow!("invalid program for stage {i}: {e}"))?;
        }

        // channels: fwd i→i+1, bwd i+1→i
        let mut fwd_txs: Vec<Option<Sender<SendLit>>> = Vec::new();
        let mut fwd_rxs: Vec<Option<Receiver<SendLit>>> = vec![None];
        for _ in 0..n - 1 {
            let (tx, rx) = channel();
            fwd_txs.push(Some(tx));
            fwd_rxs.push(Some(rx));
        }
        fwd_txs.push(None);
        let mut bwd_txs: Vec<Option<Sender<SendLit>>> = vec![None];
        let mut bwd_rxs: Vec<Option<Receiver<SendLit>>> = Vec::new();
        for _ in 0..n - 1 {
            let (tx, rx) = channel();
            bwd_txs.push(Some(tx));
            bwd_rxs.push(Some(rx));
        }
        bwd_rxs.push(None);

        let (rep_tx, rep_rx) = channel();
        let mut ctls = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        // init-status channel so launch() fails fast on a bad artifact
        let (ready_tx, ready_rx) = channel::<Result<usize, String>>();

        for i in 0..n {
            let (ctl_tx, ctl_rx) = channel();
            ctls.push(ctl_tx);
            let io = WorkerIo {
                ctl: ctl_rx,
                fwd_in: fwd_rxs[i].take(),
                fwd_out: fwd_txs[i].take(),
                bwd_in: bwd_rxs[i].take(),
                bwd_out: bwd_txs[i].take(),
                report: rep_tx.clone(),
            };
            let man = manifest.clone();
            let ready = ready_tx.clone();
            let cfg = WorkerCfg {
                stage: i,
                n_stages: n,
                kind,
                m,
                lr,
                seed: seed.wrapping_add(i as i32),
            };
            handles.push(std::thread::spawn(move || -> crate::Result<()> {
                match Worker::new(&man, cfg) {
                    Ok(w) => {
                        ready.send(Ok(i)).ok();
                        w.run(io)
                    }
                    Err(e) => {
                        ready.send(Err(format!("stage {i}: {e}"))).ok();
                        Err(e)
                    }
                }
            }));
        }
        drop(ready_tx);
        for _ in 0..n {
            match ready_rx.recv() {
                Ok(Ok(_)) => {}
                Ok(Err(msg)) => anyhow::bail!("worker init failed: {msg}"),
                Err(_) => anyhow::bail!("worker died during init"),
            }
        }
        Ok(PipelineEngine { manifest, kind, m, ctls, reports: rep_rx, handles })
    }

    /// Run one mini-batch: `inputs`/`targets` are per-micro-batch token
    /// slices of length `micro_batch × seq` each.
    pub fn step(&self, inputs: &[Vec<i32>], targets: &[Vec<i32>]) -> crate::Result<StepStats> {
        anyhow::ensure!(inputs.len() == self.m && targets.len() == self.m);
        let man = &self.manifest;
        let shape = [man.micro_batch, man.seq];
        let in_lits: Vec<SendLit> = inputs
            .iter()
            .map(|v| i32_literal(v, &shape).map(SendLit))
            .collect::<crate::Result<_>>()?;
        let tgt_lits: Vec<SendLit> = targets
            .iter()
            .map(|v| i32_literal(v, &shape).map(SendLit))
            .collect::<crate::Result<_>>()?;

        let t0 = std::time::Instant::now();
        let n = self.ctls.len();
        // Move (not clone) the literals into the owning workers — §Perf:
        // avoids 2·M deep copies per step on the feed path.
        let mut in_lits = Some(in_lits);
        let mut tgt_lits = Some(tgt_lits);
        for (i, ctl) in self.ctls.iter().enumerate() {
            let msg = Ctl::Run {
                inputs: (i == 0).then(|| in_lits.take().expect("inputs consumed once")),
                targets: (i == n - 1).then(|| tgt_lits.take().expect("targets consumed once")),
            };
            ctl.send(msg).map_err(|_| anyhow::anyhow!("worker {i} gone"))?;
        }
        let mut per_stage = vec![(0.0, 0.0, 0.0, 0.0); n];
        let mut loss = 0.0f32;
        for _ in 0..n {
            let rep = self
                .reports
                .recv()
                .map_err(|_| anyhow::anyhow!("a worker died mid-step"))?;
            per_stage[rep.stage] = (rep.fwd_secs, rep.bwd_secs, rep.opt_secs, rep.stall_secs);
            if !rep.losses.is_empty() {
                loss = rep.losses.iter().sum::<f32>() / rep.losses.len() as f32;
            }
        }
        Ok(StepStats { loss, secs: t0.elapsed().as_secs_f64(), per_stage })
    }

    /// Stop all workers and join.
    pub fn shutdown(self) -> crate::Result<()> {
        for ctl in &self.ctls {
            ctl.send(Ctl::Stop).ok();
        }
        for h in self.handles {
            match h.join() {
                Ok(r) => r?,
                Err(_) => anyhow::bail!("worker panicked"),
            }
        }
        Ok(())
    }
}
