//! The **real** pipeline training engine: one OS thread per stage, each
//! owning its compiled XLA stage programs and parameter state; `mpsc`
//! channels carry activations forward and gradients backward; the static
//! op sequences from `schedule::generators` drive every worker — the same
//! source of truth the simulator executes.
//!
//! * [`engine`] — builds the worker topology and runs training steps.
//! * [`worker`] — the per-stage thread: op interpreter + state.
//! * [`training`] — high-level loop with data generation, loss logging,
//!   throughput metrics, and the measured profiler.
//! * [`dp_engine`] — data-parallel baseline: every worker runs the whole
//!   model and ring-all-reduces gradients (over `collective::ring`).

pub mod dp_engine;
pub mod engine;
pub mod training;
pub mod worker;

pub use engine::PipelineEngine;
pub use training::{train, TrainReport};
