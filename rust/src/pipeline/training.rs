//! High-level training loop over the real engine: data generation, loss
//! logging, throughput metrics, and the measured profiler that feeds the
//! planner (the paper's "short profiling run").

use super::engine::PipelineEngine;
use crate::config::TrainConfig;
use crate::data::MarkovCorpus;
use crate::metrics::Metrics;
use crate::runtime::{Manifest, Runtime};
use crate::util::logging;

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// (step, mean loss) curve at `log_every` granularity.
    pub curve: Vec<(usize, f32)>,
    /// First logged loss.
    pub first_loss: f32,
    /// Final logged loss.
    pub final_loss: f32,
    /// Theoretical corpus entropy floor (nats).
    pub entropy_floor: f64,
    /// Tokens processed per second (end to end).
    pub tokens_per_sec: f64,
    /// Total wall-clock seconds.
    pub total_secs: f64,
    /// Mean per-stage (fwd, bwd, opt, stall) seconds per step.
    pub per_stage_means: Vec<(f64, f64, f64, f64)>,
}

impl TrainReport {
    /// Render the loss curve as text (one line per log point).
    pub fn render_curve(&self) -> String {
        let mut s = String::new();
        for (step, loss) in &self.curve {
            s.push_str(&format!("step {step:>5}  loss {loss:.4}\n"));
        }
        s.push_str(&format!("entropy floor ≈ {:.4}\n", self.entropy_floor));
        s
    }
}

/// Train with the pipeline engine per `cfg`. `manifest_dir` overrides
/// `cfg.artifacts` when given (examples pass CLI paths through).
pub fn train(cfg: &TrainConfig) -> crate::Result<TrainReport> {
    let kind = cfg
        .schedule_kind()?
        .ok_or_else(|| anyhow::anyhow!("use dp_engine::train_dp for schedule=dp"))?;
    let manifest = Manifest::load(&cfg.artifacts)?;
    manifest.crosscheck_zoo()?;
    let micro = manifest.micro_batch;
    let seq = manifest.seq;
    logging::info(&format!(
        "training {} ({} params) with {} M={} micro={} on {} stages",
        manifest.model,
        crate::util::fmt_params(manifest.total_params() as u64),
        kind.label(),
        cfg.m,
        micro,
        manifest.n_stages
    ));
    let engine = PipelineEngine::launch(manifest, kind, cfg.m, cfg.lr, cfg.seed as i32)?;
    let mut corpus = MarkovCorpus::new(engine.manifest.vocab, cfg.branch, cfg.noise, cfg.seed);
    let metrics = Metrics::new();

    let mut curve = Vec::new();
    let mut per_stage_sums = vec![(0.0, 0.0, 0.0, 0.0); engine.manifest.n_stages];
    let t0 = std::time::Instant::now();
    let mut window: Vec<f32> = Vec::new();
    for step in 0..cfg.steps {
        let mut inputs = Vec::with_capacity(cfg.m);
        let mut targets = Vec::with_capacity(cfg.m);
        for _ in 0..cfg.m {
            let (x, y) = corpus.batch(micro, seq);
            inputs.push(x);
            targets.push(y);
        }
        let stats = engine.step(&inputs, &targets)?;
        metrics.observe("minibatch_secs", stats.secs);
        window.push(stats.loss);
        for (s, p) in per_stage_sums.iter_mut().zip(&stats.per_stage) {
            s.0 += p.0;
            s.1 += p.1;
            s.2 += p.2;
            s.3 += p.3;
        }
        if (step + 1) % cfg.log_every == 0 || step + 1 == cfg.steps {
            let mean = window.iter().sum::<f32>() / window.len() as f32;
            window.clear();
            curve.push((step + 1, mean));
            logging::info(&format!("step {:>5}  loss {mean:.4}", step + 1));
        }
    }
    let total_secs = t0.elapsed().as_secs_f64();
    engine.shutdown()?;

    let tokens = cfg.steps * cfg.m * micro * seq;
    let steps = cfg.steps.max(1) as f64;
    Ok(TrainReport {
        first_loss: curve.first().map(|c| c.1).unwrap_or(f32::NAN),
        final_loss: curve.last().map(|c| c.1).unwrap_or(f32::NAN),
        entropy_floor: corpus.entropy_floor(),
        tokens_per_sec: tokens as f64 / total_secs,
        total_secs,
        per_stage_means: per_stage_sums
            .into_iter()
            .map(|(f, b, o, s)| (f / steps, b / steps, o / steps, s / steps))
            .collect(),
        curve,
    })
}

/// Measured profiler: time each stage's fwd/bwd once on the real
/// executables (median of `reps`), producing the per-stage costs the
/// planner consumes — the paper's measured-profile path at small scale.
pub fn measure_stage_times(rt: &Runtime, reps: usize) -> crate::Result<Vec<(f64, f64)>> {
    let man = &rt.manifest;
    let mut out = Vec::with_capacity(rt.stages.len());
    let toks = vec![0i32; man.micro_batch * man.seq];
    let tok_lit = crate::runtime::i32_literal(&toks, &[man.micro_batch, man.seq])?;
    let act = crate::runtime::f32_literal(&man.act_shape(), 0.01)?;
    for st in &rt.stages {
        let params = st.init(7)?;
        let acc = st.zero_acc()?;
        let x = if st.meta.kind == "first" { &tok_lit } else { &act };
        let tgt = (st.meta.kind == "last").then_some(&tok_lit);
        let gy_or_t: &xla::Literal = if st.meta.kind == "last" { &tok_lit } else { &act };
        let mut fs = Vec::new();
        let mut bs = Vec::new();
        for _ in 0..reps.max(1) {
            let t0 = std::time::Instant::now();
            let _ = st.fwd(&params, x, tgt)?;
            fs.push(t0.elapsed().as_secs_f64());
            let t0 = std::time::Instant::now();
            let _ = st.bwd(&params, &acc, x, gy_or_t)?;
            bs.push(t0.elapsed().as_secs_f64());
        }
        fs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        bs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.push((fs[fs.len() / 2], bs[bs.len() / 2]));
    }
    Ok(out)
}
