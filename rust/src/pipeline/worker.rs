//! The per-stage worker thread: interprets its static `StageProgram`
//! against the compiled XLA stage, owning parameters / Adam state /
//! gradient accumulators / the per-micro-batch input stash.

use crate::runtime::{Manifest, StageExe};
use crate::schedule::{generators, Op, ScheduleKind};
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use xla::Literal;

/// Send-safe wrapper for moving a Literal between threads.
///
/// Safety: `xla::Literal` exclusively owns a heap-allocated C++
/// `xla::Literal` (no `Rc`, no thread-local state); transferring it
/// through a channel transfers unique ownership, so no aliasing occurs.
pub struct SendLit(pub Literal);
unsafe impl Send for SendLit {}

/// Per-mini-batch command to a worker.
pub enum Ctl {
    /// Run one mini-batch: stage 0 receives the micro-batch inputs, the
    /// last stage receives the per-micro-batch targets.
    Run {
        /// Micro-batch inputs (stage 0 only).
        inputs: Option<Vec<SendLit>>,
        /// Micro-batch targets (last stage only).
        targets: Option<Vec<SendLit>>,
    },
    /// Shut down.
    Stop,
}

/// What a worker reports after each mini-batch.
#[derive(Debug)]
pub struct StepReport {
    /// Stage index.
    pub stage: usize,
    /// Per-micro-batch losses (last stage only).
    pub losses: Vec<f32>,
    /// Seconds in fwd ops.
    pub fwd_secs: f64,
    /// Seconds in bwd ops.
    pub bwd_secs: f64,
    /// Seconds in the optimizer.
    pub opt_secs: f64,
    /// Seconds blocked on channel receives (pipeline stall time).
    pub stall_secs: f64,
}

/// Static configuration of one worker.
pub struct WorkerCfg {
    /// Stage index.
    pub stage: usize,
    /// Total stages.
    pub n_stages: usize,
    /// Schedule.
    pub kind: ScheduleKind,
    /// Micro-batches per mini-batch.
    pub m: usize,
    /// Learning rate.
    pub lr: f32,
    /// Init seed (stage-unique).
    pub seed: i32,
}

/// Channel endpoints of one worker.
pub struct WorkerIo {
    /// Control from the engine.
    pub ctl: Receiver<Ctl>,
    /// Activations from the previous stage (None for stage 0).
    pub fwd_in: Option<Receiver<SendLit>>,
    /// Activations to the next stage (None for the last stage).
    pub fwd_out: Option<Sender<SendLit>>,
    /// Gradients from the next stage (None for the last stage).
    pub bwd_in: Option<Receiver<SendLit>>,
    /// Gradients to the previous stage (None for stage 0).
    pub bwd_out: Option<Sender<SendLit>>,
    /// Per-mini-batch report to the engine.
    pub report: Sender<StepReport>,
}

/// Worker state + main loop. Constructed **inside** its thread (the
/// PJRT client is thread-local).
pub struct Worker {
    cfg: WorkerCfg,
    exe: StageExe,
    params: Vec<Literal>,
    acc: Vec<Literal>,
    m_state: Vec<Literal>,
    v_state: Vec<Literal>,
    step: f32,
    /// PipeDream weight stashing: version used for each in-flight mb.
    stashed_weights: HashMap<usize, Vec<Literal>>,
}

impl Worker {
    /// Compile the stage on a fresh thread-local client and init state.
    pub fn new(manifest: &Manifest, cfg: WorkerCfg) -> crate::Result<Worker> {
        let client = xla::PjRtClient::cpu()?;
        let exe = StageExe::load(&client, manifest, cfg.stage)?;
        let params = exe.init(cfg.seed)?;
        let acc = exe.zero_acc()?;
        let m_state = exe.zero_acc()?;
        let v_state = exe.zero_acc()?;
        Ok(Worker {
            cfg,
            exe,
            params,
            acc,
            m_state,
            v_state,
            step: 0.0,
            stashed_weights: HashMap::new(),
        })
    }

    /// Run mini-batches until `Ctl::Stop`.
    pub fn run(mut self, io: WorkerIo) -> crate::Result<()> {
        let program = generators::program(
            self.cfg.kind,
            self.cfg.n_stages,
            self.cfg.stage,
            self.cfg.m,
        );
        loop {
            match io.ctl.recv() {
                Ok(Ctl::Run { inputs, targets }) => {
                    let rep = self.run_minibatch(&program.ops, inputs, targets, &io)?;
                    io.report.send(rep).ok();
                }
                Ok(Ctl::Stop) | Err(_) => return Ok(()),
            }
        }
    }

    fn is_last(&self) -> bool {
        self.cfg.stage + 1 == self.cfg.n_stages
    }

    fn run_minibatch(
        &mut self,
        ops: &[Op],
        inputs: Option<Vec<SendLit>>,
        targets: Option<Vec<SendLit>>,
        io: &WorkerIo,
    ) -> crate::Result<StepReport> {
        let mut inputs: Vec<Option<Literal>> = match inputs {
            Some(v) => v.into_iter().map(|l| Some(l.0)).collect(),
            None => Vec::new(),
        };
        let targets: Vec<Option<Literal>> = match targets {
            Some(v) => v.into_iter().map(|l| Some(l.0)).collect(),
            None => Vec::new(),
        };
        let mut stash: HashMap<usize, Literal> = HashMap::new();
        let mut rep = StepReport {
            stage: self.cfg.stage,
            losses: vec![0.0; if self.is_last() { self.cfg.m } else { 0 }],
            fwd_secs: 0.0,
            bwd_secs: 0.0,
            opt_secs: 0.0,
            stall_secs: 0.0,
        };
        let pipedream = self.cfg.kind == ScheduleKind::PipeDream;

        for op in ops {
            match *op {
                Op::Fwd { mb } => self.do_fwd(mb, &mut inputs, &targets, &mut stash, io, &mut rep, pipedream)?,
                Op::Bwd { mb } => self.do_bwd(mb, &targets, &mut stash, io, &mut rep, pipedream)?,
                Op::FwdBwd { fwd_mb, bwd_mb } => {
                    // FBP-AS: forward and backward of the slot share the
                    // accelerator; on the CPU engine they run back-to-back
                    // (semantically equivalent; the DES models the timing).
                    self.do_fwd(fwd_mb, &mut inputs, &targets, &mut stash, io, &mut rep, pipedream)?;
                    self.do_bwd(bwd_mb, &targets, &mut stash, io, &mut rep, pipedream)?;
                }
                Op::Update => {
                    let t0 = std::time::Instant::now();
                    self.apply_update(1.0 / self.cfg.m as f32)?;
                    rep.opt_secs += t0.elapsed().as_secs_f64();
                }
            }
        }
        Ok(rep)
    }

    #[allow(clippy::too_many_arguments)]
    fn do_fwd(
        &mut self,
        mb: usize,
        inputs: &mut Vec<Option<Literal>>,
        targets: &[Option<Literal>],
        stash: &mut HashMap<usize, Literal>,
        io: &WorkerIo,
        rep: &mut StepReport,
        pipedream: bool,
    ) -> crate::Result<()> {
        // obtain input
        let x = if self.cfg.stage == 0 {
            inputs
                .get_mut(mb)
                .and_then(|o| o.take())
                .ok_or_else(|| anyhow::anyhow!("stage 0 missing input mb {mb}"))?
        } else {
            let t0 = std::time::Instant::now();
            let r = io
                .fwd_in
                .as_ref()
                .expect("non-first stage has fwd_in")
                .recv()
                .map_err(|_| anyhow::anyhow!("fwd channel closed"))?;
            rep.stall_secs += t0.elapsed().as_secs_f64();
            r.0
        };
        if pipedream {
            // weight stashing: remember the version used for this fwd
            self.stashed_weights.insert(mb, self.params.clone());
        }
        let t0 = std::time::Instant::now();
        let tgt = if self.is_last() {
            Some(
                targets[mb]
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("last stage missing targets mb {mb}"))?,
            )
        } else {
            None
        };
        let y = self.exe.fwd(&self.params, &x, tgt)?;
        rep.fwd_secs += t0.elapsed().as_secs_f64();
        stash.insert(mb, x);
        if self.is_last() {
            rep.losses[mb] = y.to_vec::<f32>()?[0];
        } else {
            io.fwd_out
                .as_ref()
                .expect("non-last stage has fwd_out")
                .send(SendLit(y))
                .map_err(|_| anyhow::anyhow!("fwd send failed"))?;
        }
        Ok(())
    }

    fn do_bwd(
        &mut self,
        mb: usize,
        targets: &[Option<Literal>],
        stash: &mut HashMap<usize, Literal>,
        io: &WorkerIo,
        rep: &mut StepReport,
        pipedream: bool,
    ) -> crate::Result<()> {
        let gy: Literal = if self.is_last() {
            targets[mb]
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("last stage missing targets mb {mb}"))?
                .clone()
        } else {
            let t0 = std::time::Instant::now();
            let r = io
                .bwd_in
                .as_ref()
                .expect("non-last stage has bwd_in")
                .recv()
                .map_err(|_| anyhow::anyhow!("bwd channel closed"))?;
            rep.stall_secs += t0.elapsed().as_secs_f64();
            r.0
        };
        let x = stash
            .remove(&mb)
            .ok_or_else(|| anyhow::anyhow!("bwd {mb} before fwd at stage {}", self.cfg.stage))?;
        let t0 = std::time::Instant::now();
        // PipeDream: backward runs on the stashed weight version.
        let params_for_bwd: &[Literal] = if pipedream {
            self.stashed_weights.get(&mb).map(|v| v.as_slice()).unwrap_or(&self.params)
        } else {
            &self.params
        };
        let (acc, gx) = self.exe.bwd(params_for_bwd, &self.acc, &x, &gy)?;
        self.acc = acc;
        rep.bwd_secs += t0.elapsed().as_secs_f64();
        if let (Some(gx), Some(tx)) = (gx, io.bwd_out.as_ref()) {
            tx.send(SendLit(gx)).map_err(|_| anyhow::anyhow!("bwd send failed"))?;
        }
        if pipedream {
            self.stashed_weights.remove(&mb);
            // inter-batch semantics: update immediately after each backward
            let t0 = std::time::Instant::now();
            self.apply_update(1.0)?;
            rep.opt_secs += t0.elapsed().as_secs_f64();
        }
        Ok(())
    }

    fn apply_update(&mut self, grad_scale: f32) -> crate::Result<()> {
        self.step += 1.0;
        let (p, m, v) = self.exe.opt(
            &self.params,
            &self.acc,
            &self.m_state,
            &self.v_state,
            self.step,
            self.cfg.lr,
            grad_scale,
        )?;
        self.params = p;
        self.m_state = m;
        self.v_state = v;
        self.acc = self.exe.zero_acc()?;
        Ok(())
    }
}
