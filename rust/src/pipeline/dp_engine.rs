//! Data-parallel baseline engine: `n` worker threads each run the **whole**
//! model (all stage executables chained) on their own shard of the batch,
//! then ring-all-reduce gradients (`collective::ring`) and step Adam —
//! the paper's synchronized All-Reduce DP baseline, for real.

use crate::collective::ring::{make_ring, ring_allreduce, RingNode};
use crate::config::TrainConfig;
use crate::data::MarkovCorpus;
use crate::runtime::{i32_literal, Manifest, StageExe};
use crate::util::logging;
use xla::Literal;

/// Report from a DP run (mirrors `TrainReport`'s core fields).
#[derive(Debug, Clone)]
pub struct DpReport {
    /// (step, mean loss) curve.
    pub curve: Vec<(usize, f32)>,
    /// Final loss.
    pub final_loss: f32,
    /// Tokens/s across all replicas.
    pub tokens_per_sec: f64,
    /// Total seconds.
    pub total_secs: f64,
}

struct Replica {
    stages: Vec<StageExe>,
    params: Vec<Vec<Literal>>,
    m: Vec<Vec<Literal>>,
    v: Vec<Vec<Literal>>,
    step: f32,
}

impl Replica {
    fn new(man: &Manifest, seed: i32) -> crate::Result<Replica> {
        let client = xla::PjRtClient::cpu()?;
        let stages = (0..man.n_stages)
            .map(|i| StageExe::load(&client, man, i))
            .collect::<crate::Result<Vec<_>>>()?;
        // all replicas share the same init seed → identical start weights
        let params = stages.iter().map(|s| s.init(seed)).collect::<crate::Result<Vec<_>>>()?;
        let m = stages.iter().map(|s| s.zero_acc()).collect::<crate::Result<Vec<_>>>()?;
        let v = stages.iter().map(|s| s.zero_acc()).collect::<crate::Result<Vec<_>>>()?;
        Ok(Replica { stages, params, m, v, step: 0.0 })
    }

    /// One local fwd+bwd on a batch; returns (loss, grads per stage).
    fn grad_step(&self, x: &Literal, t: &Literal) -> crate::Result<(f32, Vec<Vec<Literal>>)> {
        let n = self.stages.len();
        // forward chain, stashing stage inputs
        let mut xs: Vec<Literal> = Vec::with_capacity(n);
        let mut cur = x.clone();
        for (i, st) in self.stages.iter().enumerate() {
            xs.push(cur.clone());
            if i + 1 == n {
                break;
            }
            cur = st.fwd(&self.params[i], &cur, None)?;
        }
        let loss = self.stages[n - 1].fwd(&self.params[n - 1], &xs[n - 1], Some(t))?;
        let loss = loss.to_vec::<f32>()?[0];
        // backward chain
        let mut grads: Vec<Vec<Literal>> = vec![Vec::new(); n];
        let acc = self.stages[n - 1].zero_acc()?;
        let (g, gx) = self.stages[n - 1].bwd(&self.params[n - 1], &acc, &xs[n - 1], t)?;
        grads[n - 1] = g;
        let mut gx = gx;
        for i in (0..n - 1).rev() {
            let acc = self.stages[i].zero_acc()?;
            let gy = gx.take().expect("mid stages receive gx");
            let (g, next_gx) = self.stages[i].bwd(&self.params[i], &acc, &xs[i], &gy)?;
            grads[i] = g;
            gx = next_gx;
        }
        Ok((loss, grads))
    }

    /// All-reduce grads across the ring, then Adam with 1/n scaling.
    fn allreduce_and_update(
        &mut self,
        node: &RingNode,
        grads: Vec<Vec<Literal>>,
        lr: f32,
    ) -> crate::Result<()> {
        self.step += 1.0;
        for (i, stage_grads) in grads.into_iter().enumerate() {
            // flatten stage grads into one buffer for the collective
            let sizes: Vec<usize> = stage_grads.iter().map(|g| g.element_count()).collect();
            let mut flat: Vec<f32> = Vec::with_capacity(sizes.iter().sum());
            for g in &stage_grads {
                flat.extend(g.to_vec::<f32>()?);
            }
            ring_allreduce(node, &mut flat);
            // rebuild literals
            let mut reduced = Vec::with_capacity(stage_grads.len());
            let mut off = 0;
            for (g, &sz) in stage_grads.iter().zip(&sizes) {
                let shape: Vec<usize> = g
                    .array_shape()?
                    .dims()
                    .iter()
                    .map(|&d| d as usize)
                    .collect();
                let lit = xla::Literal::vec1(&flat[off..off + sz]);
                let lit = if shape.is_empty() {
                    lit
                } else {
                    lit.reshape(&shape.iter().map(|&d| d as i64).collect::<Vec<i64>>())?
                };
                reduced.push(lit);
                off += sz;
            }
            let (p, m, v) = self.stages[i].opt(
                &self.params[i],
                &reduced,
                &self.m[i],
                &self.v[i],
                self.step,
                lr,
                1.0 / node.n as f32,
            )?;
            self.params[i] = p;
            self.m[i] = m;
            self.v[i] = v;
        }
        Ok(())
    }
}

/// Train with `n_replicas`-way data parallelism (the DP baseline).
pub fn train_dp(cfg: &TrainConfig, n_replicas: usize) -> crate::Result<DpReport> {
    anyhow::ensure!(n_replicas >= 1);
    let man = Manifest::load(&cfg.artifacts)?;
    let micro = man.micro_batch;
    let seq = man.seq;
    logging::info(&format!(
        "DP training {} on {n_replicas} replicas, per-replica batch {micro}",
        man.model
    ));
    let nodes = make_ring(n_replicas);
    let steps = cfg.steps;
    let lr = cfg.lr;
    let log_every = cfg.log_every;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = nodes
        .into_iter()
        .map(|node| {
            let man = man.clone();
            let seed = cfg.seed;
            let branch = cfg.branch;
            let noise = cfg.noise;
            std::thread::spawn(move || -> crate::Result<Vec<(usize, f32)>> {
                let mut rep = Replica::new(&man, seed as i32)?;
                // per-replica data shard: distinct stream seed
                let mut corpus =
                    MarkovCorpus::new(man.vocab, branch, noise, seed ^ (node.rank as u64 + 1) << 17);
                let mut curve = Vec::new();
                let mut window = Vec::new();
                for step in 0..steps {
                    let (x, t) = corpus.batch(micro, seq);
                    let x = i32_literal(&x, &[micro, seq])?;
                    let t = i32_literal(&t, &[micro, seq])?;
                    let (loss, grads) = rep.grad_step(&x, &t)?;
                    rep.allreduce_and_update(&node, grads, lr)?;
                    window.push(loss);
                    if (step + 1) % log_every == 0 || step + 1 == steps {
                        let mean = window.iter().sum::<f32>() / window.len() as f32;
                        window.clear();
                        if node.rank == 0 {
                            logging::info(&format!("dp step {:>5}  loss {mean:.4}", step + 1));
                        }
                        curve.push((step + 1, mean));
                    }
                }
                Ok(curve)
            })
        })
        .collect();
    let mut curves = Vec::new();
    for h in handles {
        curves.push(h.join().map_err(|_| anyhow::anyhow!("replica panicked"))??);
    }
    let total_secs = t0.elapsed().as_secs_f64();
    let curve = curves.swap_remove(0);
    let tokens = steps * n_replicas * micro * seq;
    Ok(DpReport {
        final_loss: curve.last().map(|c| c.1).unwrap_or(f32::NAN),
        curve,
        tokens_per_sec: tokens as f64 / total_secs,
        total_secs,
    })
}
