//! Measured profiler: builds a [`Profile`] from caller-supplied timing
//! callbacks — the paper's "short profiling run" (Section 3.1), pointed at
//! real per-stage HLO executables by the runtime layer (see
//! `pipeline::training`, which wires `runtime::StageExe` timings here).
//!
//! Kept callback-based so the profile module stays independent of the XLA
//! runtime (and trivially testable).
//!
//! Real timers misbehave: clock slews produce negative deltas, a crashed
//! rep can report 0 or NaN. A negative per-layer cost would quietly trip
//! `profile::range`'s monotone-DP fallback (noted there) and a NaN would
//! poison every downstream DP, so [`profile_with_notes`] clamps any
//! non-positive or non-finite median to the 1e-12 s floor **and says
//! so** — one note per affected sample, surfaced to the caller and to
//! the log, never a silent `.max()`.

use super::{LayerCost, Profile};
use crate::cluster::Cluster;
use crate::model::Network;

/// Clamp a measured median to the positive-time floor. Returns the
/// usable value and whether a clamp happened (non-finite, zero or
/// negative input — none of which is a time).
fn clamp_time(v: f64) -> (f64, bool) {
    if v.is_finite() && v > 0.0 {
        (v.max(1e-12), false)
    } else {
        (1e-12, true)
    }
}

/// Measure per-layer times with `time_fn(device_idx, layer_idx) ->
/// (fwd_secs, bwd_secs)` (per sample), repeated `reps` times taking the
/// median — mirroring the paper's 1000-mini-batch averaging at small
/// scale. Non-positive / non-finite medians are clamped to 1e-12 s with
/// one warning note each (the second element); a clean run returns no
/// notes.
pub fn profile_with_notes(
    net: &Network,
    cluster: &Cluster,
    dtype_bytes: u64,
    reps: usize,
    mut time_fn: impl FnMut(usize, usize) -> (f64, f64),
) -> (Profile, Vec<String>) {
    assert!(reps >= 1);
    let mut notes = Vec::new();
    let mut per_device = Vec::with_capacity(cluster.len());
    for d in 0..cluster.len() {
        let mut layers = Vec::with_capacity(net.len());
        for (i, l) in net.layers.iter().enumerate() {
            let mut fs = Vec::with_capacity(reps);
            let mut bs = Vec::with_capacity(reps);
            for _ in 0..reps {
                let (f, b) = time_fn(d, i);
                fs.push(f);
                bs.push(b);
            }
            fs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            bs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            let mut pick = |what: &str, sorted: &[f64]| {
                let median = sorted[sorted.len() / 2];
                let (v, clamped) = clamp_time(median);
                if clamped {
                    notes.push(format!(
                        "measured profile: device {d} layer {i} {what} median {median:.3e} is \
                         not a positive time — clamped to 1e-12s"
                    ));
                }
                v
            };
            let fwd = pick("fwd", &fs);
            let bwd = pick("bwd", &bs);
            layers.push(LayerCost {
                fwd,
                bwd,
                fwd_fixed: 0.0, // measured times already include weight traffic
                bwd_fixed: 0.0,
                params: l.params,
                act_in_elems: net.act_in(i),
                act_out_elems: l.act_out_elems,
                stash_elems: net.act_in(i), // real engine stashes stage inputs only
                half_sat: 0.0, // measured at the target micro-batch size
            });
        }
        per_device.push(layers);
    }
    (Profile { model: net.name.clone(), dtype_bytes, per_device }, notes)
}

/// [`profile_with_notes`] with the notes routed to the log
/// ([`crate::util::logging::warn`]) — the drop-in signature the runtime
/// layer uses.
pub fn profile_with(
    net: &Network,
    cluster: &Cluster,
    dtype_bytes: u64,
    reps: usize,
    time_fn: impl FnMut(usize, usize) -> (f64, f64),
) -> Profile {
    let (profile, notes) = profile_with_notes(net, cluster, dtype_bytes, reps, time_fn);
    for n in &notes {
        crate::util::logging::warn(n);
    }
    profile
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;

    #[test]
    fn median_filters_outliers() {
        let net = zoo::mlp(&[16, 16, 16]);
        let cl = presets::cpu_cluster(1);
        let mut call = 0usize;
        let p = profile_with(&net, &cl, 4, 5, |_, _| {
            call += 1;
            // every 5th call is a huge outlier
            if call % 5 == 0 {
                (1.0, 1.0)
            } else {
                (1e-4, 2e-4)
            }
        });
        assert!((p.per_device[0][0].fwd - 1e-4).abs() < 1e-9);
        assert!((p.per_device[0][1].bwd - 2e-4).abs() < 1e-9);
    }

    #[test]
    fn device_layer_shape() {
        let net = zoo::mlp(&[8, 8, 8, 8]);
        let cl = presets::cpu_cluster(3);
        let p = profile_with(&net, &cl, 4, 1, |d, l| ((d + 1) as f64 * 1e-5, l as f64 * 1e-5 + 1e-6));
        assert_eq!(p.n_devices(), 3);
        assert_eq!(p.n_layers(), 3);
        // device index reflected in times
        assert!(p.per_device[2][0].fwd > p.per_device[0][0].fwd);
        p.validate(&cl).unwrap();
    }

    #[test]
    fn negative_and_nan_medians_clamp_with_a_note() {
        let net = zoo::mlp(&[8, 8, 8]);
        let cl = presets::cpu_cluster(1);
        // layer 0: clock slew gives a negative fwd median; layer 1: a
        // crashed rep reports NaN bwd
        let (p, notes) = profile_with_notes(&net, &cl, 4, 1, |_, l| match l {
            0 => (-3e-5, 1e-4),
            _ => (1e-4, f64::NAN),
        });
        assert_eq!(p.per_device[0][0].fwd, 1e-12);
        assert!((p.per_device[0][0].bwd - 1e-4).abs() < 1e-12, "healthy side untouched");
        assert_eq!(p.per_device[0][1].bwd, 1e-12);
        assert_eq!(notes.len(), 2, "{notes:?}");
        assert!(notes[0].contains("device 0 layer 0 fwd"), "{}", notes[0]);
        assert!(notes[0].contains("clamped"), "{}", notes[0]);
        assert!(notes[1].contains("layer 1 bwd"), "{}", notes[1]);
        // the clamped profile is fully usable downstream
        p.validate(&cl).unwrap();
        // zero is not a positive time either
        let (_, zero_notes) = profile_with_notes(&net, &cl, 4, 1, |_, _| (0.0, 1e-4));
        assert_eq!(zero_notes.len(), net.len());
    }

    #[test]
    fn clean_measurements_produce_no_notes() {
        let net = zoo::mlp(&[8, 8, 8]);
        let cl = presets::cpu_cluster(2);
        let (p, notes) = profile_with_notes(&net, &cl, 4, 3, |_, _| (1e-4, 2e-4));
        assert!(notes.is_empty(), "{notes:?}");
        p.validate(&cl).unwrap();
    }
}
