//! Measured profiler: builds a [`Profile`] from caller-supplied timing
//! callbacks — the paper's "short profiling run" (Section 3.1), pointed at
//! real per-stage HLO executables by the runtime layer (see
//! `pipeline::training`, which wires `runtime::StageExe` timings here).
//!
//! Kept callback-based so the profile module stays independent of the XLA
//! runtime (and trivially testable).

use super::{LayerCost, Profile};
use crate::cluster::Cluster;
use crate::model::Network;

/// Measure per-layer times with `time_fn(device_idx, layer_idx) ->
/// (fwd_secs, bwd_secs)` (per sample), repeated `reps` times taking the
/// median — mirroring the paper's 1000-mini-batch averaging at small scale.
pub fn profile_with(
    net: &Network,
    cluster: &Cluster,
    dtype_bytes: u64,
    reps: usize,
    mut time_fn: impl FnMut(usize, usize) -> (f64, f64),
) -> Profile {
    assert!(reps >= 1);
    let mut per_device = Vec::with_capacity(cluster.len());
    for d in 0..cluster.len() {
        let mut layers = Vec::with_capacity(net.len());
        for (i, l) in net.layers.iter().enumerate() {
            let mut fs = Vec::with_capacity(reps);
            let mut bs = Vec::with_capacity(reps);
            for _ in 0..reps {
                let (f, b) = time_fn(d, i);
                fs.push(f);
                bs.push(b);
            }
            fs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            bs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let fwd = fs[fs.len() / 2].max(1e-12);
            let bwd = bs[bs.len() / 2].max(1e-12);
            layers.push(LayerCost {
                fwd,
                bwd,
                fwd_fixed: 0.0, // measured times already include weight traffic
                bwd_fixed: 0.0,
                params: l.params,
                act_in_elems: net.act_in(i),
                act_out_elems: l.act_out_elems,
                stash_elems: net.act_in(i), // real engine stashes stage inputs only
                half_sat: 0.0, // measured at the target micro-batch size
            });
        }
        per_device.push(layers);
    }
    Profile { model: net.name.clone(), dtype_bytes, per_device }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;

    #[test]
    fn median_filters_outliers() {
        let net = zoo::mlp(&[16, 16, 16]);
        let cl = presets::cpu_cluster(1);
        let mut call = 0usize;
        let p = profile_with(&net, &cl, 4, 5, |_, _| {
            call += 1;
            // every 5th call is a huge outlier
            if call % 5 == 0 {
                (1.0, 1.0)
            } else {
                (1e-4, 2e-4)
            }
        });
        assert!((p.per_device[0][0].fwd - 1e-4).abs() < 1e-9);
        assert!((p.per_device[0][1].bwd - 2e-4).abs() < 1e-9);
    }

    #[test]
    fn device_layer_shape() {
        let net = zoo::mlp(&[8, 8, 8, 8]);
        let cl = presets::cpu_cluster(3);
        let p = profile_with(&net, &cl, 4, 1, |d, l| ((d + 1) as f64 * 1e-5, l as f64 * 1e-5 + 1e-6));
        assert_eq!(p.n_devices(), 3);
        assert_eq!(p.n_layers(), 3);
        // device index reflected in times
        assert!(p.per_device[2][0].fwd > p.per_device[0][0].fwd);
        p.validate(&cl).unwrap();
    }
}
