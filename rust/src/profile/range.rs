//! Prefix-table range costs: the O(1) cost oracle the partition hot path
//! runs on.
//!
//! `Profile::fwd_time`/`bwd_time` re-sum a layer slice on every call, so
//! the inter-layer partition DP — which probes `O(N·C²)` `(i, j)` ranges
//! per balance seed — was `O(N·C²·L)`. [`RangeCost`] precomputes, per
//! device, prefix sums over the per-layer costs so any range query is two
//! loads and a subtract.
//!
//! The per-layer time model is `fixed + var·micro/eff(micro)` with the
//! saturating utilization curve `eff = micro/(micro + half_sat)`, which
//! expands to `(fixed + var·half_sat) + var·micro` — affine in `micro`.
//! So **one** table set (a micro-independent *constant* prefix plus a
//! *slope* prefix multiplied by `micro` at query time) serves every
//! micro-batch size: the planner's phase-A prewarm builds one `RangeCost`
//! per permuted cluster view and shares it across the whole micro grid.
//!
//! Byte quantities (parameter/stash prefixes, per-layer activation
//! tables) are integers, so their prefix-difference queries are
//! *bit-exact* with `Profile`'s direct sums. Time queries agree with the
//! direct sums to rounding (the algebra is exact; only the FP summation
//! order differs) — the DP parity against the retained
//! [`dp_optimal_reference`] oracle is property-tested in
//! `tests/planner_parity.rs`.
//!
//! [`dp_optimal_reference`]: crate::partition::interlayer::dp_optimal_reference

use super::Profile;

/// The cost queries the balanced-partition flow consumes, abstracted over
/// the backing store: [`Profile`] answers them by summing layer slices
/// (`O(L)` per range), [`RangeCost`] from prefix tables (`O(1)`). Every
/// partition pass is generic over this trait, so the planner threads one
/// prefix-table set through the whole flow while ad-hoc callers keep
/// passing a bare `&Profile`.
pub trait CostModel {
    /// Number of layers.
    fn n_layers(&self) -> usize;
    /// Number of devices.
    fn n_devices(&self) -> usize;
    /// Bytes per element at training precision.
    fn dtype_bytes(&self) -> u64;
    /// Forward time of layers `lo..hi` on device `dev` at micro-batch
    /// size `micro`.
    fn fwd_time(&self, dev: usize, lo: usize, hi: usize, micro: f64) -> f64;
    /// Backward time of layers `lo..hi` on device `dev` at micro-batch
    /// size `micro`.
    fn bwd_time(&self, dev: usize, lo: usize, hi: usize, micro: f64) -> f64;
    /// Parameter bytes of layers `lo..hi`.
    fn param_bytes(&self, lo: usize, hi: usize) -> u64;
    /// Stash bytes per sample for BP across layers `lo..hi`.
    fn stash_bytes(&self, lo: usize, hi: usize) -> u64;
    /// Bytes crossing the cut after layer `i` for one sample.
    fn cut_bytes(&self, i: usize) -> u64;
    /// Input activation bytes of layer `lo` for one sample.
    fn stage_in_bytes(&self, lo: usize) -> u64;
    /// Whole-network (fwd+bwd) time of one sample on device `dev` — the
    /// `T_n` of Eq. 1.
    fn whole_net_time(&self, dev: usize) -> f64;

    /// Forward + backward time of layers `lo..hi` on device `dev`.
    fn fb_time(&self, dev: usize, lo: usize, hi: usize, micro: f64) -> f64 {
        self.fwd_time(dev, lo, hi, micro) + self.bwd_time(dev, lo, hi, micro)
    }

    /// Eq. 1: the harmonic-mean ideal per-stage time. On a [`RangeCost`]
    /// the whole-network times are precomputed at build, so this is
    /// `O(N)` instead of the `O(N·L)` re-summation `Profile` performs.
    fn eq1_ideal_time(&self) -> f64 {
        let inv_sum: f64 = (0..self.n_devices()).map(|d| 1.0 / self.whole_net_time(d)).sum();
        1.0 / inv_sum
    }
}

impl CostModel for Profile {
    fn n_layers(&self) -> usize {
        Profile::n_layers(self)
    }
    fn n_devices(&self) -> usize {
        Profile::n_devices(self)
    }
    fn dtype_bytes(&self) -> u64 {
        self.dtype_bytes
    }
    fn fwd_time(&self, dev: usize, lo: usize, hi: usize, micro: f64) -> f64 {
        Profile::fwd_time(self, dev, lo, hi, micro)
    }
    fn bwd_time(&self, dev: usize, lo: usize, hi: usize, micro: f64) -> f64 {
        Profile::bwd_time(self, dev, lo, hi, micro)
    }
    fn param_bytes(&self, lo: usize, hi: usize) -> u64 {
        Profile::param_bytes(self, lo, hi)
    }
    fn stash_bytes(&self, lo: usize, hi: usize) -> u64 {
        Profile::stash_bytes(self, lo, hi)
    }
    fn cut_bytes(&self, i: usize) -> u64 {
        Profile::cut_bytes(self, i)
    }
    fn stage_in_bytes(&self, lo: usize) -> u64 {
        Profile::stage_in_bytes(self, lo)
    }
    fn whole_net_time(&self, dev: usize) -> f64 {
        Profile::whole_net_time(self, dev)
    }
}

/// Prefix tables over a [`Profile`]: per-`(device, micro)` range costs in
/// O(1), with one table set serving every `micro` (see module docs).
/// Flat row-major layout (`device × (L+1)`) keeps a device's prefixes on
/// consecutive cache lines during the DP's inner loop.
#[derive(Debug, Clone)]
pub struct RangeCost {
    n_devices: usize,
    n_layers: usize,
    dtype_bytes: u64,
    /// Per-device prefixes of the micro-independent forward term
    /// (`fwd_fixed + fwd·half_sat`), length `n_devices · (L+1)`.
    fwd_const: Vec<f64>,
    /// Per-device prefixes of the forward slope (`fwd`), multiplied by
    /// `micro` at query time.
    fwd_slope: Vec<f64>,
    /// Backward analogue of `fwd_const`.
    bwd_const: Vec<f64>,
    /// Backward analogue of `fwd_slope`.
    bwd_slope: Vec<f64>,
    /// Parameter-count prefix (device-independent), length `L+1`.
    params: Vec<u64>,
    /// Stash-element prefix, length `L+1`.
    stash: Vec<u64>,
    /// Per-layer input activation elements (point lookups).
    act_in: Vec<u64>,
    /// Per-layer output activation elements (point lookups).
    act_out: Vec<u64>,
    /// Per-device whole-network (fwd+bwd) time at micro-batch 1, computed
    /// once at build — Eq. 1 consumers stop re-summing the profile.
    whole_net: Vec<f64>,
    /// Every per-layer cost addend was non-negative at build, so every
    /// prefix array is non-decreasing and range costs are non-increasing
    /// in `lo` — the structural half of the monotone DP's soundness
    /// argument. A profile with a negative cost (e.g. a noisy measured
    /// fit) clears this and the DP keeps the exact linear scan.
    costs_monotone: bool,
}

impl RangeCost {
    /// Build the tables from a profile: `O(N·L)` once, `O(1)` per query
    /// afterwards.
    pub fn build(profile: &Profile) -> RangeCost {
        let n = Profile::n_devices(profile);
        let l = Profile::n_layers(profile);
        let stride = l + 1;
        let mut fwd_const = vec![0.0; n * stride];
        let mut fwd_slope = vec![0.0; n * stride];
        let mut bwd_const = vec![0.0; n * stride];
        let mut bwd_slope = vec![0.0; n * stride];
        let mut costs_monotone = true;
        for (d, row) in profile.per_device.iter().enumerate() {
            let base = d * stride;
            for (i, c) in row.iter().enumerate() {
                // half_sat <= 0 means eff = 1 (no saturation term).
                let sat = if c.half_sat > 0.0 { c.half_sat } else { 0.0 };
                let fc = c.fwd_fixed + c.fwd * sat;
                let bc = c.bwd_fixed + c.bwd * sat;
                costs_monotone &= fc >= 0.0 && bc >= 0.0 && c.fwd >= 0.0 && c.bwd >= 0.0;
                fwd_const[base + i + 1] = fwd_const[base + i] + fc;
                fwd_slope[base + i + 1] = fwd_slope[base + i] + c.fwd;
                bwd_const[base + i + 1] = bwd_const[base + i] + bc;
                bwd_slope[base + i + 1] = bwd_slope[base + i] + c.bwd;
            }
        }
        let mut params = vec![0u64; stride];
        let mut stash = vec![0u64; stride];
        let mut act_in = vec![0u64; l];
        let mut act_out = vec![0u64; l];
        for (i, c) in profile.per_device[0].iter().enumerate() {
            params[i + 1] = params[i] + c.params;
            stash[i + 1] = stash[i] + c.stash_elems;
            act_in[i] = c.act_in_elems;
            act_out[i] = c.act_out_elems;
        }
        let mut rc = RangeCost {
            n_devices: n,
            n_layers: l,
            dtype_bytes: profile.dtype_bytes,
            fwd_const,
            fwd_slope,
            bwd_const,
            bwd_slope,
            params,
            stash,
            act_in,
            act_out,
            whole_net: Vec::new(),
            costs_monotone,
        };
        rc.whole_net = (0..n)
            .map(|d| {
                CostModel::fwd_time(&rc, d, 0, l, 1.0) + CostModel::bwd_time(&rc, d, 0, l, 1.0)
            })
            .collect();
        rc
    }

    /// True when every per-layer cost addend was non-negative at build
    /// (always the case for the analytical profiler), which makes every
    /// range cost non-increasing in `lo` — the precondition the DP's
    /// monotone crossing search needs on the cost side. `false` routes
    /// the DP to the exact linear scan.
    pub fn costs_monotone(&self) -> bool {
        self.costs_monotone
    }

    #[inline]
    fn base(&self, dev: usize) -> usize {
        dev * (self.n_layers + 1)
    }
}

impl CostModel for RangeCost {
    fn n_layers(&self) -> usize {
        self.n_layers
    }
    fn n_devices(&self) -> usize {
        self.n_devices
    }
    fn dtype_bytes(&self) -> u64 {
        self.dtype_bytes
    }
    #[inline]
    fn fwd_time(&self, dev: usize, lo: usize, hi: usize, micro: f64) -> f64 {
        let b = self.base(dev);
        (self.fwd_const[b + hi] - self.fwd_const[b + lo])
            + micro * (self.fwd_slope[b + hi] - self.fwd_slope[b + lo])
    }
    #[inline]
    fn bwd_time(&self, dev: usize, lo: usize, hi: usize, micro: f64) -> f64 {
        let b = self.base(dev);
        (self.bwd_const[b + hi] - self.bwd_const[b + lo])
            + micro * (self.bwd_slope[b + hi] - self.bwd_slope[b + lo])
    }
    fn param_bytes(&self, lo: usize, hi: usize) -> u64 {
        (self.params[hi] - self.params[lo]) * self.dtype_bytes
    }
    fn stash_bytes(&self, lo: usize, hi: usize) -> u64 {
        (self.stash[hi] - self.stash[lo]) * self.dtype_bytes
    }
    fn cut_bytes(&self, i: usize) -> u64 {
        self.act_out[i] * self.dtype_bytes
    }
    fn stage_in_bytes(&self, lo: usize) -> u64 {
        self.act_in[lo] * self.dtype_bytes
    }
    fn whole_net_time(&self, dev: usize) -> f64 {
        self.whole_net[dev]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;
    use crate::util::rng::Rng;

    fn close(a: f64, b: f64) -> bool {
        let scale = a.abs().max(b.abs()).max(1e-300);
        (a - b).abs() / scale < 1e-12
    }

    #[test]
    fn byte_queries_bit_exact_with_profile() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(2);
        let p = analytical::profile(&net, &cl);
        let rc = RangeCost::build(&p);
        let l = p.n_layers();
        for lo in 0..l {
            for hi in lo..=l {
                assert_eq!(CostModel::param_bytes(&rc, lo, hi), p.param_bytes(lo, hi));
                assert_eq!(CostModel::stash_bytes(&rc, lo, hi), p.stash_bytes(lo, hi));
            }
            assert_eq!(CostModel::cut_bytes(&rc, lo), p.cut_bytes(lo));
            assert_eq!(CostModel::stage_in_bytes(&rc, lo), p.stage_in_bytes(lo));
        }
    }

    #[test]
    fn time_queries_match_profile_across_micros() {
        // The affine decomposition is algebraically exact; random ranges
        // and micro-batch sizes must agree to rounding.
        let net = zoo::resnet50(224);
        let cl = presets::fpga_cluster(&["VCU129", "VCU118"]);
        let p = analytical::profile(&net, &cl);
        let rc = RangeCost::build(&p);
        let l = p.n_layers();
        let mut r = Rng::new(0xC0_57);
        for _ in 0..500 {
            let lo = (r.f64() * l as f64) as usize % l;
            let hi = lo + 1 + (r.f64() * (l - lo) as f64) as usize;
            let hi = hi.min(l);
            let d = if r.f64() < 0.5 { 0 } else { 1 };
            let micro = [1.0, 2.0, 8.0, 32.0, 128.0][(r.f64() * 5.0) as usize % 5];
            let (a, b) = (CostModel::fwd_time(&rc, d, lo, hi, micro), p.fwd_time(d, lo, hi, micro));
            assert!(close(a, b), "fwd d={d} {lo}..{hi} micro={micro}: {a} vs {b}");
            let (a, b) = (CostModel::bwd_time(&rc, d, lo, hi, micro), p.bwd_time(d, lo, hi, micro));
            assert!(close(a, b), "bwd d={d} {lo}..{hi} micro={micro}: {a} vs {b}");
        }
    }

    #[test]
    fn whole_net_and_eq1_precomputed() {
        let net = zoo::vgg16(224);
        let cl = presets::fpga_cluster(&["VCU129", "VCU118", "VCU118"]);
        let p = analytical::profile(&net, &cl);
        let rc = RangeCost::build(&p);
        for d in 0..p.n_devices() {
            assert!(close(CostModel::whole_net_time(&rc, d), p.whole_net_time(d)), "dev {d}");
        }
        assert!(close(
            CostModel::eq1_ideal_time(&rc),
            crate::partition::interlayer::eq1_ideal_time(&p)
        ));
    }

    #[test]
    fn range_times_monotone_in_lo() {
        // cost(lo, hi) must be non-increasing as lo grows — in FP, not
        // just in exact arithmetic (prefixes of non-negative addends are
        // monotone arrays, so the differences are ordered). The monotone
        // DP's binary search relies on this.
        let net = zoo::by_name("gnmt-l64").unwrap();
        let cl = presets::v100_cluster(4);
        let p = analytical::profile(&net, &cl);
        let rc = RangeCost::build(&p);
        let l = p.n_layers();
        for micro in [1.0, 8.0] {
            for lo in 0..l - 1 {
                let a = CostModel::fb_time(&rc, 0, lo, l, micro);
                let b = CostModel::fb_time(&rc, 0, lo + 1, l, micro);
                assert!(b <= a, "lo={lo}: {b} > {a}");
            }
        }
    }

    #[test]
    fn empty_range_is_zero() {
        let net = zoo::mlp(&[8, 8]);
        let cl = presets::v100_cluster(1);
        let p = analytical::profile(&net, &cl);
        let rc = RangeCost::build(&p);
        assert_eq!(CostModel::fwd_time(&rc, 0, 1, 1, 4.0), 0.0);
        assert_eq!(CostModel::param_bytes(&rc, 1, 1), 0);
    }
}
