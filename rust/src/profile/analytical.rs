//! Analytical profiler.
//!
//! * **GPU path** — roofline: `time = max(flops / (peak·eff_kind),
//!   traffic / mem_bw)` per layer, with per-kind achievable-efficiency
//!   factors (large conv/gemm run near peak; LSTM's small gemms don't).
//!   Stands in for the paper's measured 1000-mini-batch profiling run.
//! * **FPGA path** — FPDeep-style (Section 3.1): the fine-grained
//!   intra-layer pipeline keeps DSPs busy at micro-batch 1, so compute
//!   time is `flops / dsp_peak`; if a stage's weights spill to DDR, the
//!   weight stream `params·dtype / ddr_bw` bounds the layer instead
//!   (that spill test is applied at *partition* level by the memory
//!   model — here we expose both terms via the cost entries).

use super::{LayerCost, Profile};
use crate::cluster::{Cluster, ExecMode};
use crate::model::{LayerKind, Network};

/// Achievable fraction of peak compute per layer kind (GPU).
fn gpu_eff(kind: LayerKind) -> f64 {
    match kind {
        LayerKind::Conv2d => 0.55,
        LayerKind::Linear => 0.50,
        LayerKind::Lstm => 0.25,
        LayerKind::Attention => 0.35,
        LayerKind::Embedding => 0.9, // memory-bound anyway
        _ => 0.9,
    }
}

/// Achievable fraction of DSP peak per layer kind (FPGA, FPDeep mapping).
fn fpga_eff(kind: LayerKind) -> f64 {
    match kind {
        LayerKind::Conv2d => 0.85,
        LayerKind::Linear => 0.80,
        LayerKind::Lstm => 0.70,
        LayerKind::Attention => 0.65,
        _ => 0.9,
    }
}

/// Per-sample training-stash multiplier on the output activation: how many
/// activation-sized intermediates BP needs (gates/cells for LSTM, probs
/// for softmax, normalized values for norms, ...).
fn stash_multiplier(kind: LayerKind) -> u64 {
    match kind {
        LayerKind::Lstm => 10,      // gates i,f,g,o + c,h + dropout masks
        LayerKind::Attention => 4,  // q,k,v + probs
        LayerKind::Norm => 2,
        LayerKind::Conv2d => 1,
        LayerKind::Linear => 1,
        LayerKind::Embedding => 1,
        LayerKind::Softmax => 2,    // logits + probs
        LayerKind::Pool | LayerKind::Act | LayerKind::Glue => 1,
    }
}

/// Kind-dependent utilization half-saturation multiplier on the device's
/// `batch_half_sat`: convolutions keep a GPU busy from micro-batch ~1
/// (spatial parallelism), LSTM steps are tiny gemms that need batching.
fn half_sat_factor(kind: LayerKind) -> f64 {
    match kind {
        LayerKind::Conv2d => 0.15,
        LayerKind::Linear => 1.0,
        LayerKind::Lstm => 1.0, // cuDNN fuses the 4 gate gemms; h=1024 rows
        LayerKind::Attention => 0.4,
        _ => 0.1,
    }
}

/// Per-sample memory traffic of one layer's forward pass (activations
/// only — weights are a per-pass fixed cost), bytes.
fn fwd_act_traffic(act_in: u64, act_out: u64, dtype: u64) -> f64 {
    ((act_in + act_out) * dtype) as f64
}

/// Build the analytical profile of `net` on every device of `cluster`.
/// Training precision: fp32 on Sync (GPU) devices, fp16 on Async (FPGA)
/// devices — matching Section 4.3's fp16 memory optimizer. Mixed clusters
/// use the widest dtype.
pub fn profile(net: &Network, cluster: &Cluster) -> Profile {
    let dtype_bytes = if cluster.all_async() { 2 } else { 4 };
    let mut per_device = Vec::with_capacity(cluster.len());
    for dev in &cluster.devices {
        let mut layers = Vec::with_capacity(net.len());
        for (i, l) in net.layers.iter().enumerate() {
            let (eff, use_roofline) = match dev.exec {
                ExecMode::Sync => (gpu_eff(l.kind), true),
                ExecMode::Async => (fpga_eff(l.kind), false),
            };
            let peak = dev.peak_flops * eff;
            let act_in = net.act_in(i);
            let compute_f = l.flops_fwd / peak;
            let compute_b = l.flops_bwd / peak;
            let (fwd, bwd, fwd_fixed, bwd_fixed) = if use_roofline {
                let mem_f = fwd_act_traffic(act_in, l.act_out_elems, dtype_bytes) / dev.mem_bw;
                // bwd touches the stash + upstream grads: ~2x fwd traffic
                let mem_b = 2.0 * fwd_act_traffic(act_in, l.act_out_elems, dtype_bytes)
                    / dev.mem_bw;
                // weights: read once per pass fwd; read + grad-write in bwd
                let w_bytes = (l.params * dtype_bytes) as f64;
                (
                    compute_f.max(mem_f),
                    compute_b.max(mem_b),
                    w_bytes / dev.mem_bw,
                    2.0 * w_bytes / dev.mem_bw,
                )
            } else {
                // FPGA: compute-bound under the fine-grained pipeline;
                // DDR spill handled by the stage-level memory model.
                (compute_f, compute_b, 0.0, 0.0)
            };
            layers.push(LayerCost {
                fwd: fwd.max(1e-12),
                bwd: bwd.max(1e-12),
                fwd_fixed,
                bwd_fixed,
                params: l.params,
                act_in_elems: act_in,
                act_out_elems: l.act_out_elems,
                stash_elems: l.act_out_elems * stash_multiplier(l.kind),
                half_sat: dev.batch_half_sat * half_sat_factor(l.kind),
            });
        }
        per_device.push(layers);
    }
    Profile { model: net.name.clone(), dtype_bytes, per_device }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;

    #[test]
    fn vgg_fwd_time_plausible_on_v100() {
        // VGG-16 fwd ≈ 31 GFLOPs; V100 @ ~8.6 effective TFLOPS → ~3.6 ms at
        // full utilization; single-sample batches run at ~20% utilization.
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(1);
        let p = profile(&net, &cl);
        let t = p.fwd_time(0, 0, p.n_layers(), 1.0);
        assert!(t > 1e-3 && t < 30e-3, "vgg16 fwd/sample {t}s");
        // at saturating batch, per-sample time approaches the roofline
        let t64 = p.fwd_time(0, 0, p.n_layers(), 64.0) / 64.0;
        assert!(t64 > 2e-3 && t64 < 8e-3, "vgg16 fwd/sample@64 {t64}s");
    }

    #[test]
    fn bwd_about_twice_fwd() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(1);
        let p = profile(&net, &cl);
        let f = p.fwd_time(0, 0, p.n_layers(), 32.0);
        let b = p.bwd_time(0, 0, p.n_layers(), 32.0);
        let r = b / f;
        assert!(r > 1.5 && r < 2.5, "bwd/fwd ratio {r}");
    }

    #[test]
    fn fpga_uses_fp16() {
        let net = zoo::resnet50(224);
        let cl = presets::fpga_cluster(&["VCU118", "VCU118"]);
        let p = profile(&net, &cl);
        assert_eq!(p.dtype_bytes, 2);
        let gl = presets::v100_cluster(2);
        assert_eq!(profile(&net, &gl).dtype_bytes, 4);
    }

    #[test]
    fn heterogeneous_devices_differ() {
        let net = zoo::resnet50(224);
        let cl = presets::fpga_cluster(&["VCU129", "VCU118"]);
        let p = profile(&net, &cl);
        // VCU129 has 1.8x DSPs → faster whole-net time
        assert!(p.whole_net_time(0) < p.whole_net_time(1));
    }

    #[test]
    fn lstm_slower_than_equal_flops_conv() {
        // efficiency factors: LSTM gets less of peak
        let cl = presets::v100_cluster(1);
        let gn = zoo::gnmt(8, 1024, 32000, 50);
        let p = profile(&gn, &cl);
        // pick an LSTM layer, check implied efficiency < 0.3
        let li = gn.layers.iter().position(|l| l.name == "enc_lstm3").unwrap();
        let c = &p.per_device[0][li];
        let implied = gn.layers[li].flops_fwd / c.fwd / cl.devices[0].peak_flops;
        assert!(implied <= 0.30, "implied lstm eff {implied}");
    }
}
