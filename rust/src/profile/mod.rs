//! DNN profiling (Fig. 3, first box): per-layer forward/backward time on
//! every device of the cluster, plus weight and activation sizes — the
//! inputs both auto-exploration methodologies consume.
//!
//! Two sources, one representation:
//! * [`analytical`] — roofline cost model (GPU) / FPDeep-style DSP model
//!   (FPGA); stands in for the paper's 1000-mini-batch measured profiling
//!   run on hardware we don't have.
//! * [`measured`] — times real per-stage HLO executables on the CPU PJRT
//!   client (used by the real engine's planner).
//!
//! [`range::RangeCost`] precomputes prefix tables over a profile so the
//! partition hot path answers any layer-range cost in O(1); every
//! partition pass is generic over the [`range::CostModel`] trait that
//! both `Profile` and `RangeCost` implement.

pub mod analytical;
pub mod measured;
pub mod range;

pub use range::{CostModel, RangeCost};

use crate::cluster::Cluster;

/// Per-layer costs on one device, split into a **variable** per-sample
/// part (FLOPs + activation traffic, scales with micro-batch size) and a
/// **fixed** per-pass part (parameter/weight traffic — read once per
/// micro-batch regardless of its size). Batch scaling is applied by
/// [`Profile::fwd_time`] / [`Profile::bwd_time`].
#[derive(Debug, Clone)]
pub struct LayerCost {
    /// Forward seconds/sample (variable part).
    pub fwd: f64,
    /// Backward seconds/sample (variable part).
    pub bwd: f64,
    /// Forward seconds/pass (fixed part: weight reads).
    pub fwd_fixed: f64,
    /// Backward seconds/pass (fixed part: weight reads + gradient writes).
    pub bwd_fixed: f64,
    /// Trainable parameters.
    pub params: u64,
    /// Input activation elements/sample.
    pub act_in_elems: u64,
    /// Output activation elements/sample.
    pub act_out_elems: u64,
    /// Elements stashed per sample for backward (saved intermediates).
    pub stash_elems: u64,
    /// Micro-batch size at which this layer reaches 50% device
    /// utilization (kind-dependent: convs saturate at ~1 sample thanks to
    /// their spatial parallelism; LSTM/GEMM layers need batching).
    pub half_sat: f64,
}

/// A complete profile: `per_device[d][l]` is layer `l` on device `d`.
#[derive(Debug, Clone)]
pub struct Profile {
    /// Model name this profile belongs to.
    pub model: String,
    /// Bytes per element at training precision (4 = fp32, 2 = fp16).
    pub dtype_bytes: u64,
    /// Per-device, per-layer costs.
    pub per_device: Vec<Vec<LayerCost>>,
}

impl Profile {
    /// Number of layers.
    pub fn n_layers(&self) -> usize {
        self.per_device[0].len()
    }

    /// Number of devices.
    pub fn n_devices(&self) -> usize {
        self.per_device.len()
    }

    fn eff(c: &LayerCost, micro: f64) -> f64 {
        // `micro <= 0` guards the saturating branch's 0/(0+h) = 0, which
        // would turn the caller's `cost * micro / eff` into 0/0 = NaN —
        // a degenerate micro-batch size costs zero time, not NaN.
        if c.half_sat <= 0.0 || micro <= 0.0 {
            1.0
        } else {
            micro / (micro + c.half_sat)
        }
    }

    /// Forward time of layers `lo..hi` on device `dev` at micro-batch
    /// size `micro` (per-layer utilization curves applied to the variable
    /// part; the fixed weight-traffic part is paid once per pass).
    pub fn fwd_time(&self, dev: usize, lo: usize, hi: usize, micro: f64) -> f64 {
        self.per_device[dev][lo..hi]
            .iter()
            .map(|c| c.fwd_fixed + c.fwd * micro / Self::eff(c, micro))
            .sum()
    }

    /// Backward time of layers `lo..hi` on device `dev` at micro-batch
    /// size `micro`.
    pub fn bwd_time(&self, dev: usize, lo: usize, hi: usize, micro: f64) -> f64 {
        self.per_device[dev][lo..hi]
            .iter()
            .map(|c| c.bwd_fixed + c.bwd * micro / Self::eff(c, micro))
            .sum()
    }

    /// Whole-network training time (fwd+bwd) of one sample on device `dev`
    /// — the `T_n` of Eq. 1.
    pub fn whole_net_time(&self, dev: usize) -> f64 {
        self.fwd_time(dev, 0, self.n_layers(), 1.0) + self.bwd_time(dev, 0, self.n_layers(), 1.0)
    }

    /// Parameter bytes of layers `lo..hi` (weights only, at `dtype_bytes`).
    pub fn param_bytes(&self, lo: usize, hi: usize) -> u64 {
        self.per_device[0][lo..hi].iter().map(|c| c.params).sum::<u64>() * self.dtype_bytes
    }

    /// Bytes crossing the cut after layer `i` (activations in FP, same-size
    /// errors in BP) for one sample.
    pub fn cut_bytes(&self, i: usize) -> u64 {
        self.per_device[0][i].act_out_elems * self.dtype_bytes
    }

    /// Input activation bytes of layer `lo` (what an upstream stage sends
    /// us) for one sample.
    pub fn stage_in_bytes(&self, lo: usize) -> u64 {
        self.per_device[0][lo].act_in_elems * self.dtype_bytes
    }

    /// Stash bytes per sample for BP across layers `lo..hi`.
    pub fn stash_bytes(&self, lo: usize, hi: usize) -> u64 {
        self.per_device[0][lo..hi].iter().map(|c| c.stash_elems).sum::<u64>() * self.dtype_bytes
    }

    /// Sanity-check a profile against a cluster (device count matches,
    /// all times positive).
    pub fn validate(&self, cluster: &Cluster) -> crate::Result<()> {
        anyhow::ensure!(
            self.n_devices() == cluster.len(),
            "profile has {} devices, cluster has {}",
            self.n_devices(),
            cluster.len()
        );
        for (d, layers) in self.per_device.iter().enumerate() {
            anyhow::ensure!(
                layers.len() == self.n_layers(),
                "device {d} has {} layers, expected {}",
                layers.len(),
                self.n_layers()
            );
            for (l, c) in layers.iter().enumerate() {
                anyhow::ensure!(
                    c.fwd > 0.0 && c.bwd >= 0.0,
                    "device {d} layer {l}: non-positive time"
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;

    #[test]
    fn whole_net_time_is_sum() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(2);
        let p = analytical::profile(&net, &cl);
        let t = p.whole_net_time(0);
        let manual =
            p.fwd_time(0, 0, p.n_layers(), 1.0) + p.bwd_time(0, 0, p.n_layers(), 1.0);
        assert!((t - manual).abs() < 1e-15);
        p.validate(&cl).unwrap();
    }

    #[test]
    fn batch_scaling_superlinear_speedup_per_sample() {
        // per-sample time falls as micro-batch grows (utilization effect)
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(1);
        let p = analytical::profile(&net, &cl);
        let t1 = p.fwd_time(0, 0, 5, 1.0);
        let t32 = p.fwd_time(0, 0, 5, 32.0) / 32.0;
        assert!(t32 < t1, "per-sample time should drop with batch: {t32} vs {t1}");
    }

    #[test]
    fn zero_micro_batch_costs_zero_not_nan() {
        // analytical profiles have half_sat > 0, so micro = 0 used to hit
        // cost * 0 / eff(0) = 0/0 = NaN and poison every downstream DP
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(1);
        let p = analytical::profile(&net, &cl);
        assert!(p.per_device[0][0].half_sat > 0.0, "the premise: a saturating curve");
        let f = p.fwd_time(0, 0, p.n_layers(), 0.0);
        let b = p.bwd_time(0, 0, p.n_layers(), 0.0);
        assert!(f.is_finite() && b.is_finite(), "fwd {f}, bwd {b}");
        assert_eq!(f, 0.0, "no samples, no variable compute");
        assert_eq!(b, 0.0);
        // positive micro-batches are untouched by the guard
        assert!(p.fwd_time(0, 0, p.n_layers(), 1.0) > 0.0);
    }

    #[test]
    fn validate_rejects_wrong_device_count() {
        let net = zoo::mlp(&[8, 8]);
        let cl1 = presets::v100_cluster(1);
        let cl2 = presets::v100_cluster(2);
        let p = analytical::profile(&net, &cl1);
        assert!(p.validate(&cl2).is_err());
    }
}
