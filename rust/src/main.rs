//! `bapipe` — the BaPipe launcher CLI.
//!
//! Subcommands:
//!   explore   — run the Fig.-3 auto-exploration on a zoo model + cluster
//!               (--jobs N parallel phases A+B, --emit plan.json artifact,
//!               --permute device-order search, --order-search/--order-budget
//!               neighbourhood search past 8 devices, --no-prune exhaustive,
//!               --adaptive-m incumbent-bisection M refinement,
//!               --pareto keep the epoch-time × peak-memory front (adds the
//!               memory-scalable 2BW kind), --recompute add the
//!               activation-recomputation axis,
//!               --plan-cache path: persist/restore the partition cache
//!               keyed on a (model, cluster) fingerprint so repeated
//!               invocations skip phase A entirely; per-view salvage keeps
//!               the surviving device orders of a stale cache,
//!               --eval-budget N: anytime stop after N candidates)
//!   replan    — elastic-cluster replanning: replay a fault-injection
//!               scenario JSON (device loss/join, link degradation,
//!               stragglers) against an incumbent plan.json, warm-starting
//!               the exploration after every event, scheduling each plan
//!               switch's state transfers into the draining pipeline's
//!               bubbles and amortizing positioned (mid-epoch) events;
//!               `--detect samples.json` closes the loop from live timing
//!               samples instead of a script (hysteresis thresholds:
//!               --detect-enter/--detect-exit/--detect-dwell/
//!               --detect-window)
//!   plan      — plan.json artifact tooling: `plan diff <a> <b>` compares
//!               winner, time deltas and stage-boundary moves
//!   check     — statically verify a plan.json artifact without simulating:
//!               re-generate the winning schedule's stage programs and prove
//!               dependency order, FIFO transfers, deadlock freedom and the
//!               weight-staleness bound, re-derive peak memory from program
//!               text, and audit the artifact's structural invariants
//!               (partition coverage, device-order permutation, Pareto-front
//!               sortedness, provenance). `--cluster <c> --n <k>` adds
//!               device-capacity checks. Exit code 0 = clean, 1 = warnings
//!               only, 2 = violations.
//!   partition — show the balanced partition for a model/cluster
//!   simulate  — DES one schedule and print its timeline (Figs. 4–6)
//!   train     — real pipeline training over AOT artifacts  [pjrt feature]
//!   dp        — real data-parallel baseline training        [pjrt feature]
//!   profile   — measured per-stage times of an artifact bundle [pjrt]

use bapipe::cluster::{presets, Cluster};
use bapipe::config::TrainConfig;
use bapipe::model::zoo;
#[cfg(feature = "pjrt")]
use bapipe::pipeline::{dp_engine, training};
use bapipe::planner;
use bapipe::profile::analytical;
#[cfg(feature = "pjrt")]
use bapipe::runtime::Runtime;
use bapipe::schedule::ScheduleKind;
use bapipe::sim::{engine as des, timeline};
use bapipe::util::cli::Args;
use bapipe::util::logging::{self, Level};

fn cluster_by_name(name: &str, n: usize) -> Cluster {
    match name {
        "v100" => presets::v100_cluster(n),
        "vcu118" => presets::fpga_cluster(&vec!["VCU118"; n]),
        "vcu129" => presets::fpga_cluster(&vec!["VCU129"; n]),
        "fpga-mixed" => {
            let mut boards = vec!["VCU129"; n / 2];
            boards.extend(vec!["VCU118"; n - n / 2]);
            presets::fpga_cluster(&boards)
        }
        "gpu-mixed" => presets::gpu_mixed_cluster(n),
        "cpu" => presets::cpu_cluster(n),
        other => {
            panic!("unknown cluster `{other}` (v100|vcu118|vcu129|fpga-mixed|gpu-mixed|cpu)")
        }
    }
}

/// Exploration options shared by `explore` and `replan`.
fn planner_opts(args: &Args) -> planner::Options {
    planner::Options {
        batch_per_device: args.get_f64("batch", 32.0),
        samples_per_epoch: args.get_usize("samples", 50_000),
        jobs: args.get_usize("jobs", 1),
        prune: !args.has_flag("no-prune"),
        permute_devices: args.has_flag("permute"),
        order_search: args.has_flag("order-search"),
        order_budget: args.get_usize("order-budget", planner::orders::ORDER_BUDGET_DEFAULT),
        adaptive_m: args.has_flag("adaptive-m"),
        pareto: args.has_flag("pareto"),
        recompute: args.has_flag("recompute"),
        eval_budget: args.opt_str("eval-budget").map(|_| args.get_usize("eval-budget", 0)),
        ..Default::default()
    }
}

/// Load a `plan.json` artifact emitted by `explore --emit`.
fn load_plan(path: &str) -> bapipe::Result<planner::Plan> {
    let text =
        std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let json = bapipe::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
    planner::Plan::from_json(&json).map_err(|e| anyhow::anyhow!("loading {path}: {e}"))
}

fn main() -> bapipe::Result<()> {
    let args = Args::from_env();
    if args.has_flag("verbose") {
        logging::set_level(Level::Debug);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "explore" => {
            let model = args.get_str("model", "vgg16");
            let net = zoo::by_name(&model)
                .ok_or_else(|| anyhow::anyhow!("unknown model `{model}`"))?;
            let cl = cluster_by_name(&args.get_str("cluster", "v100"), args.get_usize("n", 4));
            let prof = analytical::profile(&net, &cl);
            let opts = planner_opts(&args);
            let plan = match args.opt_str("plan-cache") {
                Some(path) => {
                    // Cross-scenario cache: restore the seed/plan maps when
                    // the (model, cluster) fingerprint and device-order
                    // space match, salvage the surviving views otherwise,
                    // and persist the (possibly grown) cache after. The
                    // load outcome travels in the space notes so the
                    // report/log records it — never just stdout.
                    let fp = planner::store::fingerprint(&net, &cl, &prof);
                    let mut space = planner::SearchSpace::bapipe(&net, &cl, &prof, &opts);
                    let vfps: Vec<String> = space
                        .device_orders
                        .iter()
                        .map(|o| planner::store::view_fingerprint(&net, &cl, &prof, o))
                        .collect();
                    let (load, notes) =
                        planner::store::load_with_views(path, &fp, &space.device_orders, &vfps);
                    for note in &notes {
                        println!("{note}");
                    }
                    space.notes.extend(notes);
                    let mut cache = match load {
                        planner::store::CacheLoad::Loaded(cache) => cache,
                        planner::store::CacheLoad::Fresh(_) => planner::EvalCache::new(),
                    };
                    // Reuse the space built for cache validation: past 8
                    // devices its construction ran the budgeted order
                    // discovery, which must not run twice.
                    let plan = planner::explore_with_cache_in_space(
                        &net, &cl, &prof, &space, &opts, &mut cache,
                    );
                    planner::store::save_with_views(
                        path, &cache, &fp, &space.device_orders, &vfps,
                    )?;
                    println!("plan cache: saved {path}");
                    plan
                }
                None => planner::explore(&net, &cl, &prof, &opts),
            };
            println!("== exploration log ==");
            for l in plan.report.log_lines() {
                println!("  {l}");
            }
            println!("\n{}", plan.summary());
            if !plan.pareto_front.is_empty() {
                println!("\n== pareto front (epoch time × peak memory) ==");
                for p in &plan.pareto_front {
                    let rc = if p.candidate.recompute { "+RC" } else { "" };
                    println!(
                        "  {}{rc} M={}: epoch {:.1}s, peak {}",
                        p.candidate.kind.label(),
                        p.candidate.m,
                        p.epoch_time,
                        bapipe::util::fmt_bytes(p.peak_memory)
                    );
                }
            }
            if let Some(path) = args.opt_str("emit") {
                // emit_json re-parses what it serialized and verifies the
                // round-trip before handing the text out.
                let text = plan.emit_json()?;
                std::fs::write(path, &text)?;
                println!("\nwrote {path} ({} bytes, round-trip verified)", text.len());
            }
        }
        "replan" => {
            let model = args.get_str("model", "vgg16");
            let net = zoo::by_name(&model)
                .ok_or_else(|| anyhow::anyhow!("unknown model `{model}`"))?;
            let cl = cluster_by_name(&args.get_str("cluster", "v100"), args.get_usize("n", 4));
            let prof = analytical::profile(&net, &cl);
            let plan_path = args.opt_str("plan").ok_or_else(|| {
                anyhow::anyhow!(
                    "usage: bapipe replan --plan plan.json (--scenario scenario.json | \
                     --detect samples.json) --model <m> --cluster <c> --n <n> [explore flags]"
                )
            })?;
            let incumbent = load_plan(plan_path)?;
            let scenario = match (args.opt_str("scenario"), args.opt_str("detect")) {
                (Some(scenario_path), _) => {
                    let text = std::fs::read_to_string(scenario_path)
                        .map_err(|e| anyhow::anyhow!("reading {scenario_path}: {e}"))?;
                    let doc = bapipe::util::json::Json::parse(&text)
                        .map_err(|e| anyhow::anyhow!("parsing {scenario_path}: {e}"))?;
                    bapipe::cluster::mutate::Scenario::from_json(&doc)
                        .map_err(|e| anyhow::anyhow!("loading {scenario_path}: {e}"))?
                }
                (None, Some(samples_path)) => {
                    // The live path: drift-detect over a timing-sample
                    // stream and synthesize the event scenario, positions
                    // included (mb_per_tick × tick).
                    use bapipe::cluster::detect;
                    let text = std::fs::read_to_string(samples_path)
                        .map_err(|e| anyhow::anyhow!("reading {samples_path}: {e}"))?;
                    let doc = bapipe::util::json::Json::parse(&text)
                        .map_err(|e| anyhow::anyhow!("parsing {samples_path}: {e}"))?;
                    let stream = detect::SampleStream::from_json(&doc)
                        .map_err(|e| anyhow::anyhow!("loading {samples_path}: {e}"))?;
                    let base = detect::DetectorConfig::default();
                    let dcfg = detect::DetectorConfig {
                        enter: args.get_f64("detect-enter", base.enter),
                        exit: args.get_f64("detect-exit", base.exit),
                        min_dwell: args.get_usize("detect-dwell", base.min_dwell),
                        window: args.get_usize("detect-window", base.window),
                        ..base
                    };
                    let det = detect::detect(&stream, &dcfg)
                        .map_err(|e| anyhow::anyhow!("detecting over {samples_path}: {e}"))?;
                    for note in &det.notes {
                        println!("  {note}");
                    }
                    if det.events.is_empty() {
                        println!("detector: no drift above the hysteresis band — keeping the plan");
                        return Ok(());
                    }
                    det.to_scenario(&stream)
                }
                (None, None) => anyhow::bail!(
                    "replan needs --scenario scenario.json or --detect samples.json"
                ),
            };
            let opts = planner_opts(&args);
            let run =
                planner::elastic::run_scenario(&net, &cl, &prof, &incumbent, &scenario, &opts)
                    .map_err(|e| anyhow::anyhow!("replaying {scenario_path}: {e}"))?;
            println!("scenario: {} ({} events)", run.scenario, run.steps.len());
            for (i, step) in run.steps.iter().enumerate() {
                println!("\n== event {} — {} ==", i + 1, step.event);
                println!("cluster: {}", step.cluster);
                for p in &step.provenance {
                    println!("  {p}");
                }
                if let Some(m) = &step.migration {
                    println!("  {}", m.render());
                }
                if let Some(sc) = &step.schedule {
                    println!("  {}", sc.render());
                    let tl = sc.render_timeline(args.get_usize("width", 100));
                    if !tl.is_empty() {
                        print!("{tl}");
                    }
                }
                if let Some(d) = &step.decision {
                    println!("  {}", d.describe());
                }
                println!("{}", step.diff.render());
                println!("{}", step.plan.summary());
            }
            if let Some(path) = args.opt_str("emit") {
                let last = run
                    .steps
                    .last()
                    .ok_or_else(|| anyhow::anyhow!("scenario has no events"))?;
                let text = last.plan.emit_json()?;
                std::fs::write(path, &text)?;
                println!("\nwrote {path} ({} bytes, round-trip verified)", text.len());
            }
        }
        "plan" => {
            let sub = args.positional.get(1).map(String::as_str).unwrap_or("");
            match sub {
                "diff" => {
                    let (path_a, path_b) =
                        match (args.positional.get(2), args.positional.get(3)) {
                            (Some(a), Some(b)) => (a, b),
                            _ => anyhow::bail!(
                                "usage: bapipe plan diff <a.json> <b.json>"
                            ),
                        };
                    let a = load_plan(path_a)?;
                    let b = load_plan(path_b)?;
                    println!("{}", planner::diff::compare(&a, &b).render());
                }
                other => anyhow::bail!("unknown plan subcommand `{other}` (expected: diff)"),
            }
        }
        "check" => {
            let path = args.positional.get(1).map(String::as_str).ok_or_else(|| {
                anyhow::anyhow!(
                    "usage: bapipe check <plan.json> [--cluster <c> --n <k>]  \
                     (exit 0 clean / 1 warnings / 2 violations)"
                )
            })?;
            let plan = load_plan(path)?;
            // Capacity checks need the cluster the plan was made for; the
            // artifact carries its name in the report but not the device
            // table, so the caller passes it back in.
            let cl = args
                .opt_str("cluster")
                .map(|name| cluster_by_name(name, args.get_usize("n", 4)));
            let report = bapipe::verify::plan_audit(&plan, cl.as_ref());
            println!("{}", report.render(path));
            // The 0/1/2 exit-code contract is the whole point of this
            // subcommand (CI gates on it), so bypass `main`'s Ok path.
            std::process::exit(report.exit_code());
        }
        "partition" => {
            let model = args.get_str("model", "vgg16");
            let net = zoo::by_name(&model)
                .ok_or_else(|| anyhow::anyhow!("unknown model `{model}`"))?;
            let cl = cluster_by_name(&args.get_str("cluster", "v100"), args.get_usize("n", 4));
            let prof = analytical::profile(&net, &cl);
            let plan = bapipe::partition::balanced_partition(
                &net,
                &cl,
                &prof,
                ScheduleKind::OneFOneBSno,
                args.get_f64("micro", 4.0),
                args.get_usize("m", 16),
            )?;
            println!("{} on {}:", net.describe(), cl.describe());
            for note in &plan.notes {
                println!("  {note}");
            }
            println!("  max stage time {:.4} ms", plan.max_stage_time * 1e3);
        }
        "simulate" => {
            let sched = args.get_str("schedule", "1f1b-so");
            let kind = TrainConfig { schedule: sched.clone(), ..Default::default() }
                .schedule_kind()?
                .ok_or_else(|| anyhow::anyhow!("simulate needs a pipeline schedule"))?;
            let n = args.get_usize("n", 3);
            let m = args.get_usize("m", 8);
            let exec = if matches!(kind, ScheduleKind::OneFOneBAs | ScheduleKind::FbpAs) {
                bapipe::cluster::ExecMode::Async
            } else {
                bapipe::cluster::ExecMode::Sync
            };
            let spec = des::SimSpec::uniform(
                kind,
                n,
                m,
                args.get_f64("f", 1.0),
                args.get_f64("b", 2.0),
                args.get_f64("sr", 0.25),
                exec,
            );
            let r = des::simulate(&spec);
            println!(
                "{} N={n} M={m}: makespan {:.2}, bubble {:.1}%",
                kind.label(),
                r.makespan,
                100.0 * r.bubble_fraction
            );
            println!("{}", timeline::render(&r, n, args.get_usize("width", 100)));
        }
        #[cfg(feature = "pjrt")]
        "train" => {
            let mut cfg = match args.opt_str("config") {
                Some(path) => TrainConfig::load(path)?,
                None => TrainConfig::default(),
            };
            if let Some(a) = args.opt_str("artifacts") {
                cfg.artifacts = a.to_string();
            }
            if let Some(s) = args.opt_str("schedule") {
                cfg.schedule = s.to_string();
            }
            cfg.m = args.get_usize("m", cfg.m);
            cfg.steps = args.get_usize("steps", cfg.steps);
            cfg.lr = args.get_f64("lr", cfg.lr as f64) as f32;
            let report = training::train(&cfg)?;
            println!("{}", report.render_curve());
            println!(
                "throughput {:.1} tokens/s, total {:.1}s",
                report.tokens_per_sec, report.total_secs
            );
        }
        #[cfg(feature = "pjrt")]
        "dp" => {
            let mut cfg = TrainConfig::default();
            if let Some(a) = args.opt_str("artifacts") {
                cfg.artifacts = a.to_string();
            }
            cfg.steps = args.get_usize("steps", cfg.steps);
            cfg.lr = args.get_f64("lr", cfg.lr as f64) as f32;
            let rep = dp_engine::train_dp(&cfg, args.get_usize("replicas", 2))?;
            for (s, l) in &rep.curve {
                println!("step {s:>5}  loss {l:.4}");
            }
            println!("throughput {:.1} tokens/s", rep.tokens_per_sec);
        }
        #[cfg(feature = "pjrt")]
        "profile" => {
            let dir = args.get_str("artifacts", "artifacts/lm10m-s4-b4");
            let rt = Runtime::load(&dir)?;
            let times = training::measure_stage_times(&rt, args.get_usize("reps", 3))?;
            println!("measured per-stage times ({}):", dir);
            for (i, (f, b)) in times.iter().enumerate() {
                println!("  stage {i}: fwd {:.2} ms, bwd {:.2} ms", f * 1e3, b * 1e3);
            }
        }
        #[cfg(not(feature = "pjrt"))]
        "train" | "dp" | "profile" => {
            anyhow::bail!(
                "`{cmd}` needs the real XLA/PJRT engine; rebuild with \
                 `cargo build --release --features pjrt` (see rust/vendor/xla)"
            );
        }
        _ => {
            println!(
                "bapipe — balanced pipeline parallelism for DNN training\n\n\
                 usage: bapipe <explore|replan|plan|check|partition|simulate|train|dp|profile> [--key value ...]\n\
                 examples:\n\
                   bapipe explore --model vgg16 --cluster v100 --n 4 --batch 32\n\
                   bapipe explore --model resnet50 --cluster fpga-mixed --n 4 --batch 4 \\\n\
                       --jobs 8 --permute --adaptive-m --emit plan.json\n\
                   bapipe explore --model vgg16 --cluster gpu-mixed --n 16 --batch 8 \\\n\
                       --jobs 8 --permute --order-search --order-budget 512\n\
                       # past 8 devices: neighbourhood search over device orderings\n\
                   bapipe explore --model gnmt-l128 --cluster v100 --n 64 \\\n\
                       --plan-cache plan-cache.json   # 2nd run skips phase A\n\
                   bapipe explore --model gnmt-l64 --cluster v100 --n 8 --pareto --recompute\n\
                       # epoch-time × peak-memory front; 2BW + recomputation axes\n\
                   bapipe explore --model gnmt-l64 --cluster v100 --n 8 --eval-budget 200\n\
                       # anytime stop: best incumbent after 200 candidates\n\
                   bapipe replan --plan plan.json --scenario outage.json \\\n\
                       --model vgg16 --cluster gpu-mixed --n 16 --batch 8 --jobs 8 \\\n\
                       --permute --order-search\n\
                       # warm-started replanning after each scripted cluster event;\n\
                       # scenario JSON: {\"name\": ..., \"events\": [{\"event\": \"device-loss\",\n\
                       #   \"device\": 3}, {\"event\": \"straggler\", \"device\": 0,\n\
                       #   \"slowdown\": 1.6, \"at_mb\": 100}, ...]} — an `at_mb` position\n\
                       #   makes the switch amortize against the epoch remainder\n\
                   bapipe replan --plan plan.json --detect samples.json \\\n\
                       --model vgg16 --cluster gpu-mixed --n 16 --batch 8\n\
                       # the live loop: drift-detect over per-device/per-link timing\n\
                       # samples ({\"name\": ..., \"mb_per_tick\": 4, \"ticks\": [\n\
                       #   {\"device_times\": [...], \"link_times\": [...]}, ...]}),\n\
                       # then replan each synthesized event; thresholds via\n\
                       # --detect-enter 1.25 --detect-exit 1.1 --detect-dwell 3\n\
                   bapipe plan diff old-plan.json new-plan.json\n\
                   bapipe check plan.json --cluster v100 --n 4\n\
                       # static certification, no DES: dependency/transfer/deadlock\n\
                       # proofs + staleness bound + memory certificate + artifact\n\
                       # audit; exit 0 clean, 1 warnings, 2 violations\n\
                   bapipe simulate --schedule 1f1b-so --n 3 --m 8\n\
                   bapipe train --artifacts artifacts/lm10m-s4-b4 --schedule 1f1b --m 8 --steps 50\n\
                   bapipe dp --artifacts artifacts/lm10m-s4-b4 --replicas 2 --steps 20"
            );
        }
    }
    Ok(())
}
