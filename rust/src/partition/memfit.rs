//! Memory model + fine-tune partition based on memory capacity (the last
//! step of Fig. 3): verify every stage's working set fits its device, and
//! if not, shift boundary layers toward neighbours with headroom.

use super::Partition;
use crate::cluster::Cluster;
use crate::profile::range::CostModel;
use crate::schedule::ScheduleKind;

/// Constants of the memory model (per-device overheads beyond raw tensors).
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// Optimizer state bytes per parameter (8 = Adam fp32 moments,
    /// 4 = SGD momentum, 0 = plain SGD).
    pub optimizer_bytes_per_param: u64,
    /// Extra communication buffer bytes per parameter (gradient buckets
    /// for all-reduce; used by the DP baseline).
    pub comm_bytes_per_param: u64,
    /// Framework/runtime reserve per device, bytes (context, workspaces).
    pub framework_reserve: u64,
    /// Fraction of device capacity actually allocatable.
    pub usable_fraction: f64,
}

impl Default for MemoryModel {
    fn default() -> Self {
        MemoryModel {
            optimizer_bytes_per_param: 8,
            comm_bytes_per_param: 0,
            framework_reserve: 700 << 20, // 700 MiB
            usable_fraction: 0.95,
        }
    }
}

impl MemoryModel {
    /// The model used for DP baselines (adds the all-reduce bucket).
    pub fn data_parallel() -> Self {
        MemoryModel { comm_bytes_per_param: 4, ..Default::default() }
    }

    /// Usable bytes on a device.
    pub fn usable(&self, capacity: u64) -> u64 {
        ((capacity as f64 * self.usable_fraction) as u64).saturating_sub(self.framework_reserve)
    }
}

/// Peak memory (bytes) of stage `i` of `n` under schedule `kind` with
/// micro-batch size `micro` and `m` micro-batches per mini-batch.
/// Generic over [`CostModel`]: byte-range queries are bit-exact between
/// `Profile` sums and `RangeCost` prefix differences, so the fine-tune's
/// decisions are identical for either backing.
pub fn stage_memory_bytes<C: CostModel>(
    costs: &C,
    mm: &MemoryModel,
    kind: ScheduleKind,
    n: usize,
    i: usize,
    range: std::ops::Range<usize>,
    micro: f64,
    m: usize,
) -> u64 {
    let w = costs.param_bytes(range.start, range.end);
    let params = w / costs.dtype_bytes();
    // working weights + gradient accumulator + stashed versions
    let weights = (2 + kind.weight_versions(n, i)) as u64 * w;
    let opt = params * mm.optimizer_bytes_per_param;
    let comm = params * mm.comm_bytes_per_param;
    // activation stash: per in-flight micro-batch, everything BP needs
    let stash =
        kind.stash_depth(n, i, m) as u64 * (costs.stash_bytes(range.start, range.end) as f64 * micro) as u64;
    // boundary I/O buffers (double-buffered in and out)
    let io = 2 * (costs.stage_in_bytes(range.start) as f64 * micro) as u64
        + 2 * (costs.cut_bytes(range.end - 1) as f64 * micro) as u64;
    weights + opt + comm + stash + io
}

/// Memory of the whole net on one device under data parallelism with
/// per-device batch `b` (baseline; stores *all* activations of a batch).
pub fn dp_memory_bytes<C: CostModel>(costs: &C, mm: &MemoryModel, b: f64) -> u64 {
    let l = costs.n_layers();
    let w = costs.param_bytes(0, l);
    let params = w / costs.dtype_bytes();
    let weights = 2 * w;
    let opt = params * mm.optimizer_bytes_per_param;
    let comm = params * mm.comm_bytes_per_param;
    let stash = (costs.stash_bytes(0, l) as f64 * b) as u64;
    weights + opt + comm + stash
}

/// Result of the memory fine-tune pass.
#[derive(Debug, Clone)]
pub struct FitResult {
    /// The (possibly adjusted) partition.
    pub partition: Partition,
    /// How many boundary moves were needed.
    pub moved: usize,
}

/// Fine-tune `part` until every stage fits its device (or fail). Boundary
/// moves stay on legal cuts (`cuts` are layer indices after which cutting
/// is allowed).
pub fn fit_memory<C: CostModel>(
    costs: &C,
    cluster: &Cluster,
    part: Partition,
    kind: ScheduleKind,
    micro: f64,
    m: usize,
    cuts: &[usize],
) -> crate::Result<FitResult> {
    let mm = MemoryModel::default();
    let legal: std::collections::BTreeSet<usize> = cuts.iter().map(|&c| c + 1).collect();
    let n = part.n_stages();
    let mut cur = part;
    let mut moved = 0usize;
    let max_moves = 4 * costs.n_layers();

    let usage = |p: &Partition, i: usize| -> i64 {
        let used = stage_memory_bytes(costs, &mm, kind, n, i, p.stage(i), micro, m);
        used as i64 - mm.usable(cluster.devices[i].mem_capacity) as i64
    };

    loop {
        // find the most-violating stage
        let mut worst = None;
        let mut worst_over = 0i64;
        for i in 0..n {
            let over = usage(&cur, i);
            if over > worst_over {
                worst_over = over;
                worst = Some(i);
            }
        }
        let Some(i) = worst else {
            return Ok(FitResult { partition: cur, moved });
        };
        if moved >= max_moves {
            anyhow::bail!(
                "memory fine-tune failed: stage {i} over budget by {} after {moved} moves",
                crate::util::fmt_bytes(worst_over as u64)
            );
        }
        // Try shrinking stage i from either side toward a neighbour with
        // headroom; pick the move that most reduces the global violation.
        let mut best: Option<(usize, usize)> = None; // (bound index, new bound)
        let mut best_score = worst_over;
        // left boundary moves right (give first layers to stage i-1)
        if i > 0 {
            if let Some(&nb) = legal.range(cur.bounds[i] + 1..cur.bounds[i + 1]).next() {
                let mut b2 = cur.bounds.clone();
                b2[i] = nb;
                let cand = Partition::new(b2, *cur.bounds.last().unwrap());
                let score = (0..n).map(|s| usage(&cand, s).max(0)).max().unwrap();
                if score < best_score {
                    best_score = score;
                    best = Some((i, nb));
                }
            }
        }
        // right boundary moves left (give last layers to stage i+1)
        if i + 1 < n {
            if let Some(&nb) = legal.range(cur.bounds[i] + 1..cur.bounds[i + 1]).next_back() {
                let mut b2 = cur.bounds.clone();
                b2[i + 1] = nb;
                let cand = Partition::new(b2, *cur.bounds.last().unwrap());
                let score = (0..n).map(|s| usage(&cand, s).max(0)).max().unwrap();
                if score < best_score {
                    best = Some((i + 1, nb));
                }
            }
        }
        match best {
            Some((bi, nb)) => {
                cur.bounds[bi] = nb;
                moved += 1;
            }
            None => anyhow::bail!(
                "memory fine-tune failed: stage {i} over budget by {} and no boundary move helps",
                crate::util::fmt_bytes(worst_over as u64)
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::partition::interlayer;
    use crate::profile::analytical;

    #[test]
    fn stage_memory_components() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(2);
        let prof = analytical::profile(&net, &cl);
        let mm = MemoryModel::default();
        let all = net.len();
        // one stage owning everything ≈ DP memory minus comm buffer
        let m1 = stage_memory_bytes(
            &prof, &mm, ScheduleKind::OneFOneBSno, 1, 0, 0..all, 1.0, 1,
        );
        let dp = dp_memory_bytes(&prof, &mm, 1.0);
        let rel = (m1 as f64 - dp as f64).abs() / dp as f64;
        assert!(rel < 0.1, "single-stage pipeline ≈ DP: {m1} vs {dp}");
    }

    #[test]
    fn so_needs_more_activation_memory_than_sno() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let mm = MemoryModel::default();
        let r = 0..5;
        let sno = stage_memory_bytes(&prof, &mm, ScheduleKind::OneFOneBSno, 4, 0, r.clone(), 4.0, 16);
        let so = stage_memory_bytes(&prof, &mm, ScheduleKind::OneFOneBSo, 4, 0, r, 4.0, 16);
        assert!(so > sno, "SO {so} should exceed SNO {sno}");
    }

    #[test]
    fn gpipe_memory_grows_with_m() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let mm = MemoryModel::default();
        let a = stage_memory_bytes(&prof, &mm, ScheduleKind::GPipe, 4, 0, 0..5, 4.0, 4);
        let b = stage_memory_bytes(&prof, &mm, ScheduleKind::GPipe, 4, 0, 0..5, 4.0, 32);
        assert!(b > a);
    }

    #[test]
    fn fit_noop_when_memory_ample() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let p = interlayer::dp_optimal(&prof, &cl, &cuts, 4.0, None).unwrap();
        let r = fit_memory(&prof, &cl, p.clone(), ScheduleKind::OneFOneBSno, 4.0, 8, &cuts)
            .unwrap();
        assert_eq!(r.moved, 0);
        assert_eq!(r.partition, p);
    }

    #[test]
    fn fit_fails_when_model_cannot_fit() {
        // A giant GNMT on a single 16GB V100 cannot fit.
        let net = zoo::gnmt_l(158);
        let cl = presets::v100_cluster(1);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let p = Partition::new(vec![0, net.len()], net.len());
        assert!(fit_memory(&prof, &cl, p, ScheduleKind::OneFOneBSno, 32.0, 2, &cuts).is_err());
    }

    #[test]
    fn fit_moves_layers_off_overloaded_stage() {
        // Force an unbalanced seed on a big model: stage 0 owns almost
        // everything. The fine-tune must shift layers right.
        let net = zoo::gnmt_l(60);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let l = net.len();
        let p = Partition::new(vec![0, l - 3, l - 2, l - 1, l], l);
        let r = fit_memory(&prof, &cl, p, ScheduleKind::OneFOneBSno, 32.0, 8, &cuts).unwrap();
        assert!(r.moved > 0);
        // first stage now owns fewer layers
        assert!(r.partition.bounds[1] < l - 3);
    }
}
