//! Memory model + fine-tune partition based on memory capacity (the last
//! step of Fig. 3): verify every stage's working set fits its device, and
//! if not, shift boundary layers toward neighbours with headroom.

use super::Partition;
use crate::cluster::Cluster;
use crate::profile::range::CostModel;
use crate::schedule::ScheduleKind;

/// Constants of the memory model (per-device overheads beyond raw tensors).
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// Optimizer state bytes per parameter (8 = Adam fp32 moments,
    /// 4 = SGD momentum, 0 = plain SGD).
    pub optimizer_bytes_per_param: u64,
    /// Extra communication buffer bytes per parameter (gradient buckets
    /// for all-reduce; used by the DP baseline).
    pub comm_bytes_per_param: u64,
    /// Framework/runtime reserve per device, bytes (context, workspaces).
    pub framework_reserve: u64,
    /// Fraction of device capacity actually allocatable.
    pub usable_fraction: f64,
}

impl Default for MemoryModel {
    fn default() -> Self {
        MemoryModel {
            optimizer_bytes_per_param: 8,
            comm_bytes_per_param: 0,
            framework_reserve: 700 << 20, // 700 MiB
            usable_fraction: 0.95,
        }
    }
}

impl MemoryModel {
    /// The model used for DP baselines (adds the all-reduce bucket).
    pub fn data_parallel() -> Self {
        MemoryModel { comm_bytes_per_param: 4, ..Default::default() }
    }

    /// Usable bytes on a device.
    pub fn usable(&self, capacity: u64) -> u64 {
        ((capacity as f64 * self.usable_fraction) as u64).saturating_sub(self.framework_reserve)
    }
}

/// Kind- and recompute-aware per-stage byte components — the **single
/// source of truth** for memory pricing. The memory fine-tune
/// ([`fit_memory`]), the planner's feasibility check and its
/// simulated-peak derivation all price bytes through this struct, so a
/// plan the fine-tune accepts is priced in exactly the bytes the
/// simulator reports. The kind-aware multipliers are the Tables 1–2 rows
/// ([`ScheduleKind::stash_depth`] / [`ScheduleKind::weight_versions`]),
/// shared with `schedule::analytical::features_memory` /
/// `weights_memory`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageBytes {
    /// Occupancy-independent bytes: weights + gradient accumulator +
    /// stashed weight versions + optimizer state + comm buffers +
    /// boundary I/O buffers (+ one micro-batch of recompute workspace
    /// when recomputation is on).
    pub static_bytes: u64,
    /// Bytes stashed per in-flight micro-batch: the full intermediate
    /// stash, or boundary-only input under recomputation.
    pub per_mb_stash: u64,
    /// The schedule's worst-case stash depth (in-flight micro-batches).
    pub stash_depth: usize,
}

impl StageBytes {
    /// Worst-case peak: every stash slot the schedule can fill, filled.
    pub fn peak(&self) -> u64 {
        self.at_occupancy(self.stash_depth)
    }

    /// Bytes when `in_flight` micro-batches are live — the simulated-peak
    /// figure once `in_flight` is the DES high-water mark.
    pub fn at_occupancy(&self, in_flight: usize) -> u64 {
        self.static_bytes + in_flight as u64 * self.per_mb_stash
    }
}

/// Price stage `i` of `n` under schedule `kind` with micro-batch size
/// `micro` and `m` micro-batches per mini-batch. Generic over
/// [`CostModel`]: byte-range queries are bit-exact between `Profile`
/// sums and `RangeCost` prefix differences, so the fine-tune's decisions
/// are identical for either backing.
///
/// With `recompute`, only the stage's boundary input is stashed per
/// in-flight micro-batch; the intermediates of **one** micro-batch are
/// regenerated in a static workspace during its backward (the extra
/// forward FLOPs are priced into the DES spec by the planner).
pub fn stage_bytes<C: CostModel>(
    costs: &C,
    mm: &MemoryModel,
    kind: ScheduleKind,
    recompute: bool,
    n: usize,
    i: usize,
    range: std::ops::Range<usize>,
    micro: f64,
    m: usize,
) -> StageBytes {
    let w = costs.param_bytes(range.start, range.end);
    let params = w / costs.dtype_bytes();
    // working weights + gradient accumulator + stashed versions
    let weights = (2 + kind.weight_versions(n, i)) as u64 * w;
    let opt = params * mm.optimizer_bytes_per_param;
    let comm = params * mm.comm_bytes_per_param;
    // boundary I/O buffers (double-buffered in and out)
    let io = 2 * (costs.stage_in_bytes(range.start) as f64 * micro) as u64
        + 2 * (costs.cut_bytes(range.end - 1) as f64 * micro) as u64;
    let full_stash = (costs.stash_bytes(range.start, range.end) as f64 * micro) as u64;
    let (per_mb_stash, workspace) = if recompute {
        // boundary input per in-flight micro-batch + one micro-batch of
        // regenerated intermediates live during a backward
        ((costs.stage_in_bytes(range.start) as f64 * micro) as u64, full_stash)
    } else {
        (full_stash, 0)
    };
    StageBytes {
        static_bytes: weights + opt + comm + io + workspace,
        per_mb_stash,
        stash_depth: kind.stash_depth(n, i, m),
    }
}

/// Peak memory (bytes) of stage `i` of `n` — the worst-case
/// ([`StageBytes::peak`]) view of [`stage_bytes`].
pub fn stage_memory_bytes<C: CostModel>(
    costs: &C,
    mm: &MemoryModel,
    kind: ScheduleKind,
    recompute: bool,
    n: usize,
    i: usize,
    range: std::ops::Range<usize>,
    micro: f64,
    m: usize,
) -> u64 {
    stage_bytes(costs, mm, kind, recompute, n, i, range, micro, m).peak()
}

/// Bytes of **persistent** training state bound to layers `lo..hi`: one
/// working copy of the weights plus the optimizer state. This is what a
/// migration physically moves when a stage boundary shift reassigns the
/// layers to another device — activations/stashes drain with the
/// pipeline and gradient accumulators restart at zero, so neither
/// transfers. `planner::diff` prices replan migration reports with this.
pub fn movable_state_bytes<C: CostModel>(
    costs: &C,
    mm: &MemoryModel,
    lo: usize,
    hi: usize,
) -> u64 {
    let w = costs.param_bytes(lo, hi);
    let params = w / costs.dtype_bytes();
    w + params * mm.optimizer_bytes_per_param
}

/// Memory of the whole net on one device under data parallelism with
/// per-device batch `b` (baseline; stores *all* activations of a batch).
pub fn dp_memory_bytes<C: CostModel>(costs: &C, mm: &MemoryModel, b: f64) -> u64 {
    let l = costs.n_layers();
    let w = costs.param_bytes(0, l);
    let params = w / costs.dtype_bytes();
    let weights = 2 * w;
    let opt = params * mm.optimizer_bytes_per_param;
    let comm = params * mm.comm_bytes_per_param;
    let stash = (costs.stash_bytes(0, l) as f64 * b) as u64;
    weights + opt + comm + stash
}

/// Result of the memory fine-tune pass.
#[derive(Debug, Clone)]
pub struct FitResult {
    /// The (possibly adjusted) partition.
    pub partition: Partition,
    /// How many boundary moves were needed.
    pub moved: usize,
}

/// Fine-tune `part` until every stage fits its device (or fail). Boundary
/// moves stay on legal cuts (`cuts` are layer indices after which cutting
/// is allowed).
pub fn fit_memory<C: CostModel>(
    costs: &C,
    cluster: &Cluster,
    part: Partition,
    kind: ScheduleKind,
    recompute: bool,
    micro: f64,
    m: usize,
    cuts: &[usize],
) -> crate::Result<FitResult> {
    let mm = MemoryModel::default();
    let legal: std::collections::BTreeSet<usize> = cuts.iter().map(|&c| c + 1).collect();
    let n = part.n_stages();
    let mut cur = part;
    let mut moved = 0usize;
    let max_moves = 4 * costs.n_layers();

    let usage = |p: &Partition, i: usize| -> i64 {
        let used = stage_memory_bytes(costs, &mm, kind, recompute, n, i, p.stage(i), micro, m);
        used as i64 - mm.usable(cluster.devices[i].mem_capacity) as i64
    };

    loop {
        // find the most-violating stage
        let mut worst = None;
        let mut worst_over = 0i64;
        for i in 0..n {
            let over = usage(&cur, i);
            if over > worst_over {
                worst_over = over;
                worst = Some(i);
            }
        }
        let Some(i) = worst else {
            return Ok(FitResult { partition: cur, moved });
        };
        if moved >= max_moves {
            anyhow::bail!(
                "memory fine-tune failed: stage {i} over budget by {} after {moved} moves",
                crate::util::fmt_bytes(worst_over as u64)
            );
        }
        // Try shrinking stage i from either side toward a neighbour with
        // headroom; pick the move that most reduces the global violation.
        let mut best: Option<(usize, usize)> = None; // (bound index, new bound)
        let mut best_score = worst_over;
        // left boundary moves right (give first layers to stage i-1)
        if i > 0 {
            if let Some(&nb) = legal.range(cur.bounds[i] + 1..cur.bounds[i + 1]).next() {
                let mut b2 = cur.bounds.clone();
                b2[i] = nb;
                let cand = Partition::new(b2, *cur.bounds.last().unwrap());
                let score = (0..n).map(|s| usage(&cand, s).max(0)).max().unwrap();
                if score < best_score {
                    best_score = score;
                    best = Some((i, nb));
                }
            }
        }
        // right boundary moves left (give last layers to stage i+1)
        if i + 1 < n {
            if let Some(&nb) = legal.range(cur.bounds[i] + 1..cur.bounds[i + 1]).next_back() {
                let mut b2 = cur.bounds.clone();
                b2[i + 1] = nb;
                let cand = Partition::new(b2, *cur.bounds.last().unwrap());
                let score = (0..n).map(|s| usage(&cand, s).max(0)).max().unwrap();
                if score < best_score {
                    best = Some((i + 1, nb));
                }
            }
        }
        match best {
            Some((bi, nb)) => {
                cur.bounds[bi] = nb;
                moved += 1;
            }
            None => anyhow::bail!(
                "memory fine-tune failed: stage {i} over budget by {} and no boundary move helps",
                crate::util::fmt_bytes(worst_over as u64)
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::partition::interlayer;
    use crate::profile::analytical;

    #[test]
    fn stage_memory_components() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(2);
        let prof = analytical::profile(&net, &cl);
        let mm = MemoryModel::default();
        let all = net.len();
        // one stage owning everything ≈ DP memory minus comm buffer
        let m1 = stage_memory_bytes(
            &prof, &mm, ScheduleKind::OneFOneBSno, false, 1, 0, 0..all, 1.0, 1,
        );
        let dp = dp_memory_bytes(&prof, &mm, 1.0);
        let rel = (m1 as f64 - dp as f64).abs() / dp as f64;
        assert!(rel < 0.1, "single-stage pipeline ≈ DP: {m1} vs {dp}");
    }

    #[test]
    fn so_needs_more_activation_memory_than_sno() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let mm = MemoryModel::default();
        let r = 0..5;
        let sno =
            stage_memory_bytes(&prof, &mm, ScheduleKind::OneFOneBSno, false, 4, 0, r.clone(), 4.0, 16);
        let so = stage_memory_bytes(&prof, &mm, ScheduleKind::OneFOneBSo, false, 4, 0, r, 4.0, 16);
        assert!(so > sno, "SO {so} should exceed SNO {sno}");
    }

    #[test]
    fn kind_aware_pricing_matches_analytical_rows() {
        // Satellite regression: memfit and the analytical Tables 1–2
        // memory rows must price the *same* kind-aware bytes. Everything
        // except weights-versions and stash is kind-independent, so for
        // any kind pair the memfit byte difference must equal the
        // analytical (weights_memory + features_memory) difference — on
        // a pair whose *ranking* differs with depth: PipeDream outweighs
        // 2BW on early stages of a deep pipe (n-i-1 vs 1 stashed weight
        // versions), while GPipe out-stashes both at large M.
        use crate::schedule::analytical::{features_memory, weights_memory, Symbols};
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(8);
        let prof = analytical::profile(&net, &cl);
        let mm = MemoryModel::default();
        let (n, m, micro) = (8usize, 16usize, 4.0f64);
        let r = 0..5usize;
        let a = (prof.stash_bytes(r.start, r.end) as f64 * micro) as u64;
        let w = prof.param_bytes(r.start, r.end);
        let kinds = ScheduleKind::all();
        for ka in kinds {
            for kb in kinds {
                let ma = stage_memory_bytes(&prof, &mm, ka, false, n, 0, r.clone(), micro, m);
                let mb = stage_memory_bytes(&prof, &mm, kb, false, n, 0, r.clone(), micro, m);
                let s = Symbols { m, n, f: 1.0, b: 1.0, sr: 0.0, a: a as f64, w: w as f64 };
                let oracle_a = weights_memory(ka, &s, 1) + features_memory(ka, &s, 1);
                let oracle_b = weights_memory(kb, &s, 1) + features_memory(kb, &s, 1);
                assert_eq!(
                    ma as i64 - mb as i64,
                    (oracle_a - oracle_b) as i64,
                    "{ka:?} vs {kb:?}: memfit and analytical disagree on kind-aware bytes"
                );
            }
        }
        // the ranking-flip pair the shared helper must get right
        let pd = stage_memory_bytes(&prof, &mm, ScheduleKind::PipeDream, false, n, 0, r.clone(), micro, m);
        let bw = stage_memory_bytes(&prof, &mm, ScheduleKind::TwoBW, false, n, 0, r.clone(), micro, m);
        assert!(pd > bw, "deep-pipe stage 0: PipeDream {pd} must outweigh 2BW {bw}");
        let pd_last =
            stage_memory_bytes(&prof, &mm, ScheduleKind::PipeDream, false, n, n - 1, r.clone(), micro, m);
        let bw_last =
            stage_memory_bytes(&prof, &mm, ScheduleKind::TwoBW, false, n, n - 1, r, micro, m);
        assert!(bw_last > pd_last, "last stage: 2BW {bw_last} still buffers, PipeDream {pd_last} does not");
    }

    #[test]
    fn recompute_trades_stash_for_workspace() {
        // Recompute collapses the per-micro-batch stash to the boundary
        // input and adds one micro-batch of workspace: with a deep stash
        // (early stage of a long pipe, activation-heavy net) that is a
        // large net win; with stash depth 1 (last stage) it can only be
        // a wash or worse.
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(8);
        let prof = analytical::profile(&net, &cl);
        let mm = MemoryModel::default();
        let (n, m, micro) = (8usize, 32usize, 4.0f64);
        let r = 0..5usize;
        let full = stage_bytes(&prof, &mm, ScheduleKind::TwoBW, false, n, 0, r.clone(), micro, m);
        let rc = stage_bytes(&prof, &mm, ScheduleKind::TwoBW, true, n, 0, r.clone(), micro, m);
        assert!(rc.per_mb_stash < full.per_mb_stash, "boundary-only stash must shrink");
        assert!(
            rc.peak() < full.peak(),
            "recompute peak {} must beat full stash {} at depth {}",
            rc.peak(),
            full.peak(),
            full.stash_depth
        );
        // same stash depth either way: recompute changes bytes, not the schedule
        assert_eq!(rc.stash_depth, full.stash_depth);
        let last_full = stage_bytes(&prof, &mm, ScheduleKind::TwoBW, false, n, n - 1, r.clone(), micro, m);
        let last_rc = stage_bytes(&prof, &mm, ScheduleKind::TwoBW, true, n, n - 1, r, micro, m);
        assert!(last_rc.peak() >= last_full.peak(), "depth-1 stash: workspace cancels the saving");
    }

    #[test]
    fn movable_state_is_weights_plus_optimizer() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(2);
        let prof = analytical::profile(&net, &cl);
        let mm = MemoryModel::default();
        let l = net.len();
        let w = prof.param_bytes(0, l);
        let params = w / prof.dtype_bytes();
        assert_eq!(movable_state_bytes(&prof, &mm, 0, l), w + params * 8);
        // additive over a split
        let mid = l / 2;
        assert_eq!(
            movable_state_bytes(&prof, &mm, 0, mid) + movable_state_bytes(&prof, &mm, mid, l),
            movable_state_bytes(&prof, &mm, 0, l)
        );
        // empty range moves nothing
        assert_eq!(movable_state_bytes(&prof, &mm, 3, 3), 0);
    }

    #[test]
    fn gpipe_memory_grows_with_m() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let mm = MemoryModel::default();
        let a = stage_memory_bytes(&prof, &mm, ScheduleKind::GPipe, false, 4, 0, 0..5, 4.0, 4);
        let b = stage_memory_bytes(&prof, &mm, ScheduleKind::GPipe, false, 4, 0, 0..5, 4.0, 32);
        assert!(b > a);
    }

    #[test]
    fn fit_noop_when_memory_ample() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let p = interlayer::dp_optimal(&prof, &cl, &cuts, 4.0, None).unwrap();
        let r = fit_memory(&prof, &cl, p.clone(), ScheduleKind::OneFOneBSno, false, 4.0, 8, &cuts)
            .unwrap();
        assert_eq!(r.moved, 0);
        assert_eq!(r.partition, p);
    }

    #[test]
    fn fit_fails_when_model_cannot_fit() {
        // A giant GNMT on a single 16GB V100 cannot fit.
        let net = zoo::gnmt_l(158);
        let cl = presets::v100_cluster(1);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let p = Partition::new(vec![0, net.len()], net.len());
        assert!(fit_memory(&prof, &cl, p, ScheduleKind::OneFOneBSno, false, 32.0, 2, &cuts).is_err());
    }

    #[test]
    fn fit_moves_layers_off_overloaded_stage() {
        // Force an unbalanced seed on a big model: stage 0 owns almost
        // everything. The fine-tune must shift layers right.
        let net = zoo::gnmt_l(60);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let l = net.len();
        let p = Partition::new(vec![0, l - 3, l - 2, l - 1, l], l);
        let r = fit_memory(&prof, &cl, p, ScheduleKind::OneFOneBSno, false, 32.0, 8, &cuts).unwrap();
        assert!(r.moved > 0);
        // first stage now owns fewer layers
        assert!(r.partition.bounds[1] < l - 3);
    }
}
