//! Balanced partition (Section 3.3): splitting the layer list into N
//! contiguous stages balancing compute, communication and memory.
//!
//! The full Fig.-3 flow lives in [`balanced_partition`]:
//! 1. inter-layer partition ([`interlayer`] — Eq. 1 seed, iterative
//!    refinement, and a DP-optimal variant),
//! 2. coarse-grained partition when communication is the bottleneck
//!    ([`coarse`] — only cut where activations are below `a_th`),
//! 3. intra-layer partition when it is not ([`intralayer`] — fractional
//!    boundary layers, FPDeep-style),
//! 4. fine-tune for memory capacity ([`memfit`]).

pub mod coarse;
pub mod interlayer;
pub mod intralayer;
pub mod memfit;

use crate::cluster::{Cluster, ExecMode};
use crate::profile::range::{CostModel, RangeCost};
use crate::schedule::ScheduleKind;

/// A partition of layers `0..L` into contiguous stages. `bounds` has
/// `n_stages+1` entries: stage `i` owns layers `bounds[i]..bounds[i+1]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    /// Stage boundaries (monotone, `bounds[0]==0`, `bounds[n]==L`).
    pub bounds: Vec<usize>,
}

impl Partition {
    /// Build from boundaries; validates shape.
    pub fn new(bounds: Vec<usize>, n_layers: usize) -> Partition {
        assert!(bounds.len() >= 2, "need at least one stage");
        assert_eq!(bounds[0], 0, "first bound must be 0");
        assert_eq!(*bounds.last().unwrap(), n_layers, "last bound must be L");
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "stages must be non-empty & ordered");
        Partition { bounds }
    }

    /// Number of stages.
    pub fn n_stages(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Layer range of stage `i`.
    pub fn stage(&self, i: usize) -> std::ops::Range<usize> {
        self.bounds[i]..self.bounds[i + 1]
    }

    /// Which stage owns layer `l`.
    pub fn stage_of(&self, l: usize) -> usize {
        match self.bounds.binary_search(&l) {
            Ok(i) => i.min(self.n_stages() - 1),
            Err(i) => i - 1,
        }
    }

    /// Human-readable, e.g. `[0..5 | 5..9 | 9..22]`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> =
            (0..self.n_stages()).map(|i| format!("{}..{}", self.bounds[i], self.bounds[i + 1])).collect();
        format!("[{}]", parts.join(" | "))
    }
}

/// Per-stage forward/backward compute times (seconds per micro-batch),
/// including the FPGA weight-spill penalty: when a stage's weights exceed
/// the device's on-chip capacity, weights stream from DDR every
/// micro-batch and the stage becomes weight-bandwidth-bound (the Table 6
/// effect; Section 4.3 "guarantee weights of each stage are stored in
/// on-chip memory as much as possible"). Generic over [`CostModel`]: the
/// planner passes [`RangeCost`] prefix tables (O(1) per range), ad-hoc
/// callers a bare `&Profile`.
pub fn stage_costs<C: CostModel>(
    costs: &C,
    cluster: &Cluster,
    part: &Partition,
    micro: f64,
) -> Vec<(f64, f64)> {
    assert_eq!(part.n_stages(), cluster.len(), "one stage per device");
    (0..part.n_stages())
        .map(|i| {
            let r = part.stage(i);
            let dev = &cluster.devices[i];
            let mut f = costs.fwd_time(i, r.start, r.end, micro);
            let mut b = costs.bwd_time(i, r.start, r.end, micro);
            if dev.exec == ExecMode::Async && dev.onchip_capacity > 0 {
                let w_bytes = costs.param_bytes(r.start, r.end) as f64;
                // ~75% of BRAM/URAM usable for weights (rest: buffers).
                if w_bytes > 0.75 * dev.onchip_capacity as f64 {
                    // Weight streaming from DDR bounds each pass.
                    let stream = w_bytes / dev.mem_bw;
                    f = f.max(stream);
                    b = b.max(2.0 * stream); // read weights + write gradients
                }
            }
            (f, b)
        })
        .collect()
}

/// Communication time (seconds) to ship one micro-batch's activations
/// across the cut after stage `i` (same-size errors flow back in BP).
pub fn cut_comm_time<C: CostModel>(
    costs: &C,
    cluster: &Cluster,
    part: &Partition,
    micro: f64,
    i: usize,
) -> f64 {
    let cut_layer = part.bounds[i + 1] - 1;
    let bytes = costs.cut_bytes(cut_layer) as f64 * micro;
    cluster.link(i).xfer_time(bytes)
}

/// Result of the full balanced-partition flow.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    /// The chosen inter-layer partition.
    pub partition: Partition,
    /// Fractional refinement (FPGA intra-layer partition), if applied.
    pub frac: Option<intralayer::FracPartition>,
    /// Activation threshold `a_th` (bytes) if the coarse-grained pass ran.
    pub coarse_threshold: Option<f64>,
    /// Max per-stage (F+B) time at micro-batch 1 after balancing.
    pub max_stage_time: f64,
    /// Flow notes for reports (which passes fired).
    pub notes: Vec<String>,
}

/// The schedule-independent result of the first three Fig.-3 passes
/// (inter-layer DP, coarse-grained restriction, intra-layer fractional
/// refinement). Only the final memory fine-tune consults the schedule
/// kind (stash depths / weight versions), so this seed can be computed
/// once per `micro` and shared across every schedule candidate — which
/// is what the planner's `EvalCache` does.
#[derive(Debug, Clone)]
pub struct BalanceSeed {
    /// Partition after passes 1–3 (before the memory fine-tune).
    pub partition: Partition,
    /// Fractional refinement (intra-layer partition), if applied.
    pub frac: Option<intralayer::FracPartition>,
    /// Activation threshold `a_th` (bytes) if the coarse-grained pass ran.
    pub coarse_threshold: Option<f64>,
    /// The cut set the memory fine-tune must stay on (coarse if it ran).
    pub active_cuts: Vec<usize>,
    /// Flow notes so far (which passes fired).
    pub notes: Vec<String>,
}

/// Passes 1–3 of the Fig.-3 flow: everything that does not depend on the
/// schedule kind or micro-batch count. See [`balanced_partition`].
///
/// Builds the [`RangeCost`] prefix tables once and runs the whole flow on
/// them; callers that already hold tables for this profile (the planner's
/// phase-A prewarm shares one set per permuted view across the entire
/// micro grid) should use [`balance_stages_rc`].
pub fn balance_stages(
    net: &crate::model::Network,
    cluster: &Cluster,
    profile: &crate::profile::Profile,
    micro: f64,
) -> crate::Result<BalanceSeed> {
    let rc = RangeCost::build(profile);
    balance_stages_rc(net, cluster, &rc, micro)
}

/// [`balance_stages`] against caller-owned prefix tables: every range
/// probe of the inter-layer DP, the communication-bound test, the coarse
/// restriction and the fractional refinement is O(1).
pub fn balance_stages_rc(
    net: &crate::model::Network,
    cluster: &Cluster,
    rc: &RangeCost,
    micro: f64,
) -> crate::Result<BalanceSeed> {
    let mut notes = Vec::new();
    let cuts = net.legal_cuts();
    anyhow::ensure!(
        cuts.len() + 1 >= cluster.len(),
        "{} legal cut points cannot make {} stages",
        cuts.len(),
        cluster.len()
    );

    // 1. Inter-layer partition (Eq. 1 seed + refinement; DP-optimal is
    //    equivalent here and used as the implementation).
    let mut part = interlayer::dp_optimal_rc(rc, cluster, &cuts, micro, None)?;
    notes.push(format!("inter-layer: {}", part.describe()));

    // 2. Communication bottleneck? (Fig. 3 decision diamond.) On sync
    //    (GLOO half-duplex) clusters the edge carries activation + error
    //    per micro-batch, so the round trip is what competes with F+B.
    let duplex_factor = if cluster.all_async() { 1.0 } else { 2.0 };
    let is_comm_bound = |p: &Partition| -> bool {
        let costs = stage_costs(rc, cluster, p, micro);
        let max_comp = costs.iter().map(|(f, b)| f + b).fold(0.0, f64::max);
        (0..p.n_stages() - 1)
            .map(|i| duplex_factor * cut_comm_time(rc, cluster, p, micro, i))
            .fold(0.0, f64::max)
            > max_comp
    };

    let mut coarse_threshold = None;
    if cluster.len() > 1 && is_comm_bound(&part) {
        // Coarse-grained partition: restrict cuts to edges whose
        // activation is below a_th, then repartition (Section 3.3.3).
        let costs = stage_costs(rc, cluster, &part, micro);
        let t_target = costs.iter().map(|(f, b)| f + b).fold(0.0, f64::max);
        let min_bw = cluster.links.iter().map(|l| l.bandwidth).fold(f64::INFINITY, f64::min);
        let a_th = t_target * min_bw / (duplex_factor * micro); // bytes per sample
        let coarse_cuts = coarse::allowed_cuts(rc, &cuts, a_th);
        anyhow::ensure!(
            coarse_cuts.len() + 1 >= cluster.len(),
            "coarse partition infeasible: only {} cuts below a_th for {} stages",
            coarse_cuts.len(),
            cluster.len()
        );
        part = interlayer::dp_optimal_rc(rc, cluster, &coarse_cuts, micro, None)?;
        coarse_threshold = Some(a_th);
        notes.push(format!("coarse (a_th={:.0} B/sample): {}", a_th, part.describe()));
    }

    // 3. Intra-layer partition — only when communication is NOT the
    //    bottleneck (it adds communication; Section 3.3.2). The paper
    //    applies it to both FPGA clusters (fine-grained pipeline) and
    //    GPU clusters (boundary-layer tensor slice).
    let mut frac = None;
    if cluster.len() > 1 && !is_comm_bound(&part) {
        let fp = intralayer::refine_fractional(rc, cluster, &part, micro);
        if fp.imbalance_after < fp.imbalance_before - 1e-9 {
            notes.push(format!(
                "intra-layer: imbalance {:.4} → {:.4}",
                fp.imbalance_before, fp.imbalance_after
            ));
            frac = Some(fp);
        }
    }

    // The memory fine-tune must stay on the active cut set (coarse if it
    // ran).
    let active_cuts = match coarse_threshold {
        Some(a_th) => coarse::allowed_cuts(rc, &cuts, a_th),
        None => cuts,
    };
    Ok(BalanceSeed { partition: part, frac, coarse_threshold, active_cuts, notes })
}

/// Pass 4 of the Fig.-3 flow: fine-tune a [`BalanceSeed`] for the memory
/// footprint of one schedule kind / micro-batch count. Generic over
/// [`CostModel`] — byte-range queries are bit-exact between `Profile`
/// and [`RangeCost`], so both backings finish to identical partitions.
pub fn finish_partition<C: CostModel>(
    cluster: &Cluster,
    costs: &C,
    seed: &BalanceSeed,
    kind: ScheduleKind,
    recompute: bool,
    micro: f64,
    m: usize,
) -> crate::Result<PartitionPlan> {
    let mut notes = seed.notes.clone();
    let fitted = memfit::fit_memory(
        costs,
        cluster,
        seed.partition.clone(),
        kind,
        recompute,
        micro,
        m,
        &seed.active_cuts,
    )?;
    if fitted.moved > 0 {
        notes.push(format!("memfit: moved {} boundary layers", fitted.moved));
    }
    let part = fitted.partition;

    let stage = stage_costs(costs, cluster, &part, micro);
    let max_stage_time = stage.iter().map(|(f, b)| f + b).fold(0.0, f64::max);
    Ok(PartitionPlan {
        partition: part,
        frac: seed.frac.clone(),
        coarse_threshold: seed.coarse_threshold,
        max_stage_time,
        notes,
    })
}

/// The complete Fig.-3 balanced-partition flow.
///
/// `micro` is the micro-batch size used for balancing; `m` the number of
/// micro-batches per mini-batch (memory fine-tune needs the schedule's
/// stash depths). Equivalent to [`balance_stages`] followed by
/// [`finish_partition`].
pub fn balanced_partition(
    net: &crate::model::Network,
    cluster: &Cluster,
    profile: &crate::profile::Profile,
    kind: ScheduleKind,
    micro: f64,
    m: usize,
) -> crate::Result<PartitionPlan> {
    let rc = RangeCost::build(profile);
    let seed = balance_stages_rc(net, cluster, &rc, micro)?;
    finish_partition(cluster, &rc, &seed, kind, false, micro, m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    #[test]
    fn partition_shape() {
        let p = Partition::new(vec![0, 3, 7, 10], 10);
        assert_eq!(p.n_stages(), 3);
        assert_eq!(p.stage(1), 3..7);
        assert_eq!(p.stage_of(0), 0);
        assert_eq!(p.stage_of(3), 1);
        assert_eq!(p.stage_of(9), 2);
        assert_eq!(p.describe(), "[0..3 | 3..7 | 7..10]");
    }

    #[test]
    #[should_panic(expected = "non-empty")]
    fn empty_stage_rejected() {
        Partition::new(vec![0, 3, 3, 10], 10);
    }

    #[test]
    fn full_flow_vgg_on_4_v100() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let plan =
            balanced_partition(&net, &cl, &prof, ScheduleKind::OneFOneBSo, 8.0, 16).unwrap();
        assert_eq!(plan.partition.n_stages(), 4);
        // stage times within 3x of each other (VGG's fc block is chunky)
        let costs = stage_costs(&prof, &cl, &plan.partition, 8.0);
        let times: Vec<f64> = costs.iter().map(|(f, b)| f + b).collect();
        let max = times.iter().cloned().fold(0.0, f64::max);
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 3.0, "imbalance {max}/{min}");
    }

    #[test]
    fn full_flow_fpga_resnet() {
        let net = zoo::resnet50(224);
        let cl = presets::fpga_cluster(&["VCU118"; 4]);
        let prof = analytical::profile(&net, &cl);
        let plan = balanced_partition(&net, &cl, &prof, ScheduleKind::FbpAs, 1.0, 128).unwrap();
        assert_eq!(plan.partition.n_stages(), 4);
    }

    #[test]
    fn heterogeneous_gets_proportional_stages() {
        // VCU129 (1.8x DSPs) should get a larger share of layers/FLOPs.
        let net = zoo::vgg16(224);
        let cl = presets::fpga_cluster(&["VCU129", "VCU118"]);
        let prof = analytical::profile(&net, &cl);
        let plan = balanced_partition(&net, &cl, &prof, ScheduleKind::FbpAs, 1.0, 32).unwrap();
        let pre = net.flops_prefix();
        let r0 = plan.partition.stage(0);
        let r1 = plan.partition.stage(1);
        let f0 = pre[r0.end] - pre[r0.start];
        let f1 = pre[r1.end] - pre[r1.start];
        assert!(f0 > f1, "faster device should carry more FLOPs: {f0} vs {f1}");
    }
}
