//! Intra-layer partition (Section 3.3.2, after FPDeep): fractionally split
//! the boundary layer between adjacent stages so heterogeneous devices
//! reach exact balance. Applied only when communication is not the
//! bottleneck (it adds boundary traffic) and only on async (FPGA)
//! clusters, whose fine-grained pipelines can split a layer's output
//! channels/neurons across boards.

use super::Partition;
use crate::cluster::Cluster;
use crate::profile::range::CostModel;

/// A fractional partition: stage `i` owns the continuous layer interval
/// `[x[i], x[i+1])` where layer `l`'s interior corresponds to `[l, l+1)`.
#[derive(Debug, Clone)]
pub struct FracPartition {
    /// Continuous boundaries, length `n_stages+1`, `x[0]=0`, `x[n]=L`.
    pub x: Vec<f64>,
    /// Max/min stage time ratio − 1 before fractional refinement.
    pub imbalance_before: f64,
    /// Same after refinement (≈0 for feasible cases).
    pub imbalance_after: f64,
}

/// Stage time under a fractional boundary vector (per micro-batch).
fn stage_time_frac<C: CostModel>(costs: &C, d: usize, lo: f64, hi: f64, micro: f64) -> f64 {
    let (f, b) = frac_fwd_bwd(costs, d, lo, hi, micro);
    f + b
}

/// (fwd, bwd) time of the fractional interval `[lo, hi)` on device `d`.
pub fn frac_fwd_bwd<C: CostModel>(
    costs: &C,
    d: usize,
    lo: f64,
    hi: f64,
    micro: f64,
) -> (f64, f64) {
    let l_total = costs.n_layers();
    let mut f = 0.0;
    let mut b = 0.0;
    let mut l = lo.floor() as usize;
    while (l as f64) < hi && l < l_total {
        let seg_lo = lo.max(l as f64);
        let seg_hi = hi.min((l + 1) as f64);
        let frac = (seg_hi - seg_lo).max(0.0);
        f += costs.fwd_time(d, l, l + 1, micro) * frac;
        b += costs.bwd_time(d, l, l + 1, micro) * frac;
        l += 1;
    }
    (f, b)
}

/// Per-stage (fwd, bwd) costs of a fractional partition — feeds the DES
/// the same way `partition::stage_costs` does for integral partitions.
pub fn frac_stage_costs<C: CostModel>(
    costs: &C,
    fp: &FracPartition,
    micro: f64,
) -> Vec<(f64, f64)> {
    let n = fp.x.len() - 1;
    (0..n).map(|d| frac_fwd_bwd(costs, d, fp.x[d], fp.x[d + 1], micro)).collect()
}

/// Imbalance of a boundary vector: `max/min − 1` over stage times.
fn imbalance<C: CostModel>(costs: &C, x: &[f64], micro: f64) -> f64 {
    let n = x.len() - 1;
    let times: Vec<f64> =
        (0..n).map(|d| stage_time_frac(costs, d, x[d], x[d + 1], micro)).collect();
    let max = times.iter().cloned().fold(0.0, f64::max);
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    if min <= 0.0 {
        f64::INFINITY
    } else {
        max / min - 1.0
    }
}

/// Refine an integral partition into a balanced fractional one: bisection
/// on the common stage time `T`, greedily advancing each boundary until
/// its stage reaches `T`.
pub fn refine_fractional<C: CostModel>(
    costs: &C,
    cluster: &Cluster,
    part: &Partition,
    micro: f64,
) -> FracPartition {
    let n = cluster.len();
    let l_total = costs.n_layers() as f64;
    let x0: Vec<f64> = part.bounds.iter().map(|&b| b as f64).collect();
    let before = imbalance(costs, &x0, micro);

    // Bisection on T: find T such that consuming T per stage exactly
    // exhausts the layer interval.
    let total_each: Vec<f64> =
        (0..n).map(|d| stage_time_frac(costs, d, 0.0, l_total, micro)).collect();
    let mut t_lo = 0.0;
    let mut t_hi = total_each.iter().cloned().fold(0.0, f64::max);
    let consumed = |t: f64| -> (f64, Vec<f64>) {
        let mut x = vec![0.0];
        let mut pos = 0.0;
        for d in 0..n {
            // advance pos until stage_time(d, start..pos) == t (or end)
            let start = pos;
            let mut lo = start;
            let mut hi = l_total;
            if stage_time_frac(costs, d, start, l_total, micro) <= t {
                pos = l_total;
            } else {
                for _ in 0..60 {
                    let mid = 0.5 * (lo + hi);
                    if stage_time_frac(costs, d, start, mid, micro) < t {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                pos = 0.5 * (lo + hi);
            }
            x.push(pos);
        }
        (pos, x)
    };
    let mut best_x = x0.clone();
    for _ in 0..60 {
        let t = 0.5 * (t_lo + t_hi);
        let (end, x) = consumed(t);
        if end >= l_total {
            t_hi = t;
            best_x = x;
            best_x[n] = l_total; // snap final boundary
        } else {
            t_lo = t;
        }
    }
    // Guard monotonicity.
    for i in 1..best_x.len() {
        if best_x[i] < best_x[i - 1] {
            best_x[i] = best_x[i - 1];
        }
    }
    let after = imbalance(costs, &best_x, micro);
    FracPartition { x: best_x, imbalance_before: before, imbalance_after: after.min(before) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::partition::interlayer;
    use crate::profile::analytical;

    #[test]
    fn fractional_improves_heterogeneous_balance() {
        let net = zoo::vgg16(224);
        let cl = presets::fpga_cluster(&["VCU129", "VCU118", "VCU118"]);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let part = interlayer::dp_optimal(&prof, &cl, &cuts, 1.0, None).unwrap();
        let fp = refine_fractional(&prof, &cl, &part, 1.0);
        assert!(
            fp.imbalance_after <= fp.imbalance_before + 1e-12,
            "{} -> {}",
            fp.imbalance_before,
            fp.imbalance_after
        );
        assert!(fp.imbalance_after < 0.05, "near-perfect balance: {}", fp.imbalance_after);
        // boundaries monotone and spanning
        assert_eq!(fp.x[0], 0.0);
        assert_eq!(*fp.x.last().unwrap(), net.len() as f64);
        assert!(fp.x.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn stage_time_frac_linear_in_fraction() {
        let net = zoo::mlp(&[128, 128, 128]);
        let cl = presets::fpga_cluster(&["VCU118"]);
        let prof = analytical::profile(&net, &cl);
        let full = stage_time_frac(&prof, 0, 0.0, 1.0, 1.0);
        let half = stage_time_frac(&prof, 0, 0.0, 0.5, 1.0);
        assert!((half - 0.5 * full).abs() < 1e-15);
    }

    #[test]
    fn homogeneous_fractional_equals_flops_share() {
        let net = zoo::vgg16(224);
        let cl = presets::fpga_cluster(&["VCU118", "VCU118"]);
        let prof = analytical::profile(&net, &cl);
        let part = interlayer::dp_optimal(&prof, &cl, &net.legal_cuts(), 1.0, None).unwrap();
        let fp = refine_fractional(&prof, &cl, &part, 1.0);
        let t0 = stage_time_frac(&prof, 0, fp.x[0], fp.x[1], 1.0);
        let t1 = stage_time_frac(&prof, 1, fp.x[1], fp.x[2], 1.0);
        assert!((t0 / t1 - 1.0).abs() < 0.02, "{t0} vs {t1}");
    }
}
