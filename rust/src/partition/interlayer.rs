//! Inter-layer partition (Section 3.3.1).
//!
//! * [`eq1_ideal_time`] — the harmonic-mean ideal stage time of Eq. 1:
//!   `T = 1 / Σₙ (1/Tₙ)` where `Tₙ` is the whole-network time on device n.
//! * [`seed_partition`] — greedy partition targeting `T` per stage
//!   (the paper's "partitions DNN according to T firstly").
//! * [`refine`] — iterative boundary hill-climbing ("then iterates to
//!   load balancing").
//! * [`dp_optimal`] — exact min-max-stage-cost dynamic program over legal
//!   cuts (the PipeDream-style DP, extended with per-device times for
//!   heterogeneous clusters and an optional per-cut communication cost).

use super::Partition;
use crate::cluster::Cluster;
use crate::profile::Profile;

/// Eq. 1: ideal per-stage time given whole-network times per device.
pub fn eq1_ideal_time(profile: &Profile) -> f64 {
    let inv_sum: f64 = (0..profile.n_devices()).map(|d| 1.0 / profile.whole_net_time(d)).sum();
    1.0 / inv_sum
}

/// Per-layer (fwd+bwd) time on device `d` at micro-batch `micro`.
fn layer_time(profile: &Profile, d: usize, l: usize, micro: f64) -> f64 {
    profile.fwd_time(d, l, l + 1, micro) + profile.bwd_time(d, l, l + 1, micro)
}

/// Greedy seed: walk the layers, assigning to device `d` until its stage
/// time reaches the Eq.-1 share, cutting at the nearest legal cut.
pub fn seed_partition(
    profile: &Profile,
    cluster: &Cluster,
    cuts: &[usize],
    micro: f64,
) -> crate::Result<Partition> {
    let n = cluster.len();
    let l_total = profile.n_layers();
    if n == 1 {
        return Ok(Partition::new(vec![0, l_total], l_total));
    }
    let t_ideal = eq1_ideal_time(profile) * micro;
    let mut bounds = vec![0usize];
    let mut lo = 0usize;
    for d in 0..n - 1 {
        // accumulate until stage time ≥ ideal, then snap to a legal cut
        let mut acc = 0.0;
        let mut l = lo;
        while l < l_total && acc < t_ideal {
            acc += layer_time(profile, d, l, micro);
            l += 1;
        }
        // snap: nearest legal cut boundary b (cut after layer c means bound c+1)
        let remaining_stages = n - 1 - d;
        let bound = snap_to_cut(cuts, l, lo, l_total, remaining_stages)?;
        bounds.push(bound);
        lo = bound;
    }
    bounds.push(l_total);
    Ok(Partition::new(bounds, l_total))
}

/// Snap a desired boundary to the nearest legal cut in `(lo, hi)`, keeping
/// at least `remaining` cuts available to the right.
fn snap_to_cut(
    cuts: &[usize],
    desired: usize,
    lo: usize,
    l_total: usize,
    remaining: usize,
) -> crate::Result<usize> {
    // legal bounds are cut+1 for cut in cuts, within (lo, l_total)
    let mut best: Option<usize> = None;
    let mut best_dist = usize::MAX;
    for &c in cuts {
        let b = c + 1;
        if b <= lo || b >= l_total {
            continue;
        }
        // must leave enough legal cuts strictly to the right for the
        // remaining stage boundaries
        let right = cuts.iter().filter(|&&c2| c2 + 1 > b && c2 + 1 < l_total).count();
        if right + 1 < remaining {
            continue;
        }
        let dist = b.abs_diff(desired);
        if dist < best_dist {
            best_dist = dist;
            best = Some(b);
        }
    }
    best.ok_or_else(|| anyhow::anyhow!("no legal cut available after layer {lo}"))
}

/// Max per-stage (F+B) time of a partition.
pub fn max_stage_time(
    profile: &Profile,
    part: &Partition,
    micro: f64,
    comm: Option<&dyn Fn(usize) -> f64>,
) -> f64 {
    (0..part.n_stages())
        .map(|i| {
            let r = part.stage(i);
            let t = profile.fwd_time(i, r.start, r.end, micro)
                + profile.bwd_time(i, r.start, r.end, micro);
            let c = comm.map(|f| if i + 1 < part.n_stages() { f(i) } else { 0.0 }).unwrap_or(0.0);
            t + c
        })
        .fold(0.0, f64::max)
}

/// Iterative refinement: move stage boundaries to adjacent legal cuts
/// while the max stage time decreases.
pub fn refine(
    profile: &Profile,
    part: Partition,
    cuts: &[usize],
    micro: f64,
) -> Partition {
    let legal: std::collections::BTreeSet<usize> = cuts.iter().map(|&c| c + 1).collect();
    let mut best = part;
    let mut best_t = max_stage_time(profile, &best, micro, None);
    loop {
        let mut improved = false;
        for bi in 1..best.bounds.len() - 1 {
            for dir in [-1i64, 1] {
                // next legal bound in direction `dir`
                let cur = best.bounds[bi];
                let cand = if dir < 0 {
                    legal.range(..cur).next_back().copied()
                } else {
                    legal.range(cur + 1..).next().copied()
                };
                let Some(nb) = cand else { continue };
                if nb <= best.bounds[bi - 1] || nb >= best.bounds[bi + 1] {
                    continue;
                }
                let mut b2 = best.bounds.clone();
                b2[bi] = nb;
                let cand_part = Partition::new(b2, *best.bounds.last().unwrap());
                let t = max_stage_time(profile, &cand_part, micro, None);
                if t < best_t - 1e-15 {
                    best = cand_part;
                    best_t = t;
                    improved = true;
                }
            }
        }
        if !improved {
            return best;
        }
    }
}

/// Exact DP over legal cuts minimizing the maximum per-stage cost, with an
/// optional extra cost per cut (communication). `O(N · C²)` for C cuts.
pub fn dp_optimal(
    profile: &Profile,
    cluster: &Cluster,
    cuts: &[usize],
    micro: f64,
    cut_cost: Option<&dyn Fn(usize, usize) -> f64>, // (stage, cut_layer) -> secs
) -> crate::Result<Partition> {
    let n = cluster.len();
    let l_total = profile.n_layers();
    if n == 1 {
        return Ok(Partition::new(vec![0, l_total], l_total));
    }
    // candidate boundaries: 0, each cut+1, L
    let mut bpts: Vec<usize> = std::iter::once(0)
        .chain(cuts.iter().map(|&c| c + 1).filter(|&b| b > 0 && b < l_total))
        .chain(std::iter::once(l_total))
        .collect();
    bpts.dedup();
    let k = bpts.len();
    anyhow::ensure!(k >= n + 1, "not enough cut points ({}) for {} stages", k - 2, n);

    // stage cost of device d covering bpts[a]..bpts[b]
    let cost = |d: usize, a: usize, b: usize| -> f64 {
        let (lo, hi) = (bpts[a], bpts[b]);
        let mut t =
            profile.fwd_time(d, lo, hi, micro) + profile.bwd_time(d, lo, hi, micro);
        if d + 1 < n {
            if let Some(cc) = cut_cost {
                t += cc(d, hi - 1);
            }
        }
        t
    };

    // dp[d][j] = min over i<j of max(dp[d-1][i], cost(d, i, j))
    const INF: f64 = f64::INFINITY;
    let mut dp = vec![vec![INF; k]; n];
    let mut back = vec![vec![usize::MAX; k]; n];
    for j in 1..k {
        dp[0][j] = cost(0, 0, j);
        back[0][j] = 0;
    }
    for d in 1..n {
        for j in d + 1..k {
            for i in d..j {
                if dp[d - 1][i] == INF {
                    continue;
                }
                let c = dp[d - 1][i].max(cost(d, i, j));
                if c < dp[d][j] {
                    dp[d][j] = c;
                    back[d][j] = i;
                }
            }
        }
    }
    anyhow::ensure!(dp[n - 1][k - 1] < INF, "DP found no feasible partition");
    // reconstruct
    let mut bounds = vec![l_total];
    let mut j = k - 1;
    for d in (0..n).rev() {
        let i = back[d][j];
        bounds.push(bpts[i]);
        j = i;
    }
    bounds.reverse();
    Ok(Partition::new(bounds, l_total))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;
    use crate::util::prop::{check, ensure, Config};

    #[test]
    fn eq1_homogeneous_is_t_over_n() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let p = analytical::profile(&net, &cl);
        let t = eq1_ideal_time(&p);
        let t1 = p.whole_net_time(0);
        assert!((t - t1 / 4.0).abs() / t < 1e-9);
    }

    #[test]
    fn eq1_heterogeneous_harmonic() {
        let net = zoo::resnet50(224);
        let cl = presets::fpga_cluster(&["VCU129", "VCU118"]);
        let p = analytical::profile(&net, &cl);
        let t = eq1_ideal_time(&p);
        let (t1, t2) = (p.whole_net_time(0), p.whole_net_time(1));
        assert!((t - 1.0 / (1.0 / t1 + 1.0 / t2)).abs() / t < 1e-9);
        // ideal stage time is less than either device's share alone
        assert!(t < t1 && t < t2);
    }

    #[test]
    fn dp_beats_or_matches_seed_plus_refine() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let seed = seed_partition(&prof, &cl, &cuts, 8.0).unwrap();
        let refined = refine(&prof, seed.clone(), &cuts, 8.0);
        let dp = dp_optimal(&prof, &cl, &cuts, 8.0, None).unwrap();
        let t_seed = max_stage_time(&prof, &seed, 8.0, None);
        let t_ref = max_stage_time(&prof, &refined, 8.0, None);
        let t_dp = max_stage_time(&prof, &dp, 8.0, None);
        assert!(t_ref <= t_seed + 1e-12);
        assert!(t_dp <= t_ref + 1e-12, "DP {t_dp} must be ≤ refined {t_ref}");
    }

    #[test]
    fn dp_single_stage() {
        let net = zoo::mlp(&[64, 64, 64]);
        let cl = presets::v100_cluster(1);
        let prof = analytical::profile(&net, &cl);
        let p = dp_optimal(&prof, &cl, &net.legal_cuts(), 1.0, None).unwrap();
        assert_eq!(p.bounds, vec![0, 2]);
    }

    #[test]
    fn dp_respects_cut_restrictions() {
        let net = zoo::resnet50(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let p = dp_optimal(&prof, &cl, &cuts, 4.0, None).unwrap();
        for &b in &p.bounds[1..p.bounds.len() - 1] {
            assert!(cuts.contains(&(b - 1)), "bound {b} not at a legal cut");
        }
    }

    #[test]
    fn dp_optimality_property_vs_bruteforce() {
        // On random small profiles, DP must equal brute-force enumeration.
        check(
            &Config { cases: 60, ..Default::default() },
            |g| {
                let l = g.usize_in(3, 10);
                let n = g.usize_in(2, l.min(4) + 1);
                let times: Vec<f64> = (0..l).map(|_| g.f64_in(0.1, 10.0)).collect();
                (l, n, times)
            },
            |(l, n, times)| {
                let net = zoo::mlp(&vec![8u64; l + 1]); // l linear layers
                let cl = presets::v100_cluster(*n);
                let mut prof = analytical::profile(&net, &cl);
                for d in 0..*n {
                    for (i, t) in times.iter().enumerate() {
                        prof.per_device[d][i].fwd = *t;
                        prof.per_device[d][i].bwd = *t;
                        prof.per_device[d][i].half_sat = 0.0;
                    }
                }
                let cuts = net.legal_cuts();
                let dp = dp_optimal(&prof, &cl, &cuts, 1.0, None).unwrap();
                let t_dp = max_stage_time(&prof, &dp, 1.0, None);
                // brute force over all C(l-1, n-1) partitions
                let mut best = f64::INFINITY;
                let mut stack = vec![(vec![0usize], 0usize)];
                while let Some((bounds, _)) = stack.pop() {
                    if bounds.len() == *n {
                        let mut b = bounds.clone();
                        b.push(*l);
                        if b.windows(2).all(|w| w[0] < w[1]) {
                            let p = Partition::new(b, *l);
                            best = best.min(max_stage_time(&prof, &p, 1.0, None));
                        }
                        continue;
                    }
                    let lo = *bounds.last().unwrap();
                    for nb in lo + 1..*l {
                        let mut b2 = bounds.clone();
                        b2.push(nb);
                        stack.push((b2, 0));
                    }
                }
                ensure(
                    (t_dp - best).abs() < 1e-9,
                    format!("dp {t_dp} != brute {best}"),
                )
            },
        );
    }
}
