//! Inter-layer partition (Section 3.3.1).
//!
//! * [`eq1_ideal_time`] — the harmonic-mean ideal stage time of Eq. 1:
//!   `T = 1 / Σₙ (1/Tₙ)` where `Tₙ` is the whole-network time on device n.
//! * [`seed_partition`] — greedy partition targeting `T` per stage
//!   (the paper's "partitions DNN according to T firstly").
//! * [`refine`] — iterative boundary hill-climbing ("then iterates to
//!   load balancing").
//! * [`dp_optimal`] — exact min-max-stage-cost dynamic program over legal
//!   cuts (the PipeDream-style DP, extended with per-device times for
//!   heterogeneous clusters and an optional per-cut communication cost).
//!
//! The DP runs on [`RangeCost`] prefix tables (O(1) per range probe —
//! PipeDream's prefix-sum trick) and, when the previous DP row is
//! non-decreasing over the probe domain, replaces the inner `i` scan with
//! an `O(log C)` crossing search (the monotonicity structure DAPPLE's
//! planner exploits): `cost(d, i, j)` is non-increasing in `i` while
//! `dp[d-1][i]` is non-decreasing, so the min-of-max sits at their
//! crossing. Row monotonicity holds for homogeneous device rows without
//! per-cut costs but can fail on heterogeneous clusters or j-dependent
//! cut costs, so it is *checked on the computed values* and failing rows
//! fall back to the exact linear scan — still O(1) per probe. Overall:
//! `O(N·C·log C)` typical, `O(N·C²)` worst case, vs the seed's
//! `O(N·C²·L)`.
//!
//! The seed's triple loop is retained verbatim as
//! [`dp_optimal_reference`], the bit-exactness oracle and perf baseline
//! (the same pattern as `sim::engine::simulate_reference`).

use super::Partition;
use crate::cluster::Cluster;
use crate::profile::range::{CostModel, RangeCost};
use crate::profile::Profile;

/// Eq. 1: ideal per-stage time given whole-network times per device. On a
/// [`RangeCost`] the per-device whole-network times are precomputed at
/// build, so this is O(N) (the `Profile` path re-sums every layer).
pub fn eq1_ideal_time<C: CostModel>(costs: &C) -> f64 {
    costs.eq1_ideal_time()
}

/// Per-layer (fwd+bwd) time on device `d` at micro-batch `micro`.
fn layer_time<C: CostModel>(costs: &C, d: usize, l: usize, micro: f64) -> f64 {
    costs.fwd_time(d, l, l + 1, micro) + costs.bwd_time(d, l, l + 1, micro)
}

/// Greedy seed: walk the layers, assigning to device `d` until its stage
/// time reaches the Eq.-1 share, cutting at the nearest legal cut.
pub fn seed_partition<C: CostModel>(
    costs: &C,
    cluster: &Cluster,
    cuts: &[usize],
    micro: f64,
) -> crate::Result<Partition> {
    let n = cluster.len();
    let l_total = costs.n_layers();
    if n == 1 {
        return Ok(Partition::new(vec![0, l_total], l_total));
    }
    let t_ideal = eq1_ideal_time(costs) * micro;
    let mut bounds = vec![0usize];
    let mut lo = 0usize;
    for d in 0..n - 1 {
        // accumulate until stage time ≥ ideal, then snap to a legal cut
        let mut acc = 0.0;
        let mut l = lo;
        while l < l_total && acc < t_ideal {
            acc += layer_time(costs, d, l, micro);
            l += 1;
        }
        // snap: nearest legal cut boundary b (cut after layer c means bound c+1)
        let remaining_stages = n - 1 - d;
        let bound = snap_to_cut(cuts, l, lo, l_total, remaining_stages)?;
        bounds.push(bound);
        lo = bound;
    }
    bounds.push(l_total);
    Ok(Partition::new(bounds, l_total))
}

/// Snap a desired boundary to the nearest legal cut in `(lo, hi)`, keeping
/// at least `remaining` cuts available to the right.
fn snap_to_cut(
    cuts: &[usize],
    desired: usize,
    lo: usize,
    l_total: usize,
    remaining: usize,
) -> crate::Result<usize> {
    // legal bounds are cut+1 for cut in cuts, within (lo, l_total)
    let mut best: Option<usize> = None;
    let mut best_dist = usize::MAX;
    for &c in cuts {
        let b = c + 1;
        if b <= lo || b >= l_total {
            continue;
        }
        // must leave enough legal cuts strictly to the right for the
        // remaining stage boundaries
        let right = cuts.iter().filter(|&&c2| c2 + 1 > b && c2 + 1 < l_total).count();
        if right + 1 < remaining {
            continue;
        }
        let dist = b.abs_diff(desired);
        if dist < best_dist {
            best_dist = dist;
            best = Some(b);
        }
    }
    best.ok_or_else(|| anyhow::anyhow!("no legal cut available after layer {lo}"))
}

/// Max per-stage (F+B) time of a partition.
pub fn max_stage_time<C: CostModel>(
    costs: &C,
    part: &Partition,
    micro: f64,
    comm: Option<&dyn Fn(usize) -> f64>,
) -> f64 {
    (0..part.n_stages())
        .map(|i| {
            let r = part.stage(i);
            let t = costs.fwd_time(i, r.start, r.end, micro)
                + costs.bwd_time(i, r.start, r.end, micro);
            let c = comm.map(|f| if i + 1 < part.n_stages() { f(i) } else { 0.0 }).unwrap_or(0.0);
            t + c
        })
        .fold(0.0, f64::max)
}

/// Iterative refinement: move stage boundaries to adjacent legal cuts
/// while the max stage time decreases.
pub fn refine<C: CostModel>(
    costs: &C,
    part: Partition,
    cuts: &[usize],
    micro: f64,
) -> Partition {
    let legal: std::collections::BTreeSet<usize> = cuts.iter().map(|&c| c + 1).collect();
    let mut best = part;
    let mut best_t = max_stage_time(costs, &best, micro, None);
    loop {
        let mut improved = false;
        for bi in 1..best.bounds.len() - 1 {
            for dir in [-1i64, 1] {
                // next legal bound in direction `dir`
                let cur = best.bounds[bi];
                let cand = if dir < 0 {
                    legal.range(..cur).next_back().copied()
                } else {
                    legal.range(cur + 1..).next().copied()
                };
                let Some(nb) = cand else { continue };
                if nb <= best.bounds[bi - 1] || nb >= best.bounds[bi + 1] {
                    continue;
                }
                let mut b2 = best.bounds.clone();
                b2[bi] = nb;
                let cand_part = Partition::new(b2, *best.bounds.last().unwrap());
                let t = max_stage_time(costs, &cand_part, micro, None);
                if t < best_t - 1e-15 {
                    best = cand_part;
                    best_t = t;
                    improved = true;
                }
            }
        }
        if !improved {
            return best;
        }
    }
}

/// Candidate boundaries of the DP: 0, each cut+1 inside `(0, L)`, L.
/// `cuts` are assumed ascending (as `Network::legal_cuts` produces).
fn breakpoints(cuts: &[usize], l_total: usize) -> Vec<usize> {
    let mut bpts: Vec<usize> = std::iter::once(0)
        .chain(cuts.iter().map(|&c| c + 1).filter(|&b| b > 0 && b < l_total))
        .chain(std::iter::once(l_total))
        .collect();
    bpts.dedup();
    bpts
}

/// Walk the back-pointer table into a [`Partition`].
fn reconstruct(
    back: &[Vec<usize>],
    bpts: &[usize],
    n: usize,
    k: usize,
    l_total: usize,
) -> Partition {
    let mut bounds = vec![l_total];
    let mut j = k - 1;
    for d in (0..n).rev() {
        let i = back[d][j];
        bounds.push(bpts[i]);
        j = i;
    }
    bounds.reverse();
    Partition::new(bounds, l_total)
}

/// Exact DP over legal cuts minimizing the maximum per-stage cost, with an
/// optional extra cost per cut (communication). Builds the prefix tables
/// once and runs the prefix + monotone path (`O(N·C·log C)` typical —
/// see the module docs); callers already holding a [`RangeCost`] should
/// use [`dp_optimal_rc`] to share the tables across calls.
pub fn dp_optimal(
    profile: &Profile,
    cluster: &Cluster,
    cuts: &[usize],
    micro: f64,
    cut_cost: Option<&dyn Fn(usize, usize) -> f64>, // (stage, cut_layer) -> secs
) -> crate::Result<Partition> {
    let rc = RangeCost::build(profile);
    dp_optimal_rc(&rc, cluster, cuts, micro, cut_cost)
}

/// [`dp_optimal`] against caller-owned prefix tables: the planner builds
/// one [`RangeCost`] per permuted cluster view and threads it through
/// every balance-seed DP of the micro grid.
pub fn dp_optimal_rc(
    rc: &RangeCost,
    cluster: &Cluster,
    cuts: &[usize],
    micro: f64,
    cut_cost: Option<&dyn Fn(usize, usize) -> f64>,
) -> crate::Result<Partition> {
    dp_fast(rc, cluster, cuts, micro, cut_cost, true)
}

/// The prefix-table DP with the monotone crossing search disabled: the
/// seed's exact triple loop at O(1) per probe (`O(N·C²)`). Kept public so
/// the benches can report the seed → prefix → monotone trajectory and the
/// parity tests can pin all three to identical partitions.
pub fn dp_optimal_prefix(
    rc: &RangeCost,
    cluster: &Cluster,
    cuts: &[usize],
    micro: f64,
    cut_cost: Option<&dyn Fn(usize, usize) -> f64>,
) -> crate::Result<Partition> {
    dp_fast(rc, cluster, cuts, micro, cut_cost, false)
}

/// The seed implementation, retained verbatim as the bit-exactness oracle
/// and perf baseline: the `O(N·C²)`-probe triple loop whose cost closure
/// re-sums the layer slice on every probe (`O(N·C²·L)` total when called
/// with a `Profile`).
pub fn dp_optimal_reference<C: CostModel>(
    costs: &C,
    cluster: &Cluster,
    cuts: &[usize],
    micro: f64,
    cut_cost: Option<&dyn Fn(usize, usize) -> f64>,
) -> crate::Result<Partition> {
    let n = cluster.len();
    let l_total = costs.n_layers();
    if n == 1 {
        return Ok(Partition::new(vec![0, l_total], l_total));
    }
    let bpts = breakpoints(cuts, l_total);
    let k = bpts.len();
    anyhow::ensure!(k >= n + 1, "not enough cut points ({}) for {} stages", k - 2, n);

    // stage cost of device d covering bpts[a]..bpts[b]
    let cost = |d: usize, a: usize, b: usize| -> f64 {
        let (lo, hi) = (bpts[a], bpts[b]);
        let mut t = costs.fwd_time(d, lo, hi, micro) + costs.bwd_time(d, lo, hi, micro);
        if d + 1 < n {
            if let Some(cc) = cut_cost {
                t += cc(d, hi - 1);
            }
        }
        t
    };

    // dp[d][j] = min over i<j of max(dp[d-1][i], cost(d, i, j))
    const INF: f64 = f64::INFINITY;
    let mut dp = vec![vec![INF; k]; n];
    let mut back = vec![vec![usize::MAX; k]; n];
    for j in 1..k {
        dp[0][j] = cost(0, 0, j);
        back[0][j] = 0;
    }
    for d in 1..n {
        for j in d + 1..k {
            for i in d..j {
                if dp[d - 1][i] == INF {
                    continue;
                }
                let c = dp[d - 1][i].max(cost(d, i, j));
                if c < dp[d][j] {
                    dp[d][j] = c;
                    back[d][j] = i;
                }
            }
        }
    }
    anyhow::ensure!(dp[n - 1][k - 1] < INF, "DP found no feasible partition");
    Ok(reconstruct(&back, &bpts, n, k, l_total))
}

/// The reference linear scan over one `(d, j)` cell: smallest argmin of
/// `max(prev[i], cost(d, i, j))` over `i ∈ [d, j)`.
fn argmin_scan(
    prev: &[f64],
    cost: &impl Fn(usize, usize, usize) -> f64,
    d: usize,
    j: usize,
) -> (usize, f64) {
    let mut best = f64::INFINITY;
    let mut bi = usize::MAX;
    for i in d..j {
        let c = prev[i].max(cost(d, i, j));
        if c < best {
            best = c;
            bi = i;
        }
    }
    (bi, best)
}

/// The O(log C) crossing search over one `(d, j)` cell. Sound only when
/// `prev` is non-decreasing over `[d, j)` (checked by the caller):
/// `cost(d, ·, j)` is non-increasing in `i` (prefix differences of
/// non-negative per-layer costs — monotone in FP, not just in exact
/// arithmetic), so `max(prev[i], cost)` falls until the crossing and
/// rises after it, and the minimum sits at the crossing index `i*` or at
/// `i* − 1`. Ties resolve to the smallest index (extended leftward across
/// exact-value plateaus) so the selected back-pointer matches the linear
/// scan's first-minimum rule bit-for-bit.
fn argmin_crossing(
    prev: &[f64],
    cost: &impl Fn(usize, usize, usize) -> f64,
    d: usize,
    j: usize,
) -> (usize, f64) {
    // Smallest i in [d, j) with prev[i] >= cost(d, i, j); `j` = no crossing.
    let (mut lo, mut hi) = (d, j);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if prev[mid] >= cost(d, mid, j) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let istar = lo;
    let mut bi = usize::MAX;
    let mut best = f64::INFINITY;
    if istar < j {
        bi = istar;
        best = prev[istar].max(cost(d, istar, j));
    }
    if istar > d {
        let i = istar - 1;
        let v = prev[i].max(cost(d, i, j));
        if v <= best {
            // ties go to the smaller index, like the linear scan
            bi = i;
            best = v;
        }
    }
    while bi > d && prev[bi - 1].max(cost(d, bi - 1, j)) == best {
        bi -= 1;
    }
    (bi, best)
}

/// The prefix-table DP (shared body of [`dp_optimal_rc`] and
/// [`dp_optimal_prefix`]). Rolls the DP table two rows at a time; per
/// row, the previous row is checked for monotonicity over the probe
/// domain and the inner loop picks the crossing search or the exact scan
/// accordingly.
fn dp_fast(
    rc: &RangeCost,
    cluster: &Cluster,
    cuts: &[usize],
    micro: f64,
    cut_cost: Option<&dyn Fn(usize, usize) -> f64>,
    monotone: bool,
) -> crate::Result<Partition> {
    let n = cluster.len();
    let l_total = rc.n_layers();
    if n == 1 {
        return Ok(Partition::new(vec![0, l_total], l_total));
    }
    let bpts = breakpoints(cuts, l_total);
    let k = bpts.len();
    anyhow::ensure!(k >= n + 1, "not enough cut points ({}) for {} stages", k - 2, n);

    // stage cost of device d covering bpts[a]..bpts[b] — O(1) per probe
    let cost = |d: usize, a: usize, b: usize| -> f64 {
        let (lo, hi) = (bpts[a], bpts[b]);
        let mut t = rc.fwd_time(d, lo, hi, micro) + rc.bwd_time(d, lo, hi, micro);
        if d + 1 < n {
            if let Some(cc) = cut_cost {
                t += cc(d, hi - 1);
            }
        }
        t
    };

    // The crossing search additionally needs cost(d, ·, j) non-increasing
    // in i, which holds exactly when every prefix addend was non-negative
    // at table build (always true for analytical profiles; a pathological
    // caller-built profile clears the flag and keeps the exact scan).
    let monotone = monotone && rc.costs_monotone();

    const INF: f64 = f64::INFINITY;
    let mut back = vec![vec![usize::MAX; k]; n];
    let mut prev = vec![INF; k];
    for j in 1..k {
        prev[j] = cost(0, 0, j);
        back[0][j] = 0;
    }
    let mut cur = vec![INF; k];
    for d in 1..n {
        cur.fill(INF);
        // Probe domain of row d: i ∈ [d, k-2]. Homogeneous device rows
        // without per-cut costs are provably non-decreasing (shrinking
        // the covered span cannot raise the optimal bottleneck);
        // heterogeneous rows or j-dependent cut costs can break this, so
        // the check runs on the actual values and a failing row keeps the
        // exact scan.
        let row_monotone = monotone && (d..k - 2).all(|i| prev[i] <= prev[i + 1]);
        for j in d + 1..k {
            let (bi, bv) = if row_monotone {
                argmin_crossing(&prev, &cost, d, j)
            } else {
                argmin_scan(&prev, &cost, d, j)
            };
            cur[j] = bv;
            back[d][j] = bi;
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    anyhow::ensure!(prev[k - 1] < INF, "DP found no feasible partition");
    Ok(reconstruct(&back, &bpts, n, k, l_total))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;
    use crate::util::prop::{check, ensure, Config};

    #[test]
    fn eq1_homogeneous_is_t_over_n() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let p = analytical::profile(&net, &cl);
        let t = eq1_ideal_time(&p);
        let t1 = p.whole_net_time(0);
        assert!((t - t1 / 4.0).abs() / t < 1e-9);
    }

    #[test]
    fn eq1_heterogeneous_harmonic() {
        let net = zoo::resnet50(224);
        let cl = presets::fpga_cluster(&["VCU129", "VCU118"]);
        let p = analytical::profile(&net, &cl);
        let t = eq1_ideal_time(&p);
        let (t1, t2) = (p.whole_net_time(0), p.whole_net_time(1));
        assert!((t - 1.0 / (1.0 / t1 + 1.0 / t2)).abs() / t < 1e-9);
        // ideal stage time is less than either device's share alone
        assert!(t < t1 && t < t2);
    }

    #[test]
    fn dp_beats_or_matches_seed_plus_refine() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let seed = seed_partition(&prof, &cl, &cuts, 8.0).unwrap();
        let refined = refine(&prof, seed.clone(), &cuts, 8.0);
        let dp = dp_optimal(&prof, &cl, &cuts, 8.0, None).unwrap();
        let t_seed = max_stage_time(&prof, &seed, 8.0, None);
        let t_ref = max_stage_time(&prof, &refined, 8.0, None);
        let t_dp = max_stage_time(&prof, &dp, 8.0, None);
        assert!(t_ref <= t_seed + 1e-12);
        assert!(t_dp <= t_ref + 1e-12, "DP {t_dp} must be ≤ refined {t_ref}");
    }

    #[test]
    fn dp_single_stage() {
        let net = zoo::mlp(&[64, 64, 64]);
        let cl = presets::v100_cluster(1);
        let prof = analytical::profile(&net, &cl);
        let p = dp_optimal(&prof, &cl, &net.legal_cuts(), 1.0, None).unwrap();
        assert_eq!(p.bounds, vec![0, 2]);
    }

    #[test]
    fn dp_respects_cut_restrictions() {
        let net = zoo::resnet50(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let p = dp_optimal(&prof, &cl, &cuts, 4.0, None).unwrap();
        for &b in &p.bounds[1..p.bounds.len() - 1] {
            assert!(cuts.contains(&(b - 1)), "bound {b} not at a legal cut");
        }
    }

    #[test]
    fn dp_optimality_property_vs_bruteforce() {
        // On random small profiles, DP must equal brute-force enumeration.
        check(
            &Config { cases: 60, ..Default::default() },
            |g| {
                let l = g.usize_in(3, 10);
                let n = g.usize_in(2, l.min(4) + 1);
                let times: Vec<f64> = (0..l).map(|_| g.f64_in(0.1, 10.0)).collect();
                (l, n, times)
            },
            |(l, n, times)| {
                let net = zoo::mlp(&vec![8u64; l + 1]); // l linear layers
                let cl = presets::v100_cluster(*n);
                let mut prof = analytical::profile(&net, &cl);
                for d in 0..*n {
                    for (i, t) in times.iter().enumerate() {
                        prof.per_device[d][i].fwd = *t;
                        prof.per_device[d][i].bwd = *t;
                        prof.per_device[d][i].half_sat = 0.0;
                    }
                }
                let cuts = net.legal_cuts();
                let dp = dp_optimal(&prof, &cl, &cuts, 1.0, None).unwrap();
                let t_dp = max_stage_time(&prof, &dp, 1.0, None);
                // brute force over all C(l-1, n-1) partitions
                let mut best = f64::INFINITY;
                let mut stack = vec![(vec![0usize], 0usize)];
                while let Some((bounds, _)) = stack.pop() {
                    if bounds.len() == *n {
                        let mut b = bounds.clone();
                        b.push(*l);
                        if b.windows(2).all(|w| w[0] < w[1]) {
                            let p = Partition::new(b, *l);
                            best = best.min(max_stage_time(&prof, &p, 1.0, None));
                        }
                        continue;
                    }
                    let lo = *bounds.last().unwrap();
                    for nb in lo + 1..*l {
                        let mut b2 = bounds.clone();
                        b2.push(nb);
                        stack.push((b2, 0));
                    }
                }
                ensure(
                    (t_dp - best).abs() < 1e-9,
                    format!("dp {t_dp} != brute {best}"),
                )
            },
        );
    }

    #[test]
    fn prefix_and_monotone_match_reference_on_random_heterogeneous() {
        // Random per-device layer times (independent across devices —
        // this exercises the non-monotone fallback rows as well as the
        // crossing search) must yield the exact partition the reference
        // triple loop selects, for all three implementations.
        check(
            &Config { cases: 40, seed: 0xD0_0DC0DE, max_size: 16 },
            |g| {
                let l = g.usize_in(4, 14);
                let n = g.usize_in(2, l.min(5));
                let times: Vec<Vec<f64>> = (0..n)
                    .map(|_| (0..l).map(|_| g.f64_in(0.05, 10.0)).collect())
                    .collect();
                (l, n, times)
            },
            |(l, n, times)| {
                let net = zoo::mlp(&vec![8u64; l + 1]);
                let cl = presets::v100_cluster(*n);
                let mut prof = analytical::profile(&net, &cl);
                for d in 0..*n {
                    for (i, t) in times[d].iter().enumerate() {
                        prof.per_device[d][i].fwd = *t;
                        prof.per_device[d][i].bwd = 0.7 * *t;
                        prof.per_device[d][i].half_sat = 0.0;
                    }
                }
                let cuts = net.legal_cuts();
                let rc = RangeCost::build(&prof);
                let reference = dp_optimal_reference(&prof, &cl, &cuts, 2.0, None).unwrap();
                let prefix = dp_optimal_prefix(&rc, &cl, &cuts, 2.0, None).unwrap();
                let fast = dp_optimal_rc(&rc, &cl, &cuts, 2.0, None).unwrap();
                ensure(
                    prefix.bounds == reference.bounds,
                    format!("prefix {:?} != reference {:?}", prefix.bounds, reference.bounds),
                )?;
                ensure(
                    fast.bounds == reference.bounds,
                    format!("monotone {:?} != reference {:?}", fast.bounds, reference.bounds),
                )
            },
        );
    }

    #[test]
    fn negative_cost_profile_disables_crossing_search() {
        // A pathological profile (e.g. a noisy measured fit producing a
        // negative fixed cost) breaks the cost-side monotonicity the
        // crossing search needs; RangeCost records that at build and the
        // DP must keep the exact scan — still matching the oracle loop.
        let net = zoo::mlp(&[16u64; 7]); // 6 linear layers
        let cl = presets::v100_cluster(3);
        let mut prof = analytical::profile(&net, &cl);
        assert!(RangeCost::build(&prof).costs_monotone());
        prof.per_device[1][2].fwd_fixed = -5e-4;
        let rc = RangeCost::build(&prof);
        assert!(!rc.costs_monotone());
        let cuts = net.legal_cuts();
        let oracle = dp_optimal_reference(&rc, &cl, &cuts, 4.0, None).unwrap();
        let fast = dp_optimal_rc(&rc, &cl, &cuts, 4.0, None).unwrap();
        assert_eq!(oracle.bounds, fast.bounds);
    }

    #[test]
    fn monotone_dp_handles_cut_costs() {
        // Per-cut communication costs depend on j (the cut layer), which
        // breaks row monotonicity in general — the runtime check must
        // route those rows to the exact scan and still match the oracle
        // triple loop probe for probe (same prefix tables, so the
        // partitions are bit-identical by construction of the search).
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let rc = RangeCost::build(&prof);
        for micro in [1.0, 8.0] {
            let comm = |stage: usize, cut_layer: usize| -> f64 {
                let bytes = prof.cut_bytes(cut_layer) as f64 * micro;
                cl.link(stage).xfer_time(bytes) * 2.0
            };
            let oracle = dp_optimal_reference(&rc, &cl, &cuts, micro, Some(&comm)).unwrap();
            let fast = dp_optimal_rc(&rc, &cl, &cuts, micro, Some(&comm)).unwrap();
            assert_eq!(oracle.bounds, fast.bounds, "micro {micro}");
            // and across cost backings the selected partitions are
            // equally optimal (summation order may break exact ties)
            let seed = dp_optimal_reference(&prof, &cl, &cuts, micro, Some(&comm)).unwrap();
            let t_of = |p: &Partition| {
                let comm_of = |i: usize| comm(i, p.bounds[i + 1] - 1);
                max_stage_time(&prof, p, micro, Some(&comm_of))
            };
            let t_seed = t_of(&seed);
            let t_fast = t_of(&fast);
            assert!(
                (t_seed - t_fast).abs() <= 1e-9 * t_seed.max(t_fast),
                "micro {micro}: {t_fast} vs {t_seed}"
            );
        }
    }
}
