//! Coarse-grained partition based on communication (Section 3.3.3): when
//! the inter-stage transfer time exceeds the stage compute time, restrict
//! cuts to edges whose activation size is below the threshold `a_th`, so
//! the coarse network "no longer suffers from a communication bottleneck".

use crate::profile::range::CostModel;

/// Filter `cuts` down to edges whose per-sample activation bytes are at
/// most `a_th` bytes.
pub fn allowed_cuts<C: CostModel>(costs: &C, cuts: &[usize], a_th: f64) -> Vec<usize> {
    cuts.iter().copied().filter(|&c| (costs.cut_bytes(c) as f64) <= a_th).collect()
}

/// The smallest `a_th` that still leaves at least `need` cut points —
/// used when the ideal threshold is infeasible and we must trade some
/// communication overlap for feasibility.
pub fn relax_threshold<C: CostModel>(costs: &C, cuts: &[usize], need: usize) -> Option<f64> {
    let mut sizes: Vec<f64> = cuts.iter().map(|&c| costs.cut_bytes(c) as f64).collect();
    if sizes.len() < need {
        return None;
    }
    sizes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(sizes[need - 1])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    #[test]
    fn threshold_filters_big_edges() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(2);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let all = allowed_cuts(&prof, &cuts, f64::INFINITY);
        assert_eq!(all.len(), cuts.len());
        // A tight threshold keeps only late (small-activation) edges.
        let small = allowed_cuts(&prof, &cuts, 64.0 * 1024.0);
        assert!(!small.is_empty());
        assert!(small.len() < cuts.len());
        for &c in &small {
            assert!(prof.cut_bytes(c) <= 64 * 1024);
        }
        // VGG activations shrink with depth → allowed cuts are the later ones
        let min_allowed = *small.iter().min().unwrap();
        let disallowed_late =
            cuts.iter().filter(|&&c| c > min_allowed && !small.contains(&c)).count();
        let disallowed_early = cuts.iter().filter(|&&c| c < min_allowed).count();
        assert!(disallowed_early >= disallowed_late);
    }

    #[test]
    fn relax_threshold_keeps_exactly_need() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let cuts = net.legal_cuts();
        let th = relax_threshold(&prof, &cuts, 3).unwrap();
        let kept = allowed_cuts(&prof, &cuts, th);
        assert!(kept.len() >= 3);
        // one fewer than the 3rd-smallest leaves < 3
        let kept2 = allowed_cuts(&prof, &cuts, th * 0.999);
        assert!(kept2.len() <= kept.len());
    }

    #[test]
    fn relax_threshold_infeasible() {
        let net = zoo::mlp(&[4, 4]);
        let cl = presets::v100_cluster(1);
        let prof = analytical::profile(&net, &cl);
        assert!(relax_threshold(&prof, &[], 1).is_none());
    }
}
