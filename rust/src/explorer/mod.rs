//! Automatic exploration (Fig. 3): given a DNN profile and hardware
//! constraints, BaPipe searches schedule kind × micro-batch count ×
//! balanced partition, evaluates each candidate with the discrete-event
//! simulator, enforces memory feasibility, and returns the fastest plan —
//! falling back to data parallelism when the pipeline cannot beat it
//! (the paper's ResNet-50 outcome).

use crate::cluster::Cluster;
use crate::model::Network;
use crate::partition::intralayer::frac_stage_costs;
use crate::partition::memfit::{stage_memory_bytes, MemoryModel};
use crate::partition::{balanced_partition, cut_comm_time, stage_costs, Partition, PartitionPlan};
use crate::profile::Profile;
use crate::schedule::ScheduleKind;
use crate::sim::engine::{epoch_time, simulate, SimSpec};
use crate::sim::dp;

/// Exploration options.
#[derive(Debug, Clone)]
pub struct Options {
    /// Per-device batch size `B` (paper's Table 3 notation). The global
    /// mini-batch entering the pipeline is `B × N`.
    pub batch_per_device: f64,
    /// Samples per epoch (used to convert mini-batch time → epoch time).
    pub samples_per_epoch: usize,
    /// Micro-batch-count candidates `M` (filtered to divisors of the
    /// global mini-batch).
    pub m_candidates: Vec<usize>,
    /// Also evaluate plain data parallelism and pick it if faster.
    pub consider_dp: bool,
}

impl Default for Options {
    fn default() -> Self {
        Options {
            batch_per_device: 32.0,
            samples_per_epoch: 50_000,
            m_candidates: vec![2, 4, 8, 16, 32, 64, 128],
            consider_dp: true,
        }
    }
}

/// The selected parallelization.
#[derive(Debug, Clone)]
pub enum Choice {
    /// Pipeline parallelism with the given schedule / micro-batching /
    /// partition.
    Pipeline {
        /// Chosen schedule.
        kind: ScheduleKind,
        /// Micro-batches per mini-batch.
        m: usize,
        /// Micro-batch size (samples).
        micro: f64,
        /// The balanced partition.
        partition: Partition,
    },
    /// Data parallelism won (e.g. ResNet-50 on PCIe V100s).
    DataParallel,
}

/// A fully evaluated plan.
#[derive(Debug, Clone)]
pub struct Plan {
    /// What BaPipe chose.
    pub choice: Choice,
    /// Time per (global) mini-batch, seconds.
    pub minibatch_time: f64,
    /// Epoch time, seconds.
    pub epoch_time: f64,
    /// Epoch time of the DP baseline (∞ if DP does not fit memory).
    pub dp_epoch_time: f64,
    /// Speedup over the DP baseline.
    pub speedup_over_dp: f64,
    /// Per-stage memory (bytes); one entry (whole net) for DP.
    pub stage_memory: Vec<u64>,
    /// Exploration log: every candidate evaluated with its epoch time.
    pub log: Vec<String>,
}

impl Plan {
    /// One-paragraph human-readable report.
    pub fn report(&self) -> String {
        let head = match &self.choice {
            Choice::Pipeline { kind, m, micro, partition } => format!(
                "BaPipe plan: {} with M={m} (micro-batch {micro}), partition {}",
                kind.label(),
                partition.describe()
            ),
            Choice::DataParallel => "BaPipe plan: data parallelism (pipeline cannot beat DP here)".to_string(),
        };
        format!(
            "{head}\n  mini-batch {:.4}s, epoch {:.1}s, {:.2}x over DP\n  stage memory: [{}]",
            self.minibatch_time,
            self.epoch_time,
            self.speedup_over_dp,
            self.stage_memory.iter().map(|&b| crate::util::fmt_bytes(b)).collect::<Vec<_>>().join(", ")
        )
    }
}

/// Build the SimSpec for a full balanced-partition plan, using the
/// intra-layer fractional stage costs when the flow produced them (the
/// paper's Section 3.3.2 refinement; communication stays at the integral
/// boundaries, which the fractional bounds stay within one layer of).
pub fn build_spec_plan(
    profile: &Profile,
    cluster: &Cluster,
    plan: &PartitionPlan,
    kind: ScheduleKind,
    micro: f64,
    m: usize,
) -> SimSpec {
    let mut spec = build_spec(profile, cluster, &plan.partition, kind, micro, m);
    if let Some(fp) = &plan.frac {
        let frac = frac_stage_costs(profile, fp, micro);
        // keep any stage-level floor (FPGA weight-spill penalty) from the
        // integral costs: the fractional refinement only rebalances compute
        for (i, (f, b)) in frac.into_iter().enumerate() {
            spec.fwd[i] = f.max(1e-12);
            spec.bwd[i] = b.max(1e-12);
        }
    }
    spec
}

/// Build the SimSpec for a (kind, partition, micro) candidate.
pub fn build_spec(
    profile: &Profile,
    cluster: &Cluster,
    part: &Partition,
    kind: ScheduleKind,
    micro: f64,
    m: usize,
) -> SimSpec {
    let costs = stage_costs(profile, cluster, part, micro);
    let n = part.n_stages();
    let fwd_xfer: Vec<f64> =
        (0..n - 1).map(|i| cut_comm_time(profile, cluster, part, micro, i)).collect();
    SimSpec {
        kind,
        m,
        fwd: costs.iter().map(|c| c.0).collect(),
        bwd: costs.iter().map(|c| c.1).collect(),
        update: vec![0.0; n],
        bwd_xfer: fwd_xfer.clone(), // errors are activation-sized (Section 1)
        fwd_xfer,
        exec: cluster.devices.iter().map(|d| d.exec).collect(),
    }
}

/// Per-stage memory of a candidate plan.
pub fn plan_memory(
    profile: &Profile,
    kind: ScheduleKind,
    part: &Partition,
    micro: f64,
    m: usize,
) -> Vec<u64> {
    let mm = MemoryModel::default();
    let n = part.n_stages();
    (0..n)
        .map(|i| stage_memory_bytes(profile, &mm, kind, n, i, part.stage(i), micro, m))
        .collect()
}

/// Does every stage of a candidate fit its device?
fn fits(profile: &Profile, cluster: &Cluster, kind: ScheduleKind, part: &Partition, micro: f64, m: usize) -> bool {
    let mm = MemoryModel::default();
    plan_memory(profile, kind, part, micro, m)
        .iter()
        .zip(&cluster.devices)
        .all(|(&used, d)| used <= mm.usable(d.mem_capacity))
}

/// Evaluate one fully-specified pipeline candidate. Returns
/// `(minibatch_time, epoch_time)` or None if infeasible.
pub fn evaluate_pipeline(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    kind: ScheduleKind,
    m: usize,
    opts: &Options,
) -> Option<(f64, f64, Partition)> {
    let n = cluster.len();
    let global = opts.batch_per_device * n as f64;
    if m == 0 || (global as usize) % m != 0 {
        return None;
    }
    let micro = global / m as f64;
    let plan = balanced_partition(net, cluster, profile, kind, micro, m).ok()?;
    if !fits(profile, cluster, kind, &plan.partition, micro, m) {
        return None;
    }
    let spec = build_spec_plan(profile, cluster, &plan, kind, micro, m);
    let n_mb = (opts.samples_per_epoch as f64 / global).ceil() as usize;
    let mb_time = simulate(&spec).makespan;
    let ep = epoch_time(&spec, n_mb);
    Some((mb_time, ep, plan.partition))
}

/// The full BaPipe exploration (Fig. 3).
pub fn explore(net: &Network, cluster: &Cluster, profile: &Profile, opts: &Options) -> Plan {
    let mut log = Vec::new();
    let mut best: Option<(f64, f64, ScheduleKind, usize, Partition)> = None;

    for kind in ScheduleKind::bapipe_candidates() {
        if !kind.eligible(cluster) {
            log.push(format!("{}: ineligible on {}", kind.label(), cluster.describe()));
            continue;
        }
        for &m in &opts.m_candidates {
            match evaluate_pipeline(net, cluster, profile, kind, m, opts) {
                Some((mb, ep, part)) => {
                    log.push(format!("{} M={m}: epoch {:.1}s", kind.label(), ep));
                    if best.as_ref().map(|b| ep < b.1).unwrap_or(true) {
                        best = Some((mb, ep, kind, m, part));
                    }
                }
                None => log.push(format!("{} M={m}: infeasible", kind.label())),
            }
        }
    }

    // DP baseline.
    let dpr = dp::minibatch(profile, cluster, opts.batch_per_device);
    let dp_epoch = if dpr.fits {
        dp::epoch_time(profile, cluster, opts.batch_per_device, opts.samples_per_epoch)
    } else {
        f64::INFINITY
    };
    log.push(format!(
        "DP B={}: epoch {:.1}s{}",
        opts.batch_per_device,
        dp_epoch,
        if dpr.fits { "" } else { " (out of memory)" }
    ));

    match best {
        Some((mb, ep, kind, m, part)) if !(opts.consider_dp && dp_epoch < ep) => {
            let micro = opts.batch_per_device * cluster.len() as f64 / m as f64;
            let mem = plan_memory(profile, kind, &part, micro, m);
            Plan {
                choice: Choice::Pipeline { kind, m, micro, partition: part },
                minibatch_time: mb,
                epoch_time: ep,
                dp_epoch_time: dp_epoch,
                speedup_over_dp: dp_epoch / ep,
                stage_memory: mem,
                log,
            }
        }
        _ => {
            let mm = MemoryModel::data_parallel();
            let mem = vec![crate::partition::memfit::dp_memory_bytes(
                profile,
                &mm,
                opts.batch_per_device,
            )];
            Plan {
                choice: Choice::DataParallel,
                minibatch_time: dpr.minibatch_time,
                epoch_time: dp_epoch,
                dp_epoch_time: dp_epoch,
                speedup_over_dp: 1.0,
                stage_memory: mem,
                log,
            }
        }
    }
}

/// GPipe baseline: fill-drain schedule, **BaPipe's partition** (the paper
/// gives GPipe our partitions since it has no balancer), best feasible M.
pub fn plan_gpipe(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
) -> Option<(f64, usize)> {
    let mut best: Option<(f64, usize)> = None;
    for &m in &opts.m_candidates {
        if let Some((_, ep, _)) =
            evaluate_pipeline(net, cluster, profile, ScheduleKind::GPipe, m, opts)
        {
            if best.map(|b| ep < b.0).unwrap_or(true) {
                best = Some((ep, m));
            }
        }
    }
    best
}

/// PipeDream baseline: inter-batch 1F1B with weight stashing, its own
/// DP-style partitioner (compute+comm, no memory term), per-device batch
/// halved until the stash fits.
pub fn plan_pipedream(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
) -> Option<(f64, f64)> {
    let cuts = net.legal_cuts();
    let mut b = opts.batch_per_device;
    while b >= 1.0 {
        let comm = |stage: usize, cut_layer: usize| -> f64 {
            let bytes = profile.cut_bytes(cut_layer) as f64 * b;
            cluster.link(stage.min(cluster.len() - 2)).xfer_time(bytes) * 2.0
        };
        let part =
            crate::partition::interlayer::dp_optimal(profile, cluster, &cuts, b, Some(&comm))
                .ok()?;
        if fits(profile, cluster, ScheduleKind::PipeDream, &part, b, 1) {
            let spec = build_spec(profile, cluster, &part, ScheduleKind::PipeDream, b, 1);
            let n_mb = (opts.samples_per_epoch as f64 / b).ceil() as usize;
            let ep = epoch_time(&spec, n_mb);
            return Some((ep, b));
        }
        b /= 2.0;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    fn opts(b: f64) -> Options {
        Options { batch_per_device: b, samples_per_epoch: 8192, ..Default::default() }
    }

    #[test]
    fn vgg_picks_pipeline_and_beats_dp() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let plan = explore(&net, &cl, &prof, &opts(32.0));
        match plan.choice {
            Choice::Pipeline { kind, .. } => {
                // GPU cluster → one of the sync schedules
                assert!(matches!(
                    kind,
                    ScheduleKind::OneFOneBSno | ScheduleKind::OneFOneBSo
                ));
            }
            Choice::DataParallel => panic!("VGG-16 should pipeline: {}", plan.report()),
        }
        assert!(plan.speedup_over_dp > 1.5, "speedup {}", plan.speedup_over_dp);
    }

    #[test]
    fn resnet_degenerates_to_dp() {
        // Table 3: "the best partition is DP" for ResNet-50 on PCIe V100s.
        // At 8 GPUs our calibration reproduces the paper's outcome exactly;
        // at 4 GPUs the pipeline-vs-DP margin is within the GLOO-bandwidth
        // calibration noise (documented in EXPERIMENTS.md), so we assert
        // the robust configuration plus a near-parity bound on the other.
        let net = zoo::resnet50(224);
        let cl8 = presets::v100_cluster(8);
        let prof8 = analytical::profile(&net, &cl8);
        let plan8 = explore(&net, &cl8, &prof8, &opts(32.0));
        assert!(
            matches!(plan8.choice, Choice::DataParallel),
            "resnet50 on 8 V100 should fall back to DP:\n{}",
            plan8.log.join("\n")
        );
        assert_eq!(plan8.speedup_over_dp, 1.0);

        let cl4 = presets::v100_cluster(4);
        let prof4 = analytical::profile(&net, &cl4);
        let plan4 = explore(&net, &cl4, &prof4, &opts(32.0));
        assert!(
            plan4.speedup_over_dp < 1.7,
            "resnet50 on 4 V100 must be near DP parity, got {:.2}x",
            plan4.speedup_over_dp
        );
        // and far from the VGG-class wins (>2x would be the wrong shape)
        let vgg = zoo::vgg16(224);
        let pv = analytical::profile(&vgg, &cl4);
        let plan_vgg = explore(&vgg, &cl4, &pv, &opts(32.0));
        assert!(
            plan_vgg.speedup_over_dp > plan4.speedup_over_dp,
            "vgg must benefit more from pipelining than resnet: {:.2} vs {:.2}",
            plan_vgg.speedup_over_dp,
            plan4.speedup_over_dp
        );
    }

    #[test]
    fn fpga_cluster_picks_async_schedule() {
        let net = zoo::resnet50(224);
        let cl = presets::fpga_cluster(&["VCU129"; 4]);
        let prof = analytical::profile(&net, &cl);
        let mut o = opts(4.0);
        o.consider_dp = false;
        let plan = explore(&net, &cl, &prof, &o);
        match plan.choice {
            Choice::Pipeline { kind, .. } => {
                assert!(matches!(kind, ScheduleKind::OneFOneBAs | ScheduleKind::FbpAs));
            }
            _ => panic!("expected pipeline"),
        }
    }

    #[test]
    fn gpipe_slower_than_bapipe() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let o = opts(32.0);
        let plan = explore(&net, &cl, &prof, &o);
        let (gp, _) = plan_gpipe(&net, &cl, &prof, &o).unwrap();
        assert!(
            plan.epoch_time <= gp * 1.001,
            "bapipe {} vs gpipe {gp}",
            plan.epoch_time
        );
    }

    #[test]
    fn pipedream_feasible_on_vgg() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let (ep, b) = plan_pipedream(&net, &cl, &prof, &opts(64.0)).unwrap();
        assert!(ep > 0.0);
        assert!(b >= 1.0);
    }

    #[test]
    fn exploration_log_covers_all_candidates() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(2);
        let prof = analytical::profile(&net, &cl);
        let plan = explore(&net, &cl, &prof, &opts(32.0));
        // async kinds logged as ineligible on GPUs
        assert!(plan.log.iter().any(|l| l.contains("1F1B-AS: ineligible")));
        assert!(plan.log.iter().any(|l| l.contains("DP B=32")));
    }
}
