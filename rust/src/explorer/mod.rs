//! Automatic exploration (Fig. 3) — compatibility façade.
//!
//! The exploration engine lives in [`crate::planner`]: typed candidates,
//! memoized partitions, branch-and-bound pruning and parallel evaluation.
//! This module keeps the seed explorer's surface — [`Options`],
//! [`Choice`], a [`Plan`] with a `Vec<String>` log, [`explore`] and the
//! GPipe / PipeDream baselines — as thin delegations, so existing call
//! sites (benches, examples, tests) keep working unchanged. New code
//! should prefer [`crate::planner`] and its machine-readable
//! [`crate::planner::ExplorationReport`].

use crate::cluster::Cluster;
use crate::model::Network;
use crate::planner;
use crate::profile::Profile;

pub use crate::planner::{
    build_spec, build_spec_plan, evaluate_pipeline, plan_memory, Choice, Options,
};

/// A fully evaluated plan (seed shape: summary numbers plus a
/// line-per-candidate exploration log derived from the typed report).
#[derive(Debug, Clone)]
pub struct Plan {
    /// What BaPipe chose.
    pub choice: Choice,
    /// Time per (global) mini-batch, seconds.
    pub minibatch_time: f64,
    /// Epoch time, seconds.
    pub epoch_time: f64,
    /// Epoch time of the DP baseline (∞ if DP does not fit memory).
    pub dp_epoch_time: f64,
    /// Speedup over the DP baseline.
    pub speedup_over_dp: f64,
    /// Per-stage memory (bytes); one entry (whole net) for DP.
    pub stage_memory: Vec<u64>,
    /// Exploration log, one line per candidate: `epoch …s` when
    /// simulated, `pruned (lower bound …s)` when branch-and-bound skipped
    /// it (the default — pass `prune: false` for the seed's exhaustive
    /// log), or `infeasible`; plus ineligible-kind and DP-baseline lines.
    pub log: Vec<String>,
}

impl Plan {
    /// One-paragraph human-readable report.
    pub fn report(&self) -> String {
        let head = match &self.choice {
            Choice::Pipeline { kind, m, micro, recompute, partition } => format!(
                "BaPipe plan: {}{} with M={m} (micro-batch {micro}), partition {}",
                kind.label(),
                if *recompute { "+RC" } else { "" },
                partition.describe()
            ),
            Choice::DataParallel => {
                "BaPipe plan: data parallelism (pipeline cannot beat DP here)".to_string()
            }
        };
        format!(
            "{head}\n  mini-batch {:.4}s, epoch {:.1}s, {:.2}x over DP\n  stage memory: [{}]",
            self.minibatch_time,
            self.epoch_time,
            self.speedup_over_dp,
            self.stage_memory.iter().map(|&b| crate::util::fmt_bytes(b)).collect::<Vec<_>>().join(", ")
        )
    }
}

impl From<planner::Plan> for Plan {
    fn from(p: planner::Plan) -> Plan {
        Plan {
            log: p.report.log_lines(),
            choice: p.choice,
            minibatch_time: p.minibatch_time,
            epoch_time: p.epoch_time,
            dp_epoch_time: p.dp_epoch_time,
            speedup_over_dp: p.speedup_over_dp,
            stage_memory: p.stage_memory,
        }
    }
}

/// The full BaPipe exploration (Fig. 3), via the planner. Same selected
/// plan as the seed exhaustive grid search — pruning and parallelism
/// never change the reduction result.
pub fn explore(net: &Network, cluster: &Cluster, profile: &Profile, opts: &Options) -> Plan {
    planner::explore(net, cluster, profile, opts).into()
}

/// GPipe baseline: fill-drain schedule, **BaPipe's partition** (the paper
/// gives GPipe our partitions since it has no balancer), best feasible M.
pub fn plan_gpipe(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
) -> Option<(f64, usize)> {
    planner::plan_gpipe(net, cluster, profile, opts)
}

/// PipeDream baseline: inter-batch 1F1B with weight stashing, its own
/// DP-style partitioner (compute+comm, no memory term), per-device batch
/// halved until the stash fits.
pub fn plan_pipedream(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
) -> Option<(f64, f64)> {
    planner::plan_pipedream(net, cluster, profile, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;
    use crate::schedule::ScheduleKind;

    fn opts(b: f64) -> Options {
        Options { batch_per_device: b, samples_per_epoch: 8192, ..Default::default() }
    }

    #[test]
    fn vgg_picks_pipeline_and_beats_dp() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let plan = explore(&net, &cl, &prof, &opts(32.0));
        match plan.choice {
            Choice::Pipeline { kind, .. } => {
                // GPU cluster → one of the sync schedules
                assert!(matches!(
                    kind,
                    ScheduleKind::OneFOneBSno | ScheduleKind::OneFOneBSo
                ));
            }
            Choice::DataParallel => panic!("VGG-16 should pipeline: {}", plan.report()),
        }
        assert!(plan.speedup_over_dp > 1.5, "speedup {}", plan.speedup_over_dp);
    }

    #[test]
    fn resnet_degenerates_to_dp() {
        // Table 3: "the best partition is DP" for ResNet-50 on PCIe V100s.
        // At 8 GPUs our calibration reproduces the paper's outcome exactly;
        // at 4 GPUs the pipeline-vs-DP margin is within the GLOO-bandwidth
        // calibration noise (documented in EXPERIMENTS.md), so we assert
        // the robust configuration plus a near-parity bound on the other.
        let net = zoo::resnet50(224);
        let cl8 = presets::v100_cluster(8);
        let prof8 = analytical::profile(&net, &cl8);
        let plan8 = explore(&net, &cl8, &prof8, &opts(32.0));
        assert!(
            matches!(plan8.choice, Choice::DataParallel),
            "resnet50 on 8 V100 should fall back to DP:\n{}",
            plan8.log.join("\n")
        );
        assert_eq!(plan8.speedup_over_dp, 1.0);

        let cl4 = presets::v100_cluster(4);
        let prof4 = analytical::profile(&net, &cl4);
        let plan4 = explore(&net, &cl4, &prof4, &opts(32.0));
        assert!(
            plan4.speedup_over_dp < 1.7,
            "resnet50 on 4 V100 must be near DP parity, got {:.2}x",
            plan4.speedup_over_dp
        );
        // and far from the VGG-class wins (>2x would be the wrong shape)
        let vgg = zoo::vgg16(224);
        let pv = analytical::profile(&vgg, &cl4);
        let plan_vgg = explore(&vgg, &cl4, &pv, &opts(32.0));
        assert!(
            plan_vgg.speedup_over_dp > plan4.speedup_over_dp,
            "vgg must benefit more from pipelining than resnet: {:.2} vs {:.2}",
            plan_vgg.speedup_over_dp,
            plan4.speedup_over_dp
        );
    }

    #[test]
    fn fpga_cluster_picks_async_schedule() {
        let net = zoo::resnet50(224);
        let cl = presets::fpga_cluster(&["VCU129"; 4]);
        let prof = analytical::profile(&net, &cl);
        let mut o = opts(4.0);
        o.consider_dp = false;
        let plan = explore(&net, &cl, &prof, &o);
        match plan.choice {
            Choice::Pipeline { kind, .. } => {
                assert!(matches!(kind, ScheduleKind::OneFOneBAs | ScheduleKind::FbpAs));
            }
            _ => panic!("expected pipeline"),
        }
    }

    #[test]
    fn gpipe_slower_than_bapipe() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let o = opts(32.0);
        let plan = explore(&net, &cl, &prof, &o);
        let (gp, _) = plan_gpipe(&net, &cl, &prof, &o).unwrap();
        assert!(
            plan.epoch_time <= gp * 1.001,
            "bapipe {} vs gpipe {gp}",
            plan.epoch_time
        );
    }

    #[test]
    fn pipedream_feasible_on_vgg() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let (ep, b) = plan_pipedream(&net, &cl, &prof, &opts(64.0)).unwrap();
        assert!(ep > 0.0);
        assert!(b >= 1.0);
    }

    #[test]
    fn exploration_log_covers_all_candidates() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(2);
        let prof = analytical::profile(&net, &cl);
        let plan = explore(&net, &cl, &prof, &opts(32.0));
        // async kinds logged as ineligible on GPUs
        assert!(plan.log.iter().any(|l| l.contains("1F1B-AS: ineligible")));
        assert!(plan.log.iter().any(|l| l.contains("DP B=32")));
    }

    #[test]
    fn facade_matches_planner_exactly() {
        // The compat façade must report the same plan the planner built.
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let o = opts(32.0);
        let a = explore(&net, &cl, &prof, &o);
        let b = crate::planner::explore(&net, &cl, &prof, &o);
        assert_eq!(a.choice, b.choice);
        assert_eq!(a.epoch_time, b.epoch_time);
        assert_eq!(a.stage_memory, b.stage_memory);
        assert_eq!(a.log, b.report.log_lines());
    }
}
