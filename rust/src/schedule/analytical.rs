//! Closed-form performance model — Tables 1 and 2 of the paper, plus the
//! same quantities for the GPipe / PipeDream baselines.
//!
//! Symbols (paper notation): `M` micro-batches per mini-batch, `N`
//! pipeline stages, `F`/`B` per-stage forward/backward compute time
//! (balanced partition assumption), `SR` one-hop send/receive time,
//! `a` activation bytes per micro-batch at a stage boundary, `w` stage
//! weight bytes, `i` the 1-based stage index in the memory rows.

use super::ScheduleKind;

/// Inputs to the closed forms.
#[derive(Debug, Clone, Copy)]
pub struct Symbols {
    /// Micro-batches per mini-batch.
    pub m: usize,
    /// Pipeline stages.
    pub n: usize,
    /// Per-stage forward time (s).
    pub f: f64,
    /// Per-stage backward time (s).
    pub b: f64,
    /// One-hop send/receive time per micro-batch activation (s).
    pub sr: f64,
    /// Activation bytes per micro-batch crossing a stage boundary.
    pub a: f64,
    /// Weight bytes per stage.
    pub w: f64,
}

/// Mini-batch time (Tables 1–2 row 1).
pub fn minibatch_time(kind: ScheduleKind, s: &Symbols) -> f64 {
    let (m, n) = (s.m as f64, s.n as f64);
    let fb = s.f + s.b;
    match kind {
        // Table 1: (M+N-1)(F+B) — communication fully overlapped.
        ScheduleKind::OneFOneBAs | ScheduleKind::FbpAs => (m + n - 1.0) * fb,
        // Table 2, 1F1B-SNO: (M+N-1)(F+B) + (N+M-2-⌈(M-1)/N⌉)·2SR.
        // 2BW runs the identical op sequence (only its *memory* rows
        // differ — double-buffered weights), so it shares the form.
        ScheduleKind::OneFOneBSno | ScheduleKind::TwoBW => {
            let ceil = ((s.m - 1) as f64 / n).ceil();
            (m + n - 1.0) * fb + (n + m - 2.0 - ceil) * 2.0 * s.sr
        }
        // Table 2, 1F1B-SO: (M+N-1)(F+B) + (N-1)·2SR.
        ScheduleKind::OneFOneBSo => (m + n - 1.0) * fb + (n - 1.0) * 2.0 * s.sr,
        // GPipe fill-drain with non-overlapped communication behaves like
        // the naïve sync pipeline on the fill and drain ramps.
        ScheduleKind::GPipe => (m + n - 1.0) * fb + (n + m - 2.0) * 2.0 * s.sr,
        // PipeDream steady state: one mini-batch (= micro-batch) per
        // max-stage period; its GLOO communication sits on the critical
        // path (the paper's Section 4.2.1 observation), so the period is
        // F+B+2SR and there is no fill/drain bubble across mini-batches.
        ScheduleKind::PipeDream => m * (fb + 2.0 * s.sr),
    }
}

/// Pipeline-bubble fraction (Tables 1–2 row 2): idle time / total time.
pub fn bubble_fraction(kind: ScheduleKind, s: &Symbols) -> f64 {
    let (m, n) = (s.m as f64, s.n as f64);
    let fb = s.f + s.b;
    match kind {
        ScheduleKind::OneFOneBAs | ScheduleKind::FbpAs => (n - 1.0) / (m + n - 1.0),
        ScheduleKind::OneFOneBSno | ScheduleKind::TwoBW => {
            let ceil = ((s.m - 1) as f64 / n).ceil();
            let num = (n - 1.0) * (fb + 2.0 * s.sr) + (m - 1.0 - ceil) * 2.0 * s.sr;
            num / minibatch_time(kind, s)
        }
        ScheduleKind::OneFOneBSo => {
            (n - 1.0) * (fb + 2.0 * s.sr) / minibatch_time(kind, s)
        }
        ScheduleKind::GPipe => {
            let t = minibatch_time(kind, s);
            (t - m * fb) / t
        }
        ScheduleKind::PipeDream => {
            let t = minibatch_time(kind, s);
            (t - m * fb) / t
        }
    }
}

/// Peak feature (activation) memory at 1-based stage `i` (Tables 1–2 row 3).
pub fn features_memory(kind: ScheduleKind, s: &Symbols, i: usize) -> f64 {
    assert!(i >= 1 && i <= s.n);
    kind.stash_depth(s.n, i - 1, s.m) as f64 * s.a
}

/// Weights(+gradient/version) memory per stage (Tables 1–2 row 4).
pub fn weights_memory(kind: ScheduleKind, s: &Symbols, i: usize) -> f64 {
    assert!(i >= 1 && i <= s.n);
    // All intra-batch schedules: weights + gradient accumulator = 2w.
    // PipeDream: + stashed versions.
    (2 + kind.weight_versions(s.n, i - 1)) as f64 * s.w
}

/// Demand bandwidth to fully overlap communication (Tables 1–2 row 5),
/// bytes/s.
pub fn demand_bandwidth(kind: ScheduleKind, s: &Symbols) -> f64 {
    match kind {
        // Table 1: a/F for 1F1B (activation must stream during one F),
        // 2a/(F+B) for FBP (activation + error during one combined slot).
        ScheduleKind::OneFOneBAs => s.a / s.f,
        ScheduleKind::FbpAs => 2.0 * s.a / (s.f + s.b),
        // Table 2: both sync schedules demand a/F; 2BW streams the same
        // per-micro-batch activation during one forward slot.
        ScheduleKind::OneFOneBSno | ScheduleKind::OneFOneBSo | ScheduleKind::TwoBW => s.a / s.f,
        ScheduleKind::GPipe => s.a / s.f,
        ScheduleKind::PipeDream => 2.0 * s.a / (s.f + s.b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn syms() -> Symbols {
        Symbols { m: 8, n: 3, f: 1.0, b: 2.0, sr: 0.25, a: 1e6, w: 4e6 }
    }

    #[test]
    fn table1_equal_time_and_bubble() {
        // Table 1: 1F1B-AS and FBP-AS have identical time & bubble.
        let s = syms();
        let t1 = minibatch_time(ScheduleKind::OneFOneBAs, &s);
        let t2 = minibatch_time(ScheduleKind::FbpAs, &s);
        assert_eq!(t1, t2);
        assert_eq!(t1, (8.0 + 3.0 - 1.0) * 3.0);
        let b1 = bubble_fraction(ScheduleKind::OneFOneBAs, &s);
        assert!((b1 - 2.0 / 10.0).abs() < 1e-12);
    }

    #[test]
    fn table1_fbp_memory_double_bandwidth_lower() {
        let s = syms();
        for i in 1..=s.n {
            assert_eq!(
                features_memory(ScheduleKind::FbpAs, &s, i),
                2.0 * features_memory(ScheduleKind::OneFOneBAs, &s, i)
            );
        }
        // bandwidth demand: a/F vs 2a/(F+B); with B=2F the FBP demand is lower
        assert!(
            demand_bandwidth(ScheduleKind::FbpAs, &s)
                < demand_bandwidth(ScheduleKind::OneFOneBAs, &s)
        );
    }

    #[test]
    fn table2_so_beats_sno() {
        let s = syms();
        let sno = minibatch_time(ScheduleKind::OneFOneBSno, &s);
        let so = minibatch_time(ScheduleKind::OneFOneBSo, &s);
        assert!(so < sno, "SO {so} must beat SNO {sno}");
        // Exact forms:
        let ceil = ((s.m - 1) as f64 / s.n as f64).ceil(); // ⌈7/3⌉ = 3
        assert_eq!(ceil, 3.0);
        assert!((sno - (10.0 * 3.0 + (3.0 + 8.0 - 2.0 - 3.0) * 0.5)).abs() < 1e-12);
        assert!((so - (10.0 * 3.0 + 2.0 * 0.5)).abs() < 1e-12);
    }

    #[test]
    fn table2_sno_extra_bubble_grows_with_m() {
        let mut s = syms();
        s.sr = 0.5;
        let mut gap = |m: usize| {
            s.m = m;
            minibatch_time(ScheduleKind::OneFOneBSno, &s)
                - minibatch_time(ScheduleKind::OneFOneBSo, &s)
        };
        assert!(gap(32) > gap(8), "SNO's non-overlap penalty is ∝ M");
    }

    #[test]
    fn weights_memory_2w_intra_batch() {
        let s = syms();
        for kind in [ScheduleKind::OneFOneBAs, ScheduleKind::FbpAs, ScheduleKind::OneFOneBSno, ScheduleKind::OneFOneBSo] {
            assert_eq!(weights_memory(kind, &s, 1), 2.0 * s.w, "{kind:?}");
        }
        // PipeDream stage 1 of 3 stashes 2 extra versions → 4w.
        assert_eq!(weights_memory(ScheduleKind::PipeDream, &s, 1), 4.0 * s.w);
        assert_eq!(weights_memory(ScheduleKind::PipeDream, &s, 3), 2.0 * s.w);
    }

    #[test]
    fn features_memory_decreases_along_pipeline() {
        let s = syms();
        let f1 = features_memory(ScheduleKind::OneFOneBAs, &s, 1);
        let f3 = features_memory(ScheduleKind::OneFOneBAs, &s, 3);
        assert!(f1 > f3);
        assert_eq!(f1, 3.0 * s.a);
        assert_eq!(f3, 1.0 * s.a);
    }

    #[test]
    fn bubble_fraction_vanishes_with_large_m() {
        let mut s = syms();
        s.m = 10_000;
        for kind in [ScheduleKind::OneFOneBAs, ScheduleKind::OneFOneBSo] {
            assert!(bubble_fraction(kind, &s) < 0.01, "{kind:?}");
        }
    }
}
