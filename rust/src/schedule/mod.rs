//! Pipeline scheduling (Section 3.2): schedule kinds, per-stage op-sequence
//! generators (one source of truth for both the discrete-event simulator
//! and the real engine's schedule drivers), the closed-form performance
//! model of Tables 1–2 ([`analytical`]), and the baseline schedules
//! (GPipe fill-drain, PipeDream inter-batch 1F1B).

pub mod analytical;
pub mod generators;

use crate::cluster::{Cluster, ExecMode};

/// The pipeline-scheduling methodologies BaPipe explores, plus baselines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScheduleKind {
    /// 1F1B with asynchronous (streamed) communication — FPGA (Fig. 5a).
    OneFOneBAs,
    /// Forward and backward computed in parallel, asynchronous — FPGA
    /// (Fig. 5b, FPDeep).
    FbpAs,
    /// Naïve synchronous 1F1B, communication not overlapped — GPU (Fig. 6a).
    OneFOneBSno,
    /// Synchronous 1F1B with doubled warm-up so communication overlaps —
    /// GPU (Fig. 6b, BaPipe's contribution).
    OneFOneBSo,
    /// GPipe fill-drain: all forwards then all backwards (baseline).
    GPipe,
    /// PipeDream inter-batch 1F1B with weight stashing (baseline).
    PipeDream,
    /// Double-buffered weight versions (PipeDream-2BW, arXiv 2006.09503):
    /// 1F1B-shaped execution with exactly **one** extra weight version
    /// beyond the working copy on every stage — constant in pipeline
    /// depth, unlike PipeDream's `n-i-1` stashed versions. The
    /// memory-scalable kind the planner reaches for when activations fit
    /// but weights do not.
    TwoBW,
}

impl ScheduleKind {
    /// All intra-batch kinds BaPipe's explorer considers.
    pub fn bapipe_candidates() -> [ScheduleKind; 4] {
        [
            ScheduleKind::OneFOneBAs,
            ScheduleKind::FbpAs,
            ScheduleKind::OneFOneBSno,
            ScheduleKind::OneFOneBSo,
        ]
    }

    /// Is this schedule the right family for the cluster? Async schedules
    /// need every device to support asynchronous execution; the sync
    /// 1F1B variants are the GPU family — on an all-async (FPGA) cluster
    /// BaPipe explores 1F1B-AS/FBP-AS instead (Section 3.2). Baselines
    /// run anywhere.
    pub fn eligible(&self, cluster: &Cluster) -> bool {
        match self {
            ScheduleKind::OneFOneBAs | ScheduleKind::FbpAs => cluster.all_async(),
            ScheduleKind::OneFOneBSno | ScheduleKind::OneFOneBSo => !cluster.all_async(),
            _ => true,
        }
    }

    /// Does the schedule update weights synchronously per mini-batch
    /// (intra-batch parallelism — consistent weights)?
    pub fn intra_batch(&self) -> bool {
        !matches!(self, ScheduleKind::PipeDream)
    }

    /// Number of in-flight micro-batch activations stage `i` (0-based) of
    /// `n` must stash, for `m` micro-batches per mini-batch (Tables 1–2
    /// "Features memory" rows, expressed 0-based: the paper's
    /// `(N-i+1)·a` with 1-based i equals our `n-i`).
    pub fn stash_depth(&self, n: usize, i: usize, m: usize) -> usize {
        let base = n - i; // 1F1B warm-up depth at stage i
        match self {
            ScheduleKind::OneFOneBAs | ScheduleKind::OneFOneBSno | ScheduleKind::TwoBW => {
                base.min(m)
            }
            ScheduleKind::FbpAs | ScheduleKind::OneFOneBSo => (2 * base).min(m),
            ScheduleKind::GPipe => m, // all micro-batches of the mini-batch
            ScheduleKind::PipeDream => base,
        }
    }

    /// Extra stored weight *versions* beyond the working copy: PipeDream
    /// stashes one per in-flight mini-batch (`n-i-1`), 2BW double-buffers
    /// exactly one regardless of depth, and the plain intra-batch
    /// schedules need none.
    pub fn weight_versions(&self, n: usize, i: usize) -> usize {
        match self {
            ScheduleKind::PipeDream => (n - i).saturating_sub(1),
            ScheduleKind::TwoBW => 1,
            _ => 0,
        }
    }

    /// Execution mode this schedule requires (None = runs in either).
    pub fn required_exec(&self) -> Option<ExecMode> {
        match self {
            ScheduleKind::OneFOneBAs | ScheduleKind::FbpAs => Some(ExecMode::Async),
            ScheduleKind::OneFOneBSno | ScheduleKind::OneFOneBSo => Some(ExecMode::Sync),
            _ => None,
        }
    }

    /// Memory-equivalence class: kinds with identical Tables 1–2 memory
    /// rows (same [`ScheduleKind::stash_depth`] and
    /// [`ScheduleKind::weight_versions`] for every `(n, i, m)`). The
    /// balanced-partition flow consults the schedule only through those
    /// two rows (the memory fine-tune), so two kinds in one class always
    /// produce the same partition for the same `(micro, m)` — the
    /// planner's `EvalCache` keys on this class to share partition work.
    pub fn memory_class(&self) -> u8 {
        match self {
            ScheduleKind::OneFOneBAs | ScheduleKind::OneFOneBSno => 0,
            ScheduleKind::FbpAs | ScheduleKind::OneFOneBSo => 1,
            ScheduleKind::GPipe => 2,
            ScheduleKind::PipeDream => 3,
            ScheduleKind::TwoBW => 4,
        }
    }

    /// Inverse of [`ScheduleKind::label`] — used when deserializing plan
    /// artifacts (`plan.json`).
    pub fn from_label(label: &str) -> Option<ScheduleKind> {
        match label {
            "1F1B-AS" => Some(ScheduleKind::OneFOneBAs),
            "FBP-AS" => Some(ScheduleKind::FbpAs),
            "1F1B-SNO" => Some(ScheduleKind::OneFOneBSno),
            "1F1B-SO" => Some(ScheduleKind::OneFOneBSo),
            "GPipe" => Some(ScheduleKind::GPipe),
            "PipeDream" => Some(ScheduleKind::PipeDream),
            "2BW" => Some(ScheduleKind::TwoBW),
            _ => None,
        }
    }

    /// Every kind, for label round-trips and property tests.
    pub fn all() -> [ScheduleKind; 7] {
        [
            ScheduleKind::OneFOneBAs,
            ScheduleKind::FbpAs,
            ScheduleKind::OneFOneBSno,
            ScheduleKind::OneFOneBSo,
            ScheduleKind::GPipe,
            ScheduleKind::PipeDream,
            ScheduleKind::TwoBW,
        ]
    }

    /// Short name used in reports (matches the paper's Table 3 labels).
    pub fn label(&self) -> &'static str {
        match self {
            ScheduleKind::OneFOneBAs => "1F1B-AS",
            ScheduleKind::FbpAs => "FBP-AS",
            ScheduleKind::OneFOneBSno => "1F1B-SNO",
            ScheduleKind::OneFOneBSo => "1F1B-SO",
            ScheduleKind::GPipe => "GPipe",
            ScheduleKind::PipeDream => "PipeDream",
            ScheduleKind::TwoBW => "2BW",
        }
    }
}

/// One operation in a stage's static program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Forward of micro-batch `mb` (0-based within the mini-batch).
    Fwd {
        /// Micro-batch index.
        mb: usize,
    },
    /// Backward of micro-batch `mb`.
    Bwd {
        /// Micro-batch index.
        mb: usize,
    },
    /// Forward of `fwd_mb` and backward of `bwd_mb` computed *in parallel*
    /// (FBP-AS on FPGAs; the slot costs F+B on shared DSPs).
    FwdBwd {
        /// Forward micro-batch index.
        fwd_mb: usize,
        /// Backward micro-batch index.
        bwd_mb: usize,
    },
    /// Apply the optimizer with the gradients accumulated this mini-batch.
    Update,
}

/// A stage's static op sequence for one mini-batch.
#[derive(Debug, Clone, PartialEq)]
pub struct StageProgram {
    /// Ops in execution order.
    pub ops: Vec<Op>,
}

impl StageProgram {
    /// Count of forward ops (including the fwd half of FwdBwd).
    pub fn n_fwd(&self) -> usize {
        self.ops
            .iter()
            .filter(|o| matches!(o, Op::Fwd { .. } | Op::FwdBwd { .. }))
            .count()
    }

    /// Count of backward ops (including the bwd half of FwdBwd).
    pub fn n_bwd(&self) -> usize {
        self.ops
            .iter()
            .filter(|o| matches!(o, Op::Bwd { .. } | Op::FwdBwd { .. }))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;

    #[test]
    fn eligibility() {
        let gpu = presets::v100_cluster(2);
        let fpga = presets::fpga_cluster(&["VCU118", "VCU118"]);
        assert!(!ScheduleKind::OneFOneBAs.eligible(&gpu));
        assert!(ScheduleKind::OneFOneBSo.eligible(&gpu));
        assert!(ScheduleKind::OneFOneBAs.eligible(&fpga));
        assert!(ScheduleKind::FbpAs.eligible(&fpga));
        assert!(!ScheduleKind::OneFOneBSno.eligible(&fpga));
        assert!(ScheduleKind::GPipe.eligible(&gpu));
        assert!(ScheduleKind::GPipe.eligible(&fpga));
    }

    #[test]
    fn stash_depth_matches_tables() {
        // Table 1 (0-based stage i of N): 1F1B stores N-i, FBP stores 2(N-i).
        let n = 4;
        let m = 16;
        for i in 0..n {
            assert_eq!(ScheduleKind::OneFOneBAs.stash_depth(n, i, m), n - i);
            assert_eq!(ScheduleKind::FbpAs.stash_depth(n, i, m), 2 * (n - i));
            assert_eq!(ScheduleKind::OneFOneBSo.stash_depth(n, i, m), 2 * (n - i));
            assert_eq!(ScheduleKind::GPipe.stash_depth(n, i, m), m);
        }
        // capped by M when M is small
        assert_eq!(ScheduleKind::FbpAs.stash_depth(4, 0, 3), 3);
    }

    #[test]
    fn pipedream_weight_versions_decrease_along_pipe() {
        let n = 4;
        let v: Vec<usize> =
            (0..n).map(|i| ScheduleKind::PipeDream.weight_versions(n, i)).collect();
        assert_eq!(v, vec![3, 2, 1, 0]);
        assert_eq!(ScheduleKind::OneFOneBSo.weight_versions(n, 0), 0);
    }

    #[test]
    fn two_bw_weight_versions_constant_in_depth() {
        // 2BW's defining trait (arXiv 2006.09503): exactly one extra
        // weight version on every stage, no matter how deep the pipe —
        // vs PipeDream's n-i-1.
        for n in 1..=16usize {
            for i in 0..n {
                assert_eq!(ScheduleKind::TwoBW.weight_versions(n, i), 1);
                assert_eq!(
                    ScheduleKind::TwoBW.stash_depth(n, i, 8),
                    ScheduleKind::OneFOneBAs.stash_depth(n, i, 8),
                    "2BW stashes like plain 1F1B at n={n} i={i}"
                );
            }
        }
        assert!(ScheduleKind::TwoBW.intra_batch());
        assert_eq!(ScheduleKind::TwoBW.required_exec(), None);
    }

    #[test]
    fn intra_batch_flags() {
        assert!(ScheduleKind::OneFOneBSo.intra_batch());
        assert!(ScheduleKind::GPipe.intra_batch());
        assert!(!ScheduleKind::PipeDream.intra_batch());
    }

    #[test]
    fn labels_match_paper() {
        assert_eq!(ScheduleKind::OneFOneBAs.label(), "1F1B-AS");
        assert_eq!(ScheduleKind::FbpAs.label(), "FBP-AS");
        assert_eq!(ScheduleKind::OneFOneBSno.label(), "1F1B-SNO");
        assert_eq!(ScheduleKind::OneFOneBSo.label(), "1F1B-SO");
    }

    #[test]
    fn labels_round_trip() {
        for kind in ScheduleKind::all() {
            assert_eq!(ScheduleKind::from_label(kind.label()), Some(kind));
        }
        assert_eq!(ScheduleKind::from_label("nope"), None);
    }

    #[test]
    fn memory_class_implies_identical_memory_rows() {
        // The planner's partition cache relies on this: same class ⇒ same
        // stash depth and weight versions everywhere.
        let kinds = ScheduleKind::all();
        for a in kinds {
            for b in kinds {
                if a.memory_class() != b.memory_class() {
                    continue;
                }
                for n in 1..=6usize {
                    for i in 0..n {
                        for m in 1..=32usize {
                            assert_eq!(
                                a.stash_depth(n, i, m),
                                b.stash_depth(n, i, m),
                                "{a:?} vs {b:?} at n={n} i={i} m={m}"
                            );
                            assert_eq!(a.weight_versions(n, i), b.weight_versions(n, i));
                        }
                    }
                }
            }
        }
    }
}
