//! Static per-stage op-sequence generators for every schedule kind.
//!
//! These sequences are the single source of truth: the discrete-event
//! simulator executes them against a cost model, and the real engine's
//! schedule drivers execute them against compiled XLA stage programs.

use super::{Op, ScheduleKind, StageProgram};

/// Generate the op sequence for stage `i` (0-based) of `n` stages with
/// `m` micro-batches per mini-batch.
pub fn program(kind: ScheduleKind, n: usize, i: usize, m: usize) -> StageProgram {
    let mut ops = Vec::with_capacity(2 * m + 1);
    program_into(kind, n, i, m, &mut ops);
    StageProgram { ops }
}

/// [`program`] into a caller-provided buffer (ops are appended; the
/// buffer is not cleared). This is the allocation-free entry point the
/// simulator's reusable [`crate::sim::engine::SimArena`] builds its flat
/// per-stage op table from. Callers that cannot afford the table at all
/// (the batched simulator at 1024 stages × M=4096) use the closed-form
/// [`ProgramShape`] view instead, which answers the same sequence in
/// `O(1)` per op.
pub fn program_into(kind: ScheduleKind, n: usize, i: usize, m: usize, ops: &mut Vec<Op>) {
    assert!(n >= 1 && i < n && m >= 1, "program({kind:?}, n={n}, i={i}, m={m})");
    match kind {
        ScheduleKind::OneFOneBAs | ScheduleKind::OneFOneBSno | ScheduleKind::TwoBW => {
            one_f_one_b(n - i, m, true, ops)
        }
        ScheduleKind::OneFOneBSo => one_f_one_b((2 * (n - i)).min(m.max(1)), m, true, ops),
        ScheduleKind::GPipe => gpipe(m, ops),
        ScheduleKind::PipeDream => one_f_one_b(n - i, m, false, ops),
        ScheduleKind::FbpAs => fbp(n, i, m, ops),
    }
}

/// Classic 1F1B at warm-up depth `w`: `w` forwards, then alternate
/// backward/forward, then drain backwards; `update` appends the
/// mini-batch optimizer step (intra-batch schedules only).
fn one_f_one_b(w: usize, m: usize, update: bool, ops: &mut Vec<Op>) {
    let w = w.min(m).max(1);
    for k in 0..w {
        ops.push(Op::Fwd { mb: k });
    }
    for j in 0..m - w {
        ops.push(Op::Bwd { mb: j });
        ops.push(Op::Fwd { mb: w + j });
    }
    for j in m - w..m {
        ops.push(Op::Bwd { mb: j });
    }
    if update {
        ops.push(Op::Update);
    }
}

/// GPipe fill-drain: all forwards (0..m), then all backwards in reverse
/// micro-batch order (the last forward's activations unwind first).
fn gpipe(m: usize, ops: &mut Vec<Op>) {
    for k in 0..m {
        ops.push(Op::Fwd { mb: k });
    }
    for k in (0..m).rev() {
        ops.push(Op::Bwd { mb: k });
    }
    ops.push(Op::Update);
}

/// FBP-AS (FPDeep): forward and backward streams run concurrently on the
/// same accelerator. Slot `t` computes forward of micro-batch `t` (while
/// `t < m`) and backward of micro-batch `t - o_i` (once non-negative),
/// where `o_i = 2·(n-1-i)+1` is the round-trip distance from stage `i` to
/// the last stage and back.
fn fbp(n: usize, i: usize, m: usize, ops: &mut Vec<Op>) {
    let o = 2 * (n - 1 - i) + 1;
    // last backward (mb m-1) lands in slot m-1+o
    for t in 0..m + o {
        let f = if t < m { Some(t) } else { None };
        let b = if t >= o && t - o < m { Some(t - o) } else { None };
        match (f, b) {
            (Some(fk), Some(bk)) => ops.push(Op::FwdBwd { fwd_mb: fk, bwd_mb: bk }),
            (Some(fk), None) => ops.push(Op::Fwd { mb: fk }),
            (None, Some(bk)) => ops.push(Op::Bwd { mb: bk }),
            (None, None) => {} // idle gap slot between fwd and bwd streams
        }
    }
    ops.push(Op::Update);
}

/// Closed-form view of one stage's program: the schedule generators above
/// are all affine in `m` (`const + m·slope` phase boundaries), so the
/// whole sequence can be answered positionally without materializing a
/// table. [`ProgramShape::op_at`] is `O(1)` per op and
/// `(0..len()).map(op_at)` is defined to equal [`program`]'s op list
/// exactly (property-tested below). The batched simulator
/// (`crate::sim::batch`) walks stages through this view: at 1024 stages ×
/// M=4096 the explicit table is ~8M ops of build-and-stream traffic *per
/// candidate*, which this removes entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProgramShape {
    /// 1F1B at effective warm-up depth `w` (already clamped to `1..=m`):
    /// `w` forwards, `2·(m-w)` alternating bwd/fwd slots, `w` drain
    /// backwards, then the optional update.
    OneFOneB {
        /// Clamped warm-up depth.
        w: usize,
        /// Micro-batches per mini-batch.
        m: usize,
        /// Does the program end with `Op::Update`?
        update: bool,
    },
    /// GPipe fill-drain: `m` forwards, `m` reverse-order backwards, update.
    GPipe {
        /// Micro-batches per mini-batch.
        m: usize,
    },
    /// FBP-AS with round-trip offset `o = 2·(n-1-i)+1`; idle gap slots of
    /// the generator (possible when `o > m`) are skipped, so positions map
    /// to executed ops only.
    Fbp {
        /// Round-trip offset from stage `i` to the last stage and back.
        o: usize,
        /// Micro-batches per mini-batch.
        m: usize,
    },
}

impl ProgramShape {
    /// The shape of stage `i` (0-based) of `n` under `kind` with `m`
    /// micro-batches — mirrors the [`program_into`] dispatch exactly.
    pub fn of(kind: ScheduleKind, n: usize, i: usize, m: usize) -> ProgramShape {
        assert!(n >= 1 && i < n && m >= 1, "shape({kind:?}, n={n}, i={i}, m={m})");
        match kind {
            ScheduleKind::OneFOneBAs | ScheduleKind::OneFOneBSno | ScheduleKind::TwoBW => {
                ProgramShape::OneFOneB { w: (n - i).min(m).max(1), m, update: true }
            }
            ScheduleKind::OneFOneBSo => ProgramShape::OneFOneB {
                w: (2 * (n - i)).min(m.max(1)).min(m).max(1),
                m,
                update: true,
            },
            ScheduleKind::PipeDream => {
                ProgramShape::OneFOneB { w: (n - i).min(m).max(1), m, update: false }
            }
            ScheduleKind::GPipe => ProgramShape::GPipe { m },
            ScheduleKind::FbpAs => ProgramShape::Fbp { o: 2 * (n - 1 - i) + 1, m },
        }
    }

    /// Number of ops in the program (gap slots excluded).
    pub fn len(&self) -> usize {
        match *self {
            ProgramShape::OneFOneB { m, update, .. } => 2 * m + update as usize,
            ProgramShape::GPipe { m } => 2 * m + 1,
            ProgramShape::Fbp { o, m } => m + o.min(m) + 1,
        }
    }

    /// Programs are never empty (`m >= 1`).
    pub fn is_empty(&self) -> bool {
        false
    }

    /// The op at position `pc` (`pc < len()`), equal to `program(..).ops[pc]`.
    pub fn op_at(&self, pc: usize) -> Op {
        debug_assert!(pc < self.len());
        match *self {
            ProgramShape::OneFOneB { w, m, .. } => {
                if pc < w {
                    // warm-up forwards
                    Op::Fwd { mb: pc }
                } else if pc < 2 * m - w {
                    // steady alternation: even offsets drain Bwd{j},
                    // odd offsets admit Fwd{w+j}
                    let q = pc - w;
                    if q % 2 == 0 {
                        Op::Bwd { mb: q / 2 }
                    } else {
                        Op::Fwd { mb: w + q / 2 }
                    }
                } else if pc < 2 * m {
                    // drain backwards: mb = (m-w) + (pc - (2m-w)) = pc - m
                    Op::Bwd { mb: pc - m }
                } else {
                    Op::Update
                }
            }
            ProgramShape::GPipe { m } => {
                if pc < m {
                    Op::Fwd { mb: pc }
                } else if pc < 2 * m {
                    Op::Bwd { mb: 2 * m - 1 - pc }
                } else {
                    Op::Update
                }
            }
            ProgramShape::Fbp { o, m } => {
                if pc < o.min(m) {
                    // fwd stream alone until the first backward lands
                    Op::Fwd { mb: pc }
                } else if pc < m {
                    Op::FwdBwd { fwd_mb: pc, bwd_mb: pc - o }
                } else if pc < m + o.min(m) {
                    // bwd-only tail: generator slot t = max(m, o) + (pc - m)
                    Op::Bwd { mb: o.max(m) + (pc - m) - o }
                } else {
                    Op::Update
                }
            }
        }
    }
}

/// Structural invariants every stage program must satisfy — used by unit
/// and property tests, and asserted by the real engine at startup.
pub fn validate(p: &StageProgram, m: usize, intra_batch: bool) -> Result<(), String> {
    let mut fwd_seen = vec![false; m];
    let mut bwd_seen = vec![false; m];
    let mut update_seen = false;
    for op in &p.ops {
        match *op {
            Op::Fwd { mb } => mark(&mut fwd_seen, mb, "fwd")?,
            Op::Bwd { mb } => {
                if !fwd_seen.get(mb).copied().unwrap_or(false) {
                    return Err(format!("bwd {mb} before its fwd"));
                }
                mark(&mut bwd_seen, mb, "bwd")?;
            }
            Op::FwdBwd { fwd_mb, bwd_mb } => {
                mark(&mut fwd_seen, fwd_mb, "fwd")?;
                if fwd_mb != bwd_mb && !fwd_seen.get(bwd_mb).copied().unwrap_or(false) {
                    return Err(format!("bwd {bwd_mb} before its fwd"));
                }
                mark(&mut bwd_seen, bwd_mb, "bwd")?;
            }
            Op::Update => {
                if update_seen {
                    return Err("duplicate update".into());
                }
                update_seen = true;
            }
        }
        if update_seen && !bwd_seen.iter().all(|&b| b) {
            return Err("update before all backwards".into());
        }
    }
    if !fwd_seen.iter().all(|&f| f) {
        return Err("missing fwd ops".into());
    }
    if !bwd_seen.iter().all(|&b| b) {
        return Err("missing bwd ops".into());
    }
    if intra_batch && !update_seen {
        return Err("intra-batch schedule missing update".into());
    }
    Ok(())
}

fn mark(seen: &mut [bool], mb: usize, what: &str) -> Result<(), String> {
    if mb >= seen.len() {
        return Err(format!("{what} mb {mb} out of range"));
    }
    if seen[mb] {
        return Err(format!("duplicate {what} {mb}"));
    }
    seen[mb] = true;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure, Config};

    #[test]
    fn one_f_one_b_fig5a_shape() {
        // Fig. 5(a): 3 accelerators, M=8; accelerator 1 (i=0) warms up 3.
        let p = program(ScheduleKind::OneFOneBAs, 3, 0, 8);
        let head: Vec<Op> = p.ops[..4].to_vec();
        assert_eq!(
            head,
            vec![Op::Fwd { mb: 0 }, Op::Fwd { mb: 1 }, Op::Fwd { mb: 2 }, Op::Bwd { mb: 0 }]
        );
        // last stage (i=2) warms up 1: F0 B0 F1 B1 ...
        let p2 = program(ScheduleKind::OneFOneBAs, 3, 2, 8);
        assert_eq!(p2.ops[..4], [Op::Fwd { mb: 0 }, Op::Bwd { mb: 0 }, Op::Fwd { mb: 1 }, Op::Bwd { mb: 1 }]);
    }

    #[test]
    fn so_doubles_warmup() {
        let p_sno = program(ScheduleKind::OneFOneBSno, 3, 0, 12);
        let p_so = program(ScheduleKind::OneFOneBSo, 3, 0, 12);
        let warm = |p: &StageProgram| {
            p.ops.iter().take_while(|o| matches!(o, Op::Fwd { .. })).count()
        };
        assert_eq!(warm(&p_sno), 3);
        assert_eq!(warm(&p_so), 6);
    }

    #[test]
    fn gpipe_reverse_drain() {
        let p = program(ScheduleKind::GPipe, 4, 1, 3);
        assert_eq!(
            p.ops,
            vec![
                Op::Fwd { mb: 0 },
                Op::Fwd { mb: 1 },
                Op::Fwd { mb: 2 },
                Op::Bwd { mb: 2 },
                Op::Bwd { mb: 1 },
                Op::Bwd { mb: 0 },
                Op::Update
            ]
        );
    }

    #[test]
    fn fbp_concurrent_slots() {
        // 3 stages, last stage (i=2): o = 1, so slot 1 is FwdBwd{1,0}.
        let p = program(ScheduleKind::FbpAs, 3, 2, 4);
        assert_eq!(p.ops[0], Op::Fwd { mb: 0 });
        assert_eq!(p.ops[1], Op::FwdBwd { fwd_mb: 1, bwd_mb: 0 });
        validate(&p, 4, true).unwrap();
    }

    #[test]
    fn pipedream_has_no_update() {
        let p = program(ScheduleKind::PipeDream, 3, 0, 6);
        assert!(!p.ops.iter().any(|o| matches!(o, Op::Update)));
        validate(&p, 6, false).unwrap();
    }

    #[test]
    fn all_kinds_validate_property() {
        // Property: every (kind, n, i, m) yields a structurally valid program.
        check(
            &Config { cases: 300, ..Default::default() },
            |g| {
                let n = g.usize_in(1, 9);
                let i = g.usize_in(0, n);
                let m = g.usize_in(1, 33);
                let kinds = ScheduleKind::all();
                let kind = kinds[g.usize_in(0, kinds.len())];
                (kind, n, i, m)
            },
            |&(kind, n, i, m)| {
                let p = program(kind, n, i, m);
                ensure(
                    validate(&p, m, kind.intra_batch()).is_ok(),
                    format!("{kind:?} n={n} i={i} m={m}: {:?}", validate(&p, m, kind.intra_batch())),
                )
            },
        );
    }

    #[test]
    fn program_into_appends_and_matches_program() {
        // The buffer entry point appends (existing content survives) and
        // produces exactly the ops of `program` for every kind.
        for kind in ScheduleKind::all() {
            let mut buf = vec![Op::Update];
            program_into(kind, 4, 1, 8, &mut buf);
            let p = program(kind, 4, 1, 8);
            assert_eq!(buf[0], Op::Update, "{kind:?}");
            assert_eq!(&buf[1..], &p.ops[..], "{kind:?}");
        }
    }

    #[test]
    fn program_shape_equals_table_for_every_kind_property() {
        // The closed-form positional view must reproduce the generator
        // table op-for-op: same length, same op at every pc. This is what
        // lets the batched simulator replace the table entirely.
        check(
            &Config { cases: 400, ..Default::default() },
            |g| {
                let n = g.usize_in(1, 10);
                let i = g.usize_in(0, n);
                let m = g.usize_in(1, 40);
                let kinds = ScheduleKind::all();
                let kind = kinds[g.usize_in(0, kinds.len())];
                (kind, n, i, m)
            },
            |&(kind, n, i, m)| {
                let table = program(kind, n, i, m);
                let shape = ProgramShape::of(kind, n, i, m);
                ensure(
                    shape.len() == table.ops.len(),
                    format!(
                        "{kind:?} n={n} i={i} m={m}: shape len {} != table len {}",
                        shape.len(),
                        table.ops.len()
                    ),
                )?;
                for (pc, &op) in table.ops.iter().enumerate() {
                    ensure(
                        shape.op_at(pc) == op,
                        format!(
                            "{kind:?} n={n} i={i} m={m} pc={pc}: shape {:?} != table {op:?}",
                            shape.op_at(pc)
                        ),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn op_counts() {
        for kind in [
            ScheduleKind::OneFOneBAs,
            ScheduleKind::FbpAs,
            ScheduleKind::OneFOneBSno,
            ScheduleKind::OneFOneBSo,
            ScheduleKind::GPipe,
            ScheduleKind::TwoBW,
        ] {
            let p = program(kind, 4, 2, 10);
            assert_eq!(p.n_fwd(), 10, "{kind:?}");
            assert_eq!(p.n_bwd(), 10, "{kind:?}");
        }
    }

    #[test]
    fn two_bw_program_is_one_f_one_b_with_update() {
        // 2BW executes the plain 1F1B schedule — the memory behaviour
        // (double-buffered weights) differs, the op sequence does not.
        for i in 0..4usize {
            let p = program(ScheduleKind::TwoBW, 4, i, 8);
            assert_eq!(p.ops, program(ScheduleKind::OneFOneBAs, 4, i, 8).ops, "stage {i}");
            assert!(matches!(p.ops.last(), Some(Op::Update)));
            validate(&p, 8, true).unwrap();
        }
    }
}
