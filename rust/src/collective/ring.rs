//! Ring all-reduce (Section 2.1: "Ring All-Reduce options are used
//! commonly nowadays"): each rank sends to `(rank+1) % n` and receives
//! from `(rank-1+n) % n`; `n-1` reduce-scatter steps then `n-1`
//! all-gather steps over equal chunks. Implemented over `std::sync::mpsc`
//! channels — the in-process stand-in for GLOO.

use std::sync::mpsc::{Receiver, Sender};

/// One rank's endpoints in the ring.
pub struct RingNode {
    /// This rank.
    pub rank: usize,
    /// Total ranks.
    pub n: usize,
    /// Send to successor.
    pub tx: Sender<Vec<f32>>,
    /// Receive from predecessor.
    pub rx: Receiver<Vec<f32>>,
}

/// Build the channel ring for `n` ranks.
pub fn make_ring(n: usize) -> Vec<RingNode> {
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = std::sync::mpsc::channel();
        txs.push(tx);
        rxs.push(rx);
    }
    // rank r sends on txs[(r+1)%n]'s receiving channel: rearrange so that
    // node r holds tx_to_successor and rx_from_predecessor.
    let mut nodes = Vec::with_capacity(n);
    let mut rx_iter = rxs.into_iter();
    for r in 0..n {
        let tx = txs[(r + 1) % n].clone();
        let rx = rx_iter.next().unwrap();
        nodes.push(RingNode { rank: r, n, tx, rx });
    }
    nodes
}

/// In-place ring all-reduce (sum) of `buf` across all ranks. Every rank
/// must call this with equal-length buffers. Chunks are `ceil(len/n)`.
pub fn ring_allreduce(node: &RingNode, buf: &mut [f32]) {
    let n = node.n;
    if n == 1 {
        return;
    }
    let len = buf.len();
    let chunk = len.div_ceil(n);
    let bounds = |c: usize| -> (usize, usize) {
        let lo = (c % n) * chunk;
        (lo.min(len), (lo + chunk).min(len))
    };
    // reduce-scatter: after step s, rank r owns the fully-reduced chunk
    // (r + 1) ... standard ring: at step s, rank r sends chunk (r - s)
    // and receives chunk (r - s - 1), accumulating.
    for s in 0..n - 1 {
        let send_c = (node.rank + n - s) % n;
        let (lo, hi) = bounds(send_c);
        node.tx.send(buf[lo..hi].to_vec()).expect("ring send");
        let recv = node.rx.recv().expect("ring recv");
        let recv_c = (node.rank + n - s - 1) % n;
        let (lo, hi) = bounds(recv_c);
        for (d, v) in buf[lo..hi].iter_mut().zip(recv.iter()) {
            *d += v;
        }
    }
    // all-gather: circulate the reduced chunks.
    for s in 0..n - 1 {
        let send_c = (node.rank + 1 + n - s) % n;
        let (lo, hi) = bounds(send_c);
        node.tx.send(buf[lo..hi].to_vec()).expect("ring send");
        let recv = node.rx.recv().expect("ring recv");
        let recv_c = (node.rank + n - s) % n;
        let (lo, hi) = bounds(recv_c);
        buf[lo..hi].copy_from_slice(&recv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_allreduce(n: usize, len: usize) {
        let nodes = make_ring(n);
        let handles: Vec<_> = nodes
            .into_iter()
            .map(|node| {
                thread::spawn(move || {
                    let mut buf: Vec<f32> =
                        (0..len).map(|i| (node.rank * len + i) as f32).collect();
                    ring_allreduce(&node, &mut buf);
                    buf
                })
            })
            .collect();
        let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // expected: elementwise sum over ranks
        let expect: Vec<f32> =
            (0..len).map(|i| (0..n).map(|r| (r * len + i) as f32).sum()).collect();
        for (r, buf) in results.iter().enumerate() {
            assert_eq!(buf, &expect, "rank {r}");
        }
    }

    #[test]
    fn allreduce_2_ranks() {
        run_allreduce(2, 10);
    }

    #[test]
    fn allreduce_4_ranks() {
        run_allreduce(4, 1003); // non-divisible length exercises chunk clamping
    }

    #[test]
    fn allreduce_8_ranks_small() {
        run_allreduce(8, 5); // len < n: some empty chunks
    }

    #[test]
    fn allreduce_single_rank_noop() {
        let nodes = make_ring(1);
        let mut buf = vec![1.0, 2.0];
        ring_allreduce(&nodes[0], &mut buf);
        assert_eq!(buf, vec![1.0, 2.0]);
    }
}
