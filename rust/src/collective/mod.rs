//! Collective communication over in-process channels — the substrate for
//! the data-parallel baseline engine: a real ring all-reduce
//! (reduce-scatter + all-gather) across worker threads.

pub mod ring;

pub use ring::{ring_allreduce, RingNode};
