//! Configuration system: JSON-backed configs for training runs and
//! exploration (parsed with `util::json`), defaulting sensibly so the CLI
//! works with zero files.

use crate::util::json::Json;
use std::path::Path;

/// Training-run configuration (the real engine).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Artifact directory (must contain manifest.json).
    pub artifacts: String,
    /// Schedule name: `gpipe` | `1f1b` (SNO) | `1f1b-so` | `fbp` | `pipedream` | `dp`.
    pub schedule: String,
    /// Micro-batches per mini-batch.
    pub m: usize,
    /// Training steps (mini-batches).
    pub steps: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Data seed.
    pub seed: u64,
    /// Markov corpus branch factor.
    pub branch: usize,
    /// Markov corpus uniform-noise mass.
    pub noise: f64,
    /// Log every k steps.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifacts: "artifacts/lm10m-s4-b4".into(),
            schedule: "1f1b".into(),
            m: 8,
            steps: 50,
            lr: 1e-3,
            seed: 0,
            branch: 8,
            noise: 0.1,
            log_every: 5,
        }
    }
}

impl TrainConfig {
    /// Parse from a JSON object (unknown keys rejected to catch typos).
    pub fn from_json(j: &Json) -> crate::Result<TrainConfig> {
        let mut c = TrainConfig::default();
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("config must be an object"))?;
        for (k, v) in obj {
            match k.as_str() {
                "artifacts" => c.artifacts = v.as_str().unwrap_or(&c.artifacts).to_string(),
                "schedule" => c.schedule = v.as_str().unwrap_or(&c.schedule).to_string(),
                "m" => c.m = v.as_usize().ok_or_else(|| anyhow::anyhow!("bad m"))?,
                "steps" => c.steps = v.as_usize().ok_or_else(|| anyhow::anyhow!("bad steps"))?,
                "lr" => c.lr = v.as_f64().ok_or_else(|| anyhow::anyhow!("bad lr"))? as f32,
                "seed" => c.seed = v.as_i64().ok_or_else(|| anyhow::anyhow!("bad seed"))? as u64,
                "branch" => c.branch = v.as_usize().ok_or_else(|| anyhow::anyhow!("bad branch"))?,
                "noise" => c.noise = v.as_f64().ok_or_else(|| anyhow::anyhow!("bad noise"))?,
                "log_every" => {
                    c.log_every = v.as_usize().ok_or_else(|| anyhow::anyhow!("bad log_every"))?
                }
                other => anyhow::bail!("unknown config key `{other}`"),
            }
        }
        Ok(c)
    }

    /// Load from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<TrainConfig> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j)
    }

    /// Resolve the schedule name to a kind (pipeline) or None (= DP).
    pub fn schedule_kind(&self) -> crate::Result<Option<crate::schedule::ScheduleKind>> {
        use crate::schedule::ScheduleKind::*;
        Ok(match self.schedule.as_str() {
            "1f1b" | "1f1b-sno" => Some(OneFOneBSno),
            "1f1b-so" => Some(OneFOneBSo),
            "1f1b-as" => Some(OneFOneBAs),
            "fbp" | "fbp-as" => Some(FbpAs),
            "gpipe" => Some(GPipe),
            "pipedream" => Some(PipeDream),
            "dp" => None,
            other => anyhow::bail!("unknown schedule `{other}`"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_overrides() {
        let j = Json::parse(r#"{"schedule":"gpipe","m":16,"lr":0.01}"#).unwrap();
        let c = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c.schedule, "gpipe");
        assert_eq!(c.m, 16);
        assert!((c.lr - 0.01).abs() < 1e-9);
        assert_eq!(c.steps, TrainConfig::default().steps);
    }

    #[test]
    fn unknown_key_rejected() {
        let j = Json::parse(r#"{"schdule":"gpipe"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
    }

    #[test]
    fn schedule_names_resolve() {
        for (name, some) in [
            ("1f1b", true),
            ("1f1b-so", true),
            ("gpipe", true),
            ("fbp", true),
            ("pipedream", true),
            ("dp", false),
        ] {
            let c = TrainConfig { schedule: name.into(), ..Default::default() };
            assert_eq!(c.schedule_kind().unwrap().is_some(), some, "{name}");
        }
        let bad = TrainConfig { schedule: "zzz".into(), ..Default::default() };
        assert!(bad.schedule_kind().is_err());
    }
}
