//! Static schedule and plan-artifact verification — certificates without
//! simulation.
//!
//! Everything else in this crate that argues a schedule is *correct* does
//! so dynamically: `simulate_reference` executes the program and the tests
//! compare trajectories. This module proves the same properties from the
//! program text alone, in one linear walk per stage plus one topological
//! pass over the inter-stage op graph:
//!
//! * **Dependency order** ([`program::walk_stage`]) — per micro-batch,
//!   forward before backward, no duplicate or missing ops, the weight
//!   update only after every backward has drained.
//! * **Transfer ordering and deadlock freedom**
//!   ([`program::check_transfers`], [`program::check_deadlock`]) — every
//!   activation/error a stage consumes is produced by its neighbour,
//!   micro-batches cross each stage boundary in FIFO order per direction,
//!   and the inter-stage op graph (program-order chains plus send/recv
//!   edges) is acyclic, so no send can wait on its own receiver.
//! * **Weight-version staleness** ([`program::required_weight_versions`])
//!   — versions are tracked symbolically: plain intra-batch schedules
//!   (1F1B, GPipe, FBP) need zero shadow versions, `TwoBW` declares
//!   exactly one (`stale ≤ 1`), PipeDream's per-mini-batch updates need
//!   `N − i − 1` at stage `i`; a program whose update lands while an
//!   in-flight micro-batch still reads the old version is rejected.
//! * **Memory bound** ([`memory::check_memory`]) — the peak in-flight
//!   occupancy re-derived from the op walk must not exceed the declared
//!   stash depth, and priced through the same
//!   [`crate::partition::memfit::StageBytes`] the planner used it must
//!   fit the usable device capacity and agree with any recorded
//!   `peak_memory` figure.
//! * **Plan artifacts** ([`plan_audit::plan_audit`]) — `plan.json`
//!   structure: the partition covers all layers exactly once, the device
//!   order is a permutation of the cluster, the Pareto front really is
//!   non-dominated and sorted, bookkeeping counts and provenance
//!   references resolve.
//!
//! Every violation is a typed [`VerifyError`] carrying the offending
//! `(stage, pc, micro)` coordinates, and diagnostics are sorted by those
//! coordinates so the output is independent of evaluation order (jobs 1 ≡
//! jobs 8). Surfaced three ways: `bapipe check <plan.json>` (exit 0/1/2 =
//! clean/warnings/violations), `cfg(debug_assertions)` gates inside
//! `planner::eval::prepare`, and the `tests/verify_schedule.rs` property
//! harness.

pub mod memory;
pub mod plan_audit;
pub mod program;

pub use memory::check_memory;
pub use plan_audit::plan_audit;
pub use program::{check_stage_programs, materialize};

use crate::partition::memfit::StageBytes;
use crate::schedule::ScheduleKind;
use crate::sim::engine::SimSpec;
use std::fmt;

/// Which op family a diagnostic refers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    /// A forward pass of one micro-batch.
    Fwd,
    /// A backward pass of one micro-batch.
    Bwd,
    /// The weight update.
    Update,
}

impl fmt::Display for OpClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            OpClass::Fwd => "fwd",
            OpClass::Bwd => "bwd",
            OpClass::Update => "update",
        })
    }
}

/// One violation found by the static verifier. Every variant carries the
/// coordinates of the offending op — `stage` (pipeline stage index), `pc`
/// (position in that stage's program), `micro` (micro-batch index) —
/// wherever they exist, so a diagnostic points at a single op instance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VerifyError {
    /// A backward op appears before the forward of the same micro-batch.
    DependencyOrder {
        /// Stage whose program is broken.
        stage: usize,
        /// Program counter of the premature backward.
        pc: usize,
        /// Micro-batch whose forward has not run yet.
        micro: usize,
    },
    /// The same op (per micro-batch) appears twice in one stage program.
    DuplicateOp {
        /// Stage whose program is broken.
        stage: usize,
        /// Program counter of the second occurrence.
        pc: usize,
        /// Micro-batch the duplicated op belongs to.
        micro: usize,
        /// Which op family is duplicated.
        what: OpClass,
    },
    /// A required op never appears in the stage program.
    MissingOp {
        /// Stage whose program is incomplete.
        stage: usize,
        /// Micro-batch whose op is missing.
        micro: usize,
        /// Which op family is missing.
        what: OpClass,
    },
    /// A micro-batch index outside `0..M`.
    MicroOutOfRange {
        /// Stage whose program is broken.
        stage: usize,
        /// Program counter of the out-of-range op.
        pc: usize,
        /// The offending micro-batch index.
        micro: usize,
    },
    /// The weight update is applied while ops of the same mini-batch are
    /// still in flight (a later op would read the new version
    /// inconsistently).
    UpdateBeforeDrain {
        /// Stage whose program is broken.
        stage: usize,
        /// Program counter of the premature update.
        pc: usize,
    },
    /// Wrong number of update ops for the schedule's batching discipline.
    UpdateCount {
        /// Stage whose program is broken.
        stage: usize,
        /// Updates found in the program.
        found: usize,
        /// Updates the discipline requires (1 intra-batch, 0 inter-batch).
        expected: usize,
    },
    /// An op consumes an activation/error its neighbour stage never
    /// produces (a dropped transfer).
    MissingProducer {
        /// Consuming stage.
        stage: usize,
        /// Program counter of the consumer op.
        pc: usize,
        /// Micro-batch that is never produced upstream.
        micro: usize,
    },
    /// Micro-batches cross a stage boundary out of FIFO order: the
    /// consumer reads them in a different order than the producer emits
    /// them, so the channel would deliver the wrong tensor first.
    TransferOrder {
        /// Consuming stage.
        stage: usize,
        /// Program counter of the first out-of-order consumer op.
        pc: usize,
        /// Micro-batch consumed out of order.
        micro: usize,
    },
    /// The inter-stage op graph has a cycle: some send waits (through
    /// program order and transfer edges) on its own receiver, so the
    /// schedule deadlocks before the DES would ever run it.
    DeadlockCycle {
        /// The stages participating in the cycle, sorted ascending.
        stages: Vec<usize>,
    },
    /// The schedule needs more weight versions than it declares: an
    /// update lands between some micro-batch's forward and backward
    /// without a shadow copy to keep the pair consistent.
    StalenessBound {
        /// Stage whose version budget is exceeded.
        stage: usize,
        /// Shadow versions the program text actually requires.
        required: usize,
        /// Shadow versions the schedule kind declares.
        declared: usize,
    },
    /// The program's peak in-flight occupancy exceeds the stash depth the
    /// memory model budgeted for.
    StashDepth {
        /// Stage whose stash is under-provisioned.
        stage: usize,
        /// Peak simultaneous in-flight micro-batches derived from the op
        /// walk.
        derived: usize,
        /// Stash depth the memory model declares.
        declared: usize,
    },
    /// A stage's certified peak bytes exceed the usable device capacity.
    MemoryBound {
        /// Stage that does not fit.
        stage: usize,
        /// Certified peak bytes.
        peak: u64,
        /// Usable capacity after the memory model's reserve.
        usable: u64,
    },
    /// A recorded peak-memory figure disagrees with the static
    /// certificate (it exceeds the worst-case bound the stash depth
    /// implies).
    PeakMismatch {
        /// Stage whose record is inconsistent.
        stage: usize,
        /// Peak bytes the artifact records.
        recorded: u64,
        /// Peak bytes the certificate allows at most.
        certified: u64,
    },
    /// A structural defect in a plan artifact (partition coverage, device
    /// order, Pareto front, bookkeeping counts, provenance references).
    PlanStructure {
        /// Human-readable description of the defect.
        what: String,
    },
}

impl VerifyError {
    /// The `(stage, pc, micro)` sort key. Coordinates a variant does not
    /// have sort as `usize::MAX`, so stage-level diagnostics follow the
    /// op-level ones of the same stage and artifact-level diagnostics come
    /// last. This ordering is what makes verifier output deterministic
    /// across `--jobs`.
    pub fn coords(&self) -> (usize, usize, usize) {
        const NA: usize = usize::MAX;
        match self {
            VerifyError::DependencyOrder { stage, pc, micro }
            | VerifyError::MicroOutOfRange { stage, pc, micro }
            | VerifyError::MissingProducer { stage, pc, micro }
            | VerifyError::TransferOrder { stage, pc, micro }
            | VerifyError::DuplicateOp { stage, pc, micro, .. } => (*stage, *pc, *micro),
            VerifyError::UpdateBeforeDrain { stage, pc } => (*stage, *pc, NA),
            VerifyError::MissingOp { stage, micro, .. } => (*stage, NA, *micro),
            VerifyError::UpdateCount { stage, .. }
            | VerifyError::StalenessBound { stage, .. }
            | VerifyError::StashDepth { stage, .. }
            | VerifyError::MemoryBound { stage, .. }
            | VerifyError::PeakMismatch { stage, .. } => (*stage, NA, NA),
            VerifyError::DeadlockCycle { stages } => {
                (stages.first().copied().unwrap_or(NA), NA, NA)
            }
            VerifyError::PlanStructure { .. } => (NA, NA, NA),
        }
    }
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VerifyError::DependencyOrder { stage, pc, micro } => {
                write!(f, "stage {stage} pc {pc}: bwd of micro-batch {micro} before its fwd")
            }
            VerifyError::DuplicateOp { stage, pc, micro, what } => {
                write!(f, "stage {stage} pc {pc}: duplicate {what} of micro-batch {micro}")
            }
            VerifyError::MissingOp { stage, micro, what } => {
                write!(f, "stage {stage}: missing {what} of micro-batch {micro}")
            }
            VerifyError::MicroOutOfRange { stage, pc, micro } => {
                write!(f, "stage {stage} pc {pc}: micro-batch {micro} out of range")
            }
            VerifyError::UpdateBeforeDrain { stage, pc } => {
                write!(f, "stage {stage} pc {pc}: update applied before the mini-batch drained")
            }
            VerifyError::UpdateCount { stage, found, expected } => {
                write!(f, "stage {stage}: {found} update op(s), expected {expected}")
            }
            VerifyError::MissingProducer { stage, pc, micro } => write!(
                f,
                "stage {stage} pc {pc}: micro-batch {micro} consumed but never produced by \
                 the neighbour stage"
            ),
            VerifyError::TransferOrder { stage, pc, micro } => write!(
                f,
                "stage {stage} pc {pc}: micro-batch {micro} crosses the stage boundary out \
                 of FIFO order"
            ),
            VerifyError::DeadlockCycle { stages } => {
                write!(f, "send/recv deadlock cycle through stages {stages:?}")
            }
            VerifyError::StalenessBound { stage, required, declared } => write!(
                f,
                "stage {stage}: schedule requires {required} shadow weight version(s) but \
                 declares {declared}"
            ),
            VerifyError::StashDepth { stage, derived, declared } => write!(
                f,
                "stage {stage}: peak in-flight occupancy {derived} exceeds the declared \
                 stash depth {declared}"
            ),
            VerifyError::MemoryBound { stage, peak, usable } => write!(
                f,
                "stage {stage}: certified peak {peak} B exceeds usable capacity {usable} B"
            ),
            VerifyError::PeakMismatch { stage, recorded, certified } => write!(
                f,
                "stage {stage}: recorded peak {recorded} B exceeds the certified bound \
                 {certified} B"
            ),
            VerifyError::PlanStructure { what } => write!(f, "plan: {what}"),
        }
    }
}

/// The outcome of one verification pass: hard violations (typed) plus
/// advisory warnings (things that look suspicious but do not falsify the
/// plan). [`VerifyReport::exit_code`] maps this onto the `bapipe check`
/// exit convention.
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    /// Hard violations, sorted by [`VerifyError::coords`].
    pub violations: Vec<VerifyError>,
    /// Advisory warnings, sorted lexicographically.
    pub warnings: Vec<String>,
}

impl VerifyReport {
    /// True when there is nothing to report at all.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty() && self.warnings.is_empty()
    }

    /// The `bapipe check` exit convention: 0 clean, 1 warnings only,
    /// 2 violations.
    pub fn exit_code(&self) -> i32 {
        if !self.violations.is_empty() {
            2
        } else if !self.warnings.is_empty() {
            1
        } else {
            0
        }
    }

    /// Fold another report into this one.
    pub fn merge(&mut self, other: VerifyReport) {
        self.violations.extend(other.violations);
        self.warnings.extend(other.warnings);
    }

    /// Sort diagnostics into the canonical coordinate order (and drop
    /// exact duplicates), making the rendered output independent of the
    /// order individual checks ran in.
    pub fn sort(&mut self) {
        // Same coordinates: fall back to the message so ties are still
        // deterministic.
        self.violations.sort_by_key(|e| (e.coords(), e.to_string()));
        self.violations.dedup();
        self.warnings.sort();
        self.warnings.dedup();
    }

    /// Human-readable diagnostics, one per line, prefixed with the
    /// subject (typically the artifact path or a schedule label).
    pub fn render(&self, subject: &str) -> String {
        if self.is_clean() {
            return format!("{subject}: clean");
        }
        let mut out = format!(
            "{subject}: {} violation(s), {} warning(s)",
            self.violations.len(),
            self.warnings.len()
        );
        for v in &self.violations {
            out.push_str(&format!("\n  violation: {v}"));
        }
        for w in &self.warnings {
            out.push_str(&format!("\n  warning: {w}"));
        }
        out
    }
}

/// Statically verify the generated program of `kind` for an `n`-stage
/// pipeline at `m` micro-batches: materialize every stage's op sequence
/// from [`crate::schedule::generators::ProgramShape`] and run the full
/// dependency / transfer / deadlock / staleness / stash analysis. A clean
/// report is a certificate that the schedule is executable without ever
/// running the DES.
pub fn check_program(kind: ScheduleKind, n: usize, m: usize) -> VerifyReport {
    if n == 0 || m == 0 {
        let mut r = VerifyReport::default();
        r.violations.push(VerifyError::PlanStructure {
            what: format!("degenerate schedule shape: N={n}, M={m}"),
        });
        return r;
    }
    let programs: Vec<Vec<crate::schedule::Op>> =
        (0..n).map(|i| materialize(kind, n, i, m)).collect();
    check_stage_programs(kind, n, m, &programs)
}

/// Structural verification of a DES spec plus its generated program:
/// vector lengths agree, every time is finite and non-negative, and the
/// program certificate holds. This is what the `cfg(debug_assertions)`
/// planner gate runs on every candidate.
pub fn check_spec(spec: &SimSpec) -> VerifyReport {
    let n = spec.n();
    let mut report = VerifyReport::default();
    let mut structural = |ok: bool, what: String| {
        if !ok {
            report.violations.push(VerifyError::PlanStructure { what });
        }
    };
    structural(
        spec.bwd.len() == n && spec.exec.len() == n,
        format!(
            "spec vector lengths disagree: fwd {n}, bwd {}, exec {}",
            spec.bwd.len(),
            spec.exec.len()
        ),
    );
    structural(
        spec.fwd_xfer.len() + 1 == n.max(1) && spec.bwd_xfer.len() + 1 == n.max(1),
        format!(
            "spec transfer lengths disagree: {} stages, {} fwd_xfer, {} bwd_xfer",
            n,
            spec.fwd_xfer.len(),
            spec.bwd_xfer.len()
        ),
    );
    let finite = |v: &[f64]| v.iter().all(|t| t.is_finite() && *t >= 0.0);
    structural(
        finite(&spec.fwd)
            && finite(&spec.bwd)
            && finite(&spec.fwd_xfer)
            && finite(&spec.bwd_xfer)
            && spec.update.is_finite()
            && spec.update >= 0.0,
        "spec has a negative or non-finite time".to_string(),
    );
    report.merge(check_program(spec.kind, n, spec.m));
    report.sort();
    report
}

/// Verify one planner candidate end to end: the program certificate plus
/// the memory-bound certificate against the priced
/// [`StageBytes`] and (optionally) per-stage usable capacities in
/// pipeline order.
pub fn check_candidate(
    kind: ScheduleKind,
    n: usize,
    m: usize,
    stage_bytes: &[StageBytes],
    usable: Option<&[u64]>,
) -> VerifyReport {
    let mut report = check_program(kind, n, m);
    let peaks: Vec<usize> =
        (0..n).map(|i| program::peak_occupancy(&materialize(kind, n, i, m))).collect();
    report.merge(check_memory(&peaks, stage_bytes, usable, None));
    report.sort();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ExecMode;

    #[test]
    fn all_kinds_certify_clean() {
        for kind in ScheduleKind::all() {
            for n in [1usize, 2, 3, 4, 8] {
                for m in [1usize, 2, 3, 4, 8, 16] {
                    let r = check_program(kind, n, m);
                    assert!(
                        r.is_clean(),
                        "{} N={n} M={m}: {}",
                        kind.label(),
                        r.render("program")
                    );
                }
            }
        }
    }

    #[test]
    fn spec_check_accepts_uniform_specs() {
        for kind in ScheduleKind::all() {
            for exec in [ExecMode::Sync, ExecMode::Async] {
                let spec = SimSpec::uniform(kind, 4, 8, 1.0, 2.0, 0.25, exec);
                let r = check_spec(&spec);
                assert!(r.is_clean(), "{} {exec:?}: {}", kind.label(), r.render("spec"));
            }
        }
    }

    #[test]
    fn spec_check_rejects_nonfinite_times() {
        let mut spec = SimSpec::uniform(ScheduleKind::GPipe, 3, 4, 1.0, 2.0, 0.25, ExecMode::Sync);
        spec.fwd[1] = f64::NAN;
        let r = check_spec(&spec);
        assert_eq!(r.exit_code(), 2);
        assert!(r
            .violations
            .iter()
            .any(|v| matches!(v, VerifyError::PlanStructure { what } if what.contains("finite"))));
    }

    #[test]
    fn degenerate_shape_is_a_violation_not_a_panic() {
        assert_eq!(check_program(ScheduleKind::GPipe, 0, 4).exit_code(), 2);
        assert_eq!(check_program(ScheduleKind::GPipe, 2, 0).exit_code(), 2);
    }

    #[test]
    fn report_sorting_is_canonical() {
        let mut r = VerifyReport::default();
        r.violations.push(VerifyError::UpdateCount { stage: 2, found: 0, expected: 1 });
        r.violations.push(VerifyError::DependencyOrder { stage: 0, pc: 3, micro: 1 });
        r.violations.push(VerifyError::DependencyOrder { stage: 0, pc: 1, micro: 0 });
        r.violations.push(VerifyError::PlanStructure { what: "x".into() });
        r.sort();
        let coords: Vec<(usize, usize, usize)> = r.violations.iter().map(|v| v.coords()).collect();
        let mut sorted = coords.clone();
        sorted.sort();
        assert_eq!(coords, sorted);
        assert!(matches!(r.violations[0], VerifyError::DependencyOrder { pc: 1, .. }));
        assert!(matches!(r.violations.last(), Some(VerifyError::PlanStructure { .. })));
    }

    #[test]
    fn render_counts_and_exit_codes() {
        let mut r = VerifyReport::default();
        assert_eq!(r.exit_code(), 0);
        assert_eq!(r.render("x"), "x: clean");
        r.warnings.push("odd".into());
        assert_eq!(r.exit_code(), 1);
        r.violations.push(VerifyError::PlanStructure { what: "bad".into() });
        assert_eq!(r.exit_code(), 2);
        let text = r.render("plan.json");
        assert!(text.contains("1 violation(s), 1 warning(s)"));
        assert!(text.contains("violation: plan: bad"));
        assert!(text.contains("warning: odd"));
    }
}
