//! Program-text analyses: dependency order, transfer FIFO, deadlock
//! topology, weight-version staleness, peak occupancy.
//!
//! Everything here operates on materialized per-stage op sequences
//! (`Vec<Op>`), so the same passes verify both generated programs (via
//! [`materialize`]) and arbitrary — possibly mutated — programs fed in by
//! the property harness.

use super::{OpClass, VerifyError, VerifyReport};
use crate::schedule::generators::ProgramShape;
use crate::schedule::{Op, ScheduleKind};

/// Materialize stage `i`'s op sequence from the closed-form
/// [`ProgramShape`] — the verifier's single source of program text, the
/// same shape the batched simulator executes.
pub fn materialize(kind: ScheduleKind, n: usize, i: usize, m: usize) -> Vec<Op> {
    let shape = ProgramShape::of(kind, n, i, m);
    (0..shape.len()).map(|pc| shape.op_at(pc)).collect()
}

/// The result of one stage's dependency walk.
#[derive(Debug, Clone)]
pub struct StageWalk {
    /// Violations found, in program order.
    pub errors: Vec<VerifyError>,
    /// High-water mark of simultaneously in-flight micro-batches (a
    /// micro-batch is in flight from its forward until its backward
    /// retires it; a fused `FwdBwd` slot admits its forward before
    /// retiring its backward, matching the stash accounting).
    pub peak_in_flight: usize,
}

/// Walk one stage's op sequence and prove the per-stage dependency
/// discipline: forward before backward per micro-batch, no duplicates, no
/// missing ops, micro-batch indices in range, and — for intra-batch
/// schedules — exactly one update, applied only after every backward has
/// drained.
pub fn walk_stage(stage: usize, ops: &[Op], m: usize, intra_batch: bool) -> StageWalk {
    let mut w = WalkState {
        stage,
        m,
        errors: Vec::new(),
        fwd_done: vec![false; m],
        bwd_done: vec![false; m],
        open: vec![false; m],
        in_flight: 0,
        peak: 0,
    };
    let mut updates: Vec<usize> = Vec::new();

    for (pc, op) in ops.iter().enumerate() {
        match *op {
            Op::Fwd { mb } => w.fwd(pc, mb),
            Op::Bwd { mb } => w.bwd(pc, mb),
            Op::FwdBwd { fwd_mb, bwd_mb } => {
                // The forward is admitted before the backward retires, so
                // the fused slot's footprint counts both micro-batches.
                w.fwd(pc, fwd_mb);
                w.bwd(pc, bwd_mb);
            }
            Op::Update => updates.push(pc),
        }
    }

    for mb in 0..m {
        if !w.fwd_done[mb] {
            w.errors.push(VerifyError::MissingOp { stage, micro: mb, what: OpClass::Fwd });
        }
        if !w.bwd_done[mb] {
            w.errors.push(VerifyError::MissingOp { stage, micro: mb, what: OpClass::Bwd });
        }
    }

    let expected_updates = usize::from(intra_batch);
    if updates.len() != expected_updates {
        w.errors.push(VerifyError::UpdateCount {
            stage,
            found: updates.len(),
            expected: expected_updates,
        });
    }
    if let Some(&first_update) = updates.first() {
        // Any compute op after the first update reads the new weight
        // version while the mini-batch it belongs to already started on
        // the old one — inconsistent without a shadow copy, and plain
        // intra-batch schedules declare none.
        let compute_after = ops[first_update..].iter().any(|op| !matches!(op, Op::Update));
        if compute_after {
            w.errors.push(VerifyError::UpdateBeforeDrain { stage, pc: first_update });
        }
    }

    StageWalk { errors: w.errors, peak_in_flight: w.peak }
}

/// Mutable state of one stage's dependency walk.
struct WalkState {
    stage: usize,
    m: usize,
    errors: Vec<VerifyError>,
    fwd_done: Vec<bool>,
    bwd_done: Vec<bool>,
    open: Vec<bool>,
    in_flight: usize,
    peak: usize,
}

impl WalkState {
    fn fwd(&mut self, pc: usize, mb: usize) {
        let stage = self.stage;
        if mb >= self.m {
            self.errors.push(VerifyError::MicroOutOfRange { stage, pc, micro: mb });
            return;
        }
        if self.fwd_done[mb] {
            self.errors.push(VerifyError::DuplicateOp { stage, pc, micro: mb, what: OpClass::Fwd });
            return;
        }
        self.fwd_done[mb] = true;
        self.open[mb] = true;
        self.in_flight += 1;
        self.peak = self.peak.max(self.in_flight);
    }

    fn bwd(&mut self, pc: usize, mb: usize) {
        let stage = self.stage;
        if mb >= self.m {
            self.errors.push(VerifyError::MicroOutOfRange { stage, pc, micro: mb });
            return;
        }
        if !self.fwd_done[mb] {
            self.errors.push(VerifyError::DependencyOrder { stage, pc, micro: mb });
        }
        if self.bwd_done[mb] {
            self.errors.push(VerifyError::DuplicateOp { stage, pc, micro: mb, what: OpClass::Bwd });
            return;
        }
        self.bwd_done[mb] = true;
        if self.open[mb] {
            self.open[mb] = false;
            self.in_flight -= 1;
        }
    }
}

/// Peak simultaneous in-flight micro-batches of one op sequence — the
/// occupancy the memory certificate prices through
/// [`crate::partition::memfit::StageBytes::at_occupancy`].
pub fn peak_occupancy(ops: &[Op]) -> usize {
    let mut in_flight = 0usize;
    let mut peak = 0usize;
    for op in ops {
        match op {
            Op::Fwd { .. } => {
                in_flight += 1;
                peak = peak.max(in_flight);
            }
            Op::FwdBwd { .. } => {
                in_flight += 1;
                peak = peak.max(in_flight);
                in_flight = in_flight.saturating_sub(1);
            }
            Op::Bwd { .. } => in_flight = in_flight.saturating_sub(1),
            Op::Update => {}
        }
    }
    peak
}

/// The forward events of one op sequence as `(pc, micro)` pairs in
/// program order (fused slots contribute their forward half).
fn fwd_events(ops: &[Op]) -> Vec<(usize, usize)> {
    ops.iter()
        .enumerate()
        .filter_map(|(pc, op)| match *op {
            Op::Fwd { mb } | Op::FwdBwd { fwd_mb: mb, .. } => Some((pc, mb)),
            _ => None,
        })
        .collect()
}

/// The backward events of one op sequence as `(pc, micro)` pairs in
/// program order (fused slots contribute their backward half).
fn bwd_events(ops: &[Op]) -> Vec<(usize, usize)> {
    ops.iter()
        .enumerate()
        .filter_map(|(pc, op)| match *op {
            Op::Bwd { mb } | Op::FwdBwd { bwd_mb: mb, .. } => Some((pc, mb)),
            _ => None,
        })
        .collect()
}

/// Keep the first occurrence per micro-batch (duplicates are reported by
/// the stage walk; the transfer analysis reasons about first use).
fn dedup_first(events: &[(usize, usize)]) -> Vec<(usize, usize)> {
    let mut seen = std::collections::BTreeSet::new();
    events.iter().copied().filter(|&(_, mb)| seen.insert(mb)).collect()
}

/// First occurrence per micro-batch as a `micro → pc` map.
fn by_micro(events: &[(usize, usize)]) -> std::collections::BTreeMap<usize, usize> {
    dedup_first(events).into_iter().map(|(pc, mb)| (mb, pc)).collect()
}

/// Check one direction of one stage boundary: every micro-batch the
/// consumer reads must be produced by the neighbour, and the common
/// micro-batches must cross in the same relative order on both sides
/// (FIFO channels deliver in send order; a reordered consumer would wait
/// on a tensor stuck behind the one it skipped).
fn check_edge_direction(
    producer: &[(usize, usize)],
    consumer: &[(usize, usize)],
    consumer_stage: usize,
    errors: &mut Vec<VerifyError>,
) {
    let prod = dedup_first(producer);
    let cons = dedup_first(consumer);
    let produced: std::collections::BTreeSet<usize> = prod.iter().map(|&(_, mb)| mb).collect();
    for &(pc, mb) in &cons {
        if !produced.contains(&mb) {
            errors.push(VerifyError::MissingProducer { stage: consumer_stage, pc, micro: mb });
        }
    }
    let consumed: std::collections::BTreeSet<usize> = cons.iter().map(|&(_, mb)| mb).collect();
    let prod_common: Vec<usize> =
        prod.iter().map(|&(_, mb)| mb).filter(|mb| consumed.contains(mb)).collect();
    let cons_common: Vec<(usize, usize)> =
        cons.iter().copied().filter(|&(_, mb)| produced.contains(&mb)).collect();
    for (&p_mb, &(c_pc, c_mb)) in prod_common.iter().zip(cons_common.iter()) {
        if p_mb != c_mb {
            // Report only the first mismatch per edge-direction: every
            // later position is skewed by the same reorder.
            errors.push(VerifyError::TransferOrder {
                stage: consumer_stage,
                pc: c_pc,
                micro: c_mb,
            });
            break;
        }
    }
}

/// Prove cross-stage transfer sanity for every adjacent stage pair:
/// forward activations flow `i → i+1` (stage 0's inputs are local),
/// backward errors flow `i+1 → i` (the last stage's are local). Each
/// direction gets the producer-exists and FIFO-order checks of
/// [`check_edge_direction`].
pub fn check_transfers(programs: &[Vec<Op>]) -> Vec<VerifyError> {
    let mut errors = Vec::new();
    for i in 0..programs.len().saturating_sub(1) {
        // Forward direction: stage i produces, stage i+1 consumes.
        check_edge_direction(
            &fwd_events(&programs[i]),
            &fwd_events(&programs[i + 1]),
            i + 1,
            &mut errors,
        );
        // Backward direction: stage i+1 produces, stage i consumes.
        check_edge_direction(
            &bwd_events(&programs[i + 1]),
            &bwd_events(&programs[i]),
            i,
            &mut errors,
        );
    }
    errors
}

/// Prove deadlock freedom: build the inter-stage op graph — each stage's
/// program-order chain plus one edge per transfer (forward producer to
/// its consumer downstream, backward producer to its consumer upstream)
/// — and topologically sort it. A cycle means some send transitively
/// waits on its own receiver and the schedule can never complete; the
/// DES would hit its dynamic deadlock assertion, the verifier proves it
/// without running.
pub fn check_deadlock(programs: &[Vec<Op>]) -> Vec<VerifyError> {
    let n = programs.len();
    let mut offset = vec![0usize; n + 1];
    for i in 0..n {
        offset[i + 1] = offset[i] + programs[i].len();
    }
    let total = offset[n];
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); total];
    let mut indeg = vec![0u32; total];
    let mut edge = |from: usize, to: usize| {
        adj[from].push(to as u32);
        indeg[to] += 1;
    };

    for i in 0..n {
        for pc in 1..programs[i].len() {
            edge(offset[i] + pc - 1, offset[i] + pc);
        }
    }
    for i in 0..n.saturating_sub(1) {
        // Forward transfers: first fwd of each micro-batch at stage i
        // feeds the matching fwd at stage i+1.
        let prod = by_micro(&fwd_events(&programs[i]));
        for (pc, mb) in dedup_first(&fwd_events(&programs[i + 1])) {
            if let Some(&p_pc) = prod.get(&mb) {
                edge(offset[i] + p_pc, offset[i + 1] + pc);
            }
        }
        // Backward transfers: first bwd of each micro-batch at stage i+1
        // feeds the matching bwd at stage i.
        let prod = by_micro(&bwd_events(&programs[i + 1]));
        for (pc, mb) in dedup_first(&bwd_events(&programs[i])) {
            if let Some(&p_pc) = prod.get(&mb) {
                edge(offset[i + 1] + p_pc, offset[i] + pc);
            }
        }
    }

    // Kahn's algorithm; anything never popped sits on a cycle (or
    // downstream of one — the reported stage set covers both).
    let mut queue: Vec<usize> = (0..total).filter(|&v| indeg[v] == 0).collect();
    let mut popped = 0usize;
    while let Some(v) = queue.pop() {
        popped += 1;
        for &w in &adj[v] {
            indeg[w as usize] -= 1;
            if indeg[w as usize] == 0 {
                queue.push(w as usize);
            }
        }
    }
    if popped == total {
        return Vec::new();
    }
    let mut stages: Vec<usize> = (0..total)
        .filter(|&v| indeg[v] > 0)
        .map(|v| offset.partition_point(|&o| o <= v) - 1)
        .collect();
    stages.sort_unstable();
    stages.dedup();
    vec![VerifyError::DeadlockCycle { stages }]
}

/// Shadow weight versions the program text requires: for each micro-batch
/// with both halves present, count the update events between its forward
/// and its backward — each one is a version the pair must be shielded
/// from. Inter-batch schedules (PipeDream) apply one asynchronous update
/// per mini-batch, i.e. per foreign backward, so there every foreign
/// backward in the window counts as an update.
pub fn required_weight_versions(ops: &[Op], intra_batch: bool) -> usize {
    let fwds = dedup_first(&fwd_events(ops));
    let bwd_pcs = dedup_first(&bwd_events(ops));
    let bwds = by_micro(&bwd_events(ops));
    let update_pcs: Vec<usize> = ops
        .iter()
        .enumerate()
        .filter_map(|(pc, op)| matches!(op, Op::Update).then_some(pc))
        .collect();
    let mut worst = 0usize;
    for (f_pc, mb) in fwds {
        let Some(&b_pc) = bwds.get(&mb) else { continue };
        if b_pc <= f_pc {
            continue;
        }
        let mut intervening = update_pcs.iter().filter(|&&u| f_pc < u && u < b_pc).count();
        if !intra_batch {
            intervening += bwd_pcs
                .iter()
                .filter(|&&(pc, other)| other != mb && f_pc < pc && pc < b_pc)
                .count();
        }
        worst = worst.max(intervening);
    }
    worst
}

/// Certify the staleness bound of one stage: the versions the program
/// requires must be covered by what the schedule kind declares
/// ([`ScheduleKind::weight_versions`] — 0 for plain intra-batch kinds,
/// exactly 1 shadow for `TwoBW`, `N−i−1` for PipeDream).
pub fn check_weight_versions(
    stage: usize,
    ops: &[Op],
    intra_batch: bool,
    declared: usize,
) -> Vec<VerifyError> {
    let required = required_weight_versions(ops, intra_batch);
    if required > declared {
        vec![VerifyError::StalenessBound { stage, required, declared }]
    } else {
        Vec::new()
    }
}

/// Run the full program-level analysis over explicit per-stage op
/// sequences: per-stage dependency walks, stash-depth cross-check against
/// the kind's declared depth, weight-version staleness, transfer
/// ordering, and the deadlock topology. This is the mutation-harness
/// entry point; [`super::check_program`] feeds it generated programs.
pub fn check_stage_programs(
    kind: ScheduleKind,
    n: usize,
    m: usize,
    programs: &[Vec<Op>],
) -> VerifyReport {
    let mut report = VerifyReport::default();
    if programs.len() != n {
        report.violations.push(VerifyError::PlanStructure {
            what: format!("{} stage programs for an N={n} pipeline", programs.len()),
        });
        report.sort();
        return report;
    }
    let intra = kind.intra_batch();
    for (i, ops) in programs.iter().enumerate() {
        let walk = walk_stage(i, ops, m, intra);
        report.violations.extend(walk.errors);
        let declared = kind.stash_depth(n, i, m);
        if walk.peak_in_flight > declared {
            report.violations.push(VerifyError::StashDepth {
                stage: i,
                derived: walk.peak_in_flight,
                declared,
            });
        }
        report.violations.extend(check_weight_versions(
            i,
            ops,
            intra,
            kind.weight_versions(n, i),
        ));
    }
    report.violations.extend(check_transfers(programs));
    report.violations.extend(check_deadlock(programs));
    report.sort();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::generators;

    /// Materialized shapes must agree with the generator programs the
    /// DES executes — the verifier certifies what actually runs.
    #[test]
    fn materialize_matches_generator() {
        for kind in ScheduleKind::all() {
            for n in [1usize, 2, 3, 5] {
                for i in 0..n {
                    for m in [1usize, 2, 4, 9] {
                        assert_eq!(
                            materialize(kind, n, i, m),
                            generators::program(kind, n, i, m).ops,
                            "{} N={n} i={i} M={m}",
                            kind.label()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn walk_flags_bwd_before_fwd() {
        let ops = vec![Op::Bwd { mb: 0 }, Op::Fwd { mb: 0 }, Op::Update];
        let walk = walk_stage(1, &ops, 1, true);
        assert!(walk
            .errors
            .iter()
            .any(|e| matches!(e, VerifyError::DependencyOrder { stage: 1, pc: 0, micro: 0 })));
    }

    #[test]
    fn walk_flags_duplicates_and_missing() {
        let ops = vec![Op::Fwd { mb: 0 }, Op::Fwd { mb: 0 }, Op::Bwd { mb: 0 }, Op::Update];
        let walk = walk_stage(0, &ops, 2, true);
        assert!(walk.errors.iter().any(|e| matches!(
            e,
            VerifyError::DuplicateOp { pc: 1, micro: 0, what: OpClass::Fwd, .. }
        )));
        assert!(walk
            .errors
            .iter()
            .any(|e| matches!(e, VerifyError::MissingOp { micro: 1, what: OpClass::Fwd, .. })));
        assert!(walk
            .errors
            .iter()
            .any(|e| matches!(e, VerifyError::MissingOp { micro: 1, what: OpClass::Bwd, .. })));
    }

    #[test]
    fn walk_flags_early_update() {
        let ops = vec![
            Op::Fwd { mb: 0 },
            Op::Fwd { mb: 1 },
            Op::Bwd { mb: 0 },
            Op::Update,
            Op::Bwd { mb: 1 },
        ];
        let walk = walk_stage(0, &ops, 2, true);
        assert!(walk
            .errors
            .iter()
            .any(|e| matches!(e, VerifyError::UpdateBeforeDrain { stage: 0, pc: 3 })));
    }

    #[test]
    fn peak_occupancy_matches_declared_stash() {
        // The derived high-water mark never exceeds the declared stash
        // depth and is exactly the in-flight figure for the plain kinds.
        for kind in ScheduleKind::all() {
            for n in [1usize, 2, 4, 6] {
                for i in 0..n {
                    for m in [1usize, 3, 8, 16] {
                        let peak = peak_occupancy(&materialize(kind, n, i, m));
                        let declared = kind.stash_depth(n, i, m);
                        assert!(
                            peak <= declared,
                            "{} N={n} i={i} M={m}: peak {peak} > stash {declared}",
                            kind.label()
                        );
                    }
                }
            }
        }
        // Spot-check the exact figures the paper's Table 1 predicts.
        assert_eq!(peak_occupancy(&materialize(ScheduleKind::GPipe, 4, 0, 8)), 8);
        assert_eq!(peak_occupancy(&materialize(ScheduleKind::OneFOneBSno, 4, 0, 8)), 4);
        assert_eq!(peak_occupancy(&materialize(ScheduleKind::OneFOneBSno, 4, 3, 8)), 1);
    }

    #[test]
    fn transfers_flag_dropped_producer() {
        let mut programs: Vec<Vec<Op>> =
            (0..3).map(|i| materialize(ScheduleKind::OneFOneBSno, 3, i, 4)).collect();
        // Drop micro-batch 2's forward at stage 1: stage 2 now consumes a
        // tensor nobody sends.
        programs[1].retain(|op| !matches!(op, Op::Fwd { mb: 2 }));
        let errors = check_transfers(&programs);
        assert!(errors
            .iter()
            .any(|e| matches!(e, VerifyError::MissingProducer { stage: 2, micro: 2, .. })));
    }

    #[test]
    fn transfers_flag_fifo_reorder() {
        let mut programs: Vec<Vec<Op>> =
            (0..2).map(|i| materialize(ScheduleKind::GPipe, 2, i, 4)).collect();
        // Swap the first two forwards at the consumer only: the channel
        // still delivers 0 first, but the consumer now wants 1 first.
        let (a, b) = (0, 1);
        programs[1].swap(a, b);
        let errors = check_transfers(&programs);
        assert!(errors
            .iter()
            .any(|e| matches!(e, VerifyError::TransferOrder { stage: 1, pc: 0, micro: 1 })));
    }

    #[test]
    fn deadlock_cycle_detected() {
        // Stage 0 wants its backward before sending forward 0 on; stage 1
        // needs forward 0 before it can produce that backward: a classic
        // send/recv cycle.
        let programs = vec![
            vec![Op::Bwd { mb: 0 }, Op::Fwd { mb: 0 }, Op::Update],
            vec![Op::Fwd { mb: 0 }, Op::Bwd { mb: 0 }, Op::Update],
        ];
        let errors = check_deadlock(&programs);
        assert_eq!(errors.len(), 1);
        assert!(
            matches!(&errors[0], VerifyError::DeadlockCycle { stages } if stages[..] == [0, 1])
        );
    }

    #[test]
    fn generated_programs_are_deadlock_free() {
        for kind in ScheduleKind::all() {
            for n in [1usize, 2, 4] {
                for m in [1usize, 4, 9] {
                    let programs: Vec<Vec<Op>> =
                        (0..n).map(|i| materialize(kind, n, i, m)).collect();
                    assert!(
                        check_deadlock(&programs).is_empty(),
                        "{} N={n} M={m}",
                        kind.label()
                    );
                }
            }
        }
    }

    #[test]
    fn weight_versions_match_the_declared_bounds() {
        // PipeDream at stage i of n needs min(n-i, m) - 1 versions; the
        // kind declares n-i-1, which covers it. Intra-batch kinds need 0.
        for n in [2usize, 4, 6] {
            for i in 0..n {
                for m in [1usize, 4, 16] {
                    let ops = materialize(ScheduleKind::PipeDream, n, i, m);
                    let required = required_weight_versions(&ops, false);
                    assert_eq!(required, (n - i).min(m).saturating_sub(1), "N={n} i={i} M={m}");
                    assert!(required <= ScheduleKind::PipeDream.weight_versions(n, i));
                }
            }
        }
        for kind in [ScheduleKind::OneFOneBSno, ScheduleKind::GPipe, ScheduleKind::TwoBW] {
            let ops = materialize(kind, 4, 1, 8);
            assert_eq!(required_weight_versions(&ops, true), 0);
        }
        // 2BW: exactly one shadow version declared, bounding stale <= 1.
        assert_eq!(ScheduleKind::TwoBW.weight_versions(4, 1), 1);
    }

    #[test]
    fn staleness_rejects_underdeclared_versions() {
        let ops = materialize(ScheduleKind::PipeDream, 4, 0, 8);
        let required = required_weight_versions(&ops, false);
        assert!(required >= 1);
        let errors = check_weight_versions(0, &ops, false, required - 1);
        assert!(matches!(
            errors.as_slice(),
            [VerifyError::StalenessBound { stage: 0, declared, .. }] if *declared == required - 1
        ));
    }
}
