//! The memory-bound certificate: peak occupancy re-derived from program
//! text, priced through the planner's own [`StageBytes`], cross-checked
//! against declared stash depths, recorded peaks, and device capacity.

use super::{VerifyError, VerifyReport};
use crate::partition::memfit::StageBytes;

/// Certify the memory story of one plan:
///
/// * `derived_peaks[i]` — stage `i`'s peak in-flight occupancy from the
///   op walk ([`super::program::peak_occupancy`]) — must not exceed
///   `stage_bytes[i].stash_depth`, the depth the memory model budgeted
///   (an off-by-one stash depth is exactly the bug this catches).
/// * The worst-case bytes `stage_bytes[i].peak()` must fit
///   `usable[i]` when capacities are given (already passed through
///   [`crate::partition::memfit::MemoryModel::usable`]).
/// * Any `recorded[i]` peak figure (e.g. the plan's simulated
///   `peak_memory`) must not exceed the certified worst case; a recorded
///   figure *below* the statically certain floor
///   `at_occupancy(derived_peaks[i])` is flagged as a warning — it cannot
///   falsify the plan but it means the artifact's accounting drifted.
pub fn check_memory(
    derived_peaks: &[usize],
    stage_bytes: &[StageBytes],
    usable: Option<&[u64]>,
    recorded: Option<&[u64]>,
) -> VerifyReport {
    let mut report = VerifyReport::default();
    if derived_peaks.len() != stage_bytes.len() {
        report.violations.push(VerifyError::PlanStructure {
            what: format!(
                "{} derived occupancies vs {} StageBytes entries",
                derived_peaks.len(),
                stage_bytes.len()
            ),
        });
        report.sort();
        return report;
    }
    for (i, (&peak_in_flight, sb)) in derived_peaks.iter().zip(stage_bytes).enumerate() {
        if peak_in_flight > sb.stash_depth {
            report.violations.push(VerifyError::StashDepth {
                stage: i,
                derived: peak_in_flight,
                declared: sb.stash_depth,
            });
        }
        let certified_floor = sb.at_occupancy(peak_in_flight.min(sb.stash_depth));
        let worst_case = sb.peak();
        if let Some(usable) = usable {
            if let Some(&cap) = usable.get(i) {
                if worst_case > cap {
                    report.violations.push(VerifyError::MemoryBound {
                        stage: i,
                        peak: worst_case,
                        usable: cap,
                    });
                }
            }
        }
        if let Some(recorded) = recorded {
            if let Some(&rec) = recorded.get(i) {
                if rec > worst_case {
                    report.violations.push(VerifyError::PeakMismatch {
                        stage: i,
                        recorded: rec,
                        certified: worst_case,
                    });
                } else if rec < certified_floor {
                    report.warnings.push(format!(
                        "stage {i}: recorded peak {rec} B below the statically certain floor \
                         {certified_floor} B"
                    ));
                }
            }
        }
    }
    report.sort();
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sb(static_bytes: u64, per_mb: u64, depth: usize) -> StageBytes {
        StageBytes { static_bytes, per_mb_stash: per_mb, stash_depth: depth }
    }

    #[test]
    fn clean_when_everything_agrees() {
        let bytes = [sb(100, 10, 4), sb(80, 10, 2)];
        let peaks = [4usize, 2];
        let usable = [200u64, 200];
        let recorded = [140u64, 100];
        let r = check_memory(&peaks, &bytes, Some(&usable), Some(&recorded));
        assert!(r.is_clean(), "{}", r.render("memory"));
    }

    #[test]
    fn off_by_one_stash_depth_is_rejected() {
        // The program needs 4 in flight but the memory model budgeted 3.
        let bytes = [sb(100, 10, 3)];
        let r = check_memory(&[4], &bytes, None, None);
        assert!(matches!(
            r.violations.as_slice(),
            [VerifyError::StashDepth { stage: 0, derived: 4, declared: 3 }]
        ));
    }

    #[test]
    fn capacity_overflow_is_rejected() {
        let bytes = [sb(100, 10, 4)]; // worst case 140 B
        let usable = [120u64];
        let r = check_memory(&[4], &bytes, Some(&usable), None);
        assert!(matches!(
            r.violations.as_slice(),
            [VerifyError::MemoryBound { stage: 0, peak: 140, usable: 120 }]
        ));
    }

    #[test]
    fn recorded_peak_above_bound_is_rejected_below_floor_is_warned() {
        let bytes = [sb(100, 10, 4), sb(100, 10, 4)];
        // Stage 0 records more than the worst case; stage 1 records less
        // than the floor its own occupancy implies.
        let recorded = [150u64, 120];
        let r = check_memory(&[4, 4], &bytes, None, Some(&recorded));
        assert!(matches!(
            r.violations.as_slice(),
            [VerifyError::PeakMismatch { stage: 0, recorded: 150, certified: 140 }]
        ));
        assert_eq!(r.warnings.len(), 1);
        assert!(r.warnings[0].contains("stage 1"));
    }

    #[test]
    fn length_mismatch_is_structural() {
        let r = check_memory(&[1, 2], &[sb(1, 1, 1)], None, None);
        assert_eq!(r.exit_code(), 2);
        assert!(matches!(r.violations.as_slice(), [VerifyError::PlanStructure { .. }]));
    }
}
