//! Structural audit of `plan.json` artifacts and plan-store contents.
//!
//! [`Plan::from_json`] already rejects malformed JSON shapes; this pass
//! checks the *semantic* structure of a loaded plan — the invariants a
//! consumer (a training launcher, a replan warm start, a plan-store
//! client) silently relies on:
//!
//! * the partition covers all layers exactly once (bounds start at 0 and
//!   strictly increase; every Pareto member covers the same layer total),
//! * the device order is a permutation (of the cluster, when one is
//!   given),
//! * the chosen schedule's generated program passes the full static
//!   certificate ([`super::check_program`]),
//! * the Pareto front really is non-dominated and sorted fastest-first
//!   with strictly decreasing peak memory,
//! * bookkeeping adds up (`simulated_count`/`pruned_count` match the
//!   evaluations; recorded peak memory stays under the worst-case stage
//!   memory; order-provenance references resolve),
//! * with a cluster in hand, every stage fits its device's usable
//!   capacity under the default [`MemoryModel`].
//!
//! Byte-level pricing of occupancy (the `StageBytes` cross-check) lives
//! in the planner's debug gate where the profile is available — an
//! artifact alone does not carry per-micro-batch byte figures.

use super::{VerifyError, VerifyReport};
use crate::cluster::Cluster;
use crate::partition::memfit::MemoryModel;
use crate::planner::{Choice, Outcome, ParetoPoint, Plan};

/// Audit a loaded plan artifact. `cluster` enables the capacity checks;
/// without it the audit is purely self-consistency. Returns the sorted
/// diagnostics report ([`VerifyReport::exit_code`] gives the `bapipe
/// check` exit status).
pub fn plan_audit(plan: &Plan, cluster: Option<&Cluster>) -> VerifyReport {
    let mut report = VerifyReport::default();

    let finite_time = |name: &str, t: f64, report: &mut VerifyReport| {
        if !t.is_finite() || t < 0.0 {
            report.violations.push(VerifyError::PlanStructure {
                what: format!("{name} is {t}, expected a finite non-negative time"),
            });
        }
    };
    finite_time("minibatch_time", plan.minibatch_time, &mut report);
    finite_time("epoch_time", plan.epoch_time, &mut report);

    audit_device_order(&plan.device_order, cluster, &mut report);

    match &plan.choice {
        Choice::Pipeline { kind, m, micro, recompute: _, partition } => {
            audit_bounds("partition", &partition.bounds, &mut report);
            let n = partition.n_stages();
            if *m == 0 {
                report
                    .violations
                    .push(VerifyError::PlanStructure { what: "pipeline has M=0".into() });
            }
            if !(micro.is_finite() && *micro > 0.0) {
                report.violations.push(VerifyError::PlanStructure {
                    what: format!("micro-batch size {micro} is not positive"),
                });
            }
            if plan.device_order.len() != n {
                report.violations.push(VerifyError::PlanStructure {
                    what: format!(
                        "device order covers {} devices but the partition has {n} stages",
                        plan.device_order.len()
                    ),
                });
            }
            if !plan.stage_memory.is_empty() && plan.stage_memory.len() != n {
                report.violations.push(VerifyError::PlanStructure {
                    what: format!(
                        "stage_memory has {} entries for {n} stages",
                        plan.stage_memory.len()
                    ),
                });
            }
            if *m >= 1 && n >= 1 {
                report.merge(super::check_program(*kind, n, *m));
            }
            if let Some(cl) = cluster {
                let mm = MemoryModel::default();
                for (i, &bytes) in plan.stage_memory.iter().enumerate() {
                    let dev = plan.device_order.get(i).and_then(|&d| cl.devices.get(d));
                    if let Some(dev) = dev {
                        let usable = mm.usable(dev.mem_capacity);
                        if bytes > usable {
                            report.violations.push(VerifyError::MemoryBound {
                                stage: i,
                                peak: bytes,
                                usable,
                            });
                        }
                    }
                }
            }
            // The winning evaluation's simulated peaks must stay under the
            // worst-case stage memory the plan reports.
            if let Some(best) = plan.report.best_evaluation() {
                let matches_choice = best.candidate.kind == *kind && best.candidate.m == *m;
                if let Outcome::Evaluated { peak_memory, .. } = &best.outcome {
                    if matches_choice && peak_memory.len() == plan.stage_memory.len() {
                        for (i, (&rec, &bound)) in
                            peak_memory.iter().zip(&plan.stage_memory).enumerate()
                        {
                            if rec > bound {
                                report.violations.push(VerifyError::PeakMismatch {
                                    stage: i,
                                    recorded: rec,
                                    certified: bound,
                                });
                            }
                        }
                    }
                }
            }
        }
        Choice::DataParallel => {
            if plan.stage_memory.len() > 1 {
                report.violations.push(VerifyError::PlanStructure {
                    what: format!(
                        "data-parallel plan records {} stage memories, expected at most 1",
                        plan.stage_memory.len()
                    ),
                });
            }
        }
    }

    audit_pareto(&plan.pareto_front, &plan.choice, &mut report);
    audit_report_bookkeeping(plan, &mut report);

    report.sort();
    report
}

/// The device order must be a permutation of `0..len`, and match the
/// cluster size when a cluster is given.
fn audit_device_order(order: &[usize], cluster: Option<&Cluster>, report: &mut VerifyReport) {
    let mut sorted: Vec<usize> = order.to_vec();
    sorted.sort_unstable();
    if sorted.iter().enumerate().any(|(i, &d)| i != d) {
        report.violations.push(VerifyError::PlanStructure {
            what: format!("device order {order:?} is not a permutation of 0..{}", order.len()),
        });
    }
    if let Some(cl) = cluster {
        if order.len() != cl.len() {
            report.violations.push(VerifyError::PlanStructure {
                what: format!(
                    "device order covers {} devices but the cluster has {}",
                    order.len(),
                    cl.len()
                ),
            });
        }
    }
}

/// Partition bounds must start at 0 and strictly increase — every layer
/// assigned to exactly one stage.
fn audit_bounds(what: &str, bounds: &[usize], report: &mut VerifyReport) {
    let ok = bounds.len() >= 2
        && bounds[0] == 0
        && bounds.windows(2).all(|w| w[0] < w[1]);
    if !ok {
        report.violations.push(VerifyError::PlanStructure {
            what: format!("{what} bounds {bounds:?} do not cover the layers exactly once"),
        });
    }
}

/// The stored Pareto front must be sorted fastest-first with strictly
/// decreasing peak memory — which for a front stored in that order is
/// exactly pairwise non-domination — and every member must cover the same
/// layer total as the chosen partition.
fn audit_pareto(front: &[ParetoPoint], choice: &Choice, report: &mut VerifyReport) {
    for (k, p) in front.iter().enumerate() {
        audit_bounds(&format!("pareto[{k}]"), &p.partition.bounds, report);
        if p.candidate.m == 0 {
            report
                .violations
                .push(VerifyError::PlanStructure { what: format!("pareto[{k}] has M=0") });
        }
        if let Choice::Pipeline { partition, .. } = choice {
            if p.partition.bounds.last() != partition.bounds.last() {
                report.violations.push(VerifyError::PlanStructure {
                    what: format!(
                        "pareto[{k}] covers {:?} layers, plan covers {:?}",
                        p.partition.bounds.last(),
                        partition.bounds.last()
                    ),
                });
            }
        }
    }
    for (k, w) in front.windows(2).enumerate() {
        let (a, b) = (&w[0], &w[1]);
        let sorted = a.epoch_time < b.epoch_time && a.peak_memory > b.peak_memory;
        if !sorted {
            report.violations.push(VerifyError::PlanStructure {
                what: format!(
                    "pareto front not non-dominated/sorted at index {}: ({:.6}s, {} B) then \
                     ({:.6}s, {} B)",
                    k + 1,
                    a.epoch_time,
                    a.peak_memory,
                    b.epoch_time,
                    b.peak_memory
                ),
            });
        }
    }
}

/// The exploration record must add up: outcome counts match the recorded
/// totals, per-evaluation structures are self-consistent, provenance
/// references resolve, and no simulated epoch undercuts its own
/// analytical lower bound.
fn audit_report_bookkeeping(plan: &Plan, report: &mut VerifyReport) {
    let r = &plan.report;
    let evaluated =
        r.evaluations.iter().filter(|e| matches!(e.outcome, Outcome::Evaluated { .. })).count();
    let pruned =
        r.evaluations.iter().filter(|e| matches!(e.outcome, Outcome::Pruned { .. })).count();
    if evaluated != r.simulated_count {
        report.violations.push(VerifyError::PlanStructure {
            what: format!(
                "simulated_count {} but {evaluated} evaluated outcomes",
                r.simulated_count
            ),
        });
    }
    if pruned != r.pruned_count {
        report.violations.push(VerifyError::PlanStructure {
            what: format!("pruned_count {} but {pruned} pruned outcomes", r.pruned_count),
        });
    }
    for (k, ev) in r.evaluations.iter().enumerate() {
        if !r.order_provenance.is_empty() && ev.candidate.perm >= r.order_provenance.len() {
            report.violations.push(VerifyError::PlanStructure {
                what: format!(
                    "evaluation {k} references device order {} but only {} provenance \
                     entries exist",
                    ev.candidate.perm,
                    r.order_provenance.len()
                ),
            });
        }
        if let Outcome::Evaluated { epoch_time, lower_bound, partition, peak_memory, .. } =
            &ev.outcome
        {
            if !peak_memory.is_empty() && peak_memory.len() != partition.n_stages() {
                report.violations.push(VerifyError::PlanStructure {
                    what: format!(
                        "evaluation {k} records {} peaks for {} stages",
                        peak_memory.len(),
                        partition.n_stages()
                    ),
                });
            }
            // A simulated epoch below its own analytical lower bound means
            // the pruning invariant is broken somewhere — suspicious but
            // not plan-falsifying, so it is a warning.
            if *epoch_time < lower_bound * (1.0 - 1e-6) {
                report.warnings.push(format!(
                    "evaluation {k}: simulated epoch {epoch_time:.6}s undercuts its \
                     analytical lower bound {lower_bound:.6}s"
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::Partition;
    use crate::planner::{Candidate, Evaluation, ExplorationReport};
    use crate::schedule::ScheduleKind;

    fn tiny_plan() -> Plan {
        let kind = ScheduleKind::OneFOneBSno;
        let partition = Partition::new(vec![0, 2, 5], 5);
        let candidate = Candidate { kind, m: 4, micro: 2.0, perm: 0, recompute: false };
        let outcome = Outcome::Evaluated {
            minibatch_time: 1.0,
            epoch_time: 10.0,
            lower_bound: 8.0,
            partition: partition.clone(),
            peak_memory: vec![100, 90],
        };
        let report = ExplorationReport {
            model: "tiny".into(),
            cluster: "2x test".into(),
            batch_per_device: 8.0,
            samples_per_epoch: 100,
            jobs: 1,
            ineligible: vec![],
            notes: vec![],
            order_provenance: vec![],
            evaluations: vec![Evaluation { candidate, outcome }],
            simulated_count: 1,
            pruned_count: 0,
            cache_hits: 0,
            dp_considered: true,
            dp_fits: true,
            dp_minibatch_time: 2.0,
            dp_epoch_time: 20.0,
        };
        Plan {
            choice: Choice::Pipeline { kind, m: 4, micro: 2.0, recompute: false, partition },
            device_order: vec![0, 1],
            minibatch_time: 1.0,
            epoch_time: 10.0,
            dp_epoch_time: 20.0,
            speedup_over_dp: 2.0,
            stage_memory: vec![120, 100],
            pareto_front: vec![],
            report,
        }
    }

    #[test]
    fn tiny_plan_audits_clean() {
        let r = plan_audit(&tiny_plan(), None);
        assert!(r.is_clean(), "{}", r.render("tiny"));
    }

    #[test]
    fn broken_device_order_is_rejected() {
        let mut plan = tiny_plan();
        plan.device_order = vec![1, 1];
        let r = plan_audit(&plan, None);
        assert_eq!(r.exit_code(), 2);
        assert!(r.violations.iter().any(
            |v| matches!(v, VerifyError::PlanStructure { what } if what.contains("permutation"))
        ));
    }

    #[test]
    fn count_drift_is_rejected() {
        let mut plan = tiny_plan();
        plan.report.simulated_count = 7;
        let r = plan_audit(&plan, None);
        assert!(r.violations.iter().any(
            |v| matches!(v, VerifyError::PlanStructure { what } if what.contains("simulated_count"))
        ));
    }

    #[test]
    fn recorded_peak_above_stage_memory_is_rejected() {
        let mut plan = tiny_plan();
        plan.stage_memory = vec![95, 100];
        let r = plan_audit(&plan, None);
        assert!(r.violations.iter().any(|v| matches!(
            v,
            VerifyError::PeakMismatch { stage: 0, recorded: 100, certified: 95 }
        )));
    }

    #[test]
    fn unsorted_pareto_front_is_rejected() {
        let mut plan = tiny_plan();
        let partition = Partition::new(vec![0, 2, 5], 5);
        let mk = |epoch: f64, peak: u64| ParetoPoint {
            candidate: Candidate {
                kind: ScheduleKind::OneFOneBSno,
                m: 4,
                micro: 2.0,
                perm: 0,
                recompute: false,
            },
            minibatch_time: 1.0,
            epoch_time: epoch,
            peak_memory: peak,
            partition: partition.clone(),
        };
        plan.pareto_front = vec![mk(10.0, 100), mk(12.0, 80)];
        assert!(plan_audit(&plan, None).is_clean());
        // A dominated second member: slower *and* bigger.
        plan.pareto_front = vec![mk(10.0, 100), mk(12.0, 120)];
        let r = plan_audit(&plan, None);
        assert!(r.violations.iter().any(
            |v| matches!(v, VerifyError::PlanStructure { what } if what.contains("pareto"))
        ));
    }

    #[test]
    fn undercut_lower_bound_is_a_warning_not_a_violation() {
        let mut plan = tiny_plan();
        if let Outcome::Evaluated { lower_bound, .. } =
            &mut plan.report.evaluations[0].outcome
        {
            *lower_bound = 11.0; // epoch_time stays 10.0
        }
        let r = plan_audit(&plan, None);
        assert_eq!(r.exit_code(), 1, "{}", r.render("tiny"));
        assert!(r.warnings[0].contains("lower bound"));
    }
}
