//! Property-testing mini-framework (the offline crate cache has no
//! `proptest`). Seeded case generation with failure reporting: each
//! property runs `cases` random inputs drawn from a caller-supplied
//! generator; on failure the framework retries with progressively
//! "smaller" regenerated inputs (size-bounded regeneration — a pragmatic
//! stand-in for structural shrinking) and reports the smallest failing
//! seed so the case is exactly reproducible.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; each case `i` uses `seed + i`.
    pub seed: u64,
    /// Maximum "size" hint passed to generators (they scale dimensions by it).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, seed: 0xBAB1_9E5E, max_size: 64 }
    }
}

/// Generation context handed to generators: RNG + size hint.
pub struct Gen<'a> {
    /// The seeded RNG for this case.
    pub rng: &'a mut Rng,
    /// Size hint in `[1, max_size]`, grows with the case index.
    pub size: usize,
}

impl<'a> Gen<'a> {
    /// usize in `[lo, hi)` clamped to the size hint's spirit.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    /// A vec of length in `[min_len, min_len+size]` filled by `f`.
    pub fn vec_of<T>(&mut self, min_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = min_len + self.rng.below((self.size + 1) as u64) as usize;
        let size = self.size;
        (0..len)
            .map(|_| {
                let mut g = Gen { rng: self.rng, size };
                f(&mut g)
            })
            .collect()
    }
}

/// Run a property: generate inputs, check, regenerate-smaller on failure.
///
/// Panics (test failure) with the offending seed, size and debug repr.
pub fn check<T: std::fmt::Debug>(
    cfg: &Config,
    mut gen: impl FnMut(&mut Gen) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        // Ramp size 1..=max_size across cases so small inputs come first.
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let mut rng = Rng::new(seed);
        let mut g = Gen { rng: &mut rng, size };
        let input = gen(&mut g);
        if let Err(msg) = prop(&input) {
            // Regenerate with shrinking sizes from the same seed family to
            // find a smaller counterexample.
            let mut best: (usize, T, String) = (size, input, msg);
            for shrink_size in (1..size).rev() {
                let mut rng = Rng::new(seed);
                let mut g = Gen { rng: &mut rng, size: shrink_size };
                let cand = gen(&mut g);
                if let Err(m) = prop(&cand) {
                    best = (shrink_size, cand, m);
                }
            }
            panic!(
                "property failed (seed={seed}, size={}):\n  input: {:?}\n  reason: {}",
                best.0, best.1, best.2
            );
        }
    }
}

/// Assert-style helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            &Config { cases: 50, ..Default::default() },
            |g| g.usize_in(0, 100),
            |&x| {
                count += 1;
                ensure(x < 100, "in range")
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            &Config { cases: 200, ..Default::default() },
            |g| g.usize_in(0, 1000),
            |&x| ensure(x < 500, format!("{x} >= 500")),
        );
    }

    #[test]
    fn vec_generator_respects_min_len() {
        check(
            &Config { cases: 64, ..Default::default() },
            |g| g.vec_of(2, |g| g.f64_in(0.0, 1.0)),
            |v| ensure(v.len() >= 2, "min len"),
        );
    }
}
