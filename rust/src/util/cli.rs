//! Tiny CLI argument parser (the offline crate cache has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Unknown flags are collected so callers can reject or ignore them.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus key/value options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options (last occurrence wins).
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (no argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut a = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.options.insert(body.to_string(), v);
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    /// Parse the process command line (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Is a bare flag present? (`--foo`)
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// String option with default.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// usize option with default; panics with a clear message on bad input.
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        match self.options.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got `{v}`")),
        }
    }

    /// u64 option with default.
    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        match self.options.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got `{v}`")),
        }
    }

    /// f64 option with default.
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        match self.options.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects a number, got `{v}`")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("train --steps 100 --lr=0.01 config.json --verbose");
        assert_eq!(a.positional, vec!["train", "config.json"]);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!((a.get_f64("lr", 0.0) - 0.01).abs() < 1e-12);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn flag_before_flag() {
        let a = parse("--fast --out dir");
        assert!(a.has_flag("fast"));
        assert_eq!(a.get_str("out", ""), "dir");
    }

    #[test]
    fn last_wins() {
        let a = parse("--n 1 --n 2");
        assert_eq!(a.get_usize("n", 0), 2);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_usize("x", 7), 7);
        assert_eq!(a.get_str("s", "d"), "d");
        assert!(!a.has_flag("nope"));
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_int_panics() {
        parse("--n abc").get_usize("n", 0);
    }
}
