//! Seeded PRNGs (SplitMix64 and xoshiro256**) built in-repo — the offline
//! crate set has no `rand`. Deterministic across platforms; used for
//! synthetic data, property-test generation and parameter shuffling.

/// SplitMix64 — tiny, solid stream for seeding and simple uses.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the general-purpose generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (any seed, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire rejection for unbiased range reduction.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range empty");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "Rng::weighted: all-zero weights");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
