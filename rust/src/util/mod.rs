//! Substrate utilities built in-repo because the offline crate set has no
//! serde / serde_json / rand / clap / proptest: a JSON parser and writer
//! ([`json`]), seeded PRNGs ([`rng`]), descriptive statistics ([`stats`]),
//! a tiny CLI argument parser ([`cli`]), a property-testing mini-framework
//! ([`prop`]) and plain-text logging helpers ([`logging`]).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;

/// The f64 global mini-batch `B × N`, snapped to the nearest integer when
/// the product lands within float noise of one (7.999999999999999 × 4 =
/// 31.999999999999996 means 32): every consumer — the planner's
/// divisibility filter, micro-batch sizes, mini-batches-per-epoch ceil,
/// the DP baseline's epoch conversion — must see the *same* value, or a
/// noisy batch read from a config inflates epoch counts by one whole
/// mini-batch. Genuinely fractional globals pass through unchanged.
pub fn canonical_global_batch(batch_per_device: f64, n_devices: usize) -> f64 {
    let g = batch_per_device * n_devices as f64;
    let r = g.round();
    if r > 0.0 && (g - r).abs() < 1e-9 * r {
        r
    } else {
        g
    }
}

/// Format a byte count with binary units (`1.50 GiB`).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", b, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration given in seconds with an auto-selected unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3} s", s)
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format a parameter count the way the paper does (`445.6M`, `1.35B`).
pub fn fmt_params(p: u64) -> String {
    if p >= 1_000_000_000 {
        format!("{:.2}B", p as f64 / 1e9)
    } else if p >= 1_000_000 {
        format!("{:.1}M", p as f64 / 1e6)
    } else if p >= 1_000 {
        format!("{:.1}K", p as f64 / 1e3)
    } else {
        p.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_global_snaps_float_noise_only() {
        // the PR's motivating input: a hair below 32 snaps to 32
        let g = canonical_global_batch(7.999999999999999, 4);
        assert_eq!(g, 32.0);
        // exact integers are untouched
        assert_eq!(canonical_global_batch(32.0, 4), 128.0);
        // genuinely fractional globals pass through
        assert_eq!(canonical_global_batch(0.3, 4), 0.3 * 4.0);
        assert_eq!(canonical_global_batch(0.5, 1), 0.5);
    }

    #[test]
    fn bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(fmt_bytes(16 * 1024 * 1024 * 1024), "16.00 GiB");
    }

    #[test]
    fn secs_units() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 us");
        assert_eq!(fmt_secs(3.0e-8), "30.0 ns");
    }

    #[test]
    fn params_units() {
        assert_eq!(fmt_params(445_600_000), "445.6M");
        assert_eq!(fmt_params(1_350_000_000), "1.35B");
        assert_eq!(fmt_params(950), "950");
    }
}
