//! Minimal JSON value model, recursive-descent parser and writer.
//!
//! Built in-repo because the offline crate cache has no `serde_json`.
//! Supports the full JSON grammar (objects, arrays, strings with escapes
//! and `\uXXXX`, numbers, booleans, null). Object key order is preserved
//! (insertion order) so emitted manifests and configs diff cleanly.
//!
//! The parser is hardened for untrusted artifact input: nesting deeper
//! than [`MAX_DEPTH`] levels and duplicate object keys are both typed
//! [`JsonError`]s rather than a stack overflow / silent last-writer-wins
//! — `bapipe check` audits plan files that may have been hand-edited.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64; integers round-trip up to 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object. Keys sorted (BTreeMap) for deterministic output.
    Obj(BTreeMap<String, Json>),
}

/// Maximum container nesting depth the parser accepts. The recursive
/// descent uses the call stack, so unbounded depth would let a small
/// hostile document (`[[[[…`) overflow it; 128 is far beyond any plan
/// or config artifact this crate emits.
pub const MAX_DEPTH: usize = 128;

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field access; `None` if not an object or key missing.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As i64 if numeric and integral.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => Some(*n as i64),
            _ => None,
        }
    }

    /// As usize if a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    /// As &str if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool if boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// As object map if an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers that produce good error messages.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError { msg: format!("missing field `{key}`"), pos: 0 })
    }

    /// Required string field.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| JsonError { msg: format!("field `{key}` is not a string"), pos: 0 })
    }

    /// Required usize field.
    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| JsonError { msg: format!("field `{key}` is not a usize"), pos: 0 })
    }

    /// Required f64 field.
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| JsonError { msg: format!("field `{key}` is not a number"), pos: 0 })
    }

    /// Required array field.
    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| JsonError { msg: format!("field `{key}` is not an array"), pos: 0 })
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, None, 0);
        s
    }

    /// Serialize pretty-printed with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, Some(2), 0);
        s
    }
}

/// Convenience constructors.
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from `(key, value)` pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Parse or serialization error with byte position.
#[derive(Debug, Clone)]
pub struct JsonError {
    /// Human-readable message.
    pub msg: String,
    /// Byte offset in the input (0 for semantic errors).
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.nested(Self::object),
            Some(b'[') => self.nested(Self::array),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    /// Run one container parse a level deeper, rejecting documents past
    /// [`MAX_DEPTH`] before recursing (the error is typed; without this
    /// a deep-enough document overflows the call stack instead).
    fn nested(
        &mut self,
        f: fn(&mut Self) -> Result<Json, JsonError>,
    ) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        self.depth += 1;
        let v = f(self)?;
        self.depth -= 1;
        Ok(v)
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let kpos = self.i;
            let k = self.string()?;
            // Last-writer-wins would let a hand-edited artifact silently
            // shadow a field the auditor then never sees — reject instead.
            if m.contains_key(&k) {
                return Err(JsonError {
                    msg: format!("duplicate object key `{k}`"),
                    pos: kpos,
                });
            }
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("invalid \\u escape"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(_) => {
                    // Copy one UTF-8 scalar.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hx = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hx, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn write_json(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9.0e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{}", n));
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (depth + 1)));
                }
                write_json(e, out, indent, depth + 1);
            }
            if indent.is_some() && !a.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent.unwrap() * depth));
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, e)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (depth + 1)));
                }
                write_escaped(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_json(e, out, indent, depth + 1);
            }
            if indent.is_some() && !m.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent.unwrap() * depth));
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_escapes() {
        assert_eq!(
            Json::parse(r#""a\n\t\"\\A""#).unwrap(),
            Json::Str("a\n\t\"\\A".into())
        );
        // surrogate pair: U+1F600
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("\u{1F600}".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":{"d":true}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"bapipe","n":8,"f":0.5,"tags":["a","b"],"nested":{"x":[1,2,3],"y":null}}"#;
        let j = Json::parse(src).unwrap();
        let back = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, back);
        let pretty = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, pretty);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 3, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(j.req_usize("n").unwrap(), 3);
        assert_eq!(j.req_str("s").unwrap(), "x");
        assert_eq!(j.req_f64("f").unwrap(), 1.5);
        assert!(j.req("missing").is_err());
        assert!(j.req_usize("s").is_err());
        assert_eq!(Json::Num(1.5).as_i64(), None);
    }

    #[test]
    fn integers_roundtrip_exact() {
        let j = Json::parse("9007199254740992").unwrap(); // 2^53 — too big for exact i64 path guard
        assert!(j.as_f64().is_some());
        let j = Json::parse("123456789012").unwrap();
        assert_eq!(j.as_i64(), Some(123456789012));
        assert_eq!(j.to_string_compact(), "123456789012");
    }

    #[test]
    fn builder_obj() {
        let j = obj(vec![("a", 1usize.into()), ("b", "x".into())]);
        assert_eq!(j.to_string_compact(), r#"{"a":1,"b":"x"}"#);
    }

    #[test]
    fn depth_limit_is_a_typed_error_not_a_stack_overflow() {
        // 100 levels (within MAX_DEPTH) parse fine…
        let deep_ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&deep_ok).is_ok());
        // …but past the limit the parser refuses with a typed error
        // instead of recursing until the stack dies.
        let deep_bad = format!("{}1{}", "[".repeat(400), "]".repeat(400));
        let err = Json::parse(&deep_bad).unwrap_err();
        assert!(err.msg.contains("nesting deeper than"), "{err}");
        // Mixed object/array nesting counts every container level.
        let mixed = format!("{}1{}", r#"{"a":["#.repeat(200), "]}".repeat(200));
        assert!(Json::parse(&mixed).unwrap_err().msg.contains("nesting"));
    }

    #[test]
    fn duplicate_keys_are_rejected() {
        let err = Json::parse(r#"{"a":1,"a":2}"#).unwrap_err();
        assert!(err.msg.contains("duplicate object key `a`"), "{err}");
        assert_eq!(err.pos, 7); // byte offset of the second `"a"`
        // Nested objects get the same treatment.
        assert!(Json::parse(r#"{"x":{"k":1,"k":2}}"#).is_err());
        // Same key in *different* objects is of course fine.
        assert!(Json::parse(r#"{"x":{"k":1},"y":{"k":2}}"#).is_ok());
    }
}
