//! Plain-text leveled logging to stderr with a global verbosity switch.
//! Kept deliberately simple (no `log`/`tracing` facade needed for a CLI
//! tool): `info!`-style macros would hide the module; explicit calls keep
//! the hot path free of formatting unless the level is enabled.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity levels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Errors only.
    Error = 0,
    /// + warnings.
    Warn = 1,
    /// + high-level progress (default).
    Info = 2,
    /// + per-step details.
    Debug = 3,
    /// + per-op details (schedule traces, channel hops).
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global verbosity.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Current verbosity.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Is `l` enabled under the current verbosity?
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Log a message at a level (no-op if disabled).
pub fn log(l: Level, msg: &str) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {msg}");
    }
}

/// Info-level convenience.
pub fn info(msg: &str) {
    log(Level::Info, msg);
}

/// Debug-level convenience.
pub fn debug(msg: &str) {
    log(Level::Debug, msg);
}

/// Warn-level convenience.
pub fn warn(msg: &str) {
    log(Level::Warn, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }
}
