//! Minimal benchmark harness (the offline crate set has no criterion):
//! warm-up + N timed iterations, median/p90 reporting in criterion-like
//! one-line format. Used by every `rust/benches/*.rs` target
//! (`harness = false`).

use super::stats::Summary;
use std::time::Instant;

/// Time `f` with `warmup` + `iters` runs; print and return the summary.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let s = Summary::of(&samples);
    println!(
        "bench {name:<40} median {:>12} p90 {:>12} (n={})",
        super::fmt_secs(s.p50),
        super::fmt_secs(s.p90),
        s.n
    );
    s
}

/// Print a markdown-ish table: header + rows of equal arity.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let ncols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        assert_eq!(r.len(), ncols, "row arity");
        for (w, cell) in widths.iter_mut().zip(r) {
            *w = (*w).max(cell.len());
        }
    }
    let fmt_row = |cells: Vec<String>| {
        cells
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join(" | ")
    };
    println!("{}", fmt_row(header.iter().map(|s| s.to_string()).collect()));
    println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("-|-"));
    for r in rows {
        println!("{}", fmt_row(r.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_summary() {
        let s = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.n, 5);
        assert!(s.p50 >= 0.0);
    }

    #[test]
    fn table_renders() {
        print_table("t", &["a", "bb"], &[vec!["1".into(), "2".into()]]);
    }
}
