//! Descriptive statistics and a simple streaming histogram — used by the
//! bench harness and the metrics module (offline cache has no criterion).

/// Summary statistics over a sample of f64s.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 for n<2).
    pub std: f64,
    /// Median (linear-interpolated).
    pub p50: f64,
    /// 90th percentile.
    pub p90: f64,
    /// 99th percentile.
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; panics on empty input.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let std = if n > 1 {
            (v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64).sqrt()
        } else {
            0.0
        };
        Summary {
            n,
            min: v[0],
            max: v[n - 1],
            mean,
            std,
            p50: percentile(&v, 0.50),
            p90: percentile(&v, 0.90),
            p99: percentile(&v, 0.99),
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice. `q` in [0,1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (for speedup aggregation across workloads).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Fixed-bucket histogram over `[lo, hi)` with counts, plus under/overflow.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    under: u64,
    over: u64,
    count: u64,
    sum: f64,
}

impl Histogram {
    /// New histogram with `nbuckets` equal-width buckets over `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Self { lo, hi, buckets: vec![0; nbuckets], under: 0, over: 0, count: 0, sum: 0.0 }
    }

    /// Record an observation.
    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let n = self.buckets.len();
            let w = (self.hi - self.lo) / n as f64;
            let idx = (((x - self.lo) / w) as usize).min(n - 1);
            self.buckets[idx] += 1;
        }
    }

    /// Total observation count.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of all recorded values.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bucket midpoints.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64) as u64;
        let mut acc = self.under;
        let w = (self.hi - self.lo) / self.buckets.len() as f64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.lo + (i as f64 + 0.5) * w;
            }
        }
        self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
        assert_eq!(percentile(&[7.0], 0.3), 7.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.record(i as f64 + 0.5);
        }
        h.record(-1.0);
        h.record(100.0);
        assert_eq!(h.count(), 12);
        let q = h.quantile(0.5);
        assert!(q > 2.0 && q < 8.0, "q={q}");
    }
}
