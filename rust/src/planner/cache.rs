//! Memoization of balanced-partition work across candidates.
//!
//! Two levels, matching what actually varies:
//!
//! 1. **Balance seed** (passes 1–3 of Fig. 3: inter-layer DP, coarse
//!    restriction, intra-layer refinement) depends only on `micro` — it
//!    is computed once per micro-batch size and shared across *every*
//!    schedule kind. This is the expensive part (the `O(N·C²)` DP).
//! 2. **Finished partition** (pass 4: memory fine-tune) depends on the
//!    schedule only through its Tables 1–2 memory rows, so kinds in the
//!    same [`ScheduleKind::memory_class`] share the finished plan too.
//!
//! Failures are cached like successes: an infeasible seed is infeasible
//! for every kind at that `micro`.
//!
//! [`ScheduleKind::memory_class`]: crate::schedule::ScheduleKind::memory_class

use super::space::Candidate;
use crate::cluster::Cluster;
use crate::model::Network;
use crate::partition::{balance_stages, finish_partition, BalanceSeed, PartitionPlan};
use crate::profile::Profile;
use std::collections::HashMap;

/// Key of a balance seed: permutation × micro-batch size. `micro` enters
/// as raw bits — the grid produces exact binary fractions, so bit
/// equality is value equality here.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct SeedKey {
    perm: usize,
    micro_bits: u64,
}

/// Key of a finished partition: seed key × memory class × M.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct PlanKey {
    seed: SeedKey,
    memory_class: u8,
    m: usize,
}

/// Memoizing store for balanced partitions (and their failures).
#[derive(Debug, Default)]
pub struct EvalCache {
    seeds: HashMap<SeedKey, Result<BalanceSeed, String>>,
    plans: HashMap<PlanKey, Result<PartitionPlan, String>>,
    /// Requests answered from either cache level.
    pub hits: usize,
    /// Requests that ran partition passes (seed or fine-tune).
    pub misses: usize,
}

impl EvalCache {
    /// Empty cache.
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// The balanced partition for `cand`: balance seed computed once per
    /// `(perm, micro)`, memory fine-tune once per `(memory class, m)` on
    /// top of it. `cluster`/`profile` must be the views matching
    /// `cand.perm`.
    pub fn partition(
        &mut self,
        net: &Network,
        cluster: &Cluster,
        profile: &Profile,
        cand: &Candidate,
    ) -> Result<PartitionPlan, String> {
        let seed_key = SeedKey { perm: cand.perm, micro_bits: cand.micro.to_bits() };
        let plan_key =
            PlanKey { seed: seed_key, memory_class: cand.kind.memory_class(), m: cand.m };
        if let Some(found) = self.plans.get(&plan_key) {
            self.hits += 1;
            return found.clone();
        }
        let seed = match self.seeds.get(&seed_key) {
            Some(cached) => {
                self.hits += 1;
                cached.clone()
            }
            None => {
                self.misses += 1;
                let computed = balance_stages(net, cluster, profile, cand.micro)
                    .map_err(|e| e.to_string());
                self.seeds.insert(seed_key, computed.clone());
                computed
            }
        };
        let finished = match seed {
            Ok(seed) => {
                self.misses += 1;
                finish_partition(cluster, profile, &seed, cand.kind, cand.micro, cand.m)
                    .map_err(|e| e.to_string())
            }
            Err(e) => Err(e),
        };
        self.plans.insert(plan_key, finished.clone());
        finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::partition::balanced_partition;
    use crate::profile::analytical;
    use crate::schedule::ScheduleKind;

    fn cand(kind: ScheduleKind, m: usize, micro: f64) -> Candidate {
        Candidate { kind, m, micro, perm: 0 }
    }

    #[test]
    fn seed_shared_across_kinds_plan_shared_across_classes() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let mut cache = EvalCache::new();
        // First request: seed miss + fine-tune miss.
        let a = cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBSno, 16, 8.0))
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (0, 2));
        // Other kind, same micro: seed HIT, fine-tune miss (new class).
        let b = cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBSo, 16, 8.0))
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 3));
        // Same memory class as the first request: full plan HIT.
        let c = cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBAs, 16, 8.0))
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (2, 3));
        assert_eq!(a.partition, c.partition);
        // Different micro: everything fresh.
        cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBSno, 32, 4.0))
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (2, 5));
        // Memory is ample here, so both classes agree on the partition.
        assert_eq!(a.partition, b.partition);
    }

    #[test]
    fn cached_partition_matches_direct_call() {
        let net = zoo::resnet50(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let mut cache = EvalCache::new();
        let via_cache = cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBSo, 16, 8.0))
            .unwrap();
        let direct =
            balanced_partition(&net, &cl, &prof, ScheduleKind::OneFOneBSo, 8.0, 16).unwrap();
        assert_eq!(via_cache.partition, direct.partition);
        assert_eq!(via_cache.max_stage_time, direct.max_stage_time);
        assert_eq!(via_cache.notes, direct.notes);
    }

    #[test]
    fn failures_are_cached_too() {
        // A model too large for one 16 GB V100 fails the memory fine-tune.
        let net = zoo::gnmt_l(158);
        let cl = presets::v100_cluster(1);
        let prof = analytical::profile(&net, &cl);
        let mut cache = EvalCache::new();
        let c = cand(ScheduleKind::OneFOneBSno, 2, 16.0);
        assert!(cache.partition(&net, &cl, &prof, &c).is_err());
        let (h1, m1) = (cache.hits, cache.misses);
        assert!(cache.partition(&net, &cl, &prof, &c).is_err());
        assert_eq!(cache.hits, h1 + 1, "second failure must be a cache hit");
        assert_eq!(cache.misses, m1);
    }
}
