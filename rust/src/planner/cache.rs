//! Memoization of balanced-partition work across candidates.
//!
//! Two levels, matching what actually varies:
//!
//! 1. **Balance seed** (passes 1–3 of Fig. 3: inter-layer DP, coarse
//!    restriction, intra-layer refinement) depends only on `micro` — it
//!    is computed once per micro-batch size and shared across *every*
//!    schedule kind. This is the expensive part (the `O(N·C²)` DP).
//! 2. **Finished partition** (pass 4: memory fine-tune) depends on the
//!    schedule only through its Tables 1–2 memory rows, so kinds in the
//!    same [`ScheduleKind::memory_class`] share the finished plan too.
//!
//! Failures are cached like successes: an infeasible seed is infeasible
//! for every kind at that `micro`.
//!
//! Every partition pass runs on [`RangeCost`] prefix tables — built once
//! per profile view and shared across the whole micro grid (the tables
//! are micro-independent) — so the sequential path, the parallel prewarm
//! and a cache restored from disk all produce bit-identical plans.
//!
//! The `perm` component of both keys indexes the search space's device
//! orderings. Since the neighbourhood search landed ([`super::orders`]),
//! that list is a *discovered set* past 8 devices — not a fixed
//! enumeration — so a persisted cache stores the order list alongside the
//! fingerprint and [`EvalCache::from_json`] rejects any document whose
//! discovered set differs (the `perm` indices would otherwise point at
//! different layouts).
//!
//! The cache also serializes: [`EvalCache::to_json`] /
//! [`EvalCache::from_json`] persist both levels keyed by a scenario
//! fingerprint, which is how `bapipe explore --plan-cache` skips phase A
//! entirely on repeated invocations (see [`super::store`]).
//!
//! [`ScheduleKind::memory_class`]: crate::schedule::ScheduleKind::memory_class

use super::parallel;
use super::report;
use super::space::Candidate;
use crate::cluster::Cluster;
use crate::model::Network;
use crate::partition::intralayer::FracPartition;
use crate::partition::{balance_stages_rc, finish_partition, BalanceSeed, PartitionPlan};
use crate::profile::range::RangeCost;
use crate::profile::Profile;
use crate::schedule::ScheduleKind;
use crate::util::json::{obj, Json};
use std::collections::{HashMap, HashSet};

/// Key of a balance seed: permutation × micro-batch size. `micro` enters
/// as raw bits — the grid produces exact binary fractions, so bit
/// equality is value equality here.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct SeedKey {
    perm: usize,
    micro_bits: u64,
}

/// Key of a finished partition: seed key × memory class × M × recompute
/// (recompute changes the stashed bytes the fine-tune prices, so
/// variants must not share a finished plan).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct PlanKey {
    seed: SeedKey,
    memory_class: u8,
    m: usize,
    recompute: bool,
}

/// Memoizing store for balanced partitions (and their failures).
#[derive(Debug, Default)]
pub struct EvalCache {
    seeds: HashMap<SeedKey, Result<BalanceSeed, String>>,
    plans: HashMap<PlanKey, Result<PartitionPlan, String>>,
    /// Requests answered from either cache level.
    pub hits: usize,
    /// Requests that ran partition passes (seed or fine-tune).
    pub misses: usize,
}

impl EvalCache {
    /// Empty cache.
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// The balanced partition for `cand`: balance seed computed once per
    /// `(perm, micro)`, memory fine-tune once per `(memory class, m)` on
    /// top of it. `cluster`/`profile` must be the views matching
    /// `cand.perm`.
    pub fn partition(
        &mut self,
        net: &Network,
        cluster: &Cluster,
        profile: &Profile,
        cand: &Candidate,
    ) -> Result<PartitionPlan, String> {
        let seed_key = SeedKey { perm: cand.perm, micro_bits: cand.micro.to_bits() };
        let plan_key = PlanKey {
            seed: seed_key,
            memory_class: cand.kind.memory_class(),
            m: cand.m,
            recompute: cand.recompute,
        };
        if let Some(found) = self.plans.get(&plan_key) {
            self.hits += 1;
            return found.clone();
        }
        // One prefix-table build serves both passes; using the tables on
        // the miss path keeps the sequential flow bit-identical to the
        // parallel prewarm (which shares one table set per view).
        let rc = RangeCost::build(profile);
        let seed = match self.seeds.get(&seed_key) {
            Some(cached) => {
                self.hits += 1;
                cached.clone()
            }
            None => {
                self.misses += 1;
                let computed = balance_stages_rc(net, cluster, &rc, cand.micro)
                    .map_err(|e| e.to_string());
                self.seeds.insert(seed_key, computed.clone());
                computed
            }
        };
        let finished = match seed {
            Ok(seed) => {
                self.misses += 1;
                finish_partition(cluster, &rc, &seed, cand.kind, cand.recompute, cand.micro, cand.m)
                    .map_err(|e| e.to_string())
            }
            Err(e) => Err(e),
        };
        self.plans.insert(plan_key, finished.clone());
        finished
    }

    /// Fan the partition work of `candidates` out over `jobs` workers,
    /// filling both cache levels ahead of the per-candidate pass: first
    /// the balance-seed DPs (one per distinct `(perm, micro)` — phase A's
    /// dominant cost, the `O(N·C²)` inter-layer DP), then the memory
    /// fine-tunes (one per distinct `(seed, memory class, M)`; the
    /// fine-tune consults the schedule kind only through its memory
    /// class, so the first candidate's kind stands in for the class).
    ///
    /// Deterministic by construction: work lists are in first-appearance
    /// order of `candidates`, each entry is an independent pure
    /// computation, and results are inserted after the parallel batch in
    /// list order — cache contents, `hits` and `misses` are identical for
    /// every `jobs` value. Candidates whose `m` does not divide
    /// `global_batch` are skipped, exactly like the per-candidate pass
    /// rejects them before consulting the cache. `views[p]` must be the
    /// permuted `(cluster, profile)` view for permutation index `p`.
    pub fn prewarm(
        &mut self,
        net: &Network,
        views: &[(Cluster, Profile)],
        candidates: &[Candidate],
        global_batch: f64,
        jobs: usize,
    ) {
        let divisible = |c: &&Candidate| super::eval::divides_global(global_batch, c.m);

        // Seed work list: distinct (perm, micro), first-appearance order.
        let mut seed_keys: Vec<SeedKey> = Vec::new();
        let mut seen_seeds: HashSet<SeedKey> = self.seeds.keys().copied().collect();
        for c in candidates.iter().filter(divisible) {
            let key = SeedKey { perm: c.perm, micro_bits: c.micro.to_bits() };
            if seen_seeds.insert(key) {
                seed_keys.push(key);
            }
        }

        // Fine-tune work list: distinct plan keys, first-appearance order
        // (depends only on the keys, so it is known before the seeds run).
        let mut plan_work: Vec<(PlanKey, ScheduleKind)> = Vec::new();
        let mut seen_plans: HashSet<PlanKey> = self.plans.keys().copied().collect();
        for c in candidates.iter().filter(divisible) {
            let seed = SeedKey { perm: c.perm, micro_bits: c.micro.to_bits() };
            let key = PlanKey {
                seed,
                memory_class: c.kind.memory_class(),
                m: c.m,
                recompute: c.recompute,
            };
            if seen_plans.insert(key) {
                plan_work.push((key, c.kind));
            }
        }

        // One prefix-table set per permuted view *with work*, shared by
        // every balance-seed DP and memory fine-tune on that view across
        // the whole micro grid (the tables are micro-independent: batch
        // scaling enters as a multiplier on the slope prefixes). A fully
        // warm cache — the `--plan-cache` reuse path — builds none.
        let mut used = vec![false; views.len()];
        for key in &seed_keys {
            used[key.perm] = true;
        }
        for (key, _) in &plan_work {
            used[key.seed.perm] = true;
        }
        let rcs: Vec<Option<RangeCost>> = views
            .iter()
            .zip(&used)
            .map(|((_, prof), &u)| if u { Some(RangeCost::build(prof)) } else { None })
            .collect();
        let rc_of =
            |perm: usize| rcs[perm].as_ref().expect("tables built for every perm with work");

        let seeds = parallel::run_indexed(jobs, seed_keys.len(), |k| {
            let key = &seed_keys[k];
            let (cl, _) = &views[key.perm];
            balance_stages_rc(net, cl, rc_of(key.perm), f64::from_bits(key.micro_bits))
                .map_err(|e| e.to_string())
        });
        for (key, res) in seed_keys.iter().zip(seeds) {
            self.misses += 1;
            self.seeds.insert(*key, res);
        }

        let seeds_done = &self.seeds;
        let plans = parallel::run_indexed(jobs, plan_work.len(), |k| {
            let (key, kind) = &plan_work[k];
            let (cl, _) = &views[key.seed.perm];
            match seeds_done.get(&key.seed).expect("seed prewarmed above") {
                Ok(seed) => finish_partition(
                    cl,
                    rc_of(key.seed.perm),
                    seed,
                    *kind,
                    key.recompute,
                    f64::from_bits(key.seed.micro_bits),
                    key.m,
                )
                .map_err(|e| e.to_string()),
                Err(e) => Err(e.clone()),
            }
        });
        for ((key, _), res) in plan_work.iter().zip(plans) {
            // an Err seed runs no fine-tune pass — not a miss, like the
            // sequential path
            if matches!(self.seeds.get(&key.seed), Some(Ok(_))) {
                self.misses += 1;
            }
            self.plans.insert(*key, res);
        }
    }

    /// Serialize both cache levels for cross-invocation reuse (`bapipe
    /// explore --plan-cache`). Entries are emitted in sorted key order so
    /// the document is deterministic; `fingerprint` ties the cache to one
    /// `(model, cluster)` scenario and `device_orders` pins the meaning
    /// of the `perm` indices.
    pub fn to_json(&self, fingerprint: &str, device_orders: &[Vec<usize>]) -> Json {
        self.to_json_with_views(fingerprint, device_orders, &[])
    }

    /// [`EvalCache::to_json`] with per-view fingerprints embedded
    /// (`view_fingerprints[p]` = [`super::store::view_fingerprint`] of
    /// device order `p`). The key is emitted only when non-empty, so
    /// documents saved without views stay byte-identical to the v1
    /// format. Embedded views are what lets [`EvalCache::salvage_json`]
    /// reuse individual permutations of an otherwise-stale cache.
    pub fn to_json_with_views(
        &self,
        fingerprint: &str,
        device_orders: &[Vec<usize>],
        view_fingerprints: &[String],
    ) -> Json {
        let mut seeds: Vec<(&SeedKey, &Result<BalanceSeed, String>)> = self.seeds.iter().collect();
        seeds.sort_by_key(|(k, _)| (k.perm, k.micro_bits));
        let mut plans: Vec<(&PlanKey, &Result<PartitionPlan, String>)> =
            self.plans.iter().collect();
        plans.sort_by_key(|(k, _)| (k.seed.perm, k.seed.micro_bits, k.memory_class, k.m, k.recompute));
        let mut pairs = vec![
            ("format", Json::from(PLAN_CACHE_FORMAT)),
            ("fingerprint", Json::from(fingerprint)),
            (
                "device_orders",
                Json::Arr(
                    device_orders
                        .iter()
                        .map(|o| Json::Arr(o.iter().map(|&d| Json::from(d)).collect()))
                        .collect(),
                ),
            ),
        ];
        if !view_fingerprints.is_empty() {
            pairs.push((
                "view_fingerprints",
                Json::Arr(view_fingerprints.iter().map(|f| Json::from(f.clone())).collect()),
            ));
        }
        pairs.push((
            "seeds",
            Json::Arr(seeds.into_iter().map(|(k, r)| seed_entry_to_json(k, r)).collect()),
        ));
        pairs.push((
            "plans",
            Json::Arr(plans.into_iter().map(|(k, r)| plan_entry_to_json(k, r)).collect()),
        ));
        obj(pairs)
    }

    /// Inverse of [`EvalCache::to_json`]. Rejects a document whose
    /// format, fingerprint or device-order space does not match the
    /// current scenario — a stale cache must never poison a different
    /// exploration (hit/miss statistics restart at zero).
    pub fn from_json(
        j: &Json,
        fingerprint: &str,
        device_orders: &[Vec<usize>],
    ) -> crate::Result<EvalCache> {
        let format = report::req_str(j, "format")?;
        anyhow::ensure!(format == PLAN_CACHE_FORMAT, "unknown plan-cache format `{format}`");
        let fp = report::req_str(j, "fingerprint")?;
        anyhow::ensure!(
            fp == fingerprint,
            "fingerprint mismatch (cache {fp}, scenario {fingerprint})"
        );
        let orders = j
            .req_arr("device_orders")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .iter()
            .map(|o| {
                o.as_arr()
                    .ok_or_else(|| anyhow::anyhow!("bad device order"))?
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad device index")))
                    .collect::<crate::Result<Vec<usize>>>()
            })
            .collect::<crate::Result<Vec<Vec<usize>>>>()?;
        anyhow::ensure!(
            orders == device_orders,
            "device-order space changed; cached permutation indices would not line up"
        );
        let mut cache = EvalCache::new();
        for e in j.req_arr("seeds").map_err(|e| anyhow::anyhow!("{e}"))? {
            let (key, res) = seed_entry_from_json(e)?;
            cache.seeds.insert(key, res);
        }
        for e in j.req_arr("plans").map_err(|e| anyhow::anyhow!("{e}"))? {
            let (key, res) = plan_entry_from_json(e)?;
            cache.plans.insert(key, res);
        }
        Ok(cache)
    }

    /// Re-key this cache's entries from one view namespace into another:
    /// `cached_views[p]` / `current_views[q]` are per-view fingerprints
    /// ([`super::store::view_fingerprint`]), and every entry whose old
    /// `perm` has a fingerprint-identical current view is kept under the
    /// current index. Entries whose view no longer exists are dropped;
    /// when two cached views match the same current view the
    /// lowest-old-perm entries win (deterministic). This is how the
    /// elastic replanner carries partition work across a cluster mutation
    /// instead of rejecting the whole cache, and how
    /// [`EvalCache::salvage_json`] partially restores a stale document.
    /// Hit/miss statistics restart at zero.
    pub fn salvage(
        &self,
        cached_views: &[String],
        current_views: &[String],
    ) -> (EvalCache, SalvageStats) {
        use std::collections::hash_map::Entry;
        let map: Vec<Option<usize>> = cached_views
            .iter()
            .map(|fp| current_views.iter().position(|c| c == fp))
            .collect();
        let mut out = EvalCache::new();
        let mut stats = SalvageStats {
            views_matched: current_views
                .iter()
                .filter(|c| cached_views.contains(c))
                .count(),
            views_total: current_views.len(),
            seeds_reused: 0,
            plans_reused: 0,
            entries_dropped: 0,
        };
        // deterministic insertion order: sorted old keys, first wins
        let mut seeds: Vec<(&SeedKey, &Result<BalanceSeed, String>)> = self.seeds.iter().collect();
        seeds.sort_by_key(|(k, _)| (k.perm, k.micro_bits));
        for (k, v) in seeds {
            match map.get(k.perm).copied().flatten() {
                Some(np) => match out.seeds.entry(SeedKey { perm: np, ..*k }) {
                    Entry::Vacant(e) => {
                        e.insert(v.clone());
                        stats.seeds_reused += 1;
                    }
                    Entry::Occupied(_) => stats.entries_dropped += 1,
                },
                None => stats.entries_dropped += 1,
            }
        }
        let mut plans: Vec<(&PlanKey, &Result<PartitionPlan, String>)> =
            self.plans.iter().collect();
        plans.sort_by_key(|(k, _)| (k.seed.perm, k.seed.micro_bits, k.memory_class, k.m, k.recompute));
        for (k, v) in plans {
            match map.get(k.seed.perm).copied().flatten() {
                Some(np) => {
                    let nk = PlanKey { seed: SeedKey { perm: np, ..k.seed }, ..*k };
                    match out.plans.entry(nk) {
                        Entry::Vacant(e) => {
                            e.insert(v.clone());
                            stats.plans_reused += 1;
                        }
                        Entry::Occupied(_) => stats.entries_dropped += 1,
                    }
                }
                None => stats.entries_dropped += 1,
            }
        }
        (out, stats)
    }

    /// Partial restore of a cache document that failed the all-or-nothing
    /// [`EvalCache::from_json`] match: entries are re-keyed per view via
    /// [`EvalCache::salvage`], using the `view_fingerprints` the document
    /// was saved with ([`EvalCache::to_json_with_views`]). Errors when the
    /// document has no embedded views (pre-view-fingerprint caches stay
    /// all-or-nothing) or is structurally unreadable.
    pub fn salvage_json(
        j: &Json,
        current_views: &[String],
    ) -> crate::Result<(EvalCache, SalvageStats)> {
        let format = report::req_str(j, "format")?;
        anyhow::ensure!(format == PLAN_CACHE_FORMAT, "unknown plan-cache format `{format}`");
        let cached_views = match j.get("view_fingerprints") {
            None => anyhow::bail!("cache document carries no per-view fingerprints"),
            Some(v) => v
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("`view_fingerprints` is not an array"))?
                .iter()
                .map(|f| {
                    f.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow::anyhow!("bad `view_fingerprints` entry"))
                })
                .collect::<crate::Result<Vec<String>>>()?,
        };
        let mut full = EvalCache::new();
        for e in j.req_arr("seeds").map_err(|e| anyhow::anyhow!("{e}"))? {
            let (key, res) = seed_entry_from_json(e)?;
            full.seeds.insert(key, res);
        }
        for e in j.req_arr("plans").map_err(|e| anyhow::anyhow!("{e}"))? {
            let (key, res) = plan_entry_from_json(e)?;
            full.plans.insert(key, res);
        }
        Ok(full.salvage(&cached_views, current_views))
    }
}

/// What a per-view cache salvage kept and dropped
/// ([`EvalCache::salvage`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SalvageStats {
    /// Current views that found a fingerprint-identical cached view.
    pub views_matched: usize,
    /// Total current views.
    pub views_total: usize,
    /// Balance-seed entries carried over.
    pub seeds_reused: usize,
    /// Finished-partition entries carried over.
    pub plans_reused: usize,
    /// Entries whose view vanished (or collided) and were dropped.
    pub entries_dropped: usize,
}

/// On-disk format tag of the persisted plan cache.
pub const PLAN_CACHE_FORMAT: &str = "bapipe-plan-cache-v1";

// ------------------------------------------- plan-cache (de)serialization

fn string_list(j: &Json, key: &str) -> crate::Result<Vec<String>> {
    j.req_arr(key)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .iter()
        .map(|v| v.as_str().map(str::to_string).ok_or_else(|| anyhow::anyhow!("bad `{key}` entry")))
        .collect()
}

fn usize_list(j: &Json, key: &str) -> crate::Result<Vec<usize>> {
    j.req_arr(key)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad `{key}` entry")))
        .collect()
}

fn frac_to_json(fp: &FracPartition) -> Json {
    obj(vec![
        ("x", Json::Arr(fp.x.iter().map(|&v| Json::Num(v)).collect())),
        ("imbalance_before", report::num_or_null(fp.imbalance_before)),
        ("imbalance_after", report::num_or_null(fp.imbalance_after)),
    ])
}

fn frac_from_json(j: &Json) -> crate::Result<FracPartition> {
    let x = j
        .req_arr("x")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("bad fractional boundary")))
        .collect::<crate::Result<Vec<f64>>>()?;
    Ok(FracPartition {
        x,
        imbalance_before: report::req_f64(j, "imbalance_before")?,
        imbalance_after: report::req_f64(j, "imbalance_after")?,
    })
}

/// The fields `BalanceSeed` and `PartitionPlan` share (partition,
/// optional frac, optional coarse threshold, notes) — one serializer core
/// so a future field can't be added to one side and silently dropped by
/// the other. Key order in the emitted object is irrelevant: `obj` sorts.
fn flow_core_to_json(
    partition: &crate::partition::Partition,
    frac: &Option<FracPartition>,
    coarse_threshold: Option<f64>,
    notes: &[String],
) -> Vec<(&'static str, Json)> {
    let mut pairs = vec![
        ("partition", report::partition_to_json(partition)),
        ("notes", Json::Arr(notes.iter().map(|n| Json::from(n.clone())).collect())),
    ];
    if let Some(fp) = frac {
        pairs.push(("frac", frac_to_json(fp)));
    }
    if let Some(th) = coarse_threshold {
        pairs.push(("coarse_threshold", Json::Num(th)));
    }
    pairs
}

type FlowCore = (crate::partition::Partition, Option<FracPartition>, Option<f64>, Vec<String>);

fn flow_core_from_json(j: &Json) -> crate::Result<FlowCore> {
    let partition =
        report::partition_from_json(j.req("partition").map_err(|e| anyhow::anyhow!("{e}"))?)?;
    let frac = match j.get("frac") {
        Some(f) => Some(frac_from_json(f)?),
        None => None,
    };
    let coarse_threshold = j.get("coarse_threshold").and_then(|v| v.as_f64());
    Ok((partition, frac, coarse_threshold, string_list(j, "notes")?))
}

fn seed_to_json(s: &BalanceSeed) -> Json {
    let mut pairs = flow_core_to_json(&s.partition, &s.frac, s.coarse_threshold, &s.notes);
    pairs.push((
        "active_cuts",
        Json::Arr(s.active_cuts.iter().map(|&c| Json::from(c)).collect()),
    ));
    obj(pairs)
}

fn seed_from_json(j: &Json) -> crate::Result<BalanceSeed> {
    let (partition, frac, coarse_threshold, notes) = flow_core_from_json(j)?;
    Ok(BalanceSeed {
        partition,
        frac,
        coarse_threshold,
        active_cuts: usize_list(j, "active_cuts")?,
        notes,
    })
}

fn plan_to_json(p: &PartitionPlan) -> Json {
    let mut pairs = flow_core_to_json(&p.partition, &p.frac, p.coarse_threshold, &p.notes);
    pairs.push(("max_stage_time", Json::Num(p.max_stage_time)));
    obj(pairs)
}

fn plan_from_json(j: &Json) -> crate::Result<PartitionPlan> {
    let (partition, frac, coarse_threshold, notes) = flow_core_from_json(j)?;
    Ok(PartitionPlan {
        partition,
        frac,
        coarse_threshold,
        max_stage_time: report::req_f64(j, "max_stage_time")?,
        notes,
    })
}

fn seed_entry_to_json(k: &SeedKey, r: &Result<BalanceSeed, String>) -> Json {
    let mut pairs = vec![
        ("perm", Json::from(k.perm)),
        ("micro", Json::Num(f64::from_bits(k.micro_bits))),
    ];
    match r {
        Ok(s) => pairs.push(("seed", seed_to_json(s))),
        Err(e) => pairs.push(("error", Json::from(e.clone()))),
    }
    obj(pairs)
}

fn seed_entry_from_json(j: &Json) -> crate::Result<(SeedKey, Result<BalanceSeed, String>)> {
    let key = SeedKey {
        perm: report::req_usize(j, "perm")?,
        micro_bits: report::req_f64(j, "micro")?.to_bits(),
    };
    let res = match j.get("seed") {
        Some(s) => Ok(seed_from_json(s)?),
        None => Err(report::req_str(j, "error")?),
    };
    Ok((key, res))
}

fn plan_entry_to_json(k: &PlanKey, r: &Result<PartitionPlan, String>) -> Json {
    let mut pairs = vec![
        ("perm", Json::from(k.seed.perm)),
        ("micro", Json::Num(f64::from_bits(k.seed.micro_bits))),
        ("memory_class", Json::from(k.memory_class as usize)),
        ("m", Json::from(k.m)),
    ];
    // emitted only when set: default-off entries stay byte-identical to
    // pre-recompute documents (and old documents parse leniently below)
    if k.recompute {
        pairs.push(("recompute", Json::Bool(true)));
    }
    match r {
        Ok(p) => pairs.push(("plan", plan_to_json(p))),
        Err(e) => pairs.push(("error", Json::from(e.clone()))),
    }
    obj(pairs)
}

fn plan_entry_from_json(j: &Json) -> crate::Result<(PlanKey, Result<PartitionPlan, String>)> {
    let memory_class = u8::try_from(report::req_usize(j, "memory_class")?)
        .map_err(|_| anyhow::anyhow!("memory_class out of range"))?;
    let key = PlanKey {
        seed: SeedKey {
            perm: report::req_usize(j, "perm")?,
            micro_bits: report::req_f64(j, "micro")?.to_bits(),
        },
        memory_class,
        m: report::req_usize(j, "m")?,
        // lenient: absent in pre-recompute cache documents
        recompute: j.get("recompute").and_then(|v| v.as_bool()).unwrap_or(false),
    };
    let res = match j.get("plan") {
        Some(p) => Ok(plan_from_json(p)?),
        None => Err(report::req_str(j, "error")?),
    };
    Ok((key, res))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::partition::balanced_partition;
    use crate::profile::analytical;
    use crate::schedule::ScheduleKind;

    fn cand(kind: ScheduleKind, m: usize, micro: f64) -> Candidate {
        Candidate { kind, m, micro, perm: 0, recompute: false }
    }

    #[test]
    fn seed_shared_across_kinds_plan_shared_across_classes() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let mut cache = EvalCache::new();
        // First request: seed miss + fine-tune miss.
        let a = cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBSno, 16, 8.0))
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (0, 2));
        // Other kind, same micro: seed HIT, fine-tune miss (new class).
        let b = cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBSo, 16, 8.0))
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 3));
        // Same memory class as the first request: full plan HIT.
        let c = cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBAs, 16, 8.0))
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (2, 3));
        assert_eq!(a.partition, c.partition);
        // Different micro: everything fresh.
        cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBSno, 32, 4.0))
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (2, 5));
        // Memory is ample here, so both classes agree on the partition.
        assert_eq!(a.partition, b.partition);
    }

    #[test]
    fn cached_partition_matches_direct_call() {
        let net = zoo::resnet50(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let mut cache = EvalCache::new();
        let via_cache = cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBSo, 16, 8.0))
            .unwrap();
        let direct =
            balanced_partition(&net, &cl, &prof, ScheduleKind::OneFOneBSo, 8.0, 16).unwrap();
        assert_eq!(via_cache.partition, direct.partition);
        assert_eq!(via_cache.max_stage_time, direct.max_stage_time);
        assert_eq!(via_cache.notes, direct.notes);
    }

    #[test]
    fn prewarm_fills_both_levels_deterministically() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let views = vec![crate::planner::space::permuted_view(&cl, &prof, &[0, 1, 2, 3])];
        let ms = [2usize, 4, 8, 3]; // 3 does not divide 128 → skipped
        let cands: Vec<Candidate> = ms
            .iter()
            .flat_map(|&m| {
                [ScheduleKind::OneFOneBSno, ScheduleKind::OneFOneBSo].map(|kind| Candidate {
                    kind,
                    m,
                    micro: 128.0 / m as f64,
                    perm: 0,
                    recompute: false,
                })
            })
            .collect();
        for jobs in [1usize, 4] {
            let mut warm = EvalCache::new();
            warm.prewarm(&net, &views, &cands, 128.0, jobs);
            // 3 distinct micros → 3 seed passes; × 2 memory classes → 6
            // fine-tune passes; no hits yet
            assert_eq!((warm.hits, warm.misses), (0, 9), "jobs={jobs}");
            let mut cold = EvalCache::new();
            for c in cands.iter().filter(|c| 128 % c.m == 0) {
                let a = warm.partition(&net, &cl, &prof, c).unwrap();
                let b = cold.partition(&net, &cl, &prof, c).unwrap();
                assert_eq!(a.partition, b.partition, "jobs={jobs} m={} {:?}", c.m, c.kind);
            }
            // every post-prewarm request is answered from the cache
            assert_eq!((warm.hits, warm.misses), (6, 9), "jobs={jobs}");
        }
    }

    #[test]
    fn plan_cache_round_trips_through_json() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let mut cache = EvalCache::new();
        let c1 = cand(ScheduleKind::OneFOneBSno, 16, 8.0);
        let c2 = cand(ScheduleKind::OneFOneBSo, 32, 4.0);
        let a1 = cache.partition(&net, &cl, &prof, &c1).unwrap();
        let a2 = cache.partition(&net, &cl, &prof, &c2).unwrap();

        let orders = vec![vec![0usize, 1, 2, 3]];
        let text = cache.to_json("fp123", &orders).to_string_pretty();
        let mut restored =
            EvalCache::from_json(&Json::parse(&text).unwrap(), "fp123", &orders).unwrap();
        // every request is answered from the restored cache: no partition
        // pass runs (this is what lets --plan-cache skip phase A)
        let b1 = restored.partition(&net, &cl, &prof, &c1).unwrap();
        let b2 = restored.partition(&net, &cl, &prof, &c2).unwrap();
        assert_eq!((restored.hits, restored.misses), (2, 0));
        assert_eq!(a1.partition, b1.partition);
        assert_eq!(a1.max_stage_time, b1.max_stage_time);
        assert_eq!(a1.notes, b1.notes);
        assert_eq!(a2.partition, b2.partition);
        // the document itself is stable (deterministic entry order)
        assert_eq!(restored.to_json("fp123", &orders).to_string_pretty(), text);

        // wrong fingerprint or a changed device-order space is rejected
        assert!(EvalCache::from_json(&Json::parse(&text).unwrap(), "other", &orders).is_err());
        let other_orders = vec![vec![0usize, 1, 2, 3], vec![1, 0, 2, 3]];
        assert!(
            EvalCache::from_json(&Json::parse(&text).unwrap(), "fp123", &other_orders).is_err()
        );
    }

    #[test]
    fn plan_cache_preserves_failures() {
        // A cached infeasibility must survive the round trip: the restored
        // cache answers it as a hit without re-running the fine-tune.
        let net = zoo::gnmt_l(158);
        let cl = presets::v100_cluster(1);
        let prof = analytical::profile(&net, &cl);
        let mut cache = EvalCache::new();
        let c = cand(ScheduleKind::OneFOneBSno, 2, 16.0);
        assert!(cache.partition(&net, &cl, &prof, &c).is_err());
        let orders = vec![vec![0usize]];
        let text = cache.to_json("fp", &orders).to_string_compact();
        let mut restored =
            EvalCache::from_json(&Json::parse(&text).unwrap(), "fp", &orders).unwrap();
        assert!(restored.partition(&net, &cl, &prof, &c).is_err());
        assert_eq!((restored.hits, restored.misses), (1, 0), "cached failure must be a hit");
    }

    #[test]
    fn salvage_rekeys_surviving_views_and_drops_the_rest() {
        let net = zoo::vgg16(224);
        let cl = presets::gpu_mixed_cluster(2);
        let prof = analytical::profile(&net, &cl);
        let orders = [vec![0usize, 1], vec![1, 0]];
        let fps: Vec<String> = orders
            .iter()
            .map(|o| crate::planner::store::view_fingerprint(&net, &cl, &prof, o))
            .collect();
        assert_ne!(fps[0], fps[1], "heterogeneous swap must change the view fingerprint");
        let mut cache = EvalCache::new();
        for (perm, order) in orders.iter().enumerate() {
            let (vcl, vprof) = crate::planner::space::permuted_view(&cl, &prof, order);
            cache
                .partition(
                    &net,
                    &vcl,
                    &vprof,
                    &Candidate {
                        kind: ScheduleKind::OneFOneBSno,
                        m: 16,
                        micro: 8.0,
                        perm,
                        recompute: false,
                    },
                )
                .unwrap();
        }
        // The next run discovers only the swapped order, now at index 0:
        // its entries must be re-keyed 1 → 0, the identity view's dropped.
        let current = vec![fps[1].clone()];
        let (mut salvaged, st) = cache.salvage(&fps, &current);
        assert_eq!(st.views_matched, 1);
        assert_eq!(st.views_total, 1);
        assert_eq!(st.seeds_reused, 1);
        assert_eq!(st.plans_reused, 1);
        assert_eq!(st.entries_dropped, 2);
        let (vcl, vprof) = crate::planner::space::permuted_view(&cl, &prof, &[1, 0]);
        let via = salvaged
            .partition(
                &net,
                &vcl,
                &vprof,
                &Candidate {
                    kind: ScheduleKind::OneFOneBSno,
                    m: 16,
                    micro: 8.0,
                    perm: 0,
                    recompute: false,
                },
            )
            .unwrap();
        assert_eq!((salvaged.hits, salvaged.misses), (1, 0), "salvaged entry must answer");
        // bit-identical to a cold computation on the same view
        let mut cold = EvalCache::new();
        let direct = cold
            .partition(
                &net,
                &vcl,
                &vprof,
                &Candidate {
                    kind: ScheduleKind::OneFOneBSno,
                    m: 16,
                    micro: 8.0,
                    perm: 0,
                    recompute: false,
                },
            )
            .unwrap();
        assert_eq!(via.partition, direct.partition);

        // the same salvage through a serialized document
        let doc = cache.to_json_with_views("fp", &orders, &fps);
        let (mut from_doc, st2) =
            EvalCache::salvage_json(&Json::parse(&doc.to_string_compact()).unwrap(), &current)
                .unwrap();
        assert_eq!(st2, st);
        assert!(from_doc
            .partition(
                &net,
                &vcl,
                &vprof,
                &Candidate {
                    kind: ScheduleKind::OneFOneBSno,
                    m: 16,
                    micro: 8.0,
                    perm: 0,
                    recompute: false,
                },
            )
            .is_ok());
        assert_eq!((from_doc.hits, from_doc.misses), (1, 0));
        // documents without embedded views stay all-or-nothing
        let plain = cache.to_json("fp", &orders);
        assert!(EvalCache::salvage_json(&plain, &current).is_err());
        // and embedding views never disturbs the plain document bytes
        assert_eq!(
            cache.to_json_with_views("fp", &orders, &[]).to_string_pretty(),
            plain.to_string_pretty()
        );
    }

    #[test]
    fn failures_are_cached_too() {
        // A model too large for one 16 GB V100 fails the memory fine-tune.
        let net = zoo::gnmt_l(158);
        let cl = presets::v100_cluster(1);
        let prof = analytical::profile(&net, &cl);
        let mut cache = EvalCache::new();
        let c = cand(ScheduleKind::OneFOneBSno, 2, 16.0);
        assert!(cache.partition(&net, &cl, &prof, &c).is_err());
        let (h1, m1) = (cache.hits, cache.misses);
        assert!(cache.partition(&net, &cl, &prof, &c).is_err());
        assert_eq!(cache.hits, h1 + 1, "second failure must be a cache hit");
        assert_eq!(cache.misses, m1);
    }
}
