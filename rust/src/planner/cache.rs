//! Memoization of balanced-partition work across candidates.
//!
//! Two levels, matching what actually varies:
//!
//! 1. **Balance seed** (passes 1–3 of Fig. 3: inter-layer DP, coarse
//!    restriction, intra-layer refinement) depends only on `micro` — it
//!    is computed once per micro-batch size and shared across *every*
//!    schedule kind. This is the expensive part (the `O(N·C²)` DP).
//! 2. **Finished partition** (pass 4: memory fine-tune) depends on the
//!    schedule only through its Tables 1–2 memory rows, so kinds in the
//!    same [`ScheduleKind::memory_class`] share the finished plan too.
//!
//! Failures are cached like successes: an infeasible seed is infeasible
//! for every kind at that `micro`.
//!
//! [`ScheduleKind::memory_class`]: crate::schedule::ScheduleKind::memory_class

use super::parallel;
use super::space::Candidate;
use crate::cluster::Cluster;
use crate::model::Network;
use crate::partition::{balance_stages, finish_partition, BalanceSeed, PartitionPlan};
use crate::profile::Profile;
use crate::schedule::ScheduleKind;
use std::collections::{HashMap, HashSet};

/// Key of a balance seed: permutation × micro-batch size. `micro` enters
/// as raw bits — the grid produces exact binary fractions, so bit
/// equality is value equality here.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct SeedKey {
    perm: usize,
    micro_bits: u64,
}

/// Key of a finished partition: seed key × memory class × M.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct PlanKey {
    seed: SeedKey,
    memory_class: u8,
    m: usize,
}

/// Memoizing store for balanced partitions (and their failures).
#[derive(Debug, Default)]
pub struct EvalCache {
    seeds: HashMap<SeedKey, Result<BalanceSeed, String>>,
    plans: HashMap<PlanKey, Result<PartitionPlan, String>>,
    /// Requests answered from either cache level.
    pub hits: usize,
    /// Requests that ran partition passes (seed or fine-tune).
    pub misses: usize,
}

impl EvalCache {
    /// Empty cache.
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// The balanced partition for `cand`: balance seed computed once per
    /// `(perm, micro)`, memory fine-tune once per `(memory class, m)` on
    /// top of it. `cluster`/`profile` must be the views matching
    /// `cand.perm`.
    pub fn partition(
        &mut self,
        net: &Network,
        cluster: &Cluster,
        profile: &Profile,
        cand: &Candidate,
    ) -> Result<PartitionPlan, String> {
        let seed_key = SeedKey { perm: cand.perm, micro_bits: cand.micro.to_bits() };
        let plan_key =
            PlanKey { seed: seed_key, memory_class: cand.kind.memory_class(), m: cand.m };
        if let Some(found) = self.plans.get(&plan_key) {
            self.hits += 1;
            return found.clone();
        }
        let seed = match self.seeds.get(&seed_key) {
            Some(cached) => {
                self.hits += 1;
                cached.clone()
            }
            None => {
                self.misses += 1;
                let computed = balance_stages(net, cluster, profile, cand.micro)
                    .map_err(|e| e.to_string());
                self.seeds.insert(seed_key, computed.clone());
                computed
            }
        };
        let finished = match seed {
            Ok(seed) => {
                self.misses += 1;
                finish_partition(cluster, profile, &seed, cand.kind, cand.micro, cand.m)
                    .map_err(|e| e.to_string())
            }
            Err(e) => Err(e),
        };
        self.plans.insert(plan_key, finished.clone());
        finished
    }

    /// Fan the partition work of `candidates` out over `jobs` workers,
    /// filling both cache levels ahead of the per-candidate pass: first
    /// the balance-seed DPs (one per distinct `(perm, micro)` — phase A's
    /// dominant cost, the `O(N·C²)` inter-layer DP), then the memory
    /// fine-tunes (one per distinct `(seed, memory class, M)`; the
    /// fine-tune consults the schedule kind only through its memory
    /// class, so the first candidate's kind stands in for the class).
    ///
    /// Deterministic by construction: work lists are in first-appearance
    /// order of `candidates`, each entry is an independent pure
    /// computation, and results are inserted after the parallel batch in
    /// list order — cache contents, `hits` and `misses` are identical for
    /// every `jobs` value. Candidates whose `m` does not divide
    /// `global_batch` are skipped, exactly like the per-candidate pass
    /// rejects them before consulting the cache. `views[p]` must be the
    /// permuted `(cluster, profile)` view for permutation index `p`.
    pub fn prewarm(
        &mut self,
        net: &Network,
        views: &[(Cluster, Profile)],
        candidates: &[Candidate],
        global_batch: f64,
        jobs: usize,
    ) {
        let divisible = |c: &&Candidate| super::eval::divides_global(global_batch, c.m);

        // Seed work list: distinct (perm, micro), first-appearance order.
        let mut seed_keys: Vec<SeedKey> = Vec::new();
        let mut seen_seeds: HashSet<SeedKey> = self.seeds.keys().copied().collect();
        for c in candidates.iter().filter(divisible) {
            let key = SeedKey { perm: c.perm, micro_bits: c.micro.to_bits() };
            if seen_seeds.insert(key) {
                seed_keys.push(key);
            }
        }
        let seeds = parallel::run_indexed(jobs, seed_keys.len(), |k| {
            let key = &seed_keys[k];
            let (cl, prof) = &views[key.perm];
            balance_stages(net, cl, prof, f64::from_bits(key.micro_bits))
                .map_err(|e| e.to_string())
        });
        for (key, res) in seed_keys.iter().zip(seeds) {
            self.misses += 1;
            self.seeds.insert(*key, res);
        }

        // Fine-tune work list: distinct plan keys, first-appearance order.
        let mut plan_work: Vec<(PlanKey, ScheduleKind)> = Vec::new();
        let mut seen_plans: HashSet<PlanKey> = self.plans.keys().copied().collect();
        for c in candidates.iter().filter(divisible) {
            let seed = SeedKey { perm: c.perm, micro_bits: c.micro.to_bits() };
            let key = PlanKey { seed, memory_class: c.kind.memory_class(), m: c.m };
            if seen_plans.insert(key) {
                plan_work.push((key, c.kind));
            }
        }
        let seeds_done = &self.seeds;
        let plans = parallel::run_indexed(jobs, plan_work.len(), |k| {
            let (key, kind) = &plan_work[k];
            let (cl, prof) = &views[key.seed.perm];
            match seeds_done.get(&key.seed).expect("seed prewarmed above") {
                Ok(seed) => finish_partition(
                    cl,
                    prof,
                    seed,
                    *kind,
                    f64::from_bits(key.seed.micro_bits),
                    key.m,
                )
                .map_err(|e| e.to_string()),
                Err(e) => Err(e.clone()),
            }
        });
        for ((key, _), res) in plan_work.iter().zip(plans) {
            // an Err seed runs no fine-tune pass — not a miss, like the
            // sequential path
            if matches!(self.seeds.get(&key.seed), Some(Ok(_))) {
                self.misses += 1;
            }
            self.plans.insert(*key, res);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::partition::balanced_partition;
    use crate::profile::analytical;
    use crate::schedule::ScheduleKind;

    fn cand(kind: ScheduleKind, m: usize, micro: f64) -> Candidate {
        Candidate { kind, m, micro, perm: 0 }
    }

    #[test]
    fn seed_shared_across_kinds_plan_shared_across_classes() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let mut cache = EvalCache::new();
        // First request: seed miss + fine-tune miss.
        let a = cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBSno, 16, 8.0))
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (0, 2));
        // Other kind, same micro: seed HIT, fine-tune miss (new class).
        let b = cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBSo, 16, 8.0))
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 3));
        // Same memory class as the first request: full plan HIT.
        let c = cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBAs, 16, 8.0))
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (2, 3));
        assert_eq!(a.partition, c.partition);
        // Different micro: everything fresh.
        cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBSno, 32, 4.0))
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (2, 5));
        // Memory is ample here, so both classes agree on the partition.
        assert_eq!(a.partition, b.partition);
    }

    #[test]
    fn cached_partition_matches_direct_call() {
        let net = zoo::resnet50(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let mut cache = EvalCache::new();
        let via_cache = cache
            .partition(&net, &cl, &prof, &cand(ScheduleKind::OneFOneBSo, 16, 8.0))
            .unwrap();
        let direct =
            balanced_partition(&net, &cl, &prof, ScheduleKind::OneFOneBSo, 8.0, 16).unwrap();
        assert_eq!(via_cache.partition, direct.partition);
        assert_eq!(via_cache.max_stage_time, direct.max_stage_time);
        assert_eq!(via_cache.notes, direct.notes);
    }

    #[test]
    fn prewarm_fills_both_levels_deterministically() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let views = vec![crate::planner::space::permuted_view(&cl, &prof, &[0, 1, 2, 3])];
        let ms = [2usize, 4, 8, 3]; // 3 does not divide 128 → skipped
        let cands: Vec<Candidate> = ms
            .iter()
            .flat_map(|&m| {
                [ScheduleKind::OneFOneBSno, ScheduleKind::OneFOneBSo].map(|kind| Candidate {
                    kind,
                    m,
                    micro: 128.0 / m as f64,
                    perm: 0,
                })
            })
            .collect();
        for jobs in [1usize, 4] {
            let mut warm = EvalCache::new();
            warm.prewarm(&net, &views, &cands, 128.0, jobs);
            // 3 distinct micros → 3 seed passes; × 2 memory classes → 6
            // fine-tune passes; no hits yet
            assert_eq!((warm.hits, warm.misses), (0, 9), "jobs={jobs}");
            let mut cold = EvalCache::new();
            for c in cands.iter().filter(|c| 128 % c.m == 0) {
                let a = warm.partition(&net, &cl, &prof, c).unwrap();
                let b = cold.partition(&net, &cl, &prof, c).unwrap();
                assert_eq!(a.partition, b.partition, "jobs={jobs} m={} {:?}", c.m, c.kind);
            }
            // every post-prewarm request is answered from the cache
            assert_eq!((warm.hits, warm.misses), (6, 9), "jobs={jobs}");
        }
    }

    #[test]
    fn failures_are_cached_too() {
        // A model too large for one 16 GB V100 fails the memory fine-tune.
        let net = zoo::gnmt_l(158);
        let cl = presets::v100_cluster(1);
        let prof = analytical::profile(&net, &cl);
        let mut cache = EvalCache::new();
        let c = cand(ScheduleKind::OneFOneBSno, 2, 16.0);
        assert!(cache.partition(&net, &cl, &prof, &c).is_err());
        let (h1, m1) = (cache.hits, cache.misses);
        assert!(cache.partition(&net, &cl, &prof, &c).is_err());
        assert_eq!(cache.hits, h1 + 1, "second failure must be a cache hit");
        assert_eq!(cache.misses, m1);
    }
}
