//! Comparison of two serialized `plan.json` artifacts — the typed model
//! behind `bapipe plan diff <a.json> <b.json>` — plus migration pricing
//! for the elastic replanner.
//!
//! The diff answers the three questions an operator has when a plan
//! artifact changes between runs (new profile, new cluster, new planner
//! version): did the *winner* change, by how much did the predicted
//! times move, and which stage boundaries shifted where. Plans need not
//! have the same device or stage counts — the post-device-loss replan
//! case — in which case boundaries are compared over the common prefix
//! (aligned by boundary index) and the device-count change plus the
//! added/removed device slots are reported explicitly.
//!
//! [`migration`] prices what a plan change physically costs: every layer
//! whose device assignment changes must move its persistent state
//! (weights + optimizer, [`crate::partition::memfit::movable_state_bytes`])
//! over the wire.

use super::report::{Choice, Plan};
use crate::partition::memfit::{movable_state_bytes, MemoryModel};
use crate::profile::range::CostModel;

/// One moved stage boundary between two partitions (same boundary index
/// on both sides).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BoundaryMove {
    /// Index into `Partition::bounds` (0 = start of stage 0).
    pub boundary: usize,
    /// Layer index the boundary sits at in plan A.
    pub from: usize,
    /// Layer index the boundary sits at in plan B.
    pub to: usize,
}

/// The structured difference between two plans (A → B).
#[derive(Debug, Clone)]
pub struct PlanDiff {
    /// Human-readable winner of plan A.
    pub choice_a: String,
    /// Human-readable winner of plan B.
    pub choice_b: String,
    /// Did both plans select the same parallelization (schedule, M,
    /// micro-batch size and partition, or DP on both sides)?
    pub same_choice: bool,
    /// `B − A` mini-batch time, seconds (negative = B is faster).
    pub minibatch_delta: f64,
    /// `B − A` epoch time, seconds (negative = B is faster).
    pub epoch_delta: f64,
    /// `B / A` epoch-time ratio.
    pub epoch_ratio: f64,
    /// Boundaries that moved, when both sides are pipelines. With equal
    /// stage counts every boundary is compared; with different counts
    /// (post-device-loss replans) the common prefix is, and
    /// `partition_note` records the count change.
    pub boundary_moves: Vec<BoundaryMove>,
    /// Why boundaries were not (fully) compared stage-by-stage: mode
    /// mismatch, or a stage-count change limiting the comparison to the
    /// common prefix.
    pub partition_note: Option<String>,
    /// Did the winning device ordering change?
    pub device_order_changed: bool,
    /// Device count in plan A (`device_order` length).
    pub devices_a: usize,
    /// Device count in plan B.
    pub devices_b: usize,
    /// Device slots present in B's order but not in A's (joins, by slot
    /// id as the plan numbers them).
    pub added_devices: Vec<usize>,
    /// Device slots present in A's order but not in B's (losses).
    pub removed_devices: Vec<usize>,
}

/// One-line human description of a plan's choice.
fn describe_choice(choice: &Choice) -> String {
    match choice {
        Choice::Pipeline { kind, m, micro, recompute, partition } => format!(
            "{}{} M={m} (micro-batch {micro}) partition {}",
            kind.label(),
            if *recompute { "+RC" } else { "" },
            partition.describe()
        ),
        Choice::DataParallel => "data-parallel".to_string(),
    }
}

/// Compare two plans (A → B). Never panics on mismatched device or stage
/// counts — the elastic replanner diffs across losses and joins.
pub fn compare(a: &Plan, b: &Plan) -> PlanDiff {
    let mut boundary_moves = Vec::new();
    let mut partition_note = None;
    match (&a.choice, &b.choice) {
        (Choice::Pipeline { partition: pa, .. }, Choice::Pipeline { partition: pb, .. }) => {
            let common = pa.bounds.len().min(pb.bounds.len());
            for i in 0..common {
                if pa.bounds[i] != pb.bounds[i] {
                    boundary_moves.push(BoundaryMove {
                        boundary: i,
                        from: pa.bounds[i],
                        to: pb.bounds[i],
                    });
                }
            }
            if pa.n_stages() != pb.n_stages() {
                partition_note = Some(format!(
                    "stage counts differ ({} vs {}); boundaries compared over the common prefix",
                    pa.n_stages(),
                    pb.n_stages()
                ));
            }
        }
        (Choice::DataParallel, Choice::DataParallel) => {}
        _ => {
            partition_note =
                Some("parallelization modes differ; boundaries not comparable".to_string())
        }
    }
    let added_devices: Vec<usize> =
        b.device_order.iter().filter(|d| !a.device_order.contains(d)).copied().collect();
    let removed_devices: Vec<usize> =
        a.device_order.iter().filter(|d| !b.device_order.contains(d)).copied().collect();
    PlanDiff {
        choice_a: describe_choice(&a.choice),
        choice_b: describe_choice(&b.choice),
        same_choice: a.choice == b.choice,
        minibatch_delta: b.minibatch_time - a.minibatch_time,
        epoch_delta: b.epoch_time - a.epoch_time,
        epoch_ratio: b.epoch_time / a.epoch_time,
        boundary_moves,
        partition_note,
        device_order_changed: a.device_order != b.device_order,
        devices_a: a.device_order.len(),
        devices_b: b.device_order.len(),
        added_devices,
        removed_devices,
    }
}

impl PlanDiff {
    /// Render the diff as the CLI's multi-line report.
    pub fn render(&self) -> String {
        let mut lines = vec![
            format!("plan A: {}", self.choice_a),
            format!("plan B: {}", self.choice_b),
            format!(
                "winner: {}",
                if self.same_choice { "identical" } else { "CHANGED" }
            ),
            // Plans that never evaluated a side (e.g. DP infeasible on
            // both) carry ±inf times; deltas and the ratio are then
            // NaN/inf and bare format specifiers would print noise —
            // stub the timing line out instead.
            if self.minibatch_delta.is_finite()
                && self.epoch_delta.is_finite()
                && self.epoch_ratio.is_finite()
            {
                format!(
                    "mini-batch: {:+.6}s  epoch: {:+.3}s  (B/A {:.4}x)",
                    self.minibatch_delta, self.epoch_delta, self.epoch_ratio
                )
            } else {
                "mini-batch: n/a  epoch: n/a  (B/A n/a)".to_string()
            },
        ];
        match (&self.partition_note, self.boundary_moves.is_empty()) {
            (Some(note), true) => lines.push(format!("boundaries: {note}")),
            (None, true) => lines.push("boundaries: unchanged".to_string()),
            (note, false) => {
                if let Some(note) = note {
                    lines.push(format!("boundaries: {note}"));
                }
                for mv in &self.boundary_moves {
                    lines.push(format!(
                        "boundary {}: layer {} -> {}",
                        mv.boundary, mv.from, mv.to
                    ));
                }
            }
        }
        if self.devices_a != self.devices_b {
            lines.push(format!("devices: {} -> {}", self.devices_a, self.devices_b));
        }
        if !self.removed_devices.is_empty() {
            lines.push(format!("removed devices: {:?}", self.removed_devices));
        }
        if !self.added_devices.is_empty() {
            lines.push(format!("added devices: {:?}", self.added_devices));
        }
        if self.device_order_changed {
            lines.push("device order: CHANGED".to_string());
        }
        lines.join("\n")
    }
}

/// What a plan change physically costs: layers whose device assignment
/// changed, priced as the bytes of persistent state (weights + optimizer)
/// that must cross the wire before training can resume.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MigrationReport {
    /// Total layers in the model.
    pub n_layers: usize,
    /// Layers whose physical device changed (including layers restored
    /// onto a new device after a loss).
    pub moved_layers: usize,
    /// Weights + optimizer-state bytes those layers carry.
    pub bytes: u64,
}

impl MigrationReport {
    /// One-line human summary.
    pub fn render(&self) -> String {
        format!(
            "migration: {}/{} layers move, {} of weights+optimizer state",
            self.moved_layers,
            self.n_layers,
            crate::util::fmt_bytes(self.bytes)
        )
    }
}

/// Price a migration between two per-layer *physical* device assignments
/// (`assign[layer] = Some(physical_device)`, `None` when the layer's
/// former host is gone — a loss; its state must be restored onto the new
/// host from elsewhere, which still costs the transfer). Both maps must
/// cover the same model; the caller is responsible for expressing device
/// identity in one shared namespace (the elastic replanner maps post-event
/// slots back through the mutation lineage).
pub fn migration<C: CostModel>(
    costs: &C,
    mm: &MemoryModel,
    assign_a: &[Option<usize>],
    assign_b: &[Option<usize>],
) -> MigrationReport {
    assert_eq!(
        assign_a.len(),
        assign_b.len(),
        "migration maps must cover the same layer count"
    );
    let mut moved_layers = 0usize;
    let mut bytes = 0u64;
    for l in 0..assign_a.len() {
        let moved = match (assign_a[l], assign_b[l]) {
            (Some(da), Some(db)) => da != db,
            // former host lost: state restored onto the new host
            (None, Some(_)) => true,
            // layer not placed in B (shouldn't happen for a full plan)
            (_, None) => false,
        };
        if moved {
            moved_layers += 1;
            bytes += movable_state_bytes(costs, mm, l, l + 1);
        }
    }
    MigrationReport { n_layers: assign_a.len(), moved_layers, bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::Partition;
    use crate::planner::report::ExplorationReport;
    use crate::schedule::ScheduleKind;

    fn report() -> ExplorationReport {
        ExplorationReport {
            model: "VGG-16".into(),
            cluster: "4x V100".into(),
            batch_per_device: 32.0,
            samples_per_epoch: 8192,
            jobs: 1,
            ineligible: Vec::new(),
            notes: Vec::new(),
            order_provenance: Vec::new(),
            evaluations: Vec::new(),
            simulated_count: 0,
            pruned_count: 0,
            cache_hits: 0,
            dp_considered: false,
            dp_fits: false,
            dp_minibatch_time: f64::INFINITY,
            dp_epoch_time: f64::INFINITY,
        }
    }

    fn pipeline_plan(m: usize, bounds: Vec<usize>, epoch: f64) -> Plan {
        let n_layers = *bounds.last().unwrap();
        Plan {
            choice: Choice::Pipeline {
                kind: ScheduleKind::OneFOneBSo,
                m,
                micro: 128.0 / m as f64,
                recompute: false,
                partition: Partition::new(bounds, n_layers),
            },
            device_order: vec![0, 1],
            minibatch_time: epoch / 64.0,
            epoch_time: epoch,
            dp_epoch_time: f64::INFINITY,
            speedup_over_dp: f64::INFINITY,
            stage_memory: vec![1 << 30; 2],
            pareto_front: Vec::new(),
            report: report(),
        }
    }

    #[test]
    fn identical_plans_diff_clean() {
        let a = pipeline_plan(16, vec![0, 5, 12], 64.0);
        let d = compare(&a, &a);
        assert!(d.same_choice);
        assert_eq!(d.epoch_delta, 0.0);
        assert_eq!(d.epoch_ratio, 1.0);
        assert!(d.boundary_moves.is_empty());
        assert!(d.partition_note.is_none());
        assert!(!d.device_order_changed);
        assert_eq!((d.devices_a, d.devices_b), (2, 2));
        assert!(d.added_devices.is_empty() && d.removed_devices.is_empty());
        assert!(d.render().contains("winner: identical"));
        assert!(d.render().contains("boundaries: unchanged"));
        assert!(!d.render().contains("devices:"));
    }

    #[test]
    fn boundary_moves_and_deltas_reported() {
        let a = pipeline_plan(16, vec![0, 5, 12], 64.0);
        let b = pipeline_plan(16, vec![0, 7, 12], 60.0);
        let d = compare(&a, &b);
        assert!(!d.same_choice, "partition changed");
        assert_eq!(
            d.boundary_moves,
            vec![BoundaryMove { boundary: 1, from: 5, to: 7 }]
        );
        assert_eq!(d.epoch_delta, -4.0);
        assert!((d.epoch_ratio - 60.0 / 64.0).abs() < 1e-12);
        let text = d.render();
        assert!(text.contains("winner: CHANGED"), "{text}");
        assert!(text.contains("boundary 1: layer 5 -> 7"), "{text}");
    }

    #[test]
    fn mode_mismatch_is_noted() {
        let a = pipeline_plan(16, vec![0, 5, 12], 64.0);
        let mut b = pipeline_plan(16, vec![0, 5, 12], 80.0);
        b.choice = Choice::DataParallel;
        let d = compare(&a, &b);
        assert!(!d.same_choice);
        assert!(d.partition_note.as_deref().unwrap().contains("modes differ"));
        assert!(d.render().contains("modes differ"));
    }

    #[test]
    fn stage_count_mismatch_compares_common_prefix() {
        // The post-device-loss case: 2 stages vs 3 stages. Boundaries are
        // compared over the common prefix (indices 0..=2) instead of
        // being dropped, and the count change is noted.
        let a = pipeline_plan(16, vec![0, 5, 12], 64.0);
        let b = pipeline_plan(16, vec![0, 4, 8, 12], 64.0);
        let d = compare(&a, &b);
        assert_eq!(
            d.boundary_moves,
            vec![
                BoundaryMove { boundary: 1, from: 5, to: 4 },
                BoundaryMove { boundary: 2, from: 12, to: 8 },
            ]
        );
        let note = d.partition_note.as_deref().unwrap();
        assert!(note.contains("stage counts differ (2 vs 3)"), "{note}");
        let text = d.render();
        assert!(text.contains("stage counts differ"), "{text}");
        assert!(text.contains("boundary 1: layer 5 -> 4"), "{text}");
    }

    #[test]
    fn added_and_removed_devices_rendered() {
        let a = pipeline_plan(16, vec![0, 5, 12], 64.0); // order [0, 1]
        let mut b = pipeline_plan(16, vec![0, 12], 70.0);
        b.device_order = vec![0, 2]; // slot 1 lost, slot 2 joined
        let d = compare(&a, &b);
        assert_eq!((d.devices_a, d.devices_b), (2, 2));
        assert_eq!(d.removed_devices, vec![1]);
        assert_eq!(d.added_devices, vec![2]);
        assert!(d.device_order_changed);
        let text = d.render();
        assert!(text.contains("removed devices: [1]"), "{text}");
        assert!(text.contains("added devices: [2]"), "{text}");

        let mut c = pipeline_plan(16, vec![0, 12], 70.0);
        c.device_order = vec![0];
        let d2 = compare(&a, &c);
        assert_eq!((d2.devices_a, d2.devices_b), (2, 1));
        assert_eq!(d2.removed_devices, vec![1]);
        assert!(d2.render().contains("devices: 2 -> 1"));
    }

    #[test]
    fn device_order_change_flagged() {
        let a = pipeline_plan(16, vec![0, 5, 12], 64.0);
        let mut b = a.clone();
        b.device_order = vec![1, 0];
        let d = compare(&a, &b);
        assert!(d.device_order_changed);
        assert!(d.render().contains("device order: CHANGED"));
    }

    #[test]
    fn single_stage_plans_render_a_stub_not_nothing() {
        // A one-stage pipeline has exactly one real boundary pair
        // [0, L] — the diff must still say *something* about boundaries
        // rather than emitting a zero-width section.
        let a = pipeline_plan(16, vec![0, 12], 64.0);
        let d = compare(&a, &a);
        assert!(d.same_choice);
        assert!(d.boundary_moves.is_empty());
        let text = d.render();
        assert!(text.contains("boundaries: unchanged"), "{text}");
        assert!(text.lines().count() >= 4, "{text}");
    }

    #[test]
    fn non_finite_epoch_ratio_renders_a_stub() {
        // Both sides DP with infinite epoch time (never evaluated):
        // the B/A ratio is NaN — render must not print `NaNx`.
        let mut a = pipeline_plan(16, vec![0, 12], 64.0);
        a.choice = Choice::DataParallel;
        a.epoch_time = f64::INFINITY;
        a.minibatch_time = f64::INFINITY;
        let d = compare(&a, &a);
        assert!(!d.epoch_ratio.is_finite());
        let text = d.render();
        assert!(text.contains("mini-batch: n/a  epoch: n/a  (B/A n/a)"), "{text}");
        assert!(!text.contains("NaN"), "{text}");
    }

    #[test]
    fn migration_prices_moved_layers_only() {
        use crate::cluster::presets;
        use crate::model::zoo;
        use crate::profile::analytical;
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(2);
        let prof = analytical::profile(&net, &cl);
        let mm = MemoryModel::default();
        let l = net.len();
        // identical assignment → nothing moves
        let same: Vec<Option<usize>> = (0..l).map(|i| Some(if i < l / 2 { 0 } else { 1 })).collect();
        let r = migration(&prof, &mm, &same, &same);
        assert_eq!(r.moved_layers, 0);
        assert_eq!(r.bytes, 0);
        // boundary shifts by one layer: exactly that layer's state moves
        let mut shifted = same.clone();
        shifted[l / 2] = Some(0);
        let r2 = migration(&prof, &mm, &same, &shifted);
        assert_eq!(r2.moved_layers, 1);
        assert_eq!(r2.bytes, movable_state_bytes(&prof, &mm, l / 2, l / 2 + 1));
        assert!(r2.render().contains("1/"), "{}", r2.render());
        // a lost host (None in A) still costs the restore transfer
        let mut lost = same.clone();
        lost[0] = None;
        let r3 = migration(&prof, &mm, &lost, &same);
        assert_eq!(r3.moved_layers, 1);
        assert_eq!(r3.bytes, movable_state_bytes(&prof, &mm, 0, 1));
    }

    #[test]
    fn restore_pricing_round_trips_through_a_loss_join_lineage() {
        // The join-after-loss case: lose device 1, then a fresh V100
        // joins. Mapping the old assignment through the inverted,
        // *composed* lineage strands the lost device's layers at `None`;
        // restoring them onto the joiner must be priced as exactly the
        // lost device's movable state — no more, no less.
        use crate::cluster::mutate::{self, ClusterEvent};
        use crate::cluster::presets;
        use crate::model::zoo;
        use crate::profile::analytical;
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(3);
        let prof = analytical::profile(&net, &cl);
        let mm = MemoryModel::default();
        let l = net.len();
        // three contiguous chunks across old devices 0/1/2
        let old: Vec<Option<usize>> = (0..l).map(|i| Some((i * 3 / l).min(2))).collect();
        let m1 =
            mutate::apply(&net, &cl, &prof, &ClusterEvent::DeviceLoss { device: 1 }).unwrap();
        let m2 = mutate::apply(
            &net,
            &m1.cluster,
            &m1.profile,
            &ClusterEvent::DeviceJoin {
                device_name: "V100".into(),
                position: m1.cluster.len(),
                link_bandwidth: None,
                link_latency: None,
            },
        )
        .unwrap();
        // compose the two lineages (final -> old), then invert
        // (old -> final) — the same mapping the elastic replanner uses to
        // express both assignments in one namespace
        let composed: Vec<Option<usize>> =
            m2.lineage.iter().map(|mid| mid.and_then(|m| m1.lineage[m])).collect();
        let mut inv: Vec<Option<usize>> = vec![None; cl.len()];
        for (new, o) in composed.iter().enumerate() {
            if let Some(o) = *o {
                inv[o] = Some(new);
            }
        }
        assert_eq!(inv[1], None, "the lost device has no descendant");
        let joiner = composed.iter().position(|o| o.is_none()).unwrap();
        let mapped: Vec<Option<usize>> = old.iter().map(|d| d.and_then(|d| inv[d])).collect();
        let restored: Vec<Option<usize>> =
            mapped.iter().map(|d| Some(d.unwrap_or(joiner))).collect();
        let r = migration(&prof, &mm, &mapped, &restored);
        let lost_layers: Vec<usize> = (0..l).filter(|&i| mapped[i].is_none()).collect();
        assert!(!lost_layers.is_empty(), "device 1 hosted layers");
        assert_eq!(r.moved_layers, lost_layers.len(), "survivors do not move");
        // round-trip: layer-by-layer pricing == the contiguous range
        let per_layer: u64 =
            lost_layers.iter().map(|&i| movable_state_bytes(&prof, &mm, i, i + 1)).sum();
        let lo = *lost_layers.first().unwrap();
        let hi = *lost_layers.last().unwrap() + 1;
        assert_eq!(hi - lo, lost_layers.len(), "lost chunk is contiguous");
        assert_eq!(r.bytes, per_layer);
        assert_eq!(r.bytes, movable_state_bytes(&prof, &mm, lo, hi));
        assert!(r.bytes > 0, "vgg layers carry weights");
    }
}
