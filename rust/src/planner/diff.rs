//! Comparison of two serialized `plan.json` artifacts — the typed model
//! behind `bapipe plan diff <a.json> <b.json>`.
//!
//! The diff answers the three questions an operator has when a plan
//! artifact changes between runs (new profile, new cluster, new planner
//! version): did the *winner* change, by how much did the predicted
//! times move, and which stage boundaries shifted where.

use super::report::{Choice, Plan};

/// One moved stage boundary between two same-depth partitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BoundaryMove {
    /// Index into `Partition::bounds` (0 = start of stage 0).
    pub boundary: usize,
    /// Layer index the boundary sits at in plan A.
    pub from: usize,
    /// Layer index the boundary sits at in plan B.
    pub to: usize,
}

/// The structured difference between two plans (A → B).
#[derive(Debug, Clone)]
pub struct PlanDiff {
    /// Human-readable winner of plan A.
    pub choice_a: String,
    /// Human-readable winner of plan B.
    pub choice_b: String,
    /// Did both plans select the same parallelization (schedule, M,
    /// micro-batch size and partition, or DP on both sides)?
    pub same_choice: bool,
    /// `B − A` mini-batch time, seconds (negative = B is faster).
    pub minibatch_delta: f64,
    /// `B − A` epoch time, seconds (negative = B is faster).
    pub epoch_delta: f64,
    /// `B / A` epoch-time ratio.
    pub epoch_ratio: f64,
    /// Boundaries that moved, when both sides are pipelines of the same
    /// stage count.
    pub boundary_moves: Vec<BoundaryMove>,
    /// Why boundaries were not compared stage-by-stage (mode or stage
    /// count mismatch), when they were not.
    pub partition_note: Option<String>,
    /// Did the winning device ordering change?
    pub device_order_changed: bool,
}

/// One-line human description of a plan's choice.
fn describe_choice(choice: &Choice) -> String {
    match choice {
        Choice::Pipeline { kind, m, micro, recompute, partition } => format!(
            "{}{} M={m} (micro-batch {micro}) partition {}",
            kind.label(),
            if *recompute { "+RC" } else { "" },
            partition.describe()
        ),
        Choice::DataParallel => "data-parallel".to_string(),
    }
}

/// Compare two plans (A → B).
pub fn compare(a: &Plan, b: &Plan) -> PlanDiff {
    let mut boundary_moves = Vec::new();
    let mut partition_note = None;
    match (&a.choice, &b.choice) {
        (Choice::Pipeline { partition: pa, .. }, Choice::Pipeline { partition: pb, .. }) => {
            if pa.n_stages() == pb.n_stages() {
                for (i, (&la, &lb)) in pa.bounds.iter().zip(&pb.bounds).enumerate() {
                    if la != lb {
                        boundary_moves.push(BoundaryMove { boundary: i, from: la, to: lb });
                    }
                }
            } else {
                partition_note = Some(format!(
                    "stage counts differ ({} vs {}); boundaries not comparable",
                    pa.n_stages(),
                    pb.n_stages()
                ));
            }
        }
        (Choice::DataParallel, Choice::DataParallel) => {}
        _ => {
            partition_note =
                Some("parallelization modes differ; boundaries not comparable".to_string())
        }
    }
    PlanDiff {
        choice_a: describe_choice(&a.choice),
        choice_b: describe_choice(&b.choice),
        same_choice: a.choice == b.choice,
        minibatch_delta: b.minibatch_time - a.minibatch_time,
        epoch_delta: b.epoch_time - a.epoch_time,
        epoch_ratio: b.epoch_time / a.epoch_time,
        boundary_moves,
        partition_note,
        device_order_changed: a.device_order != b.device_order,
    }
}

impl PlanDiff {
    /// Render the diff as the CLI's multi-line report.
    pub fn render(&self) -> String {
        let mut lines = vec![
            format!("plan A: {}", self.choice_a),
            format!("plan B: {}", self.choice_b),
            format!(
                "winner: {}",
                if self.same_choice { "identical" } else { "CHANGED" }
            ),
            format!(
                "mini-batch: {:+.6}s  epoch: {:+.3}s  (B/A {:.4}x)",
                self.minibatch_delta, self.epoch_delta, self.epoch_ratio
            ),
        ];
        match (&self.partition_note, self.boundary_moves.is_empty()) {
            (Some(note), _) => lines.push(format!("boundaries: {note}")),
            (None, true) => lines.push("boundaries: unchanged".to_string()),
            (None, false) => {
                for mv in &self.boundary_moves {
                    lines.push(format!(
                        "boundary {}: layer {} -> {}",
                        mv.boundary, mv.from, mv.to
                    ));
                }
            }
        }
        if self.device_order_changed {
            lines.push("device order: CHANGED".to_string());
        }
        lines.join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::Partition;
    use crate::planner::report::ExplorationReport;
    use crate::schedule::ScheduleKind;

    fn report() -> ExplorationReport {
        ExplorationReport {
            model: "VGG-16".into(),
            cluster: "4x V100".into(),
            batch_per_device: 32.0,
            samples_per_epoch: 8192,
            jobs: 1,
            ineligible: Vec::new(),
            notes: Vec::new(),
            order_provenance: Vec::new(),
            evaluations: Vec::new(),
            simulated_count: 0,
            pruned_count: 0,
            cache_hits: 0,
            dp_considered: false,
            dp_fits: false,
            dp_minibatch_time: f64::INFINITY,
            dp_epoch_time: f64::INFINITY,
        }
    }

    fn pipeline_plan(m: usize, bounds: Vec<usize>, epoch: f64) -> Plan {
        let n_layers = *bounds.last().unwrap();
        Plan {
            choice: Choice::Pipeline {
                kind: ScheduleKind::OneFOneBSo,
                m,
                micro: 128.0 / m as f64,
                recompute: false,
                partition: Partition::new(bounds, n_layers),
            },
            device_order: vec![0, 1],
            minibatch_time: epoch / 64.0,
            epoch_time: epoch,
            dp_epoch_time: f64::INFINITY,
            speedup_over_dp: f64::INFINITY,
            stage_memory: vec![1 << 30; 2],
            pareto_front: Vec::new(),
            report: report(),
        }
    }

    #[test]
    fn identical_plans_diff_clean() {
        let a = pipeline_plan(16, vec![0, 5, 12], 64.0);
        let d = compare(&a, &a);
        assert!(d.same_choice);
        assert_eq!(d.epoch_delta, 0.0);
        assert_eq!(d.epoch_ratio, 1.0);
        assert!(d.boundary_moves.is_empty());
        assert!(d.partition_note.is_none());
        assert!(!d.device_order_changed);
        assert!(d.render().contains("winner: identical"));
        assert!(d.render().contains("boundaries: unchanged"));
    }

    #[test]
    fn boundary_moves_and_deltas_reported() {
        let a = pipeline_plan(16, vec![0, 5, 12], 64.0);
        let b = pipeline_plan(16, vec![0, 7, 12], 60.0);
        let d = compare(&a, &b);
        assert!(!d.same_choice, "partition changed");
        assert_eq!(
            d.boundary_moves,
            vec![BoundaryMove { boundary: 1, from: 5, to: 7 }]
        );
        assert_eq!(d.epoch_delta, -4.0);
        assert!((d.epoch_ratio - 60.0 / 64.0).abs() < 1e-12);
        let text = d.render();
        assert!(text.contains("winner: CHANGED"), "{text}");
        assert!(text.contains("boundary 1: layer 5 -> 7"), "{text}");
    }

    #[test]
    fn mode_mismatch_is_noted() {
        let a = pipeline_plan(16, vec![0, 5, 12], 64.0);
        let mut b = pipeline_plan(16, vec![0, 5, 12], 80.0);
        b.choice = Choice::DataParallel;
        let d = compare(&a, &b);
        assert!(!d.same_choice);
        assert!(d.partition_note.as_deref().unwrap().contains("modes differ"));
        assert!(d.render().contains("modes differ"));
    }

    #[test]
    fn stage_count_mismatch_is_noted() {
        let a = pipeline_plan(16, vec![0, 5, 12], 64.0);
        let b = pipeline_plan(16, vec![0, 4, 8, 12], 64.0);
        let d = compare(&a, &b);
        assert!(d.boundary_moves.is_empty());
        assert!(d.partition_note.as_deref().unwrap().contains("stage counts differ"));
    }

    #[test]
    fn device_order_change_flagged() {
        let a = pipeline_plan(16, vec![0, 5, 12], 64.0);
        let mut b = a.clone();
        b.device_order = vec![1, 0];
        let d = compare(&a, &b);
        assert!(d.device_order_changed);
        assert!(d.render().contains("device order: CHANGED"));
    }
}
