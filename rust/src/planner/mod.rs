//! The planner: BaPipe's Fig.-3 automatic exploration as a first-class,
//! typed, parallel subsystem.
//!
//! The seed implementation (now the [`crate::explorer`] compat façade)
//! ran a sequential exhaustive grid search and reported `Vec<String>`
//! logs. This module restructures that loop into composable parts:
//!
//! * [`space::SearchSpace`] — enumerates candidates (schedule kind ×
//!   micro-batch count × device orderings for heterogeneous clusters);
//! * [`orders`] — past the 8-device exhaustive wall, a deterministic
//!   neighbourhood search over device orderings (`--order-search`):
//!   heuristic seed layouts hill-climbed by swap / adjacent-insert /
//!   segment-reverse moves, scored by the phase-A partition DP
//!   bottleneck under a bounded probe budget, with probes fanned over
//!   `--jobs` exactly like the prewarm — the discovered set becomes the
//!   candidate `perm` axis;
//! * [`cache::EvalCache`] — memoizes partition work at the granularity
//!   it actually varies: the kind-independent balance passes once per
//!   `micro`, the memory fine-tune once per (Tables 1–2 memory class, M)
//!   — identical `(kind, micro)` partitions are computed once, and
//!   [`EvalCache::prewarm`] fans both batches out over `jobs` workers
//!   (phase A is parallel, not just the DES phase) with one
//!   [`crate::profile::RangeCost`] prefix-table set per permuted view
//!   shared across the whole micro grid;
//! * [`store`] — cross-scenario persistence of the cache keyed on a
//!   `(model, cluster)` fingerprint (`bapipe explore --plan-cache`): a
//!   repeated invocation restores both cache levels and skips phase A
//!   entirely;
//! * [`bounds`] — closed-form lower bounds (from the Tables 1–2 model)
//!   that let a branch-and-bound pass skip discrete-event simulations
//!   which provably cannot beat the incumbent;
//! * [`eval`] — candidate → `SimSpec` → DES evaluation, on the
//!   table-free batched path ([`crate::sim::batch::FamilySim`]) with one
//!   simulator per worker thread, pooled across the grid pass and every
//!   adaptive-M round (`parallel::ScratchPool`) and reset between
//!   rounds so a big early family never pins its peak allocation;
//! * [`report`] — the typed [`Evaluation`] / [`ExplorationReport`] /
//!   [`Plan`] data model, serializable to/from JSON (`plan.json`);
//! * [`diff`] — structured comparison of two `plan.json` artifacts
//!   (`bapipe plan diff`);
//! * a scoped-thread parallel evaluator with a *deterministic reduction*:
//!   the selected plan is independent of thread interleaving, so
//!   `jobs = 1` and `jobs = 8` return identical plans — and, behind
//!   [`Options::adaptive_m`], an incumbent-bisecting refinement of the M
//!   grid that only ever adds evaluations.
//!
//! ```no_run
//! use bapipe::{cluster, model, planner, profile};
//!
//! let net = model::zoo::vgg16(224);
//! let cl = cluster::presets::v100_cluster(4);
//! let prof = profile::analytical::profile(&net, &cl);
//! let opts = planner::Options { jobs: 4, ..Default::default() };
//! let plan = planner::explore(&net, &cl, &prof, &opts);
//! println!("{}", plan.summary());
//! println!("{} DES runs, {} pruned", plan.report.simulated_count, plan.report.pruned_count);
//! ```

pub mod bounds;
pub mod cache;
pub mod diff;
pub mod elastic;
pub mod eval;
pub mod migrate;
pub mod orders;
pub mod report;
pub mod space;
pub mod store;

mod parallel;

pub use cache::EvalCache;
pub use diff::{BoundaryMove, PlanDiff};
pub use eval::{
    build_spec, build_spec_plan, evaluate_pipeline, fits, plan_memory, plan_stage_bytes,
};
pub use report::{Choice, Evaluation, ExplorationReport, Outcome, ParetoPoint, Plan};
pub use space::{Candidate, SearchSpace};

use crate::cluster::Cluster;
use crate::model::Network;
use crate::partition::memfit::{dp_memory_bytes, MemoryModel};
use crate::profile::Profile;
use crate::schedule::ScheduleKind;
use crate::sim::batch::FamilySim;
use crate::sim::dp;
use crate::sim::engine::{epoch_from_makespan, epoch_time};
use std::sync::atomic::{AtomicU64, Ordering};

/// Exploration options (superset of the seed explorer's options; every
/// addition defaults to the seed behaviour).
#[derive(Debug, Clone)]
pub struct Options {
    /// Per-device batch size `B` (paper's Table 3 notation). The global
    /// mini-batch entering the pipeline is `B × N`.
    pub batch_per_device: f64,
    /// Samples per epoch (used to convert mini-batch time → epoch time).
    pub samples_per_epoch: usize,
    /// Micro-batch-count candidates `M` (filtered to divisors of the
    /// global mini-batch).
    pub m_candidates: Vec<usize>,
    /// Also evaluate plain data parallelism and pick it if faster.
    pub consider_dp: bool,
    /// Worker threads for the DES evaluation phase (1 = sequential). The
    /// selected plan is identical for any job count.
    pub jobs: usize,
    /// Skip simulations whose analytical lower bound already exceeds the
    /// incumbent (branch-and-bound). Never changes the selected plan.
    pub prune: bool,
    /// On heterogeneous clusters, also search distinct device orderings
    /// along the pipeline chain (e.g. which FPGA of a VCU129/VCU118 mix
    /// hosts the first stage).
    pub permute_devices: bool,
    /// Past 8 devices, replace the (skipped) exhaustive device-order
    /// enumeration with the [`orders`] neighbourhood search: a heuristic
    /// seed portfolio hill-climbed under a bounded probe budget. Only
    /// consulted when `permute_devices` is set; at ≤ 8 devices the
    /// exhaustive enumeration runs unchanged.
    pub order_search: bool,
    /// Probe budget of the neighbourhood search (each probe scores one
    /// ordering via the phase-A partition DP); usage is reported in the
    /// search-space notes.
    pub order_budget: usize,
    /// After the fixed M grid, bisect the micro-batch count around the
    /// incumbent (divisors of the global mini-batch between the winner
    /// and its evaluated neighbours, repeatedly). Only ever *adds*
    /// evaluations, so the refined plan is never worse than the fixed
    /// grid's.
    pub adaptive_m: bool,
    /// Keep the whole (epoch time × simulated peak memory) Pareto front
    /// in the returned [`Plan`] instead of the fastest point alone, and
    /// widen the schedule-kind axis with the memory-scalable 2BW kind
    /// (double-buffered weight versions, PipeDream-2BW). Suspends
    /// branch-and-bound pruning — the front needs slower-but-lighter
    /// candidates simulated, which the time bound would skip. The selected
    /// plan — the fastest feasible point — is unchanged by this flag
    /// unless 2BW itself wins.
    pub pareto: bool,
    /// Add activation recomputation as a candidate axis: every
    /// (kind, M, order) point is also tried with boundary-only stashing
    /// and forward replay in the backward slot (extra FLOPs priced into
    /// the DES spec, the byte trade priced by
    /// [`crate::partition::memfit::stage_bytes`]).
    pub recompute: bool,
    /// Anytime stopping (`--eval-budget`): process at most this many
    /// feasible candidates in phase B. DES'd and pruned candidates both
    /// consume a unit — the budget counts candidates *considered*, not
    /// wall clock, so the stopping point is identical for every `--jobs`
    /// value. Candidates past the cap are reported as
    /// [`Outcome::Skipped`] with their analytical lower bound, the report
    /// carries a TRUNCATED note, and the best incumbent found within
    /// budget is returned. The budget is shared across the grid pass and
    /// every adaptive-M round. `None` = unbounded.
    pub eval_budget: Option<usize>,
}

impl Default for Options {
    fn default() -> Self {
        Options {
            batch_per_device: 32.0,
            samples_per_epoch: 50_000,
            m_candidates: vec![2, 4, 8, 16, 32, 64, 128],
            consider_dp: true,
            jobs: 1,
            prune: true,
            permute_devices: false,
            order_search: false,
            order_budget: orders::ORDER_BUDGET_DEFAULT,
            adaptive_m: false,
            pareto: false,
            recompute: false,
            eval_budget: None,
        }
    }
}

/// How a candidate fared in phase B (DES / pruning).
enum PhaseB {
    Done { minibatch_time: f64, epoch_time: f64, peak_memory: Vec<u64> },
    Pruned { lower_bound: f64 },
}

/// Monotone atomic `min` over positive f64 values (bit patterns of
/// non-negative floats order like unsigned integers).
fn atomic_min_f64(cell: &AtomicU64, value: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        if f64::from_bits(cur) <= value {
            return;
        }
        match cell.compare_exchange_weak(
            cur,
            value.to_bits(),
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Evaluate every candidate of `space`, returning the typed report (DP
/// baseline fields left unset — [`explore`] fills them).
///
/// Phase A (parallel over `opts.jobs`, deterministic): the balance-seed
/// DPs and memory fine-tunes fan out through [`EvalCache::prewarm`] —
/// work lists and result insertion are in first-appearance order, so
/// cache contents and statistics are independent of the job count — then
/// feasibility checks, `SimSpec` construction and analytical lower
/// bounds per candidate against the warm cache. Phase B (parallel over
/// `opts.jobs` scoped threads, one pooled batched simulator per worker —
/// [`crate::sim::batch::FamilySim`]): DES
/// evaluation in ascending-lower-bound order with a shared incumbent; a
/// candidate is pruned only when its lower bound *strictly* exceeds the
/// incumbent, so every pruned candidate is provably worse than the final
/// best and the reduction (min epoch time, ties to the earliest
/// candidate in enumeration order) is independent of thread
/// interleaving.
pub fn explore_space(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    space: &SearchSpace,
    opts: &Options,
) -> ExplorationReport {
    let mut cache = EvalCache::new();
    let mut pool = parallel::ScratchPool::new();
    let mut budget = opts.eval_budget;
    explore_space_with(
        net,
        cluster,
        profile,
        space,
        opts,
        &mut cache,
        &mut pool,
        f64::INFINITY,
        &mut budget,
    )
}

/// [`explore_space`] against a caller-owned cache, a caller-owned
/// per-worker simulator pool and a pre-seeded incumbent epoch time: the
/// adaptive M refinement threads one cache *and one pool* through all its
/// rounds — worker simulators (and their arenas) are built once per
/// exploration, not once per round — and starts each round's
/// branch-and-bound at the best epoch already simulated (a candidate
/// pruned against it is provably worse than a recorded evaluation, so the
/// merged selection is unchanged). `cache_hits` in the returned report
/// counts this call's hits only. `eval_budget` is the remaining anytime
/// budget ([`Options::eval_budget`]), decremented by the candidates this
/// call processes so the cap spans adaptive-M rounds; `None` = unbounded.
#[allow(clippy::too_many_arguments)]
fn explore_space_with(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    space: &SearchSpace,
    opts: &Options,
    cache: &mut EvalCache,
    pool: &mut parallel::ScratchPool<FamilySim>,
    incumbent_seed: f64,
    eval_budget: &mut Option<usize>,
) -> ExplorationReport {
    let n = cluster.len();
    // Canonical (float-noise-snapped) global batch: micro sizes, the
    // divisibility filter and the epoch's mini-batch count must all see
    // the same value (`util::canonical_global_batch`).
    let global = crate::util::canonical_global_batch(space.batch_per_device, n);
    let n_mb = (opts.samples_per_epoch as f64 / global).ceil() as usize;

    // Per-permutation views of the cluster and profile.
    let views: Vec<(Cluster, Profile)> = space
        .device_orders
        .iter()
        .map(|ord| space::permuted_view(cluster, profile, ord))
        .collect();

    let candidates = space.candidates(n);

    // Phase A: partitions — the balance-seed DPs and memory fine-tunes
    // fan out over `opts.jobs` workers ([`EvalCache::prewarm`], results
    // landing in deterministic first-appearance order) — then
    // feasibility, spec construction and lower bounds per candidate (all
    // cache reads).
    let hits_before = cache.hits;
    cache.prewarm(net, &views, &candidates, global, opts.jobs);
    let prepared: Vec<Result<eval::Prepared, String>> = candidates
        .iter()
        .map(|cand| {
            let (cl, prof) = &views[cand.perm];
            eval::prepare(net, cl, prof, cache, cand, global, n_mb)
        })
        .collect();

    // Phase B: DES in ascending-lower-bound order (tightens the incumbent
    // as early as possible), pruned against a shared incumbent.
    let mut order: Vec<usize> = (0..candidates.len()).filter(|&i| prepared[i].is_ok()).collect();
    order.sort_by(|&a, &b| {
        let (la, lb) = match (&prepared[a], &prepared[b]) {
            (Ok(pa), Ok(pb)) => (pa.lb_epoch, pb.lb_epoch),
            _ => unreachable!("order only holds feasible candidates"),
        };
        la.partial_cmp(&lb).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });

    // Anytime stopping (`--eval-budget`): cap the number of phase-B
    // candidates processed. The cut sits in the deterministic
    // lower-bound order and counts candidates considered (DES'd *or*
    // pruned), never wall clock — so the truncation point, and with it
    // the whole report, is identical for every `--jobs` value. Skipped
    // candidates keep their analytical lower bound.
    let mut budget_skipped: Vec<usize> = Vec::new();
    if let Some(b) = eval_budget {
        let cap = (*b).min(order.len());
        budget_skipped = order.split_off(cap);
        *b -= cap;
    }

    // This invocation is a new candidate family for the pooled
    // simulators: drop stale replay checkpoints and release capacity a
    // bigger earlier round pinned (`FamilySim::begin_family`).
    let m_max = order.iter().map(|&i| candidates[i].m).max().unwrap_or(1);
    pool.for_each_mut(|sim| sim.begin_family(n, m_max));

    let incumbent = AtomicU64::new(incumbent_seed.to_bits());
    let phase_b: Vec<PhaseB> =
        pool.run(opts.jobs, order.len(), FamilySim::new, |sim, k| {
            let p = match &prepared[order[k]] {
                Ok(p) => p,
                Err(_) => unreachable!("order only holds feasible candidates"),
            };
            let best_seen = f64::from_bits(incumbent.load(Ordering::Relaxed));
            // Strict inequality (an equal-epoch candidate must still be
            // simulated so the deterministic tie-break can consider it), with
            // a relative margin so summation-order rounding in the bound can
            // never prune a candidate the exhaustive search would keep.
            // Suspended under `--pareto`: the front needs slower-but-lighter
            // candidates simulated, which the time bound would prune.
            if opts.prune && !opts.pareto && p.lb_epoch * (1.0 - 1e-9) > best_seen {
                return PhaseB::Pruned { lower_bound: p.lb_epoch };
            }
            // Table-free batched DES over the worker's pooled simulator:
            // bit-exact with `simulate_fast`/`simulate_full`, no
            // per-candidate allocation or op-table build.
            let makespan = sim.run(&p.spec).makespan;
            // Simulated per-device peak bytes: the DES in-flight
            // high-water mark priced through the same `StageBytes` the
            // memory fine-tune used — never above its worst-case `peak()`.
            let peak_memory: Vec<u64> = p
                .stage_bytes
                .iter()
                .zip(sim.peak_in_flight())
                .map(|(sb, &k)| sb.at_occupancy(k))
                .collect();
            let ep = epoch_from_makespan(makespan, &p.spec, n_mb);
            atomic_min_f64(&incumbent, ep);
            PhaseB::Done { minibatch_time: makespan, epoch_time: ep, peak_memory }
        });

    // Stitch phase results back into enumeration order.
    let mut outcomes: Vec<Option<Outcome>> = prepared
        .iter()
        .map(|r| match r {
            Err(reason) => Some(Outcome::Infeasible { reason: reason.clone() }),
            Ok(_) => None,
        })
        .collect();
    for (k, res) in phase_b.into_iter().enumerate() {
        let idx = order[k];
        let p = match &prepared[idx] {
            Ok(p) => p,
            Err(_) => unreachable!(),
        };
        outcomes[idx] = Some(match res {
            PhaseB::Done { minibatch_time, epoch_time, peak_memory } => Outcome::Evaluated {
                minibatch_time,
                epoch_time,
                lower_bound: p.lb_epoch,
                partition: p.partition.clone(),
                peak_memory,
            },
            PhaseB::Pruned { lower_bound } => Outcome::Pruned { lower_bound },
        });
    }
    for &idx in &budget_skipped {
        let p = match &prepared[idx] {
            Ok(p) => p,
            Err(_) => unreachable!("budget_skipped only holds feasible candidates"),
        };
        outcomes[idx] = Some(Outcome::Skipped { lower_bound: p.lb_epoch });
    }

    let evaluations: Vec<Evaluation> = candidates
        .into_iter()
        .zip(outcomes)
        .map(|(candidate, outcome)| Evaluation {
            candidate,
            outcome: outcome.expect("every candidate received an outcome"),
        })
        .collect();

    let simulated_count =
        evaluations.iter().filter(|e| matches!(e.outcome, Outcome::Evaluated { .. })).count();
    let pruned_count =
        evaluations.iter().filter(|e| matches!(e.outcome, Outcome::Pruned { .. })).count();

    let mut notes = space.notes.clone();
    if !budget_skipped.is_empty() {
        notes.push(format!(
            "eval budget TRUNCATED: {} of {} feasible candidates skipped after {} processed \
             (--eval-budget); best incumbent within budget returned",
            budget_skipped.len(),
            order.len() + budget_skipped.len(),
            order.len()
        ));
    }

    ExplorationReport {
        model: net.describe(),
        cluster: cluster.describe(),
        batch_per_device: space.batch_per_device,
        samples_per_epoch: opts.samples_per_epoch,
        jobs: opts.jobs.max(1),
        ineligible: space.ineligible.clone(),
        notes,
        order_provenance: space.order_provenance.clone(),
        evaluations,
        simulated_count,
        pruned_count,
        cache_hits: cache.hits - hits_before,
        dp_considered: false,
        dp_fits: false,
        dp_minibatch_time: f64::INFINITY,
        dp_epoch_time: f64::INFINITY,
    }
}

/// Most bisection rounds of the adaptive M refinement (each round adds at
/// most two new M values around the incumbent).
const ADAPTIVE_M_ROUNDS: usize = 8;

/// The divisor in the *open* interval `(lo, hi)` closest to its midpoint
/// that has not been tried yet (ties to the smaller M).
fn bisect_divisor(
    divisors: &[usize],
    tried: &std::collections::BTreeSet<usize>,
    lo: usize,
    hi: usize,
) -> Option<usize> {
    if hi <= lo + 1 {
        return None;
    }
    let mid = (lo + hi) / 2;
    divisors
        .iter()
        .copied()
        .filter(|d| *d > lo && *d < hi && !tried.contains(d))
        .min_by_key(|d| (d.abs_diff(mid), *d))
}

/// Adaptive M-grid refinement ([`Options::adaptive_m`]): repeatedly
/// bisect the micro-batch-count axis around the incumbent — the divisor
/// of the global mini-batch closest to the midpoint between the winning
/// M and its nearest evaluated neighbour on each side (the full divisor
/// axis when the incumbent sits on the grid edge) — and merge the new
/// evaluations into `report`. Purely additive: every fixed-grid
/// evaluation is retained and ties keep the earlier candidate, so the
/// refined selection is never worse than the fixed grid's. The anytime
/// `eval_budget` is shared with the grid pass — an exhausted budget turns
/// every new bisection candidate into [`Outcome::Skipped`].
#[allow(clippy::too_many_arguments)]
fn refine_m(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    space: &SearchSpace,
    opts: &Options,
    cache: &mut EvalCache,
    pool: &mut parallel::ScratchPool<FamilySim>,
    report: &mut ExplorationReport,
    eval_budget: &mut Option<usize>,
) {
    // Round, never truncate: a global batch computed in f64 can land a
    // hair below its intended integer (7.999999999999999 × 4 =
    // 31.999999999999996), and truncating it to 31 would bisect the
    // divisor axis of the wrong number (see `eval::divides_global`).
    let global =
        crate::util::canonical_global_batch(space.batch_per_device, cluster.len()).round()
            as usize;
    if global == 0 {
        return;
    }
    let divisors: Vec<usize> = (1..=global).filter(|d| global % d == 0).collect();
    // One cache across every round (the caller's — so `--plan-cache`
    // persists the refinement work too); each round's branch-and-bound
    // starts at the best epoch already recorded, so new candidates that
    // provably cannot win are pruned instead of simulated.
    for round in 0..ADAPTIVE_M_ROUNDS {
        let Some(best) = report.best_evaluation() else { return };
        let best_m = best.candidate.m;
        let best_epoch = match &best.outcome {
            Outcome::Evaluated { epoch_time, .. } => *epoch_time,
            _ => unreachable!("best_evaluation only returns Evaluated entries"),
        };
        let tried: std::collections::BTreeSet<usize> =
            report.evaluations.iter().map(|e| e.candidate.m).collect();
        // When the incumbent sits on a grid edge, widen to a synthetic
        // bound just *outside* the divisor axis so the open interval of
        // `bisect_divisor` can still reach the untried endpoints M=1 and
        // M=global.
        let below = tried.range(..best_m).next_back().copied().unwrap_or(0);
        let above = tried.range(best_m + 1..).next().copied().unwrap_or(global + 1);
        let mut new_ms: Vec<usize> = Vec::new();
        for (lo, hi) in [(below, best_m), (best_m, above)] {
            if let Some(m) = bisect_divisor(&divisors, &tried, lo, hi) {
                if !new_ms.contains(&m) {
                    new_ms.push(m);
                }
            }
        }
        if new_ms.is_empty() {
            return;
        }
        new_ms.sort_unstable();
        let sub_space = SearchSpace {
            kinds: space.kinds.clone(),
            ineligible: Vec::new(), // already reported by the grid pass
            m_grid: new_ms.clone(),
            recompute_options: space.recompute_options.clone(),
            batch_per_device: space.batch_per_device,
            device_orders: space.device_orders.clone(),
            notes: Vec::new(),
            order_provenance: Vec::new(), // already reported by the grid pass
        };
        let sub = explore_space_with(
            net, cluster, profile, &sub_space, opts, cache, pool, best_epoch, eval_budget,
        );
        report.notes.push(format!(
            "adaptive-M round {}: bisected to M={new_ms:?} around incumbent M={best_m}",
            round + 1
        ));
        report.notes.extend(sub.notes);
        report.evaluations.extend(sub.evaluations);
        report.simulated_count += sub.simulated_count;
        report.pruned_count += sub.pruned_count;
        report.cache_hits += sub.cache_hits;
    }
}

/// The full BaPipe exploration (Fig. 3): enumerate the schedule ×
/// micro-batching space (optionally over device orderings), evaluate
/// with memoized partitions, branch-and-bound pruning and `opts.jobs`
/// parallel workers (phases A *and* B), optionally refine the M grid
/// around the incumbent, compare against the data-parallel baseline, and
/// return the fastest plan with its full typed report.
pub fn explore(net: &Network, cluster: &Cluster, profile: &Profile, opts: &Options) -> Plan {
    let mut cache = EvalCache::new();
    explore_with_cache(net, cluster, profile, opts, &mut cache)
}

/// [`explore`] against a caller-owned [`EvalCache`]: a cache restored
/// from disk (`bapipe explore --plan-cache`, [`store`]) answers every
/// phase-A partition request without running a single balance-seed DP or
/// memory fine-tune, and the cache accumulates this run's work — grid
/// pass and adaptive-M rounds alike — for the caller to persist.
pub fn explore_with_cache(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
    cache: &mut EvalCache,
) -> Plan {
    let space = SearchSpace::bapipe(net, cluster, profile, opts);
    explore_with_cache_in_space(net, cluster, profile, &space, opts, cache)
}

/// [`explore_with_cache`] over a caller-built [`SearchSpace`]. The CLI's
/// `--plan-cache` path builds the space once to validate the persisted
/// cache against its device-order list; past 8 devices that construction
/// runs the (budgeted, possibly expensive) `orders` discovery, so the
/// exploration must reuse the space instead of discovering a second time.
pub fn explore_with_cache_in_space(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    space: &SearchSpace,
    opts: &Options,
    cache: &mut EvalCache,
) -> Plan {
    explore_seeded_in_space(net, cluster, profile, space, opts, cache, f64::INFINITY)
}

/// [`explore_with_cache_in_space`] with a pre-seeded incumbent epoch for
/// the branch-and-bound ([`elastic`]'s warm start: the cached plan
/// re-evaluated on the mutated cluster). The seed must be an epoch time
/// *achieved by a candidate inside `space`* — then every pruned candidate
/// is provably no better than a recorded evaluation and the selection is
/// unchanged, just cheaper to reach.
pub(crate) fn explore_seeded_in_space(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    space: &SearchSpace,
    opts: &Options,
    cache: &mut EvalCache,
    incumbent_seed: f64,
) -> Plan {
    // One simulator pool for the whole exploration: the grid pass and
    // every adaptive-M round share per-worker arenas instead of
    // reallocating them per `explore_space_with` invocation.
    let mut pool = parallel::ScratchPool::new();
    // One anytime budget for the whole exploration too: the grid pass
    // spends first, the refinement rounds get the remainder.
    let mut budget = opts.eval_budget;
    let mut report = explore_space_with(
        net,
        cluster,
        profile,
        space,
        opts,
        cache,
        &mut pool,
        incumbent_seed,
        &mut budget,
    );
    if opts.adaptive_m {
        refine_m(net, cluster, profile, space, opts, cache, &mut pool, &mut report, &mut budget);
    }

    // DP baseline (the paper's 1x reference; ResNet-50's winner). The
    // mini-batch model runs once; the epoch conversion reuses it instead
    // of re-summing the whole-network profile a second time.
    let dpr = dp::minibatch(profile, cluster, opts.batch_per_device);
    let dp_epoch = if dpr.fits {
        dp::epoch_from(&dpr, cluster, opts.batch_per_device, opts.samples_per_epoch)
    } else {
        f64::INFINITY
    };
    report.dp_considered = true;
    report.dp_fits = dpr.fits;
    report.dp_minibatch_time = dpr.minibatch_time;
    report.dp_epoch_time = dp_epoch;

    // The (epoch time × simulated peak memory) front over every DES'd
    // candidate. Kept only under `--pareto` (the serialized plan stays
    // byte-compatible otherwise); the *selected* plan below is still the
    // fastest feasible point in either mode.
    let pareto_front = if opts.pareto { report.pareto_front() } else { Vec::new() };

    let best = report.best_evaluation().cloned();
    match best {
        Some(ev) => {
            let (mb, ep, partition) = match ev.outcome {
                Outcome::Evaluated { minibatch_time, epoch_time, partition, .. } => {
                    (minibatch_time, epoch_time, partition)
                }
                _ => unreachable!("best_evaluation only returns Evaluated entries"),
            };
            if opts.consider_dp && dp_epoch < ep {
                let mut plan =
                    dp_plan(profile, opts, dpr.minibatch_time, dp_epoch, cluster.len(), report);
                plan.pareto_front = pareto_front;
                return plan;
            }
            let cand = ev.candidate;
            let (_, prof_view) =
                space::permuted_view(cluster, profile, &space.device_orders[cand.perm]);
            let stage_memory = plan_memory(
                &prof_view,
                cand.kind,
                cand.recompute,
                &partition,
                cand.micro,
                cand.m,
            );
            Plan {
                choice: Choice::Pipeline {
                    kind: cand.kind,
                    m: cand.m,
                    micro: cand.micro,
                    recompute: cand.recompute,
                    partition,
                },
                device_order: space.device_orders[cand.perm].clone(),
                minibatch_time: mb,
                epoch_time: ep,
                dp_epoch_time: dp_epoch,
                speedup_over_dp: dp_epoch / ep,
                stage_memory,
                pareto_front,
                report,
            }
        }
        None => {
            let mut plan =
                dp_plan(profile, opts, dpr.minibatch_time, dp_epoch, cluster.len(), report);
            plan.pareto_front = pareto_front;
            plan
        }
    }
}

/// Build the data-parallel fallback plan (pipeline lost or infeasible).
fn dp_plan(
    profile: &Profile,
    opts: &Options,
    dp_minibatch: f64,
    dp_epoch: f64,
    n_devices: usize,
    report: ExplorationReport,
) -> Plan {
    let mm = MemoryModel::data_parallel();
    let stage_memory = vec![dp_memory_bytes(profile, &mm, opts.batch_per_device)];
    Plan {
        choice: Choice::DataParallel,
        device_order: (0..n_devices).collect(),
        minibatch_time: dp_minibatch,
        epoch_time: dp_epoch,
        dp_epoch_time: dp_epoch,
        speedup_over_dp: 1.0,
        stage_memory,
        pareto_front: Vec::new(),
        report,
    }
}

/// GPipe baseline as a [`SearchSpace`] restriction: fill-drain schedule,
/// **BaPipe's partition** (the paper gives GPipe our partitions since it
/// has no balancer), best feasible M. Returns `(epoch_time, m)`.
pub fn plan_gpipe(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
) -> Option<(f64, usize)> {
    let space = SearchSpace::restricted(ScheduleKind::GPipe, cluster, opts);
    let report = explore_space(net, cluster, profile, &space, opts);
    report.best_evaluation().map(|ev| match &ev.outcome {
        Outcome::Evaluated { epoch_time, .. } => (*epoch_time, ev.candidate.m),
        _ => unreachable!("best_evaluation only returns Evaluated entries"),
    })
}

/// PipeDream baseline: inter-batch 1F1B with weight stashing, its own
/// DP-style partitioner (compute+comm, no memory term), per-device batch
/// halved until the stash fits (the candidate batches come from
/// [`SearchSpace::pipedream_batches`]). Returns `(epoch_time, batch)`.
pub fn plan_pipedream(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
) -> Option<(f64, f64)> {
    let cuts = net.legal_cuts();
    for &b in &SearchSpace::pipedream_batches(opts.batch_per_device) {
        let comm = |stage: usize, cut_layer: usize| -> f64 {
            let bytes = profile.cut_bytes(cut_layer) as f64 * b;
            // The partition DP only charges communication on cuts that
            // have a downstream stage (`stage + 1 < n`), so `stage` is a
            // real link index — on heterogeneous chains each boundary
            // must price its *own* link, not a clamped one.
            cluster.link(stage).xfer_time(bytes) * 2.0
        };
        let part =
            crate::partition::interlayer::dp_optimal(profile, cluster, &cuts, b, Some(&comm))
                .ok()?;
        if fits(profile, cluster, ScheduleKind::PipeDream, false, &part, b, 1) {
            let spec = build_spec(profile, cluster, &part, ScheduleKind::PipeDream, false, b, 1);
            let n_mb = (opts.samples_per_epoch as f64 / b).ceil() as usize;
            return Some((epoch_time(&spec, n_mb), b));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    fn opts(b: f64) -> Options {
        Options { batch_per_device: b, samples_per_epoch: 8192, ..Default::default() }
    }

    #[test]
    fn atomic_min_is_monotone() {
        let cell = AtomicU64::new(f64::INFINITY.to_bits());
        atomic_min_f64(&cell, 3.5);
        atomic_min_f64(&cell, 7.0);
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 3.5);
        atomic_min_f64(&cell, 1.25);
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 1.25);
    }

    #[test]
    fn bisect_divisor_picks_midmost_untried() {
        use std::collections::BTreeSet;
        let global = 128usize;
        let divisors: Vec<usize> = (1..=global).filter(|d| global % d == 0).collect();
        let tried: BTreeSet<usize> = [2, 4, 8, 16, 32, 64, 128].into_iter().collect();
        // (16, 32) holds no divisor of 128 strictly inside → nothing to try
        assert_eq!(bisect_divisor(&divisors, &tried, 16, 32), None);
        // (16, 64) with 32 untried: midpoint 40, closest inside divisor 32
        let tried2: BTreeSet<usize> = [2, 4, 8, 16, 64, 128].into_iter().collect();
        assert_eq!(bisect_divisor(&divisors, &tried2, 16, 64), Some(32));
        // degenerate interval
        assert_eq!(bisect_divisor(&divisors, &tried, 8, 9), None);
        // (1, 4): the only divisor strictly inside is 2
        let none_tried = BTreeSet::new();
        assert_eq!(bisect_divisor(&divisors, &none_tried, 1, 4), Some(2));
        // edge-of-grid synthetic bounds (0 and global+1) make the axis
        // endpoints reachable: M=1 below the smallest tried M…
        let tried3: BTreeSet<usize> = [2, 4].into_iter().collect();
        assert_eq!(bisect_divisor(&divisors, &tried3, 0, 2), Some(1));
        // …and M=global above the largest tried M
        let tried4: BTreeSet<usize> = [2, 4, 8, 16, 32, 64].into_iter().collect();
        assert_eq!(bisect_divisor(&divisors, &tried4, 64, 129), Some(128));
    }

    #[test]
    fn adaptive_m_never_worse_and_purely_additive() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let fixed = explore(&net, &cl, &prof, &opts(32.0));
        let adaptive =
            explore(&net, &cl, &prof, &Options { adaptive_m: true, ..opts(32.0) });
        assert!(
            adaptive.epoch_time <= fixed.epoch_time,
            "adaptive {} vs fixed {}",
            adaptive.epoch_time,
            fixed.epoch_time
        );
        // the fixed grid's evaluations are all retained, in order, at the
        // front of the refined report
        assert_eq!(
            &adaptive.report.evaluations[..fixed.report.evaluations.len()],
            &fixed.report.evaluations[..]
        );
    }

    #[test]
    fn global_batch_rounds_instead_of_truncating() {
        // A per-device batch a hair below 8 (as a config file can easily
        // produce) makes the f64 global batch 31.999999999999996; the old
        // truncation turned that into 31 and the `% m == 0` filter
        // rejected every divisor of 32, silently emptying the space.
        let b = 7.999999999999999_f64;
        assert!((b * 4.0) < 32.0, "the premise: the product lands below 32");
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let o = Options {
            batch_per_device: b,
            samples_per_epoch: 8192,
            m_candidates: vec![32],
            consider_dp: false,
            ..Default::default()
        };
        let plan = explore(&net, &cl, &prof, &o);
        assert!(
            matches!(plan.choice, Choice::Pipeline { m: 32, .. }),
            "M=32 must survive rounding: {:?}",
            plan.report.log_lines()
        );
    }

    #[test]
    fn adaptive_m_bisects_the_rounded_global_batch() {
        // refine_m derives the divisor axis from the same near-integer
        // global batch: rounding gives the divisors of 32 (bisection from
        // M=32 reaches 16); truncation gave the divisors of 31 (= {1, 31})
        // and the refinement could only ever try M=1.
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let o = Options {
            batch_per_device: 7.999999999999999,
            samples_per_epoch: 8192,
            m_candidates: vec![32],
            consider_dp: false,
            adaptive_m: true,
            ..Default::default()
        };
        let plan = explore(&net, &cl, &prof, &o);
        assert!(
            plan.report.evaluations.iter().any(|e| e.candidate.m == 16),
            "bisection must walk the divisors of the rounded global batch: {:?}",
            plan.report.log_lines()
        );
    }

    #[test]
    fn eval_budget_truncates_deterministically_and_is_anytime() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let o = Options {
            eval_budget: Some(3),
            prune: false,
            consider_dp: false,
            ..opts(32.0)
        };
        let a = explore(&net, &cl, &prof, &o);
        let b = explore(&net, &cl, &prof, &Options { jobs: 8, ..o.clone() });
        // the budget counts candidates, not wall clock: the truncation
        // point — and with it every outcome — is job-count independent
        assert_eq!(a.report.evaluations, b.report.evaluations);
        assert_eq!(a.choice, b.choice);
        assert_eq!(a.epoch_time, b.epoch_time);
        assert_eq!(a.report.simulated_count, 3, "exactly the budget is spent");
        let skipped = a
            .report
            .evaluations
            .iter()
            .filter(|e| matches!(e.outcome, Outcome::Skipped { .. }))
            .count();
        assert!(skipped > 0, "a budget of 3 must leave candidates unprocessed");
        assert!(
            a.report.notes.iter().any(|n| n.contains("TRUNCATED")),
            "truncation must be noted: {:?}",
            a.report.notes
        );
        // anytime: the unbounded run is at least as good, and the
        // truncated run still returns a real incumbent
        let full =
            explore(&net, &cl, &prof, &Options { prune: false, consider_dp: false, ..opts(32.0) });
        assert!(matches!(a.choice, Choice::Pipeline { .. }));
        assert!(full.epoch_time <= a.epoch_time);
    }

    #[test]
    fn pruning_never_changes_the_plan() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let exhaustive = explore(&net, &cl, &prof, &Options { prune: false, ..opts(32.0) });
        let pruned = explore(&net, &cl, &prof, &Options { prune: true, ..opts(32.0) });
        assert_eq!(exhaustive.choice, pruned.choice);
        assert_eq!(exhaustive.epoch_time, pruned.epoch_time);
        assert_eq!(exhaustive.report.pruned_count, 0);
        assert!(pruned.report.simulated_count <= exhaustive.report.simulated_count);
    }

    #[test]
    fn cache_shares_partitions_across_kinds() {
        // The balance seed (passes 1–3) is kind-independent: with two
        // eligible kinds per cluster, every second candidate's seed is a
        // cache hit — one hit per M value at minimum.
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let o = opts(32.0);
        let plan = explore(&net, &cl, &prof, &o);
        assert!(
            plan.report.cache_hits >= o.m_candidates.len() - 1,
            "expected cache sharing, got {} hits",
            plan.report.cache_hits
        );
    }

    #[test]
    fn gpipe_restriction_matches_seed_loop() {
        // The SearchSpace restriction must agree with evaluating the
        // GPipe kind by hand over the M grid.
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let o = opts(32.0);
        let (ep, m) = plan_gpipe(&net, &cl, &prof, &o).unwrap();
        let mut best: Option<(f64, usize)> = None;
        for &cand_m in &o.m_candidates {
            if let Some((_, e, _)) =
                evaluate_pipeline(&net, &cl, &prof, ScheduleKind::GPipe, cand_m, &o)
            {
                if best.map(|(b, _)| e < b).unwrap_or(true) {
                    best = Some((e, cand_m));
                }
            }
        }
        let (seed_ep, seed_m) = best.unwrap();
        assert_eq!(ep, seed_ep);
        assert_eq!(m, seed_m);
    }
}
