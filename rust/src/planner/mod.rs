//! The planner: BaPipe's Fig.-3 automatic exploration as a first-class,
//! typed, parallel subsystem.
//!
//! The seed implementation (now the [`crate::explorer`] compat façade)
//! ran a sequential exhaustive grid search and reported `Vec<String>`
//! logs. This module restructures that loop into composable parts:
//!
//! * [`space::SearchSpace`] — enumerates candidates (schedule kind ×
//!   micro-batch count × device orderings for heterogeneous clusters);
//! * [`cache::EvalCache`] — memoizes partition work at the granularity
//!   it actually varies: the kind-independent balance passes once per
//!   `micro`, the memory fine-tune once per (Tables 1–2 memory class, M)
//!   — identical `(kind, micro)` partitions are computed once;
//! * [`bounds`] — closed-form lower bounds (from the Tables 1–2 model)
//!   that let a branch-and-bound pass skip discrete-event simulations
//!   which provably cannot beat the incumbent;
//! * [`eval`] — candidate → `SimSpec` → DES evaluation;
//! * [`report`] — the typed [`Evaluation`] / [`ExplorationReport`] /
//!   [`Plan`] data model, serializable to/from JSON (`plan.json`);
//! * a scoped-thread parallel evaluator with a *deterministic reduction*:
//!   the selected plan is independent of thread interleaving, so
//!   `jobs = 1` and `jobs = 8` return identical plans.
//!
//! ```no_run
//! use bapipe::{cluster, model, planner, profile};
//!
//! let net = model::zoo::vgg16(224);
//! let cl = cluster::presets::v100_cluster(4);
//! let prof = profile::analytical::profile(&net, &cl);
//! let opts = planner::Options { jobs: 4, ..Default::default() };
//! let plan = planner::explore(&net, &cl, &prof, &opts);
//! println!("{}", plan.summary());
//! println!("{} DES runs, {} pruned", plan.report.simulated_count, plan.report.pruned_count);
//! ```

pub mod bounds;
pub mod cache;
pub mod eval;
pub mod report;
pub mod space;

mod parallel;

pub use cache::EvalCache;
pub use eval::{build_spec, build_spec_plan, evaluate_pipeline, fits, plan_memory};
pub use report::{Choice, Evaluation, ExplorationReport, Outcome, Plan};
pub use space::{Candidate, SearchSpace};

use crate::cluster::Cluster;
use crate::model::Network;
use crate::partition::memfit::{dp_memory_bytes, MemoryModel};
use crate::profile::Profile;
use crate::schedule::ScheduleKind;
use crate::sim::dp;
use crate::sim::engine::{epoch_from_makespan, epoch_time, simulate};
use std::sync::atomic::{AtomicU64, Ordering};

/// Exploration options (superset of the seed explorer's options; every
/// addition defaults to the seed behaviour).
#[derive(Debug, Clone)]
pub struct Options {
    /// Per-device batch size `B` (paper's Table 3 notation). The global
    /// mini-batch entering the pipeline is `B × N`.
    pub batch_per_device: f64,
    /// Samples per epoch (used to convert mini-batch time → epoch time).
    pub samples_per_epoch: usize,
    /// Micro-batch-count candidates `M` (filtered to divisors of the
    /// global mini-batch).
    pub m_candidates: Vec<usize>,
    /// Also evaluate plain data parallelism and pick it if faster.
    pub consider_dp: bool,
    /// Worker threads for the DES evaluation phase (1 = sequential). The
    /// selected plan is identical for any job count.
    pub jobs: usize,
    /// Skip simulations whose analytical lower bound already exceeds the
    /// incumbent (branch-and-bound). Never changes the selected plan.
    pub prune: bool,
    /// On heterogeneous clusters, also search distinct device orderings
    /// along the pipeline chain (e.g. which FPGA of a VCU129/VCU118 mix
    /// hosts the first stage).
    pub permute_devices: bool,
}

impl Default for Options {
    fn default() -> Self {
        Options {
            batch_per_device: 32.0,
            samples_per_epoch: 50_000,
            m_candidates: vec![2, 4, 8, 16, 32, 64, 128],
            consider_dp: true,
            jobs: 1,
            prune: true,
            permute_devices: false,
        }
    }
}

/// How a candidate fared in phase B (DES / pruning).
enum PhaseB {
    Done { minibatch_time: f64, epoch_time: f64 },
    Pruned { lower_bound: f64 },
}

/// Monotone atomic `min` over positive f64 values (bit patterns of
/// non-negative floats order like unsigned integers).
fn atomic_min_f64(cell: &AtomicU64, value: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        if f64::from_bits(cur) <= value {
            return;
        }
        match cell.compare_exchange_weak(
            cur,
            value.to_bits(),
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Evaluate every candidate of `space`, returning the typed report (DP
/// baseline fields left unset — [`explore`] fills them).
///
/// Phase A (sequential, deterministic): balanced partitions through the
/// memoizing [`EvalCache`], feasibility checks, `SimSpec` construction
/// and analytical lower bounds. Phase B (parallel over `opts.jobs`
/// scoped threads): DES evaluation in ascending-lower-bound order with a
/// shared incumbent; a candidate is pruned only when its lower bound
/// *strictly* exceeds the incumbent, so every pruned candidate is
/// provably worse than the final best and the reduction (min epoch time,
/// ties to the earliest candidate in enumeration order) is independent
/// of thread interleaving.
pub fn explore_space(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    space: &SearchSpace,
    opts: &Options,
) -> ExplorationReport {
    let n = cluster.len();
    let global = space.batch_per_device * n as f64;
    let n_mb = (opts.samples_per_epoch as f64 / global).ceil() as usize;

    // Per-permutation views of the cluster and profile.
    let views: Vec<(Cluster, Profile)> = space
        .device_orders
        .iter()
        .map(|ord| space::permuted_view(cluster, profile, ord))
        .collect();

    let candidates = space.candidates(n);

    // Phase A: partitions (memoized), feasibility, specs, lower bounds.
    let mut cache = EvalCache::new();
    let prepared: Vec<Result<eval::Prepared, String>> = candidates
        .iter()
        .map(|cand| {
            let (cl, prof) = &views[cand.perm];
            eval::prepare(net, cl, prof, &mut cache, cand, global, n_mb)
        })
        .collect();

    // Phase B: DES in ascending-lower-bound order (tightens the incumbent
    // as early as possible), pruned against a shared incumbent.
    let mut order: Vec<usize> = (0..candidates.len()).filter(|&i| prepared[i].is_ok()).collect();
    order.sort_by(|&a, &b| {
        let (la, lb) = match (&prepared[a], &prepared[b]) {
            (Ok(pa), Ok(pb)) => (pa.lb_epoch, pb.lb_epoch),
            _ => unreachable!("order only holds feasible candidates"),
        };
        la.partial_cmp(&lb).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });

    let incumbent = AtomicU64::new(f64::INFINITY.to_bits());
    let phase_b: Vec<PhaseB> = parallel::run_indexed(opts.jobs, order.len(), |k| {
        let p = match &prepared[order[k]] {
            Ok(p) => p,
            Err(_) => unreachable!("order only holds feasible candidates"),
        };
        let best_seen = f64::from_bits(incumbent.load(Ordering::Relaxed));
        // Strict inequality (an equal-epoch candidate must still be
        // simulated so the deterministic tie-break can consider it), with
        // a relative margin so summation-order rounding in the bound can
        // never prune a candidate the exhaustive search would keep.
        if opts.prune && p.lb_epoch * (1.0 - 1e-9) > best_seen {
            return PhaseB::Pruned { lower_bound: p.lb_epoch };
        }
        let makespan = simulate(&p.spec).makespan;
        let ep = epoch_from_makespan(makespan, &p.spec, n_mb);
        atomic_min_f64(&incumbent, ep);
        PhaseB::Done { minibatch_time: makespan, epoch_time: ep }
    });

    // Stitch phase results back into enumeration order.
    let mut outcomes: Vec<Option<Outcome>> = prepared
        .iter()
        .map(|r| match r {
            Err(reason) => Some(Outcome::Infeasible { reason: reason.clone() }),
            Ok(_) => None,
        })
        .collect();
    for (k, res) in phase_b.into_iter().enumerate() {
        let idx = order[k];
        let p = match &prepared[idx] {
            Ok(p) => p,
            Err(_) => unreachable!(),
        };
        outcomes[idx] = Some(match res {
            PhaseB::Done { minibatch_time, epoch_time } => Outcome::Evaluated {
                minibatch_time,
                epoch_time,
                lower_bound: p.lb_epoch,
                partition: p.partition.clone(),
            },
            PhaseB::Pruned { lower_bound } => Outcome::Pruned { lower_bound },
        });
    }

    let evaluations: Vec<Evaluation> = candidates
        .into_iter()
        .zip(outcomes)
        .map(|(candidate, outcome)| Evaluation {
            candidate,
            outcome: outcome.expect("every candidate received an outcome"),
        })
        .collect();

    let simulated_count =
        evaluations.iter().filter(|e| matches!(e.outcome, Outcome::Evaluated { .. })).count();
    let pruned_count =
        evaluations.iter().filter(|e| matches!(e.outcome, Outcome::Pruned { .. })).count();

    ExplorationReport {
        model: net.describe(),
        cluster: cluster.describe(),
        batch_per_device: space.batch_per_device,
        samples_per_epoch: opts.samples_per_epoch,
        jobs: opts.jobs.max(1),
        ineligible: space.ineligible.clone(),
        notes: space.notes.clone(),
        evaluations,
        simulated_count,
        pruned_count,
        cache_hits: cache.hits,
        dp_considered: false,
        dp_fits: false,
        dp_minibatch_time: f64::INFINITY,
        dp_epoch_time: f64::INFINITY,
    }
}

/// The full BaPipe exploration (Fig. 3): enumerate the schedule ×
/// micro-batching space (optionally over device orderings), evaluate
/// with memoized partitions, branch-and-bound pruning and `opts.jobs`
/// parallel workers, compare against the data-parallel baseline, and
/// return the fastest plan with its full typed report.
pub fn explore(net: &Network, cluster: &Cluster, profile: &Profile, opts: &Options) -> Plan {
    let space = SearchSpace::bapipe(cluster, opts);
    let mut report = explore_space(net, cluster, profile, &space, opts);

    // DP baseline (the paper's 1x reference; ResNet-50's winner).
    let dpr = dp::minibatch(profile, cluster, opts.batch_per_device);
    let dp_epoch = if dpr.fits {
        dp::epoch_time(profile, cluster, opts.batch_per_device, opts.samples_per_epoch)
    } else {
        f64::INFINITY
    };
    report.dp_considered = true;
    report.dp_fits = dpr.fits;
    report.dp_minibatch_time = dpr.minibatch_time;
    report.dp_epoch_time = dp_epoch;

    let best = report.best_evaluation().cloned();
    match best {
        Some(ev) => {
            let (mb, ep, partition) = match ev.outcome {
                Outcome::Evaluated { minibatch_time, epoch_time, partition, .. } => {
                    (minibatch_time, epoch_time, partition)
                }
                _ => unreachable!("best_evaluation only returns Evaluated entries"),
            };
            if opts.consider_dp && dp_epoch < ep {
                return dp_plan(profile, opts, dpr.minibatch_time, dp_epoch, cluster.len(), report);
            }
            let cand = ev.candidate;
            let (_, prof_view) =
                space::permuted_view(cluster, profile, &space.device_orders[cand.perm]);
            let stage_memory =
                plan_memory(&prof_view, cand.kind, &partition, cand.micro, cand.m);
            Plan {
                choice: Choice::Pipeline {
                    kind: cand.kind,
                    m: cand.m,
                    micro: cand.micro,
                    partition,
                },
                device_order: space.device_orders[cand.perm].clone(),
                minibatch_time: mb,
                epoch_time: ep,
                dp_epoch_time: dp_epoch,
                speedup_over_dp: dp_epoch / ep,
                stage_memory,
                report,
            }
        }
        None => dp_plan(profile, opts, dpr.minibatch_time, dp_epoch, cluster.len(), report),
    }
}

/// Build the data-parallel fallback plan (pipeline lost or infeasible).
fn dp_plan(
    profile: &Profile,
    opts: &Options,
    dp_minibatch: f64,
    dp_epoch: f64,
    n_devices: usize,
    report: ExplorationReport,
) -> Plan {
    let mm = MemoryModel::data_parallel();
    let stage_memory = vec![dp_memory_bytes(profile, &mm, opts.batch_per_device)];
    Plan {
        choice: Choice::DataParallel,
        device_order: (0..n_devices).collect(),
        minibatch_time: dp_minibatch,
        epoch_time: dp_epoch,
        dp_epoch_time: dp_epoch,
        speedup_over_dp: 1.0,
        stage_memory,
        report,
    }
}

/// GPipe baseline as a [`SearchSpace`] restriction: fill-drain schedule,
/// **BaPipe's partition** (the paper gives GPipe our partitions since it
/// has no balancer), best feasible M. Returns `(epoch_time, m)`.
pub fn plan_gpipe(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
) -> Option<(f64, usize)> {
    let space = SearchSpace::restricted(ScheduleKind::GPipe, cluster, opts);
    let report = explore_space(net, cluster, profile, &space, opts);
    report.best_evaluation().map(|ev| match &ev.outcome {
        Outcome::Evaluated { epoch_time, .. } => (*epoch_time, ev.candidate.m),
        _ => unreachable!("best_evaluation only returns Evaluated entries"),
    })
}

/// PipeDream baseline: inter-batch 1F1B with weight stashing, its own
/// DP-style partitioner (compute+comm, no memory term), per-device batch
/// halved until the stash fits (the candidate batches come from
/// [`SearchSpace::pipedream_batches`]). Returns `(epoch_time, batch)`.
pub fn plan_pipedream(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
) -> Option<(f64, f64)> {
    let cuts = net.legal_cuts();
    for &b in &SearchSpace::pipedream_batches(opts.batch_per_device) {
        let comm = |stage: usize, cut_layer: usize| -> f64 {
            let bytes = profile.cut_bytes(cut_layer) as f64 * b;
            // The partition DP only charges communication on cuts that
            // have a downstream stage (`stage + 1 < n`), so `stage` is a
            // real link index — on heterogeneous chains each boundary
            // must price its *own* link, not a clamped one.
            cluster.link(stage).xfer_time(bytes) * 2.0
        };
        let part =
            crate::partition::interlayer::dp_optimal(profile, cluster, &cuts, b, Some(&comm))
                .ok()?;
        if fits(profile, cluster, ScheduleKind::PipeDream, &part, b, 1) {
            let spec = build_spec(profile, cluster, &part, ScheduleKind::PipeDream, b, 1);
            let n_mb = (opts.samples_per_epoch as f64 / b).ceil() as usize;
            return Some((epoch_time(&spec, n_mb), b));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    fn opts(b: f64) -> Options {
        Options { batch_per_device: b, samples_per_epoch: 8192, ..Default::default() }
    }

    #[test]
    fn atomic_min_is_monotone() {
        let cell = AtomicU64::new(f64::INFINITY.to_bits());
        atomic_min_f64(&cell, 3.5);
        atomic_min_f64(&cell, 7.0);
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 3.5);
        atomic_min_f64(&cell, 1.25);
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 1.25);
    }

    #[test]
    fn pruning_never_changes_the_plan() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let exhaustive = explore(&net, &cl, &prof, &Options { prune: false, ..opts(32.0) });
        let pruned = explore(&net, &cl, &prof, &Options { prune: true, ..opts(32.0) });
        assert_eq!(exhaustive.choice, pruned.choice);
        assert_eq!(exhaustive.epoch_time, pruned.epoch_time);
        assert_eq!(exhaustive.report.pruned_count, 0);
        assert!(pruned.report.simulated_count <= exhaustive.report.simulated_count);
    }

    #[test]
    fn cache_shares_partitions_across_kinds() {
        // The balance seed (passes 1–3) is kind-independent: with two
        // eligible kinds per cluster, every second candidate's seed is a
        // cache hit — one hit per M value at minimum.
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let o = opts(32.0);
        let plan = explore(&net, &cl, &prof, &o);
        assert!(
            plan.report.cache_hits >= o.m_candidates.len() - 1,
            "expected cache sharing, got {} hits",
            plan.report.cache_hits
        );
    }

    #[test]
    fn gpipe_restriction_matches_seed_loop() {
        // The SearchSpace restriction must agree with evaluating the
        // GPipe kind by hand over the M grid.
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let o = opts(32.0);
        let (ep, m) = plan_gpipe(&net, &cl, &prof, &o).unwrap();
        let mut best: Option<(f64, usize)> = None;
        for &cand_m in &o.m_candidates {
            if let Some((_, e, _)) =
                evaluate_pipeline(&net, &cl, &prof, ScheduleKind::GPipe, cand_m, &o)
            {
                if best.map(|(b, _)| e < b).unwrap_or(true) {
                    best = Some((e, cand_m));
                }
            }
        }
        let (seed_ep, seed_m) = best.unwrap();
        assert_eq!(ep, seed_ep);
        assert_eq!(m, seed_m);
    }
}
