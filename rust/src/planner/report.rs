//! The planner's typed result model — candidates' outcomes, the full
//! exploration report, and the selected plan — plus lossless JSON
//! serialization for machine-readable `plan.json` artifacts.
//!
//! Serialization goes through the in-repo `util::json` value model (the
//! offline crate set has no serde/serde_json; `Cargo.toml` documents the
//! substitution). `Plan::to_json` / `Plan::from_json` round-trip every
//! field, including non-finite epoch times (`∞` ⇔ JSON `null`).

use super::space::Candidate;
use crate::partition::Partition;
use crate::schedule::ScheduleKind;
use crate::util::json::{obj, Json};

/// The selected parallelization.
#[derive(Debug, Clone, PartialEq)]
pub enum Choice {
    /// Pipeline parallelism with the given schedule / micro-batching /
    /// partition.
    Pipeline {
        /// Chosen schedule.
        kind: ScheduleKind,
        /// Micro-batches per mini-batch.
        m: usize,
        /// Micro-batch size (samples).
        micro: f64,
        /// Activation recomputation on (stages stash boundary inputs and
        /// re-run forward during backward).
        recompute: bool,
        /// The balanced partition.
        partition: Partition,
    },
    /// Data parallelism won (e.g. ResNet-50 on PCIe V100s).
    DataParallel,
}

/// What happened to one candidate.
#[derive(Debug, Clone, PartialEq)]
pub enum Outcome {
    /// Discrete-event simulated.
    Evaluated {
        /// Simulated time per (global) mini-batch, seconds.
        minibatch_time: f64,
        /// Simulated epoch time, seconds.
        epoch_time: f64,
        /// The analytical lower bound that was checked first.
        lower_bound: f64,
        /// The balanced partition used.
        partition: Partition,
        /// Simulated per-device peak memory, bytes: the DES in-flight
        /// high-water mark priced through the same
        /// [`crate::partition::memfit::StageBytes`] the memory fine-tune
        /// used, so it never exceeds the worst-case feasibility figure.
        /// Empty in artifacts emitted before peak tracking existed.
        peak_memory: Vec<u64>,
    },
    /// Skipped: the analytical lower bound already exceeded the
    /// incumbent's simulated epoch time.
    Pruned {
        /// The bound that justified skipping, seconds.
        lower_bound: f64,
    },
    /// Not evaluable (micro-batching, partition or memory infeasibility).
    Infeasible {
        /// Human-readable reason.
        reason: String,
    },
    /// Not evaluated: the anytime `--eval-budget` cap was hit first. The
    /// candidate kept its analytical lower bound so later runs can tell
    /// whether it could have mattered.
    Skipped {
        /// The analytical lower bound computed in phase A, seconds.
        lower_bound: f64,
    },
}

/// One candidate with its outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct Evaluation {
    /// The search-space point.
    pub candidate: Candidate,
    /// How it fared.
    pub outcome: Outcome,
}

/// One non-dominated point on the (epoch time × peak memory) trade-off
/// front ([`ExplorationReport::pareto_front`], kept in
/// [`Plan::pareto_front`] under `--pareto`).
#[derive(Debug, Clone, PartialEq)]
pub struct ParetoPoint {
    /// The search-space point this plan came from.
    pub candidate: Candidate,
    /// Simulated time per (global) mini-batch, seconds.
    pub minibatch_time: f64,
    /// Simulated epoch time, seconds.
    pub epoch_time: f64,
    /// Worst device's simulated peak memory, bytes.
    pub peak_memory: u64,
    /// The balanced partition used.
    pub partition: Partition,
}

/// Everything the exploration did, as data (the seed explorer's
/// `Vec<String>` log is derived from this via
/// [`ExplorationReport::log_lines`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ExplorationReport {
    /// Workload description (e.g. `VGG-16 @224`).
    pub model: String,
    /// Cluster description (e.g. `4x V100`).
    pub cluster: String,
    /// Per-device batch size `B`.
    pub batch_per_device: f64,
    /// Samples per epoch used for epoch-time conversion.
    pub samples_per_epoch: usize,
    /// Worker threads used in the DES phase.
    pub jobs: usize,
    /// BaPipe kinds excluded by cluster eligibility.
    pub ineligible: Vec<ScheduleKind>,
    /// Search-space notes (e.g. a device-order search that was skipped
    /// or truncated) — anything the enumeration dropped is recorded here.
    pub notes: Vec<String>,
    /// Per-device-order provenance when the neighbourhood search
    /// discovered the order set (one line per `perm` index: which seed or
    /// restart found it, climb length, bottleneck score). Empty for
    /// enumerated or identity-only spaces.
    pub order_provenance: Vec<String>,
    /// Every candidate in enumeration order with its outcome.
    pub evaluations: Vec<Evaluation>,
    /// Candidates that ran the discrete-event simulator.
    pub simulated_count: usize,
    /// Candidates skipped by branch-and-bound.
    pub pruned_count: usize,
    /// Partition computations answered by the memoizing cache.
    pub cache_hits: usize,
    /// Whether the data-parallel baseline was computed (false for
    /// restricted baseline spaces such as GPipe's).
    pub dp_considered: bool,
    /// Whether DP fits device memory.
    pub dp_fits: bool,
    /// DP mini-batch time, seconds.
    pub dp_minibatch_time: f64,
    /// DP epoch time, seconds (`∞` when DP does not fit).
    pub dp_epoch_time: f64,
}

impl ExplorationReport {
    /// The winning evaluation: minimum simulated epoch time, ties going
    /// to the earliest candidate in enumeration order — exactly the seed
    /// explorer's sequential first-strictly-better rule, and independent
    /// of DES execution order.
    pub fn best_evaluation(&self) -> Option<&Evaluation> {
        let mut best: Option<(&Evaluation, f64)> = None;
        for ev in &self.evaluations {
            if let Outcome::Evaluated { epoch_time, .. } = ev.outcome {
                if best.map(|(_, b)| epoch_time < b).unwrap_or(true) {
                    best = Some((ev, epoch_time));
                }
            }
        }
        best.map(|(ev, _)| ev)
    }

    /// The non-dominated set over every simulated candidate on
    /// (epoch time, worst-device simulated peak memory): no returned
    /// point has another simulated candidate that is at least as fast
    /// *and* at least as small with one of the two strictly better.
    /// Exactly coincident points keep the earliest candidate in
    /// enumeration order — the same tie rule as [`Self::best_evaluation`]
    /// — so the front is independent of DES thread interleaving. Sorted
    /// fastest-first (peak memory strictly decreasing along the front).
    /// Candidates without peak data (pre-peak-tracking artifacts) are
    /// skipped.
    pub fn pareto_front(&self) -> Vec<ParetoPoint> {
        let pts: Vec<ParetoPoint> = self
            .evaluations
            .iter()
            .filter_map(|ev| match &ev.outcome {
                Outcome::Evaluated {
                    minibatch_time,
                    epoch_time,
                    partition,
                    peak_memory,
                    ..
                } if !peak_memory.is_empty() => Some(ParetoPoint {
                    candidate: ev.candidate.clone(),
                    minibatch_time: *minibatch_time,
                    epoch_time: *epoch_time,
                    peak_memory: peak_memory.iter().copied().max().unwrap_or(0),
                    partition: partition.clone(),
                }),
                _ => None,
            })
            .collect();
        let mut front: Vec<ParetoPoint> = Vec::new();
        'points: for (i, p) in pts.iter().enumerate() {
            for (j, q) in pts.iter().enumerate() {
                let no_worse = q.epoch_time <= p.epoch_time && q.peak_memory <= p.peak_memory;
                let strictly =
                    q.epoch_time < p.epoch_time || q.peak_memory < p.peak_memory;
                let coincident =
                    q.epoch_time == p.epoch_time && q.peak_memory == p.peak_memory;
                if (no_worse && strictly) || (coincident && j < i) {
                    continue 'points;
                }
            }
            front.push(p.clone());
        }
        front.sort_by(|a, b| {
            a.epoch_time
                .total_cmp(&b.epoch_time)
                .then(a.peak_memory.cmp(&b.peak_memory))
        });
        front
    }

    /// Human-readable exploration log in the seed explorer's line format
    /// (one line per ineligible kind, per candidate, and for the DP
    /// baseline).
    pub fn log_lines(&self) -> Vec<String> {
        let mut lines = Vec::with_capacity(
            self.evaluations.len()
                + self.ineligible.len()
                + self.notes.len()
                + self.order_provenance.len()
                + 1,
        );
        lines.extend(self.notes.iter().cloned());
        lines.extend(self.order_provenance.iter().cloned());
        for kind in &self.ineligible {
            lines.push(format!("{}: ineligible on {}", kind.label(), self.cluster));
        }
        for ev in &self.evaluations {
            let c = &ev.candidate;
            let rc = if c.recompute { "+RC" } else { "" };
            let order = if c.perm > 0 { format!(" [order {}]", c.perm) } else { String::new() };
            lines.push(match &ev.outcome {
                Outcome::Evaluated { epoch_time, .. } => {
                    format!("{}{rc} M={}{}: epoch {:.1}s", c.kind.label(), c.m, order, epoch_time)
                }
                Outcome::Pruned { lower_bound } => format!(
                    "{}{rc} M={}{}: pruned (lower bound {:.1}s)",
                    c.kind.label(),
                    c.m,
                    order,
                    lower_bound
                ),
                Outcome::Infeasible { .. } => {
                    format!("{}{rc} M={}{}: infeasible", c.kind.label(), c.m, order)
                }
                Outcome::Skipped { lower_bound } => format!(
                    "{}{rc} M={}{}: skipped (eval budget, lower bound {:.1}s)",
                    c.kind.label(),
                    c.m,
                    order,
                    lower_bound
                ),
            });
        }
        if self.dp_considered {
            lines.push(format!(
                "DP B={}: epoch {:.1}s{}",
                self.batch_per_device,
                self.dp_epoch_time,
                if self.dp_fits { "" } else { " (out of memory)" }
            ));
        }
        lines
    }

    /// Serialize to the `plan.json` report object.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", Json::from(self.model.clone())),
            ("cluster", Json::from(self.cluster.clone())),
            ("batch_per_device", Json::Num(self.batch_per_device)),
            ("samples_per_epoch", Json::from(self.samples_per_epoch)),
            ("jobs", Json::from(self.jobs)),
            (
                "ineligible",
                Json::Arr(self.ineligible.iter().map(|k| Json::from(k.label())).collect()),
            ),
            (
                "notes",
                Json::Arr(self.notes.iter().map(|n| Json::from(n.clone())).collect()),
            ),
            (
                "order_provenance",
                Json::Arr(
                    self.order_provenance.iter().map(|n| Json::from(n.clone())).collect(),
                ),
            ),
            (
                "evaluations",
                Json::Arr(self.evaluations.iter().map(evaluation_to_json).collect()),
            ),
            ("simulated_count", Json::from(self.simulated_count)),
            ("pruned_count", Json::from(self.pruned_count)),
            ("cache_hits", Json::from(self.cache_hits)),
            ("dp_considered", Json::from(self.dp_considered)),
            ("dp_fits", Json::from(self.dp_fits)),
            ("dp_minibatch_time", num_or_null(self.dp_minibatch_time)),
            ("dp_epoch_time", num_or_null(self.dp_epoch_time)),
        ])
    }

    /// Inverse of [`ExplorationReport::to_json`].
    pub fn from_json(j: &Json) -> crate::Result<ExplorationReport> {
        let evaluations = j
            .req_arr("evaluations")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .iter()
            .map(evaluation_from_json)
            .collect::<crate::Result<Vec<_>>>()?;
        let ineligible = j
            .req_arr("ineligible")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .iter()
            .map(kind_from_json)
            .collect::<crate::Result<Vec<_>>>()?;
        let notes = j
            .req_arr("notes")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .iter()
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("bad note entry"))
            })
            .collect::<crate::Result<Vec<_>>>()?;
        // Lenient: plan.json artifacts emitted before the device-order
        // search existed have no `order_provenance` key.
        let order_provenance = match j.get("order_provenance") {
            None => Vec::new(),
            Some(v) => v
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("`order_provenance` is not an array"))?
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow::anyhow!("bad order_provenance entry"))
                })
                .collect::<crate::Result<Vec<_>>>()?,
        };
        Ok(ExplorationReport {
            model: req_str(j, "model")?,
            cluster: req_str(j, "cluster")?,
            batch_per_device: req_f64(j, "batch_per_device")?,
            samples_per_epoch: req_usize(j, "samples_per_epoch")?,
            jobs: req_usize(j, "jobs")?,
            ineligible,
            notes,
            order_provenance,
            evaluations,
            simulated_count: req_usize(j, "simulated_count")?,
            pruned_count: req_usize(j, "pruned_count")?,
            cache_hits: req_usize(j, "cache_hits")?,
            dp_considered: req_bool(j, "dp_considered")?,
            dp_fits: req_bool(j, "dp_fits")?,
            dp_minibatch_time: req_f64(j, "dp_minibatch_time")?,
            dp_epoch_time: req_f64(j, "dp_epoch_time")?,
        })
    }
}

/// A fully evaluated plan — what the seed explorer returned, plus the
/// typed report and the winning device ordering.
#[derive(Debug, Clone)]
pub struct Plan {
    /// What BaPipe chose.
    pub choice: Choice,
    /// Device ordering along the pipeline chain (identity unless
    /// permutation search found a better heterogeneous layout).
    pub device_order: Vec<usize>,
    /// Time per (global) mini-batch, seconds.
    pub minibatch_time: f64,
    /// Epoch time, seconds.
    pub epoch_time: f64,
    /// Epoch time of the DP baseline (`∞` if DP does not fit memory).
    pub dp_epoch_time: f64,
    /// Speedup over the DP baseline.
    pub speedup_over_dp: f64,
    /// Per-stage memory (bytes); one entry (whole net) for DP.
    pub stage_memory: Vec<u64>,
    /// The (epoch time × simulated peak memory) Pareto front over every
    /// simulated candidate ([`ExplorationReport::pareto_front`]).
    /// Populated under [`super::Options::pareto`]; empty otherwise and in
    /// plan.json artifacts from before memory-aware planning.
    pub pareto_front: Vec<ParetoPoint>,
    /// The full exploration record.
    pub report: ExplorationReport,
}

impl Plan {
    /// One-paragraph human-readable summary (the seed explorer's
    /// `report()`, extended with search statistics).
    pub fn summary(&self) -> String {
        let head = match &self.choice {
            Choice::Pipeline { kind, m, micro, recompute, partition } => format!(
                "BaPipe plan: {}{} with M={m} (micro-batch {micro}), partition {}",
                kind.label(),
                if *recompute { "+RC" } else { "" },
                partition.describe()
            ),
            Choice::DataParallel => {
                "BaPipe plan: data parallelism (pipeline cannot beat DP here)".to_string()
            }
        };
        let order = if self.device_order.windows(2).all(|w| w[0] + 1 == w[1]) {
            String::new()
        } else {
            format!("\n  device order: {:?}", self.device_order)
        };
        let front = if self.pareto_front.is_empty() {
            String::new()
        } else {
            let lo = self.pareto_front.last().expect("non-empty front");
            let hi = &self.pareto_front[0];
            format!(
                "\n  pareto front: {} plans, epoch {:.1}s–{:.1}s, peak {}–{}",
                self.pareto_front.len(),
                hi.epoch_time,
                lo.epoch_time,
                crate::util::fmt_bytes(lo.peak_memory),
                crate::util::fmt_bytes(hi.peak_memory),
            )
        };
        let skipped = self
            .report
            .evaluations
            .iter()
            .filter(|e| matches!(e.outcome, Outcome::Skipped { .. }))
            .count();
        let budget = if skipped > 0 { format!(", {skipped} budget-skipped") } else { String::new() };
        format!(
            "{head}\n  mini-batch {:.4}s, epoch {:.1}s, {:.2}x over DP\n  stage memory: [{}]\n  \
             search: {} simulated, {} pruned, {} infeasible{budget}, {} cache hits (jobs {}){front}{order}",
            self.minibatch_time,
            self.epoch_time,
            self.speedup_over_dp,
            self.stage_memory.iter().map(|&b| crate::util::fmt_bytes(b)).collect::<Vec<_>>().join(", "),
            self.report.simulated_count,
            self.report.pruned_count,
            self.report.evaluations.len()
                - self.report.simulated_count
                - self.report.pruned_count
                - skipped,
            self.report.cache_hits,
            self.report.jobs,
        )
    }

    /// Serialize the whole plan (choice + report) as a `plan.json`
    /// document.
    pub fn to_json(&self) -> Json {
        let choice = match &self.choice {
            Choice::Pipeline { kind, m, micro, recompute, partition } => {
                let mut pairs = vec![
                    ("type", Json::from("pipeline")),
                    ("kind", Json::from(kind.label())),
                    ("m", Json::from(*m)),
                    ("micro", Json::Num(*micro)),
                ];
                // Only emitted when on: default plans keep the pre-recompute
                // key set.
                if *recompute {
                    pairs.push(("recompute", Json::Bool(true)));
                }
                pairs.push(("partition", partition_to_json(partition)));
                obj(pairs)
            }
            Choice::DataParallel => obj(vec![("type", Json::from("data-parallel"))]),
        };
        let mut pairs = vec![
            ("format", Json::from("bapipe-plan-v1")),
            ("choice", choice),
            (
                "device_order",
                Json::Arr(self.device_order.iter().map(|&d| Json::from(d)).collect()),
            ),
            ("minibatch_time", num_or_null(self.minibatch_time)),
            ("epoch_time", num_or_null(self.epoch_time)),
            ("dp_epoch_time", num_or_null(self.dp_epoch_time)),
            ("speedup_over_dp", num_or_null(self.speedup_over_dp)),
            (
                "stage_memory",
                Json::Arr(self.stage_memory.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
        ];
        // Emitted only when --pareto populated it: default documents stay
        // byte-identical to pre-pareto artifacts.
        if !self.pareto_front.is_empty() {
            pairs.push((
                "pareto_front",
                Json::Arr(self.pareto_front.iter().map(pareto_point_to_json).collect()),
            ));
        }
        pairs.push(("report", self.report.to_json()));
        obj(pairs)
    }

    /// Serialize to pretty-printed `plan.json` text and verify the
    /// document round-trips (parse back, compare choice and epoch)
    /// before handing it out — the single implementation behind the CLI
    /// `--emit` flag and the examples.
    pub fn emit_json(&self) -> crate::Result<String> {
        let text = self.to_json().to_string_pretty();
        let back = Plan::from_json(&Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?)?;
        anyhow::ensure!(
            back.choice == self.choice
                && back.epoch_time == self.epoch_time
                && back.pareto_front == self.pareto_front
                && back.report == self.report,
            "plan.json round-trip mismatch"
        );
        Ok(text)
    }

    /// Inverse of [`Plan::to_json`]; validates structure and rejects
    /// unknown formats.
    pub fn from_json(j: &Json) -> crate::Result<Plan> {
        let format = req_str(j, "format")?;
        anyhow::ensure!(format == "bapipe-plan-v1", "unknown plan format `{format}`");
        let cj = j.req("choice").map_err(|e| anyhow::anyhow!("{e}"))?;
        let choice = match req_str(cj, "type")?.as_str() {
            "pipeline" => Choice::Pipeline {
                kind: kind_from_json(cj.req("kind").map_err(|e| anyhow::anyhow!("{e}"))?)?,
                m: req_usize(cj, "m")?,
                micro: req_f64(cj, "micro")?,
                // Lenient: absent in pre-recompute artifacts.
                recompute: cj.get("recompute").and_then(Json::as_bool).unwrap_or(false),
                partition: partition_from_json(
                    cj.req("partition").map_err(|e| anyhow::anyhow!("{e}"))?,
                )?,
            },
            "data-parallel" => Choice::DataParallel,
            other => anyhow::bail!("unknown choice type `{other}`"),
        };
        let device_order = j
            .req_arr("device_order")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad device_order entry")))
            .collect::<crate::Result<Vec<_>>>()?;
        let stage_memory = j
            .req_arr("stage_memory")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .iter()
            .map(|v| {
                v.as_i64()
                    .and_then(|x| u64::try_from(x).ok())
                    .ok_or_else(|| anyhow::anyhow!("bad stage_memory entry"))
            })
            .collect::<crate::Result<Vec<_>>>()?;
        // Lenient: plan.json artifacts emitted before memory-aware
        // planning have no `pareto_front` key.
        let pareto_front = match j.get("pareto_front") {
            None => Vec::new(),
            Some(v) => v
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("`pareto_front` is not an array"))?
                .iter()
                .map(pareto_point_from_json)
                .collect::<crate::Result<Vec<_>>>()?,
        };
        Ok(Plan {
            choice,
            device_order,
            minibatch_time: req_f64(j, "minibatch_time")?,
            epoch_time: req_f64(j, "epoch_time")?,
            dp_epoch_time: req_f64(j, "dp_epoch_time")?,
            speedup_over_dp: req_f64(j, "speedup_over_dp")?,
            stage_memory,
            pareto_front,
            report: ExplorationReport::from_json(
                j.req("report").map_err(|e| anyhow::anyhow!("{e}"))?,
            )?,
        })
    }
}

// ---------------------------------------------------------------- helpers
// (shared with the plan-cache serialization in `super::cache`)

/// Non-finite floats (∞ when DP is out of memory) become JSON `null`.
pub(crate) fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

pub(crate) fn req_str(j: &Json, key: &str) -> crate::Result<String> {
    Ok(j.req_str(key).map_err(|e| anyhow::anyhow!("{e}"))?.to_string())
}

pub(crate) fn req_usize(j: &Json, key: &str) -> crate::Result<usize> {
    j.req_usize(key).map_err(|e| anyhow::anyhow!("{e}"))
}

fn req_bool(j: &Json, key: &str) -> crate::Result<bool> {
    j.req(key)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .as_bool()
        .ok_or_else(|| anyhow::anyhow!("field `{key}` is not a bool"))
}

/// f64 field where JSON `null` encodes `∞`.
pub(crate) fn req_f64(j: &Json, key: &str) -> crate::Result<f64> {
    match j.get(key) {
        None => anyhow::bail!("missing field `{key}`"),
        Some(Json::Null) => Ok(f64::INFINITY),
        Some(v) => v.as_f64().ok_or_else(|| anyhow::anyhow!("field `{key}` is not a number")),
    }
}

fn kind_from_json(j: &Json) -> crate::Result<ScheduleKind> {
    let label = j.as_str().ok_or_else(|| anyhow::anyhow!("schedule kind must be a string"))?;
    ScheduleKind::from_label(label)
        .ok_or_else(|| anyhow::anyhow!("unknown schedule kind `{label}`"))
}

pub(crate) fn partition_to_json(p: &Partition) -> Json {
    obj(vec![("bounds", Json::Arr(p.bounds.iter().map(|&b| Json::from(b)).collect()))])
}

pub(crate) fn partition_from_json(j: &Json) -> crate::Result<Partition> {
    let bounds = j
        .req_arr("bounds")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad partition bound")))
        .collect::<crate::Result<Vec<_>>>()?;
    anyhow::ensure!(bounds.len() >= 2, "partition needs at least two bounds");
    anyhow::ensure!(bounds[0] == 0, "partition must start at layer 0");
    anyhow::ensure!(
        bounds.windows(2).all(|w| w[0] < w[1]),
        "partition bounds must be strictly increasing"
    );
    let n_layers = *bounds.last().unwrap();
    Ok(Partition::new(bounds, n_layers))
}

fn evaluation_to_json(ev: &Evaluation) -> Json {
    let c = &ev.candidate;
    let mut pairs = vec![
        ("kind", Json::from(c.kind.label())),
        ("m", Json::from(c.m)),
        ("micro", Json::Num(c.micro)),
        ("perm", Json::from(c.perm)),
    ];
    // Emitted only when set: default-off documents stay byte-identical to
    // pre-recompute artifacts.
    if c.recompute {
        pairs.push(("recompute", Json::Bool(true)));
    }
    match &ev.outcome {
        Outcome::Evaluated { minibatch_time, epoch_time, lower_bound, partition, peak_memory } => {
            pairs.push(("status", Json::from("evaluated")));
            pairs.push(("minibatch_time", Json::Num(*minibatch_time)));
            pairs.push(("epoch_time", Json::Num(*epoch_time)));
            pairs.push(("lower_bound", Json::Num(*lower_bound)));
            pairs.push(("partition", partition_to_json(partition)));
            if !peak_memory.is_empty() {
                pairs.push((
                    "peak_memory",
                    Json::Arr(peak_memory.iter().map(|&b| Json::Num(b as f64)).collect()),
                ));
            }
        }
        Outcome::Pruned { lower_bound } => {
            pairs.push(("status", Json::from("pruned")));
            pairs.push(("lower_bound", Json::Num(*lower_bound)));
        }
        Outcome::Infeasible { reason } => {
            pairs.push(("status", Json::from("infeasible")));
            pairs.push(("reason", Json::from(reason.clone())));
        }
        Outcome::Skipped { lower_bound } => {
            pairs.push(("status", Json::from("skipped")));
            pairs.push(("lower_bound", Json::Num(*lower_bound)));
        }
    }
    obj(pairs)
}

/// u64-byte array field that may be absent (pre-peak-tracking artifacts).
fn opt_bytes_arr(j: &Json, key: &str) -> crate::Result<Vec<u64>> {
    match j.get(key) {
        None => Ok(Vec::new()),
        Some(v) => v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("`{key}` is not an array"))?
            .iter()
            .map(|v| {
                v.as_i64()
                    .and_then(|x| u64::try_from(x).ok())
                    .ok_or_else(|| anyhow::anyhow!("bad `{key}` entry"))
            })
            .collect(),
    }
}

fn candidate_from_json(j: &Json) -> crate::Result<Candidate> {
    Ok(Candidate {
        kind: kind_from_json(j.req("kind").map_err(|e| anyhow::anyhow!("{e}"))?)?,
        m: req_usize(j, "m")?,
        micro: req_f64(j, "micro")?,
        perm: req_usize(j, "perm")?,
        // Lenient: absent in artifacts from before the recompute axis.
        recompute: j.get("recompute").and_then(|v| v.as_bool()).unwrap_or(false),
    })
}

fn evaluation_from_json(j: &Json) -> crate::Result<Evaluation> {
    let candidate = candidate_from_json(j)?;
    let outcome = match req_str(j, "status")?.as_str() {
        "evaluated" => Outcome::Evaluated {
            minibatch_time: req_f64(j, "minibatch_time")?,
            epoch_time: req_f64(j, "epoch_time")?,
            lower_bound: req_f64(j, "lower_bound")?,
            partition: partition_from_json(
                j.req("partition").map_err(|e| anyhow::anyhow!("{e}"))?,
            )?,
            peak_memory: opt_bytes_arr(j, "peak_memory")?,
        },
        "pruned" => Outcome::Pruned { lower_bound: req_f64(j, "lower_bound")? },
        "infeasible" => Outcome::Infeasible { reason: req_str(j, "reason")? },
        "skipped" => Outcome::Skipped { lower_bound: req_f64(j, "lower_bound")? },
        other => anyhow::bail!("unknown evaluation status `{other}`"),
    };
    Ok(Evaluation { candidate, outcome })
}

fn pareto_point_to_json(p: &ParetoPoint) -> Json {
    let c = &p.candidate;
    let mut pairs = vec![
        ("kind", Json::from(c.kind.label())),
        ("m", Json::from(c.m)),
        ("micro", Json::Num(c.micro)),
        ("perm", Json::from(c.perm)),
    ];
    if c.recompute {
        pairs.push(("recompute", Json::Bool(true)));
    }
    pairs.push(("minibatch_time", Json::Num(p.minibatch_time)));
    pairs.push(("epoch_time", Json::Num(p.epoch_time)));
    pairs.push(("peak_memory", Json::Num(p.peak_memory as f64)));
    pairs.push(("partition", partition_to_json(&p.partition)));
    obj(pairs)
}

fn pareto_point_from_json(j: &Json) -> crate::Result<ParetoPoint> {
    Ok(ParetoPoint {
        candidate: candidate_from_json(j)?,
        minibatch_time: req_f64(j, "minibatch_time")?,
        epoch_time: req_f64(j, "epoch_time")?,
        peak_memory: j
            .req("peak_memory")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_i64()
            .and_then(|x| u64::try_from(x).ok())
            .ok_or_else(|| anyhow::anyhow!("bad `peak_memory`"))?,
        partition: partition_from_json(j.req("partition").map_err(|e| anyhow::anyhow!("{e}"))?)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> ExplorationReport {
        ExplorationReport {
            model: "VGG-16".into(),
            cluster: "2x V100".into(),
            batch_per_device: 32.0,
            samples_per_epoch: 8192,
            jobs: 4,
            ineligible: vec![ScheduleKind::OneFOneBAs, ScheduleKind::FbpAs],
            notes: vec!["device-order search: identity only (homogeneous cluster)".into()],
            order_provenance: vec!["order 0 [identity]: bottleneck 1.0000e-3".into()],
            evaluations: vec![
                Evaluation {
                    candidate: Candidate {
                        kind: ScheduleKind::OneFOneBSno,
                        m: 4,
                        micro: 16.0,
                        perm: 0,
                        recompute: false,
                    },
                    outcome: Outcome::Evaluated {
                        minibatch_time: 0.5,
                        epoch_time: 64.0,
                        lower_bound: 60.0,
                        partition: Partition::new(vec![0, 3, 7], 7),
                        peak_memory: vec![3 << 30, 1 << 30],
                    },
                },
                Evaluation {
                    candidate: Candidate {
                        kind: ScheduleKind::OneFOneBSo,
                        m: 8,
                        micro: 8.0,
                        perm: 0,
                        recompute: false,
                    },
                    outcome: Outcome::Pruned { lower_bound: 70.0 },
                },
                Evaluation {
                    candidate: Candidate {
                        kind: ScheduleKind::OneFOneBSo,
                        m: 3,
                        micro: 64.0 / 3.0,
                        perm: 0,
                        recompute: false,
                    },
                    outcome: Outcome::Infeasible { reason: "M=3 does not divide".into() },
                },
            ],
            simulated_count: 1,
            pruned_count: 1,
            cache_hits: 2,
            dp_considered: true,
            dp_fits: false,
            dp_minibatch_time: 1.0,
            dp_epoch_time: f64::INFINITY,
        }
    }

    fn sample_plan() -> Plan {
        Plan {
            choice: Choice::Pipeline {
                kind: ScheduleKind::OneFOneBSno,
                m: 4,
                micro: 16.0,
                recompute: false,
                partition: Partition::new(vec![0, 3, 7], 7),
            },
            device_order: vec![0, 1],
            minibatch_time: 0.5,
            epoch_time: 64.0,
            dp_epoch_time: f64::INFINITY,
            speedup_over_dp: f64::INFINITY,
            stage_memory: vec![1 << 30, 2 << 30],
            pareto_front: Vec::new(),
            report: sample_report(),
        }
    }

    #[test]
    fn plan_round_trips_through_json_with_infinities() {
        let plan = sample_plan();
        for text in [plan.to_json().to_string_pretty(), plan.to_json().to_string_compact()] {
            let parsed = Json::parse(&text).unwrap();
            let back = Plan::from_json(&parsed).unwrap();
            assert_eq!(back.choice, plan.choice);
            assert_eq!(back.device_order, plan.device_order);
            assert_eq!(back.minibatch_time, plan.minibatch_time);
            assert_eq!(back.epoch_time, plan.epoch_time);
            assert!(back.dp_epoch_time.is_infinite());
            assert!(back.speedup_over_dp.is_infinite());
            assert_eq!(back.stage_memory, plan.stage_memory);
            assert_eq!(back.report, plan.report);
        }
    }

    #[test]
    fn data_parallel_choice_round_trips() {
        let mut plan = sample_plan();
        plan.choice = Choice::DataParallel;
        let back = Plan::from_json(&Json::parse(&plan.to_json().to_string_compact()).unwrap())
            .unwrap();
        assert_eq!(back.choice, Choice::DataParallel);
    }

    #[test]
    fn unknown_format_rejected() {
        let mut j = sample_plan().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("format".into(), Json::from("bapipe-plan-v999"));
        }
        assert!(Plan::from_json(&j).is_err());
    }

    #[test]
    fn log_lines_match_seed_format() {
        let lines = sample_report().log_lines();
        assert!(lines.iter().any(|l| l == "1F1B-AS: ineligible on 2x V100"), "{lines:?}");
        assert!(lines.iter().any(|l| l == "1F1B-SNO M=4: epoch 64.0s"), "{lines:?}");
        assert!(lines.iter().any(|l| l.contains("1F1B-SO M=8: pruned")), "{lines:?}");
        assert!(lines.iter().any(|l| l == "1F1B-SO M=3: infeasible"), "{lines:?}");
        assert!(
            lines.iter().any(|l| l == "DP B=32: epoch infs (out of memory)"),
            "{lines:?}"
        );
    }

    #[test]
    fn order_provenance_surfaces_in_log_and_parses_leniently() {
        let r = sample_report();
        assert!(
            r.log_lines().iter().any(|l| l.contains("order 0 [identity]")),
            "per-order provenance must reach the human-readable log"
        );
        // round trip keeps it
        let back = ExplorationReport::from_json(
            &Json::parse(&r.to_json().to_string_compact()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.order_provenance, r.order_provenance);
        // pre-order-search artifacts have no `order_provenance` key and
        // must still load (as empty)
        let mut j = r.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("order_provenance");
        }
        let old = ExplorationReport::from_json(&j).unwrap();
        assert!(old.order_provenance.is_empty());
    }

    fn evaluated(kind: ScheduleKind, m: usize, recompute: bool, epoch: f64, peak: u64) -> Evaluation {
        Evaluation {
            candidate: Candidate { kind, m, micro: 64.0 / m as f64, perm: 0, recompute },
            outcome: Outcome::Evaluated {
                minibatch_time: epoch / 128.0,
                epoch_time: epoch,
                lower_bound: epoch * 0.9,
                partition: Partition::new(vec![0, 3, 7], 7),
                peak_memory: vec![peak, peak / 2],
            },
        }
    }

    #[test]
    fn pareto_front_is_mutually_non_dominated_and_sorted() {
        let mut r = sample_report(); // holds one Evaluated point: (64s, 3 GiB)
        // slower but smaller: must join the front
        r.evaluations.push(evaluated(ScheduleKind::TwoBW, 8, false, 70.0, 1 << 30));
        // slower AND bigger than the 2BW point: dominated
        r.evaluations.push(evaluated(ScheduleKind::GPipe, 8, false, 80.0, 2 << 30));
        // exactly coincident with the first point but later: dropped
        r.evaluations.push(evaluated(ScheduleKind::OneFOneBSo, 16, true, 64.0, 3 << 30));
        let front = r.pareto_front();
        assert_eq!(front.len(), 2, "{front:?}");
        // fastest-first, peak strictly decreasing along the front
        assert_eq!(front[0].epoch_time, 64.0);
        assert_eq!(front[0].candidate.kind, ScheduleKind::OneFOneBSno, "ties keep the earliest");
        assert_eq!(front[0].peak_memory, 3 << 30);
        assert_eq!(front[1].candidate.kind, ScheduleKind::TwoBW);
        assert!(front.windows(2).all(|w| {
            w[0].epoch_time < w[1].epoch_time && w[0].peak_memory > w[1].peak_memory
        }));
        // mutual non-domination, pairwise
        for a in &front {
            for b in &front {
                if a.candidate != b.candidate {
                    assert!(
                        a.epoch_time < b.epoch_time || a.peak_memory < b.peak_memory,
                        "{a:?} dominated by {b:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn recompute_candidates_round_trip_and_stay_silent_when_off() {
        let mut r = sample_report();
        r.evaluations.push(evaluated(ScheduleKind::OneFOneBSno, 8, true, 90.0, 1 << 30));
        let text = r.to_json().to_string_compact();
        assert!(text.contains("\"recompute\""), "on-candidates carry the key");
        let back = ExplorationReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
        assert!(back.evaluations.last().unwrap().candidate.recompute);
        // a report with no recompute candidates never mentions the key
        let plain = sample_report().to_json().to_string_compact();
        assert!(!plain.contains("recompute"));
        // the +RC marker reaches the human-readable log
        assert!(r.log_lines().iter().any(|l| l.starts_with("1F1B-SNO+RC M=8")), "{:?}", r.log_lines());
    }

    #[test]
    fn pareto_front_round_trips_and_old_artifacts_parse_leniently() {
        let mut plan = sample_plan();
        plan.pareto_front = plan.report.pareto_front();
        assert!(!plan.pareto_front.is_empty());
        let text = plan.emit_json().unwrap();
        let back = Plan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.pareto_front, plan.pareto_front);
        // an empty front never emits the key (documents stay byte-compatible)
        assert!(!sample_plan().to_json().to_string_compact().contains("pareto_front"));
        // pre-memory-planning artifact: strip every new key; the document
        // must still load, with empty front / peaks and recompute off
        let mut j = plan.to_json();
        if let Json::Obj(top) = &mut j {
            top.remove("pareto_front");
            if let Some(Json::Obj(rep)) = top.get_mut("report") {
                if let Some(Json::Arr(evs)) = rep.get_mut("evaluations") {
                    for e in evs {
                        if let Json::Obj(eo) = e {
                            eo.remove("peak_memory");
                            eo.remove("recompute");
                        }
                    }
                }
            }
        }
        let old = Plan::from_json(&j).unwrap();
        assert!(old.pareto_front.is_empty());
        for ev in &old.report.evaluations {
            assert!(!ev.candidate.recompute);
            if let Outcome::Evaluated { peak_memory, .. } = &ev.outcome {
                assert!(peak_memory.is_empty());
            }
        }
        assert!(old.report.pareto_front().is_empty(), "no peak data → no front");
    }

    #[test]
    fn skipped_outcome_round_trips_and_stays_out_of_the_front() {
        let mut r = sample_report();
        r.evaluations.push(Evaluation {
            candidate: Candidate {
                kind: ScheduleKind::GPipe,
                m: 16,
                micro: 4.0,
                perm: 0,
                recompute: false,
            },
            outcome: Outcome::Skipped { lower_bound: 55.0 },
        });
        let text = r.to_json().to_string_compact();
        assert!(text.contains("\"skipped\""));
        let back = ExplorationReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
        // a skipped candidate never wins or joins the front, even with a
        // better lower bound than the winner's epoch
        assert_eq!(back.best_evaluation().unwrap().candidate.kind, ScheduleKind::OneFOneBSno);
        assert_eq!(back.pareto_front().len(), 1);
        assert!(
            r.log_lines()
                .iter()
                .any(|l| l == "GPipe M=16: skipped (eval budget, lower bound 55.0s)"),
            "{:?}",
            r.log_lines()
        );
    }

    #[test]
    fn best_evaluation_prefers_earlier_on_ties() {
        let mut r = sample_report();
        r.evaluations.push(Evaluation {
            candidate: Candidate {
                kind: ScheduleKind::OneFOneBSo,
                m: 16,
                micro: 4.0,
                perm: 0,
                recompute: false,
            },
            outcome: Outcome::Evaluated {
                minibatch_time: 0.5,
                epoch_time: 64.0, // ties the first entry
                lower_bound: 60.0,
                partition: Partition::new(vec![0, 2, 7], 7),
                peak_memory: vec![2 << 30],
            },
        });
        let best = r.best_evaluation().unwrap();
        assert_eq!(best.candidate.kind, ScheduleKind::OneFOneBSno);
        assert_eq!(best.candidate.m, 4);
    }
}
