//! Device-order neighbourhood search — the heterogeneous placement axis
//! past the 8-device exhaustive wall.
//!
//! Below 9 devices [`super::space`] enumerates every distinct device-name
//! sequence outright. Above that the factorial space is unsearchable by
//! enumeration (16 devices of two board kinds already hold 12870 distinct
//! layouts), yet placement is exactly where heterogeneous mixes get
//! interesting: PipeDream (arXiv 1806.03377) and DAPPLE (arXiv
//! 2007.01045) both report that *where* the fast devices sit along the
//! chain matters as much as where the cuts go. This module replaces
//! enumeration with a deterministic neighbourhood search:
//!
//! 1. **Seed portfolio** — identity, compute-sorted (fastest-first and
//!    slowest-first), memory-sorted, and a slow-link-aware layout that
//!    parks the two most capable devices around the thinnest link.
//! 2. **Hill-climb** from every seed over swap / adjacent-insert /
//!    segment-reverse moves. Each round scores the whole neighbourhood in
//!    one parallel batch (the probes fan out over `--jobs` through
//!    [`super::parallel`], exactly like phase A's prewarm) and takes the
//!    best strictly-improving move, ties to the earliest move in
//!    generation order — so the climb is independent of the job count.
//! 3. **Seeded multi-restart** ([`crate::util::rng`], fixed seed) while
//!    probe budget remains, so the search escapes a weak portfolio.
//!
//! A **probe** scores one ordering by the phase-A partition machinery:
//! build the permuted view, one [`RangeCost`] prefix-table set for it
//! (as the prewarm does per view), run the inter-layer partition DP, and
//! read the pipeline bottleneck — the max over stages of `F+B` versus the
//! duplex-weighted cut communication. Probes are memoized by device-name
//! sequence (permuting two identical boards changes nothing) and capped
//! by `--order-budget`; usage is reported in the search-space notes so a
//! truncated search is never silent.
//!
//! The discovered set — identity first, then the distinct climb
//! endpoints ranked by score — becomes [`super::space::SearchSpace::device_orders`],
//! and the full exploration (phase A prewarm + DES phase B) evaluates
//! every candidate over it. Identity is always enumerated first, so a
//! non-identity winner has *strictly* beaten the identity layout.
//!
//! Finally, each kept order's provenance line is annotated with a **DES
//! mini-batch time** from one representative schedule, re-simulated
//! through a single incremental [`FamilySim`]: successive orders differ
//! in a handful of stage rows, so most annotations are dirty-row replays
//! rather than cold passes. The annotation is informational — ranking,
//! budget accounting and the kept set itself stay a pure function of the
//! partition-DP bottleneck scores (and of nothing else, so the discovery
//! remains identical across `--jobs` values).

use super::parallel;
use super::space::{permuted_view, MAX_DEVICE_ORDERS};
use super::Options;
use crate::cluster::Cluster;
use crate::model::Network;
use crate::partition::{cut_comm_time, interlayer, stage_costs};
use crate::profile::range::RangeCost;
use crate::profile::Profile;
use crate::schedule::ScheduleKind;
use crate::sim::batch::FamilySim;
use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::{HashMap, HashSet};

/// Default probe budget of the neighbourhood search (`--order-budget`).
pub const ORDER_BUDGET_DEFAULT: usize = 512;

/// Random restarts attempted while budget remains.
const MAX_RESTARTS: usize = 3;

/// Seed of the restart shuffles — fixed, so the discovered set is a pure
/// function of `(net, cluster, profile, opts)`.
const RESTART_SEED: u64 = 0x0BA9_19E5_EED5;

/// How far an element travels in one adjacent-insert move.
const INSERT_SPAN: usize = 3;

/// Longest segment a reverse move flips (length-2 reverses are swaps).
const REVERSE_MAX: usize = 6;

/// Result of [`discover`]: the device orderings the exploration will
/// evaluate, with per-order provenance and search-space notes.
#[derive(Debug, Clone)]
pub struct Discovery {
    /// Distinct orderings, identity first.
    pub orders: Vec<Vec<usize>>,
    /// One line per entry of `orders`: which seed / restart found it, how
    /// many improving moves the climb took, and its bottleneck score.
    pub provenance: Vec<String>,
    /// Search summary (probe usage vs budget, restarts, best-vs-identity
    /// score) — surfaced through the report so nothing is dropped
    /// silently.
    pub notes: Vec<String>,
}

/// Score one ordering: permute the view, build its [`RangeCost`] tables,
/// run the inter-layer partition DP and return the pipeline bottleneck —
/// `max_i (F_i + B_i)` versus the duplex-weighted per-cut communication,
/// whichever is worse. Infeasible views score `+∞`.
fn bottleneck_score(
    cluster: &Cluster,
    profile: &Profile,
    cuts: &[usize],
    micro: f64,
    order: &[usize],
) -> f64 {
    let (cl, prof) = permuted_view(cluster, profile, order);
    let rc = RangeCost::build(&prof);
    let part = match interlayer::dp_optimal_rc(&rc, &cl, cuts, micro, None) {
        Ok(p) => p,
        Err(_) => return f64::INFINITY,
    };
    let costs = stage_costs(&rc, &cl, &part, micro);
    let compute = costs.iter().map(|(f, b)| f + b).fold(0.0, f64::max);
    let duplex = if cl.all_async() { 1.0 } else { 2.0 };
    let comm = (0..part.n_stages().saturating_sub(1))
        .map(|i| duplex * cut_comm_time(&rc, &cl, &part, micro, i))
        .fold(0.0, f64::max);
    compute.max(comm)
}

/// Budgeted, memoizing probe evaluator. Probes are keyed by device-name
/// sequence; fresh keys are scored in one parallel batch per request, in
/// first-appearance order — cache contents, probe counts and therefore
/// the whole search are identical for every `jobs` value.
struct Prober<'a> {
    cluster: &'a Cluster,
    profile: &'a Profile,
    cuts: &'a [usize],
    micro: f64,
    jobs: usize,
    budget: usize,
    probes: usize,
    /// Device index → device-name id ([`Cluster::name_ids`] — the same
    /// equivalence the exhaustive enumeration dedups on).
    ids: Vec<usize>,
    scored: HashMap<Vec<usize>, f64>,
}

impl<'a> Prober<'a> {
    fn new(
        cluster: &'a Cluster,
        profile: &'a Profile,
        cuts: &'a [usize],
        micro: f64,
        jobs: usize,
        budget: usize,
    ) -> Prober<'a> {
        let ids = cluster.name_ids();
        Prober { cluster, profile, cuts, micro, jobs, budget, probes: 0, ids, scored: HashMap::new() }
    }

    /// Canonical key of an ordering: its device-name id sequence.
    fn key(&self, order: &[usize]) -> Vec<usize> {
        order.iter().map(|&i| self.ids[i]).collect()
    }

    fn remaining(&self) -> usize {
        self.budget - self.probes
    }

    /// Score every ordering. Repeats answer from the memo; fresh name
    /// sequences are charged against the budget and evaluated in one
    /// parallel batch. `None` marks an ordering the budget could not
    /// reach.
    fn score_all(&mut self, orders: &[Vec<usize>]) -> Vec<Option<f64>> {
        let mut fresh: Vec<(Vec<usize>, &Vec<usize>)> = Vec::new();
        let mut fresh_keys: HashSet<Vec<usize>> = HashSet::new();
        for o in orders {
            let k = self.key(o);
            if fresh.len() < self.remaining()
                && !self.scored.contains_key(&k)
                && fresh_keys.insert(k.clone())
            {
                fresh.push((k, o));
            }
        }
        let (cluster, profile, cuts, micro) = (self.cluster, self.profile, self.cuts, self.micro);
        let scores = parallel::run_indexed(self.jobs, fresh.len(), |i| {
            bottleneck_score(cluster, profile, cuts, micro, fresh[i].1)
        });
        self.probes += fresh.len();
        for ((k, _), s) in fresh.into_iter().zip(scores) {
            self.scored.insert(k, s);
        }
        orders.iter().map(|o| self.scored.get(&self.key(o)).copied()).collect()
    }
}

/// The deterministic move set around `order`: every pairwise swap, every
/// single-element insert up to [`INSERT_SPAN`] slots away, and every
/// segment reverse of length 3..=[`REVERSE_MAX`]. List order is the climb
/// tie-break, so it is fixed.
fn neighbourhood(order: &[usize]) -> Vec<Vec<usize>> {
    let n = order.len();
    let mut out = Vec::new();
    for i in 0..n {
        for j in i + 1..n {
            let mut o = order.to_vec();
            o.swap(i, j);
            out.push(o);
        }
    }
    for i in 0..n {
        for d in 1..=INSERT_SPAN {
            if i + d < n {
                let mut o = order.to_vec();
                let x = o.remove(i);
                o.insert(i + d, x);
                out.push(o);
            }
            if i >= d {
                let mut o = order.to_vec();
                let x = o.remove(i);
                o.insert(i - d, x);
                out.push(o);
            }
        }
    }
    for i in 0..n {
        for len in 3..=REVERSE_MAX {
            let j = i + len - 1;
            if j >= n {
                break;
            }
            let mut o = order.to_vec();
            o[i..=j].reverse();
            out.push(o);
        }
    }
    out
}

/// Hill-climb from an already-scored `start`: per round, score the whole
/// neighbourhood (one parallel batch) and take the best strictly-improving
/// move, ties to the earliest move. Returns `(endpoint, score, improving
/// moves)`.
fn climb(prober: &mut Prober, start: Vec<usize>, start_score: f64) -> (Vec<usize>, f64, usize) {
    let mut cur = start;
    let mut cur_score = start_score;
    let mut steps = 0usize;
    while prober.remaining() > 0 && cur_score.is_finite() {
        let mut neigh = neighbourhood(&cur);
        let scores = prober.score_all(&neigh);
        let mut best: Option<(f64, usize)> = None;
        for (k, s) in scores.into_iter().enumerate() {
            if let Some(s) = s {
                if s < cur_score && best.map(|(b, _)| s < b).unwrap_or(true) {
                    best = Some((s, k));
                }
            }
        }
        let Some((s, k)) = best else { break };
        cur = neigh.swap_remove(k);
        cur_score = s;
        steps += 1;
    }
    (cur, cur_score, steps)
}

/// The heuristic seed layouts (identity always first). `total[d]` is the
/// whole-network `F+B` time on device `d` at the probe micro-batch — the
/// compute-capability measure the sorts use.
fn portfolio(cluster: &Cluster, profile: &Profile, micro: f64) -> Vec<(&'static str, Vec<usize>)> {
    let n = cluster.len();
    let l = profile.n_layers();
    let total: Vec<f64> = (0..n)
        .map(|d| profile.fwd_time(d, 0, l, micro) + profile.bwd_time(d, 0, l, micro))
        .collect();
    let mut fastest_first: Vec<usize> = (0..n).collect();
    fastest_first.sort_by(|&a, &b| {
        total[a].partial_cmp(&total[b]).unwrap_or(Ordering::Equal).then(a.cmp(&b))
    });
    let mut slowest_first: Vec<usize> = (0..n).collect();
    slowest_first.sort_by(|&a, &b| {
        total[b].partial_cmp(&total[a]).unwrap_or(Ordering::Equal).then(a.cmp(&b))
    });
    let mut mem_first: Vec<usize> = (0..n).collect();
    mem_first.sort_by(|&a, &b| {
        let ka = (cluster.devices[a].mem_capacity, cluster.devices[a].onchip_capacity);
        let kb = (cluster.devices[b].mem_capacity, cluster.devices[b].onchip_capacity);
        kb.cmp(&ka).then(a.cmp(&b))
    });
    let mut seeds = vec![
        ("identity", (0..n).collect()),
        ("compute-descending", fastest_first.clone()),
        ("compute-ascending", slowest_first),
        ("memory-descending", mem_first),
    ];
    if !cluster.links.is_empty() {
        // Park the two most capable devices around the thinnest link: the
        // DP can then shrink that cut's traffic without starving compute.
        let (slot, _) = cluster
            .links
            .iter()
            .enumerate()
            .min_by(|(i, a), (j, b)| {
                a.bandwidth.partial_cmp(&b.bandwidth).unwrap_or(Ordering::Equal).then(i.cmp(j))
            })
            .expect("non-empty links");
        let mut aware = vec![usize::MAX; n];
        aware[slot] = fastest_first[0];
        aware[slot + 1] = fastest_first[1];
        let mut rest = fastest_first[2..].iter().copied();
        for s in aware.iter_mut() {
            if *s == usize::MAX {
                *s = rest.next().expect("n-2 devices fill the n-2 free slots");
            }
        }
        seeds.push(("slow-link-aware", aware));
    }
    seeds
}

/// Run the neighbourhood search and return the discovered order set. The
/// probe micro-batch is the median divisible `M` of the grid (falling
/// back to the per-device batch when none divides) — deterministic, and
/// representative of the schedules phase B will actually simulate.
pub fn discover(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
) -> Discovery {
    discover_seeded(net, cluster, profile, opts, None)
}

/// [`discover`] with an incumbent ordering injected — the elastic
/// replanner's warm start. The incumbent (the surviving devices of the
/// pre-mutation plan, in their old relative order) is scored and
/// hill-climbed *after* the normal search finishes, on a small separate
/// probe allowance, and its entries are appended to the kept set: the
/// unseeded discovery is a strict prefix of the seeded one, so a
/// warm-started search space is a superset of the cold one by
/// construction (the warm plan can never be worse). `incumbent: None` is
/// bit-identical to [`discover`]. An incumbent that is not a permutation
/// of `0..n` is ignored. The appended entries may exceed
/// [`MAX_DEVICE_ORDERS`] by up to two — the cap bounds the *search*, not
/// the warm start.
pub fn discover_seeded(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
    incumbent: Option<&[usize]>,
) -> Discovery {
    let n = cluster.len();
    let global = crate::util::canonical_global_batch(opts.batch_per_device, n);
    let mut ms: Vec<usize> = opts
        .m_candidates
        .iter()
        .copied()
        .filter(|&m| super::eval::divides_global(global, m))
        .collect();
    ms.sort_unstable();
    ms.dedup();
    let micro =
        if ms.is_empty() { opts.batch_per_device } else { global / ms[ms.len() / 2] as f64 };
    let cuts = net.legal_cuts();
    let budget = opts.order_budget.max(1);
    let mut prober = Prober::new(cluster, profile, &cuts, micro, opts.jobs, budget);

    let identity: Vec<usize> = (0..n).collect();
    let identity_key = prober.key(&identity);
    let id_score = prober.score_all(std::slice::from_ref(&identity))[0]
        .expect("budget >= 1 always scores the identity ordering");

    // Score the whole portfolio up front (a handful of probes): even if
    // the first climb eats the rest of the budget, every heuristic seed
    // enters the endpoint set with its true score and can be discovered.
    let seeds = portfolio(cluster, profile, micro);
    let seed_orders: Vec<Vec<usize>> = seeds.iter().map(|(_, o)| o.clone()).collect();
    let seed_scores = prober.score_all(&seed_orders);

    // (score, endpoint, provenance) in discovery order.
    let mut endpoints: Vec<(f64, Vec<usize>, String)> = Vec::new();
    for ((label, seed), s0) in seeds.into_iter().zip(seed_scores) {
        // A seed the budget could not score is skipped, not a stopper: a
        // later seed can still be a free memo hit (e.g. memory-descending
        // collapsing onto compute-descending's name sequence).
        let Some(s0) = s0 else { continue };
        let (order, score, steps) = climb(&mut prober, seed, s0);
        endpoints.push((
            score,
            order,
            format!("seed {label}, {steps} improving moves, bottleneck {score:.4e}"),
        ));
    }
    let mut restarts = 0usize;
    let mut rng = Rng::new(RESTART_SEED);
    while restarts < MAX_RESTARTS && prober.remaining() > 2 * n {
        let mut start = identity.clone();
        rng.shuffle(&mut start);
        restarts += 1;
        let Some(s0) = prober.score_all(std::slice::from_ref(&start))[0] else { break };
        let (order, score, steps) = climb(&mut prober, start, s0);
        endpoints.push((
            score,
            order,
            format!("restart {restarts}, {steps} improving moves, bottleneck {score:.4e}"),
        ));
    }

    // Assemble: identity first (the enumeration tie-break guarantees a
    // non-identity winner strictly beat it), then distinct endpoints by
    // (score, discovery order).
    let mut ranked: Vec<usize> = (0..endpoints.len()).collect();
    ranked.sort_by(|&a, &b| {
        endpoints[a].0.partial_cmp(&endpoints[b].0).unwrap_or(Ordering::Equal).then(a.cmp(&b))
    });
    let mut orders = vec![identity];
    let mut provenance = vec![format!("order 0 [identity]: bottleneck {id_score:.4e}")];
    let mut seen: HashSet<Vec<usize>> = HashSet::new();
    seen.insert(identity_key);
    for i in ranked {
        let (score, order, why) = &endpoints[i];
        if !score.is_finite() || orders.len() >= MAX_DEVICE_ORDERS {
            continue;
        }
        if seen.insert(prober.key(order)) {
            provenance.push(format!("order {} [{why}]", orders.len()));
            orders.push(order.clone());
        }
    }
    // Incumbent warm start: scored and climbed after the normal assembly
    // on a separate probe allowance, entries appended — see
    // [`discover_seeded`]. Appending keeps the unseeded result a prefix.
    let mut incumbent_note: Option<String> = None;
    if let Some(inc) = incumbent {
        let mut sorted = inc.to_vec();
        sorted.sort_unstable();
        if sorted == orders[0] {
            prober.budget = prober.probes + 1 + 2 * n;
            let inc = inc.to_vec();
            if let Some(s0) = prober.score_all(std::slice::from_ref(&inc))[0] {
                let (end, score, steps) = climb(&mut prober, inc.clone(), s0);
                let mut appended = 0usize;
                if seen.insert(prober.key(&inc)) {
                    provenance
                        .push(format!("order {} [incumbent seed, bottleneck {s0:.4e}]", orders.len()));
                    orders.push(inc);
                    appended += 1;
                }
                if score.is_finite() && seen.insert(prober.key(&end)) {
                    provenance.push(format!(
                        "order {} [seed incumbent, {steps} improving moves, bottleneck {score:.4e}]",
                        orders.len()
                    ));
                    orders.push(end);
                    appended += 1;
                }
                incumbent_note = Some(format!(
                    "device-order search: incumbent seed bottleneck {s0:.4e}, climbed {steps} \
                     moves to {score:.4e}, {appended} orders appended"
                ));
            }
        } else {
            incumbent_note =
                Some("device-order search: incumbent seed ignored (not a device permutation)".into());
        }
    }

    // DES provenance annotation: one representative schedule per kept
    // order, re-simulated through a single incremental simulator. The
    // spec builder is the generic [`super::eval::build_spec`] on this
    // pass's own RangeCost tables, so the annotated time is the same
    // mini-batch time phase B would compute for that candidate.
    let des_kind = ScheduleKind::bapipe_candidates()
        .into_iter()
        .find(|k| k.eligible(cluster))
        .unwrap_or(ScheduleKind::GPipe);
    let m_probe = if ms.is_empty() { 1 } else { ms[ms.len() / 2] };
    let mut fam = FamilySim::new();
    let mut annotated = 0usize;
    for (order, line) in orders.iter().zip(provenance.iter_mut()) {
        let (cl, prof) = permuted_view(cluster, profile, order);
        let rc = RangeCost::build(&prof);
        let Ok(part) = interlayer::dp_optimal_rc(&rc, &cl, &cuts, micro, None) else {
            line.push_str(", des skipped (infeasible partition)");
            continue;
        };
        let spec = super::eval::build_spec(&rc, &cl, &part, des_kind, false, micro, m_probe);
        let mb = fam.resimulate(&spec).makespan;
        line.push_str(&format!(", des minibatch {mb:.4e}s"));
        annotated += 1;
    }

    let best = endpoints.iter().map(|e| e.0).fold(id_score, f64::min);
    let mut notes = vec![
        format!(
            "device-order search: {n} devices — neighbourhood search, {} of {} probe budget \
             used, {restarts} restarts, {} orders kept (probe micro-batch {micro})",
            prober.probes,
            budget,
            orders.len()
        ),
        format!("device-order search: best bottleneck {best:.4e} vs identity {id_score:.4e}"),
        format!(
            "device-order search: DES provenance — {annotated} of {} orders re-simulated at \
             {} M={m_probe} ({} incremental replays, {} cold passes)",
            orders.len(),
            des_kind.label(),
            fam.stats.incremental_runs,
            fam.stats.full_runs + fam.stats.fallback_runs
        ),
    ];
    if let Some(line) = incumbent_note {
        notes.push(line);
    }
    Discovery { orders, provenance, notes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    fn opts(budget: usize, jobs: usize) -> Options {
        Options {
            batch_per_device: 8.0,
            consider_dp: false,
            permute_devices: true,
            order_search: true,
            order_budget: budget,
            jobs,
            ..Default::default()
        }
    }

    #[test]
    fn neighbourhood_moves_are_permutations() {
        let order: Vec<usize> = (0..7).collect();
        let moves = neighbourhood(&order);
        assert!(!moves.is_empty());
        for m in &moves {
            assert_ne!(m, &order, "a move must change the layout");
            let mut sorted = m.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, order, "moves must permute, not alter, the device set");
        }
        // the move list is deterministic (it is the climb tie-break)
        assert_eq!(moves, neighbourhood(&order));
    }

    #[test]
    fn portfolio_sorts_match_device_speeds() {
        // gpu_mixed alternates V100 (fast, even slots) and P100 (odd).
        let cl = presets::gpu_mixed_cluster(6);
        let net = zoo::vgg16(224);
        let prof = analytical::profile(&net, &cl);
        let seeds = portfolio(&cl, &prof, 8.0);
        assert_eq!(seeds[0], ("identity", vec![0, 1, 2, 3, 4, 5]));
        let fastest = &seeds.iter().find(|(l, _)| *l == "compute-descending").unwrap().1;
        assert_eq!(fastest, &vec![0, 2, 4, 1, 3, 5], "V100s first, index ties ascending");
        let slowest = &seeds.iter().find(|(l, _)| *l == "compute-ascending").unwrap().1;
        assert_eq!(slowest, &vec![1, 3, 5, 0, 2, 4]);
        // every seed is a permutation
        for (label, s) in &seeds {
            let mut sorted = s.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..6).collect::<Vec<_>>(), "{label}");
        }
    }

    #[test]
    fn discovery_is_identical_across_job_counts() {
        let cl = presets::gpu_mixed_cluster(12);
        let net = zoo::vgg16(224);
        let prof = analytical::profile(&net, &cl);
        let a = discover(&net, &cl, &prof, &opts(120, 1));
        let b = discover(&net, &cl, &prof, &opts(120, 8));
        assert_eq!(a.orders, b.orders, "the discovered set must not depend on --jobs");
        assert_eq!(a.provenance, b.provenance);
        assert_eq!(a.notes, b.notes);
    }

    #[test]
    fn discovery_respects_budget_and_keeps_identity_first() {
        let cl = presets::gpu_mixed_cluster(10);
        let net = zoo::vgg16(224);
        let prof = analytical::profile(&net, &cl);
        let d = discover(&net, &cl, &prof, &opts(1, 1));
        // budget 1 probes only the identity — nothing else can be kept
        assert_eq!(d.orders, vec![(0..10).collect::<Vec<usize>>()]);
        assert!(
            d.notes.iter().any(|n| n.contains("1 of 1 probe budget")),
            "budget usage must be reported: {:?}",
            d.notes
        );

        let d = discover(&net, &cl, &prof, &opts(200, 1));
        assert_eq!(d.orders[0], (0..10).collect::<Vec<usize>>(), "identity is always entry 0");
        assert_eq!(d.orders.len(), d.provenance.len());
        for o in &d.orders {
            let mut sorted = o.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..10).collect::<Vec<_>>(), "orders must be permutations");
        }
        // distinct name sequences only
        let keys: std::collections::BTreeSet<Vec<String>> = d
            .orders
            .iter()
            .map(|o| o.iter().map(|&i| cl.devices[i].name.clone()).collect())
            .collect();
        assert_eq!(keys.len(), d.orders.len(), "discovered orders must be distinct layouts");
    }

    #[test]
    fn provenance_lines_carry_des_minibatch_times() {
        // Every kept order's provenance line (identity included) ends
        // with a DES mini-batch annotation, and the pass reports its
        // incremental-vs-cold split in the notes.
        let cl = presets::gpu_mixed_cluster(10);
        let net = zoo::vgg16(224);
        let prof = analytical::profile(&net, &cl);
        let d = discover(&net, &cl, &prof, &opts(200, 1));
        assert!(d.orders.len() > 1, "need a non-trivial discovered set");
        assert_eq!(d.orders.len(), d.provenance.len());
        for line in &d.provenance {
            assert!(line.contains(", des minibatch "), "missing DES annotation: {line}");
        }
        assert!(
            d.notes.iter().any(|n| n.contains("DES provenance")),
            "DES pass must report itself: {:?}",
            d.notes
        );
    }

    #[test]
    fn seeded_discovery_appends_the_incumbent_after_the_unseeded_prefix() {
        let cl = presets::gpu_mixed_cluster(10);
        let net = zoo::vgg16(224);
        let prof = analytical::profile(&net, &cl);
        let base = discover(&net, &cl, &prof, &opts(120, 1));

        // The incumbent is the swapped-pairs layout — a name sequence the
        // portfolio seeds never produce on an alternating mix.
        let incumbent: Vec<usize> = vec![1, 0, 3, 2, 5, 4, 7, 6, 9, 8];
        let seeded = discover_seeded(&net, &cl, &prof, &opts(120, 1), Some(&incumbent));

        // The unseeded discovery is a strict prefix: warm search spaces
        // are supersets of cold ones by construction.
        assert_eq!(&seeded.orders[..base.orders.len()], &base.orders[..]);
        assert_eq!(&seeded.provenance[..base.provenance.len()], &base.provenance[..]);
        assert!(
            seeded.notes.iter().any(|n| n.contains("incumbent seed")),
            "incumbent phase must report itself: {:?}",
            seeded.notes
        );
        // The incumbent's name sequence is evaluable in the seeded set —
        // either appended, or already present as a kept layout.
        let key = |o: &Vec<usize>| -> Vec<String> {
            o.iter().map(|&i| cl.devices[i].name.clone()).collect()
        };
        assert!(
            seeded.orders.iter().any(|o| key(o) == key(&incumbent)),
            "incumbent layout must be in the discovered set"
        );
        assert_eq!(seeded.orders.len(), seeded.provenance.len());

        // A non-permutation incumbent is ignored, with a note.
        let bad = discover_seeded(&net, &cl, &prof, &opts(120, 1), Some(&[0usize; 10]));
        assert_eq!(bad.orders, base.orders);
        assert!(
            bad.notes.iter().any(|n| n.contains("ignored")),
            "ignored incumbent must be noted: {:?}",
            bad.notes
        );
    }

    #[test]
    fn search_finds_a_better_layout_than_an_alternating_identity() {
        // Alternating fast/slow boards force heavy adjacent layers onto
        // slow devices; any sorted layout drops the bottleneck.
        let cl = presets::gpu_mixed_cluster(12);
        let net = zoo::vgg16(224);
        let prof = analytical::profile(&net, &cl);
        let d = discover(&net, &cl, &prof, &opts(200, 2));
        assert!(d.orders.len() > 1, "search must discover non-identity layouts");
        let cuts = net.legal_cuts();
        // discover probes at the median divisible M of the default grid:
        // global 96, M = 8 → micro 12 — score at the same point here.
        let micro = 12.0;
        let id_score =
            bottleneck_score(&cl, &prof, &cuts, micro, &(0..12).collect::<Vec<usize>>());
        let best_score = d
            .orders
            .iter()
            .map(|o| bottleneck_score(&cl, &prof, &cuts, micro, o))
            .fold(f64::INFINITY, f64::min);
        assert!(
            best_score < id_score,
            "discovered bottleneck {best_score} must beat identity {id_score}"
        );
    }
}
