//! Migration scheduling — *when* to move the bytes that
//! [`super::diff::migration`] priced.
//!
//! PR 8 answered "what does this plan switch cost" in bytes; this module
//! places the actual transfers on the physical chain links while the
//! incumbent pipeline drains its last mini-batch. The draining DES
//! already knows when each boundary channel goes quiet
//! ([`SimArena::link_free_times`]), so every per-link migration slot
//! starts *behind* the last activation/error message on that link —
//! migration traffic contends with pipeline traffic instead of being
//! pretended free.
//!
//! Whether the transfer may start before the drain completes is a
//! *weight-versioning* question (PipeDream, arXiv 1806.03377): under
//! [`ScheduleKind::TwoBW`] (PipeDream-2BW, arXiv 2006.09503) every stage
//! holds a double-buffered shadow version that stays immutable for the
//! whole draining mini-batch, so copying it mid-drain is sound — the
//! receiver starts one mini-batch stale, exactly the staleness 2BW
//! already tolerates ([`MigrationSchedule::stale_weight_mb`]). Any other
//! schedule finalizes weights only at drain end, so the scheduler falls
//! back to **drain-and-copy**: every slot starts at the drain makespan.
//! Either way the stall is what the replanner's mid-epoch amortization
//! ([`super::elastic`]) charges the challenger, and the overlapped stall
//! is never worse than the fallback (each slot starts no later than the
//! makespan, so it ends no later than `makespan + slowest transfer` —
//! the bench floor in `BENCH_planner.json`'s `migration_overlap` line).

use crate::cluster::Cluster;
use crate::partition::memfit::{movable_state_bytes, MemoryModel};
use crate::profile::range::CostModel;
use crate::schedule::ScheduleKind;
use crate::sim::engine::{simulate_fast, SimArena, SimSpec};

/// One aggregated state-transfer slot on a physical link.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSlot {
    /// Physical chain link index (`cluster.links[link]`).
    pub link: usize,
    /// Direction: `true` = toward higher chain slots.
    pub forward: bool,
    /// Slot start time (s, drain timeline: 0 = drain begins).
    pub start: f64,
    /// Slot end time (s).
    pub end: f64,
    /// State bytes carried.
    pub bytes: u64,
}

/// A placed migration: per-link slots plus the derived stall — what the
/// switch costs *in time* on top of the bytes [`super::diff::migration`]
/// already reported.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationSchedule {
    /// Did the transfers overlap the drain (2BW shadow versions), or is
    /// this a drain-and-copy fallback?
    pub overlapped: bool,
    /// Makespan of the draining mini-batch (s; 0 when no draining
    /// schedule was available — pure copy).
    pub drain_makespan: f64,
    /// Aggregated transfer slots, chain order, forward before backward.
    pub slots: Vec<LinkSlot>,
    /// Per-link time the *pipeline's own* traffic occupies the link
    /// (max of both directions, clamped to the makespan) — the `#`
    /// region of [`Self::render_timeline`].
    pub link_busy_until: Vec<f64>,
    /// When the last transfer lands (s; ≥ `drain_makespan`).
    pub done_at: f64,
    /// Training stall beyond the natural drain: `done_at − makespan`.
    pub stall: f64,
    /// What the stall would be under drain-and-copy (slowest aggregated
    /// transfer, all starting at the makespan). `stall <= drain_stall`
    /// always holds.
    pub drain_stall: f64,
    /// Micro-batches the migrated shadow weights are stale by on arrival
    /// (= the draining mini-batch's M under 2BW overlap, 0 otherwise).
    pub stale_weight_mb: usize,
    /// Total state bytes moved (equals the
    /// [`super::diff::MigrationReport`] total for the same maps).
    pub bytes: u64,
    /// Human-readable decisions: overlap vs fallback and why, restore
    /// routing, degenerate cases.
    pub provenance: Vec<String>,
}

/// Place a plan switch's state transfers onto `cluster`'s chain links.
///
/// * `drain` — the incumbent's spec plus its per-stage physical hosts
///   (`hosts[stage] = chain slot`, `len = spec.n()`), both expressed on
///   `cluster`. Pass `None` when the incumbent cannot drain (a device
///   loss took one of its hosts, or there is no incumbent spec): the
///   schedule degrades to a pure copy with `drain_makespan = 0`.
/// * `assign_old` / `assign_new` — per-layer physical chain slots before
///   and after the switch, in `cluster`'s namespace (the elastic
///   replanner maps the old plan through the mutation lineage;
///   `assign_old[l] = None` marks a layer whose former host is gone — a
///   restore). A layer moves iff the slots differ, the same rule
///   [`super::diff::migration`] prices.
///
/// Transfers between slots `a` and `b` occupy every link on the chain
/// path between them, in the direction of travel; restores ride the
/// destination's fastest adjacent link inward. Per (link, direction) the
/// bytes aggregate into one slot costing
/// [`crate::cluster::Link::xfer_time`] of the total.
pub fn schedule_migration<C: CostModel>(
    costs: &C,
    mm: &MemoryModel,
    cluster: &Cluster,
    drain: Option<(&SimSpec, &[usize])>,
    assign_old: &[Option<usize>],
    assign_new: &[Option<usize>],
) -> MigrationSchedule {
    assert_eq!(assign_old.len(), assign_new.len(), "maps must cover the same layer count");
    let nl = cluster.links.len();
    let mut provenance = Vec::new();

    // --- drain timeline: makespan + per-link/direction clear times -----
    let mut f_free = vec![0.0f64; nl];
    let mut b_free = vec![0.0f64; nl];
    let mut makespan = 0.0f64;
    let mut overlapped = false;
    match drain {
        Some((spec, hosts)) => {
            assert_eq!(hosts.len(), spec.n(), "one physical host per draining stage");
            let mut arena = SimArena::new();
            makespan = simulate_fast(spec, &mut arena).makespan;
            let (fc, bc) = arena.link_free_times();
            for b in 0..spec.n().saturating_sub(1) {
                let (lo, hi) = (hosts[b].min(hosts[b + 1]), hosts[b].max(hosts[b + 1]));
                for link in lo..hi {
                    // every transfer arrival is <= makespan (consumed by
                    // an op that ends by then); the clamp is belt and
                    // braces so the overlap <= drain floor is structural
                    f_free[link] = f_free[link].max(fc[b].min(makespan));
                    b_free[link] = b_free[link].max(bc[b].min(makespan));
                }
            }
            if matches!(spec.kind, ScheduleKind::TwoBW) {
                overlapped = true;
                provenance.push(format!(
                    "overlap: {} holds an immutable shadow weight version through the drain — \
                     transfers start behind the last activation message per link",
                    spec.kind.label()
                ));
            } else {
                provenance.push(format!(
                    "drain-and-copy: {} finalizes weights only at drain end — transfers start \
                     at the {makespan:.6}s makespan",
                    spec.kind.label()
                ));
            }
        }
        None => provenance.push(
            "no draining schedule (host lost or no incumbent spec): pure copy from t=0"
                .to_string(),
        ),
    }

    // --- route moved layers onto (link, direction) byte totals ---------
    let mut fwd_bytes = vec![0u64; nl];
    let mut bwd_bytes = vec![0u64; nl];
    let mut moved_layers = 0usize;
    let mut total_bytes = 0u64;
    let mut restores = 0usize;
    for l in 0..assign_old.len() {
        let dst = match assign_new[l] {
            Some(d) => d,
            None => continue, // layer unplaced in the new plan
        };
        match assign_old[l] {
            Some(src) if src == dst => {}
            Some(src) => {
                let bytes = movable_state_bytes(costs, mm, l, l + 1);
                moved_layers += 1;
                total_bytes += bytes;
                let (lo, hi) = (src.min(dst), src.max(dst));
                let dir = if src < dst { &mut fwd_bytes } else { &mut bwd_bytes };
                for link in lo..hi {
                    dir[link] += bytes;
                }
            }
            None => {
                // former host gone: state restored from a checkpoint peer
                // over the destination's fastest adjacent link, inward
                let bytes = movable_state_bytes(costs, mm, l, l + 1);
                moved_layers += 1;
                total_bytes += bytes;
                restores += 1;
                if nl == 0 {
                    continue; // single-device cluster: restore is local
                }
                let left = dst.checked_sub(1); // link dst-1 carries it forward into dst
                let right = if dst < nl { Some(dst) } else { None }; // link dst, backward
                let pick_left = match (left, right) {
                    (Some(a), Some(b)) => {
                        cluster.links[a].bandwidth >= cluster.links[b].bandwidth
                    }
                    (Some(_), None) => true,
                    (None, _) => false,
                };
                if pick_left {
                    fwd_bytes[left.unwrap()] += bytes;
                } else {
                    bwd_bytes[right.unwrap()] += bytes;
                }
            }
        }
    }
    if restores > 0 {
        provenance.push(format!(
            "{restores} layer(s) restored onto new hosts via their fastest adjacent link{}",
            if nl == 0 { " (single device: local restore, no transfer)" } else { "" }
        ));
    }

    // --- place one aggregated slot per (link, direction) ---------------
    let mut slots = Vec::new();
    let mut drain_stall = 0.0f64;
    for link in 0..nl {
        for (forward, bytes, free) in
            [(true, fwd_bytes[link], f_free[link]), (false, bwd_bytes[link], b_free[link])]
        {
            if bytes == 0 {
                continue;
            }
            let t = cluster.links[link].xfer_time(bytes as f64);
            drain_stall = drain_stall.max(t);
            let start = if overlapped { free } else { makespan };
            slots.push(LinkSlot { link, forward, start, end: start + t, bytes });
        }
    }
    let done_at = slots.iter().fold(makespan, |acc, s| acc.max(s.end));
    let stall = (done_at - makespan).max(0.0);
    if slots.is_empty() {
        provenance.push("no state moves: migration is free".to_string());
    } else {
        provenance.push(format!(
            "{moved_layers} layer(s), {} over {} link slot(s): stall {:.6}s beyond the drain \
             (drain-and-copy would stall {:.6}s)",
            crate::util::fmt_bytes(total_bytes),
            slots.len(),
            stall,
            drain_stall
        ));
    }
    let stale_weight_mb = match (overlapped, drain) {
        (true, Some((spec, _))) if !slots.is_empty() => spec.m,
        _ => 0,
    };
    if stale_weight_mb > 0 {
        provenance.push(format!(
            "migrated shadow weights arrive {stale_weight_mb} micro-batches stale — within \
             2BW's one-mini-batch staleness bound"
        ));
    }
    MigrationSchedule {
        overlapped,
        drain_makespan: makespan,
        slots,
        link_busy_until: f_free.iter().zip(&b_free).map(|(f, b)| f.max(*b)).collect(),
        done_at,
        stall,
        drain_stall,
        stale_weight_mb,
        bytes: total_bytes,
        provenance,
    }
}

impl MigrationSchedule {
    /// One-line summary for reports: mode, stall vs fallback, bytes.
    pub fn render(&self) -> String {
        format!(
            "migration schedule: {} — {} moved, stall {:.3}ms (drain-and-copy {:.3}ms), \
             done at {:.3}ms of a {:.3}ms drain",
            if self.overlapped { "overlapped (2BW)" } else { "drain-and-copy" },
            crate::util::fmt_bytes(self.bytes),
            self.stall * 1e3,
            self.drain_stall * 1e3,
            self.done_at * 1e3,
            self.drain_makespan * 1e3,
        )
    }

    /// ASCII per-link occupancy timeline (`#` pipeline traffic, `M`
    /// migration slots) via [`crate::sim::timeline::render_link_slots`].
    pub fn render_timeline(&self, width: usize) -> String {
        let tuples: Vec<(usize, f64, f64)> =
            self.slots.iter().map(|s| (s.link, s.start, s.end)).collect();
        crate::sim::timeline::render_link_slots(
            self.link_busy_until.len(),
            &self.link_busy_until,
            &tuples,
            self.done_at.max(self.drain_makespan),
            width,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::partition::balanced_partition;
    use crate::planner::eval::build_spec;
    use crate::profile::analytical;

    /// Shared fixture: VGG-16 on 4x V100, a balanced 2BW partition, and
    /// the boundary-shift assignment pair (stage 1's first layer moves to
    /// stage 0's device).
    fn fixture(
        kind: ScheduleKind,
    ) -> (crate::profile::Profile, Cluster, SimSpec, Vec<usize>, Vec<Option<usize>>, Vec<Option<usize>>)
    {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let plan = balanced_partition(&net, &cl, &prof, kind, 8.0, 16).unwrap();
        let part = &plan.partition;
        let spec = build_spec(&prof, &cl, part, kind, false, 8.0, 16);
        let hosts: Vec<usize> = (0..part.n_stages()).collect();
        let old: Vec<Option<usize>> =
            (0..net.len()).map(|l| Some(part.stage_of(l))).collect();
        let mut new = old.clone();
        let moved = part.bounds[1]; // first layer of stage 1 -> device 0
        new[moved] = Some(0);
        (prof, cl, spec, hosts, old, new)
    }

    #[test]
    fn overlap_stall_never_exceeds_drain_and_prices_like_diff() {
        let (prof, cl, spec, hosts, old, new) = fixture(ScheduleKind::TwoBW);
        let mm = MemoryModel::default();
        let s = schedule_migration(&prof, &mm, &cl, Some((&spec, &hosts)), &old, &new);
        assert!(s.overlapped);
        assert!(s.drain_makespan > 0.0);
        assert_eq!(s.slots.len(), 1, "{:?}", s.slots);
        // overlapped slots start inside the drain, never after it
        for slot in &s.slots {
            assert!(slot.start <= s.drain_makespan + 1e-12, "{slot:?}");
            assert!(slot.end > slot.start);
        }
        assert!(s.stall <= s.drain_stall + 1e-12, "{} > {}", s.stall, s.drain_stall);
        assert!((s.done_at - s.drain_makespan - s.stall).abs() < 1e-12);
        assert_eq!(s.stale_weight_mb, spec.m);
        // byte total agrees with the diff-level pricing of the same maps
        let report = super::super::diff::migration(&prof, &mm, &old, &new);
        assert_eq!(s.bytes, report.bytes);
        assert!(s.render().contains("overlapped (2BW)"), "{}", s.render());
    }

    #[test]
    fn non_2bw_falls_back_to_drain_and_copy() {
        let (prof, cl, spec, hosts, old, new) = fixture(ScheduleKind::OneFOneBSo);
        let mm = MemoryModel::default();
        let s = schedule_migration(&prof, &mm, &cl, Some((&spec, &hosts)), &old, &new);
        assert!(!s.overlapped);
        assert_eq!(s.stale_weight_mb, 0);
        // every slot waits for the full drain, so the stall is exactly
        // the drain-and-copy stall
        for slot in &s.slots {
            assert_eq!(slot.start, s.drain_makespan);
        }
        assert!((s.stall - s.drain_stall).abs() < 1e-15);
        assert!(
            s.provenance.iter().any(|n| n.contains("drain-and-copy")),
            "{:?}",
            s.provenance
        );
    }

    #[test]
    fn restore_rides_fastest_adjacent_link_inward() {
        let (prof, cl, _spec, _hosts, old, new) = fixture(ScheduleKind::TwoBW);
        let mm = MemoryModel::default();
        // every layer of the old stage 2 lost its host; new plan keeps the
        // same slots, so only the restores transfer
        let lost: Vec<Option<usize>> =
            old.iter().map(|a| if *a == Some(2) { None } else { *a }).collect();
        let s = schedule_migration(&prof, &mm, &cl, None, &lost, &old);
        assert!(!s.overlapped);
        assert_eq!(s.drain_makespan, 0.0, "no drain info: pure copy");
        assert_eq!(s.slots.len(), 1);
        // homogeneous links: ties break toward the left neighbour (link 1
        // carries the restore forward into slot 2)
        assert_eq!((s.slots[0].link, s.slots[0].forward), (1, true));
        let expected: u64 = (0..old.len())
            .filter(|&l| old[l] == Some(2))
            .map(|l| movable_state_bytes(&prof, &mm, l, l + 1))
            .sum();
        assert_eq!(s.bytes, expected);
        assert_eq!(s.stall, s.drain_stall);
        assert!(s.provenance.iter().any(|n| n.contains("restored")), "{:?}", s.provenance);
    }

    #[test]
    fn identical_assignment_is_free() {
        let (prof, cl, spec, hosts, old, _new) = fixture(ScheduleKind::TwoBW);
        let mm = MemoryModel::default();
        let s = schedule_migration(&prof, &mm, &cl, Some((&spec, &hosts)), &old, &old);
        assert!(s.slots.is_empty());
        assert_eq!((s.bytes, s.stall, s.drain_stall), (0, 0.0, 0.0));
        assert_eq!(s.done_at, s.drain_makespan);
        assert_eq!(s.stale_weight_mb, 0, "nothing moved, nothing stale");
        assert!(s.provenance.iter().any(|n| n.contains("free")), "{:?}", s.provenance);
    }

    #[test]
    fn multi_hop_move_occupies_every_link_on_the_path() {
        let (prof, cl, spec, hosts, _old, _new) = fixture(ScheduleKind::TwoBW);
        let mm = MemoryModel::default();
        // one layer moves from slot 3 all the way to slot 0: links 0..3
        // all carry it, in the backward direction
        let n_layers = zoo::vgg16(224).len();
        let old: Vec<Option<usize>> =
            (0..n_layers).map(|l| Some(if l == 0 { 3 } else { 1 })).collect();
        let new: Vec<Option<usize>> =
            (0..n_layers).map(|l| Some(if l == 0 { 0 } else { 1 })).collect();
        let s = schedule_migration(&prof, &mm, &cl, Some((&spec, &hosts)), &old, &new);
        let links: Vec<(usize, bool)> = s.slots.iter().map(|x| (x.link, x.forward)).collect();
        assert_eq!(links, vec![(0, false), (1, false), (2, false)]);
        let per_layer = movable_state_bytes(&prof, &mm, 0, 1);
        assert!(s.slots.iter().all(|x| x.bytes == per_layer), "{:?}", s.slots);
        // timeline renders one row per physical link with M slots
        let t = s.render_timeline(40);
        assert_eq!(t.lines().count(), 3);
        assert!(t.contains('M'), "{t}");
    }
}
