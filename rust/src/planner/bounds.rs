//! Closed-form lower bounds on simulated schedules — the analytical side
//! of the planner's branch-and-bound.
//!
//! For every schedule kind the DES executes, each stage `i` must (a) wait
//! for micro-batch 0's forward to traverse stages `0..i`, (b) perform all
//! `M` forwards and `M` backwards itself, and (c) after its final
//! backward, let the error traverse stages `i-1..0` backwards. With
//! per-stage costs `f_j` / `b_j` this yields the critical-path bound
//!
//! ```text
//! makespan ≥ max_i ( Σ_{j<i} f_j  +  M·(f_i + b_i)  +  Σ_{j<i} b_j )
//! ```
//!
//! which ignores all communication (transfers only add time) and holds
//! for FBP-AS as well, whose slots cost `f + b` regardless of occupancy
//! (Table 1). On the Tables 1–2 uniform setting the bound is exactly
//! `(M+N−1)(F+B)` — the overlapped-communication mini-batch time — so it
//! is tight precisely where the paper's model is.
//!
//! A candidate whose *lower bound* on epoch time already exceeds the
//! incumbent's *simulated* epoch time provably cannot win, and the DES
//! run is skipped.

use crate::schedule::ScheduleKind;
use crate::sim::engine::SimSpec;

/// Provable lower bound on `simulate(spec).makespan` (communication-free
/// critical path; see module docs).
pub fn makespan_lower_bound(spec: &SimSpec) -> f64 {
    let n = spec.n();
    let m = spec.m as f64;
    let mut prefix_fwd = 0.0;
    let mut prefix_bwd = 0.0;
    let mut best = 0.0f64;
    for i in 0..n {
        let fb = spec.fwd[i] + spec.bwd[i];
        best = best.max(prefix_fwd + m * fb + prefix_bwd);
        prefix_fwd += spec.fwd[i];
        prefix_bwd += spec.bwd[i];
    }
    best
}

/// Provable lower bound on `epoch_time(spec, n_minibatches)`.
///
/// Intra-batch schedules drain between mini-batches, so the epoch is an
/// exact multiple of the makespan. PipeDream pipelines across
/// mini-batches: its steady period is at least the bottleneck stage's
/// `f + b`.
pub fn epoch_lower_bound(spec: &SimSpec, n_minibatches: usize) -> f64 {
    let one = makespan_lower_bound(spec);
    match spec.kind {
        ScheduleKind::PipeDream => {
            let max_fb = spec
                .fwd
                .iter()
                .zip(&spec.bwd)
                .map(|(f, b)| f + b)
                .fold(0.0, f64::max);
            one + max_fb * spec.m as f64 * n_minibatches.saturating_sub(1) as f64
        }
        _ => one * n_minibatches as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ExecMode;
    use crate::sim::engine::{epoch_time, simulate};
    use crate::util::prop::{check, ensure, Config};
    use crate::util::rng::Rng;

    #[test]
    fn tight_on_uniform_overlapped_setting() {
        // Table 1: 1F1B-AS mini-batch time is (M+N-1)(F+B); the bound
        // must equal it when communication is free.
        let spec = SimSpec::uniform(ScheduleKind::OneFOneBAs, 4, 16, 1.0, 2.0, 0.0, ExecMode::Async);
        let lb = makespan_lower_bound(&spec);
        assert!((lb - (16.0 + 4.0 - 1.0) * 3.0).abs() < 1e-12);
        let des = simulate(&spec).makespan;
        assert!((des - lb).abs() < 1e-9, "DES {des} vs bound {lb}");
    }

    #[test]
    fn bound_never_exceeds_des_property() {
        // Randomized heterogeneous specs across every kind: the bound
        // must stay below the DES makespan, and the epoch bound below the
        // DES epoch.
        let kinds = ScheduleKind::all();
        check(
            &Config { cases: 80, seed: 0xB0_07D5, max_size: 24 },
            |g| {
                let n = g.usize_in(1, 5);
                let m = g.usize_in(1, 24);
                let kind = kinds[g.usize_in(0, kinds.len())];
                let exec = match kind.required_exec() {
                    Some(e) => e,
                    None => {
                        if g.usize_in(0, 2) == 0 {
                            ExecMode::Sync
                        } else {
                            ExecMode::Async
                        }
                    }
                };
                let mut spec = SimSpec::uniform(kind, n, m, 1.0, 1.0, 0.0, exec);
                let seed = g.usize_in(0, 1 << 30) as u64;
                let mut r = Rng::new(seed);
                for i in 0..n {
                    spec.fwd[i] = 0.05 + r.f64() * 3.0;
                    spec.bwd[i] = 0.05 + r.f64() * 3.0;
                }
                for i in 0..n.saturating_sub(1) {
                    spec.fwd_xfer[i] = r.f64() * 1.5;
                    spec.bwd_xfer[i] = r.f64() * 1.5;
                }
                spec
            },
            |spec| {
                let des = simulate(spec).makespan;
                let lb = makespan_lower_bound(spec);
                ensure(
                    lb <= des * (1.0 + 1e-9),
                    format!("bound {lb} exceeds DES {des} for {:?} n={} m={}", spec.kind, spec.n(), spec.m),
                )?;
                let ep = epoch_time(spec, 5);
                let elb = epoch_lower_bound(spec, 5);
                ensure(
                    elb <= ep * (1.0 + 1e-9),
                    format!("epoch bound {elb} exceeds DES epoch {ep} for {:?}", spec.kind),
                )
            },
        );
    }

    #[test]
    fn single_stage_bound_is_exact() {
        let spec = SimSpec::uniform(ScheduleKind::OneFOneBSno, 1, 4, 1.0, 2.0, 0.0, ExecMode::Sync);
        assert!((makespan_lower_bound(&spec) - 12.0).abs() < 1e-12);
    }
}
