//! Scoped-thread work distribution for the DES evaluation phase.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(0..n)` across up to `jobs` scoped worker threads and return the
/// results in index order. `jobs <= 1` runs inline with no threads (and
/// therefore fully deterministic side-effect ordering). Workers pull
/// indices from a shared counter, so long tasks do not stall short ones.
pub(crate) fn run_indexed<T, F>(jobs: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    run_indexed_with(jobs, n, || (), |_: &mut (), i| f(i))
}

/// [`run_indexed`] with per-worker scratch state: every worker thread
/// builds one `S` via `init` and threads it through all the indices it
/// claims. This is how each DES evaluator worker owns a reusable
/// [`crate::sim::engine::SimArena`] — results must not depend on which
/// worker (and therefore which scratch) served an index.
pub(crate) fn run_indexed_with<S, T, I, F>(jobs: usize, n: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let jobs = jobs.max(1).min(n);
    if jobs == 1 {
        let mut state = init();
        return (0..n).map(|i| f(&mut state, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let value = f(&mut state, i);
                    *slots[i].lock().expect("result slot poisoned") = Some(value);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("result slot poisoned")
                .expect("every index was claimed by a worker")
        })
        .collect()
}

/// A pool of per-worker scratch states that *outlives* individual
/// [`run_indexed_with`]-style invocations: worker `w` always draws slot
/// `w`, so the grid pass and every adaptive-M round reuse the same
/// scratch (e.g. one [`crate::sim::batch::FamilySim`] arena per worker)
/// instead of reallocating it per round. The work distribution, index
/// ordering and determinism contract are exactly those of
/// [`run_indexed_with`] — results must not depend on which slot served an
/// index.
pub(crate) struct ScratchPool<S> {
    slots: Vec<Mutex<S>>,
}

impl<S: Send> ScratchPool<S> {
    /// Empty pool; slots are created lazily by [`ScratchPool::run`].
    pub(crate) fn new() -> ScratchPool<S> {
        ScratchPool { slots: Vec::new() }
    }

    /// Visit every pooled scratch mutably — maintenance between rounds
    /// (e.g. releasing arena capacity when the next family is smaller).
    pub(crate) fn for_each_mut(&mut self, mut f: impl FnMut(&mut S)) {
        for slot in &mut self.slots {
            f(slot.get_mut().expect("scratch slot poisoned"));
        }
    }

    /// [`run_indexed_with`], but the per-worker scratch comes from (and
    /// returns to) the pool. `init` only runs when the pool must grow to
    /// cover `min(jobs, n)` workers.
    pub(crate) fn run<T, I, F>(&mut self, jobs: usize, n: usize, mut init: I, f: F) -> Vec<T>
    where
        T: Send,
        I: FnMut() -> S,
        F: Fn(&mut S, usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let jobs = jobs.max(1).min(n);
        while self.slots.len() < jobs {
            self.slots.push(Mutex::new(init()));
        }
        if jobs == 1 {
            let state = self.slots[0].get_mut().expect("scratch slot poisoned");
            return (0..n).map(|i| f(state, i)).collect();
        }
        let next = AtomicUsize::new(0);
        let out: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for slot in self.slots.iter().take(jobs) {
                let (next, out, f) = (&next, &out, &f);
                scope.spawn(move || {
                    let mut state = slot.lock().expect("scratch slot poisoned");
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let value = f(&mut state, i);
                        *out[i].lock().expect("result slot poisoned") = Some(value);
                    }
                });
            }
        });
        out.into_iter()
            .map(|slot| {
                slot.into_inner()
                    .expect("result slot poisoned")
                    .expect("every index was claimed by a worker")
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_keep_index_order() {
        for jobs in [1usize, 2, 8, 64] {
            let out = run_indexed(jobs, 37, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
        }
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(run_indexed(4, 0, |i| i), Vec::<usize>::new());
        assert_eq!(run_indexed(4, 1, |i| i + 10), vec![10]);
    }

    #[test]
    fn per_worker_state_is_threaded_through() {
        for jobs in [1usize, 3, 8] {
            let out = run_indexed_with(
                jobs,
                25,
                || 0usize,
                |served, i| {
                    *served += 1; // per-worker scratch accumulates
                    assert!(*served >= 1);
                    i * 2
                },
            );
            assert_eq!(out, (0..25).map(|i| i * 2).collect::<Vec<_>>(), "jobs={jobs}");
        }
    }

    #[test]
    fn every_index_runs_exactly_once() {
        use std::sync::atomic::AtomicU32;
        let counts: Vec<AtomicU32> = (0..100).map(|_| AtomicU32::new(0)).collect();
        run_indexed(7, 100, |i| counts[i].fetch_add(1, Ordering::Relaxed));
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn pool_matches_run_indexed_with_and_keeps_order() {
        for jobs in [1usize, 3, 8, 64] {
            let mut pool: ScratchPool<usize> = ScratchPool::new();
            let out = pool.run(jobs, 37, || 0usize, |_, i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
            assert_eq!(pool.run(jobs, 0, || 0usize, |_, i| i), Vec::<usize>::new());
        }
    }

    #[test]
    fn pool_scratch_survives_across_invocations() {
        // The whole point of the pool: worker scratch accumulates across
        // rounds instead of being rebuilt per invocation.
        let mut pool: ScratchPool<usize> = ScratchPool::new();
        let mut inits = 0usize;
        for round in 0..5 {
            let out = pool.run(
                3,
                20,
                || {
                    inits += 1;
                    0usize
                },
                |served, i| {
                    *served += 1;
                    i + round
                },
            );
            assert_eq!(out, (0..20).map(|i| i + round).collect::<Vec<_>>(), "round={round}");
        }
        assert_eq!(inits, 3, "slots are created once, on the first round");
        let mut total = 0usize;
        pool.for_each_mut(|served| total += *served);
        assert_eq!(total, 100, "every index of every round hit a pooled slot");
    }
}
