//! Search-space enumeration: which `(schedule kind, micro-batch count,
//! device ordering)` triples the planner considers.
//!
//! The space is data, not control flow: baselines restrict it (GPipe is
//! the same machinery over a single kind) instead of reimplementing the
//! exploration loop, and heterogeneous FPGA mixes can widen it with
//! distinct device orderings along the pipeline chain. The device-order
//! axis splits at the 8-device wall: up to 8 devices every distinct
//! device-name sequence is enumerated outright (byte-for-byte the
//! original behaviour); above that, `--order-search` runs the
//! [`super::orders`] neighbourhood search instead of the old hard skip.

use super::orders;
use super::Options;
use crate::cluster::Cluster;
use crate::model::Network;
use crate::profile::Profile;
use crate::schedule::ScheduleKind;
use std::collections::BTreeSet;

/// Most device orderings explored on a heterogeneous cluster (distinct
/// name-sequences of a 6-board mix already stay below this).
pub const MAX_DEVICE_ORDERS: usize = 64;

/// One point of the search space.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    /// Schedule to run.
    pub kind: ScheduleKind,
    /// Micro-batches per mini-batch.
    pub m: usize,
    /// Micro-batch size in samples (global mini-batch / `m`).
    pub micro: f64,
    /// Index into [`SearchSpace::device_orders`].
    pub perm: usize,
    /// Activation recomputation: stash boundary inputs only, regenerate
    /// intermediates during backward (extra forward FLOPs priced into
    /// the DES spec). Orthogonal to `kind`.
    pub recompute: bool,
}

/// The enumerable exploration space.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Kinds to evaluate, in canonical (tie-break) order.
    pub kinds: Vec<ScheduleKind>,
    /// BaPipe kinds excluded by cluster eligibility (reported, not
    /// enumerated — e.g. async schedules on a GPU cluster).
    pub ineligible: Vec<ScheduleKind>,
    /// Micro-batch-count grid.
    pub m_grid: Vec<usize>,
    /// Per-device batch size `B`; the global mini-batch is `B × N`.
    pub batch_per_device: f64,
    /// Device orderings to try; entry 0 is always the identity.
    pub device_orders: Vec<Vec<usize>>,
    /// Search-space construction notes (e.g. a requested permutation
    /// search that was skipped or capped) — surfaced in the report so a
    /// dropped search dimension is never silent.
    pub notes: Vec<String>,
    /// Per-entry provenance of `device_orders` when the neighbourhood
    /// search produced them (which seed/restart, climb length, score);
    /// empty for enumerated or identity-only spaces.
    pub order_provenance: Vec<String>,
    /// Recompute settings to enumerate per `(kind, m)` point: `[false]`
    /// normally, `[false, true]` when `--recompute` widens the space
    /// with activation-checkpointing variants.
    pub recompute_options: Vec<bool>,
}

impl SearchSpace {
    /// The paper's Fig.-3 space: every eligible BaPipe schedule kind ×
    /// the M grid (× device orderings when `opts.permute_devices` — past
    /// 8 devices the `net`/`profile`-driven neighbourhood search, when
    /// `opts.order_search`).
    pub fn bapipe(
        net: &Network,
        cluster: &Cluster,
        profile: &Profile,
        opts: &Options,
    ) -> SearchSpace {
        let mut kinds = Vec::new();
        let mut ineligible = Vec::new();
        for kind in ScheduleKind::bapipe_candidates() {
            if kind.eligible(cluster) {
                kinds.push(kind);
            } else {
                ineligible.push(kind);
            }
        }
        let (device_orders, mut notes, order_provenance) =
            device_orders(net, cluster, profile, opts);
        // --pareto opens the memory-scalable axis: 2BW joins the kinds
        // (it runs in either exec mode), so the front can trade its one
        // extra weight buffer against the plain schedules' throughput.
        if opts.pareto {
            kinds.push(ScheduleKind::TwoBW);
            notes.push("pareto: 2BW added to the schedule-kind axis".to_string());
        }
        let recompute_options = if opts.recompute { vec![false, true] } else { vec![false] };
        if opts.recompute {
            notes.push("recompute: activation-checkpointing variants enumerated".to_string());
        }
        SearchSpace {
            kinds,
            ineligible,
            m_grid: opts.m_candidates.clone(),
            batch_per_device: opts.batch_per_device,
            device_orders,
            notes,
            order_provenance,
            recompute_options,
        }
    }

    /// A single-kind restriction (baselines — e.g. GPipe over the same M
    /// grid with BaPipe's balanced partitions).
    pub fn restricted(kind: ScheduleKind, cluster: &Cluster, opts: &Options) -> SearchSpace {
        SearchSpace {
            kinds: vec![kind],
            ineligible: Vec::new(),
            m_grid: opts.m_candidates.clone(),
            batch_per_device: opts.batch_per_device,
            device_orders: vec![(0..cluster.len()).collect()],
            notes: Vec::new(),
            order_provenance: Vec::new(),
            recompute_options: vec![false],
        }
    }

    /// PipeDream's per-device batch candidates: `b, b/2, b/4, …` down to
    /// one sample (the paper halves the batch until the weight stash
    /// fits).
    pub fn pipedream_batches(batch_per_device: f64) -> Vec<f64> {
        let mut out = Vec::new();
        let mut b = batch_per_device;
        while b >= 1.0 {
            out.push(b);
            b /= 2.0;
        }
        out
    }

    /// All candidates in deterministic enumeration order (device order,
    /// then kind, then M, then recompute off-before-on). This order is
    /// the reduction tie-break: among equal epoch times the earliest
    /// candidate wins, matching the seed explorer's first-strictly-better
    /// sequential rule.
    pub fn candidates(&self, n_devices: usize) -> Vec<Candidate> {
        let global = crate::util::canonical_global_batch(self.batch_per_device, n_devices);
        let mut out = Vec::with_capacity(
            self.device_orders.len()
                * self.kinds.len()
                * self.m_grid.len()
                * self.recompute_options.len(),
        );
        for (perm, _) in self.device_orders.iter().enumerate() {
            for &kind in &self.kinds {
                for &m in &self.m_grid {
                    for &recompute in &self.recompute_options {
                        let micro = if m == 0 { 0.0 } else { global / m as f64 };
                        out.push(Candidate { kind, m, micro, perm, recompute });
                    }
                }
            }
        }
        out
    }
}

/// The device orderings to explore, with construction notes and (for a
/// neighbourhood search) per-order provenance. Identity always; on a
/// heterogeneous cluster with permutation search enabled, every
/// *distinct* device-name sequence (permuting two identical boards
/// changes nothing), capped at [`MAX_DEVICE_ORDERS`]. Past 8 devices the
/// factorial walk is replaced by [`orders::discover`] when
/// `opts.order_search` is set. A requested search that is skipped or
/// capped is reported in the notes — never dropped silently.
fn device_orders(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
) -> (Vec<Vec<usize>>, Vec<String>, Vec<String>) {
    let n = cluster.len();
    let identity: Vec<usize> = (0..n).collect();
    if !opts.permute_devices {
        // An explicitly requested order search still needs the permute
        // axis on — say so instead of dropping the request silently.
        let notes = if opts.order_search {
            vec!["device-order search: --order-search ignored (requires --permute)".to_string()]
        } else {
            Vec::new()
        };
        return (vec![identity], notes, Vec::new());
    }
    if cluster.is_homogeneous() || n < 2 {
        return (
            vec![identity],
            vec!["device-order search: identity only (homogeneous cluster)".to_string()],
            Vec::new(),
        );
    }
    if n > 8 {
        if !opts.order_search {
            return (
                vec![identity],
                vec![format!(
                    "device-order search SKIPPED: {n} devices exceed the {}-device permutation \
                     limit (pass --order-search for the neighbourhood search)",
                    8
                )],
                Vec::new(),
            );
        }
        let d = orders::discover(net, cluster, profile, opts);
        return (d.orders, d.notes, d.provenance);
    }
    // Exhaustive walk (n ≤ 8). Dedup on device-name *ids*
    // ([`Cluster::name_ids`]) packed into one u64 — the seed's
    // `Vec<String>` key cloned every name on all n! steps (40320
    // allocations at n = 8 even when only 2 distinct layouts exist). The
    // walk also exits as soon as every distinct multiset permutation has
    // been seen instead of grinding out the rest of the factorial tail.
    // Output is byte-for-byte the original enumeration: same walk, same
    // first-occurrence order.
    let ids = cluster.name_ids();
    let mut counts = vec![0u64; ids.iter().max().map(|&m| m + 1).unwrap_or(0)];
    for &id in &ids {
        counts[id] += 1;
    }
    // n!/∏ counts! distinct name sequences (n ≤ 8, so u64 is ample).
    let factorial = |k: u64| (1..=k).product::<u64>();
    let distinct_total =
        (factorial(n as u64) / counts.iter().map(|&c| factorial(c)).product::<u64>()) as usize;
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    let mut out = Vec::new();
    let mut capped = false;
    let mut perm = identity;
    loop {
        // n ≤ 8 positions × ids < 8 → 4 bits per slot packs into a u64
        let key = perm.iter().fold(0u64, |k, &i| (k << 4) | ids[i] as u64);
        if seen.insert(key) {
            out.push(perm.clone());
            if out.len() >= MAX_DEVICE_ORDERS {
                capped = true;
                break;
            }
            if out.len() == distinct_total {
                break; // multiset exhausted — the factorial tail adds nothing
            }
        }
        if !next_permutation(&mut perm) {
            break;
        }
    }
    let mut notes = vec![format!("device-order search: {} distinct orderings", out.len())];
    if capped {
        notes.push(format!(
            "device-order search TRUNCATED at {MAX_DEVICE_ORDERS} orderings (lexicographically \
             first; more distinct layouts exist)"
        ));
    }
    (out, notes, Vec::new())
}

/// Advance `a` to its next lexicographic permutation; false when `a` was
/// already the last one.
fn next_permutation(a: &mut [usize]) -> bool {
    if a.len() < 2 {
        return false;
    }
    let mut i = a.len() - 1;
    while i > 0 && a[i - 1] >= a[i] {
        i -= 1;
    }
    if i == 0 {
        return false;
    }
    let mut j = a.len() - 1;
    while a[j] <= a[i - 1] {
        j -= 1;
    }
    a.swap(i - 1, j);
    a[i..].reverse();
    true
}

/// The cluster and profile as seen when devices are laid out along the
/// chain in `order` (links are properties of the chain slots and stay
/// put; per-device profile rows travel with their device).
pub fn permuted_view(cluster: &Cluster, profile: &Profile, order: &[usize]) -> (Cluster, Profile) {
    assert_eq!(order.len(), cluster.len(), "order must cover every device");
    let devices = order.iter().map(|&i| cluster.devices[i].clone()).collect();
    let cl = Cluster::new(devices, cluster.links.clone());
    let per_device = order.iter().map(|&i| profile.per_device[i].clone()).collect();
    let prof = Profile {
        model: profile.model.clone(),
        dtype_bytes: profile.dtype_bytes,
        per_device,
    };
    (cl, prof)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    fn space(cluster: &Cluster, opts: &Options) -> SearchSpace {
        let net = zoo::vgg16(224);
        let prof = analytical::profile(&net, cluster);
        SearchSpace::bapipe(&net, cluster, &prof, opts)
    }

    #[test]
    fn bapipe_space_splits_eligibility() {
        let gpu = presets::v100_cluster(4);
        let s = space(&gpu, &Options::default());
        assert_eq!(s.kinds, vec![ScheduleKind::OneFOneBSno, ScheduleKind::OneFOneBSo]);
        assert_eq!(s.ineligible, vec![ScheduleKind::OneFOneBAs, ScheduleKind::FbpAs]);
        let fpga = presets::fpga_cluster(&["VCU118"; 2]);
        let s = space(&fpga, &Options::default());
        assert_eq!(s.kinds, vec![ScheduleKind::OneFOneBAs, ScheduleKind::FbpAs]);
    }

    #[test]
    fn candidates_enumerate_kind_major_then_m() {
        let cl = presets::v100_cluster(2);
        let s = space(&cl, &Options::default());
        let cands = s.candidates(2);
        assert_eq!(cands.len(), 2 * s.m_grid.len());
        assert_eq!(cands[0].kind, ScheduleKind::OneFOneBSno);
        assert_eq!(cands[0].m, 2);
        assert_eq!(cands[0].micro, 32.0); // global 64 / m 2
        assert_eq!(cands[s.m_grid.len()].kind, ScheduleKind::OneFOneBSo);
    }

    #[test]
    fn pareto_and_recompute_widen_the_space() {
        let cl = presets::v100_cluster(2);
        let o = Options { pareto: true, recompute: true, ..Default::default() };
        let s = space(&cl, &o);
        assert!(s.kinds.contains(&ScheduleKind::TwoBW), "pareto adds 2BW: {:?}", s.kinds);
        assert_eq!(s.recompute_options, vec![false, true]);
        let cands = s.candidates(2);
        assert_eq!(cands.len(), 3 * s.m_grid.len() * 2);
        // recompute toggles innermost: off before on at the same (kind, m)
        assert!(!cands[0].recompute && cands[1].recompute);
        assert_eq!((cands[0].kind, cands[0].m), (cands[1].kind, cands[1].m));
        // default space is unchanged
        let plain = space(&cl, &Options::default());
        assert!(!plain.kinds.contains(&ScheduleKind::TwoBW));
        assert_eq!(plain.recompute_options, vec![false]);
    }

    #[test]
    fn homogeneous_cluster_has_identity_order_only() {
        let cl = presets::v100_cluster(4);
        let o = Options { permute_devices: true, ..Default::default() };
        let s = space(&cl, &o);
        assert_eq!(s.device_orders, vec![vec![0, 1, 2, 3]]);
        assert!(s.notes.iter().any(|n| n.contains("homogeneous")), "{:?}", s.notes);
        assert!(s.order_provenance.is_empty());
    }

    #[test]
    fn oversized_permutation_request_is_noted_not_silent() {
        let mut boards = vec!["VCU129"; 5];
        boards.extend(vec!["VCU118"; 5]);
        let cl = presets::fpga_cluster(&boards);
        let o = Options { permute_devices: true, ..Default::default() };
        let s = space(&cl, &o);
        assert_eq!(s.device_orders.len(), 1, "10 devices without --order-search: identity only");
        assert!(
            s.notes.iter().any(|n| n.contains("SKIPPED")),
            "a dropped search dimension must be reported: {:?}",
            s.notes
        );
        assert!(
            s.notes.iter().any(|n| n.contains("--order-search")),
            "the skip note must name the opt-in flag: {:?}",
            s.notes
        );
    }

    #[test]
    fn order_search_without_permute_is_noted_not_silent() {
        let cl = presets::gpu_mixed_cluster(16);
        let o = Options { order_search: true, ..Default::default() };
        let s = space(&cl, &o);
        assert_eq!(s.device_orders.len(), 1, "no --permute: identity only");
        assert!(
            s.notes.iter().any(|n| n.contains("requires --permute")),
            "an ignored --order-search must be reported: {:?}",
            s.notes
        );
    }

    #[test]
    fn truncated_enumeration_is_noted_not_silent() {
        // 4 + 4 boards have 8!/(4!·4!) = 70 distinct layouts — above the
        // 64-order cap, so the enumeration truncates and must say so.
        let mut boards = vec!["VCU129"; 4];
        boards.extend(vec!["VCU118"; 4]);
        let cl = presets::fpga_cluster(&boards);
        let o = Options { permute_devices: true, ..Default::default() };
        let s = space(&cl, &o);
        assert_eq!(s.device_orders.len(), MAX_DEVICE_ORDERS);
        assert!(
            s.notes.iter().any(|n| n.contains("TRUNCATED")),
            "a capped enumeration must be reported: {:?}",
            s.notes
        );
    }

    #[test]
    fn mixed_cluster_orders_are_distinct_name_sequences() {
        let cl = presets::fpga_cluster(&["VCU129", "VCU129", "VCU118", "VCU118"]);
        let o = Options { permute_devices: true, ..Default::default() };
        let s = space(&cl, &o);
        // 4!/(2!·2!) = 6 distinct sequences, identity first.
        assert_eq!(s.device_orders.len(), 6);
        assert_eq!(s.device_orders[0], vec![0, 1, 2, 3]);
        let mut seqs = BTreeSet::new();
        for ord in &s.device_orders {
            let names: Vec<&str> = ord.iter().map(|&i| cl.devices[i].name.as_str()).collect();
            assert!(seqs.insert(names.join("|")), "duplicate ordering {ord:?}");
        }
    }

    #[test]
    fn two_distinct_layouts_enumerate_without_walking_the_tail() {
        // 7 identical boards + 1 different: 8 distinct layouts out of 8!
        // permutations. The index-dedup walk must find exactly those 8
        // (first-occurrence order, identity first) and stop early.
        let mut boards = vec!["VCU118"; 7];
        boards.push("VCU129");
        let cl = presets::fpga_cluster(&boards);
        let o = Options { permute_devices: true, ..Default::default() };
        let s = space(&cl, &o);
        assert_eq!(s.device_orders.len(), 8);
        assert_eq!(s.device_orders[0], (0..8).collect::<Vec<usize>>());
        // each layout is "the odd board at position p" for a distinct p
        let positions: BTreeSet<usize> = s
            .device_orders
            .iter()
            .map(|ord| ord.iter().position(|&i| i == 7).unwrap())
            .collect();
        assert_eq!(positions.len(), 8);
    }

    #[test]
    fn next_permutation_walks_all() {
        let mut a = vec![0usize, 1, 2];
        let mut count = 1;
        while next_permutation(&mut a) {
            count += 1;
        }
        assert_eq!(count, 6);
        assert_eq!(a, vec![2, 1, 0]);
    }

    #[test]
    fn next_permutation_edge_cases() {
        // already the last (descending) permutation: false, unchanged
        let mut d = vec![3usize, 2, 1, 0];
        assert!(!next_permutation(&mut d));
        assert_eq!(d, vec![3, 2, 1, 0]);
        // repeated values: [1, 1] has no successor
        let mut r = vec![1usize, 1];
        assert!(!next_permutation(&mut r));
        assert_eq!(r, vec![1, 1]);
        // repeated values mid-sequence advance past the duplicates
        let mut m = vec![0usize, 1, 1];
        assert!(next_permutation(&mut m));
        assert_eq!(m, vec![1, 0, 1]);
        assert!(next_permutation(&mut m));
        assert_eq!(m, vec![1, 1, 0]);
        assert!(!next_permutation(&mut m));
        // degenerate lengths
        let mut empty: Vec<usize> = vec![];
        assert!(!next_permutation(&mut empty));
        let mut one = vec![5usize];
        assert!(!next_permutation(&mut one));
        assert_eq!(one, vec![5]);
    }

    #[test]
    fn permuted_view_moves_profile_rows_with_devices() {
        let net = zoo::vgg16(224);
        let cl = presets::fpga_cluster(&["VCU129", "VCU118"]);
        let prof = analytical::profile(&net, &cl);
        let (cl2, prof2) = permuted_view(&cl, &prof, &[1, 0]);
        assert_eq!(cl2.devices[0].name, "VCU118");
        assert_eq!(cl2.devices[1].name, "VCU129");
        // row 0 of the view is the VCU118 row of the original
        assert_eq!(prof2.per_device[0][0].fwd, prof.per_device[1][0].fwd);
        assert_eq!(prof2.per_device[1][3].bwd, prof.per_device[0][3].bwd);
        // links unchanged
        assert_eq!(cl2.links.len(), 1);
    }

    #[test]
    fn pipedream_batches_halve_to_one() {
        assert_eq!(SearchSpace::pipedream_batches(8.0), vec![8.0, 4.0, 2.0, 1.0]);
        assert_eq!(SearchSpace::pipedream_batches(0.5), Vec::<f64>::new());
    }
}
