//! Search-space enumeration: which `(schedule kind, micro-batch count,
//! device ordering)` triples the planner considers.
//!
//! The space is data, not control flow: baselines restrict it (GPipe is
//! the same machinery over a single kind) instead of reimplementing the
//! exploration loop, and heterogeneous FPGA mixes can widen it with
//! distinct device orderings along the pipeline chain.

use super::Options;
use crate::cluster::Cluster;
use crate::profile::Profile;
use crate::schedule::ScheduleKind;
use std::collections::BTreeSet;

/// Most device orderings explored on a heterogeneous cluster (distinct
/// name-sequences of a 6-board mix already stay below this).
pub const MAX_DEVICE_ORDERS: usize = 64;

/// One point of the search space.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    /// Schedule to run.
    pub kind: ScheduleKind,
    /// Micro-batches per mini-batch.
    pub m: usize,
    /// Micro-batch size in samples (global mini-batch / `m`).
    pub micro: f64,
    /// Index into [`SearchSpace::device_orders`].
    pub perm: usize,
}

/// The enumerable exploration space.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Kinds to evaluate, in canonical (tie-break) order.
    pub kinds: Vec<ScheduleKind>,
    /// BaPipe kinds excluded by cluster eligibility (reported, not
    /// enumerated — e.g. async schedules on a GPU cluster).
    pub ineligible: Vec<ScheduleKind>,
    /// Micro-batch-count grid.
    pub m_grid: Vec<usize>,
    /// Per-device batch size `B`; the global mini-batch is `B × N`.
    pub batch_per_device: f64,
    /// Device orderings to try; entry 0 is always the identity.
    pub device_orders: Vec<Vec<usize>>,
    /// Search-space construction notes (e.g. a requested permutation
    /// search that was skipped or capped) — surfaced in the report so a
    /// dropped search dimension is never silent.
    pub notes: Vec<String>,
}

impl SearchSpace {
    /// The paper's Fig.-3 space: every eligible BaPipe schedule kind ×
    /// the M grid (× device orderings when `opts.permute_devices`).
    pub fn bapipe(cluster: &Cluster, opts: &Options) -> SearchSpace {
        let mut kinds = Vec::new();
        let mut ineligible = Vec::new();
        for kind in ScheduleKind::bapipe_candidates() {
            if kind.eligible(cluster) {
                kinds.push(kind);
            } else {
                ineligible.push(kind);
            }
        }
        let (device_orders, notes) = device_orders(cluster, opts.permute_devices);
        SearchSpace {
            kinds,
            ineligible,
            m_grid: opts.m_candidates.clone(),
            batch_per_device: opts.batch_per_device,
            device_orders,
            notes,
        }
    }

    /// A single-kind restriction (baselines — e.g. GPipe over the same M
    /// grid with BaPipe's balanced partitions).
    pub fn restricted(kind: ScheduleKind, cluster: &Cluster, opts: &Options) -> SearchSpace {
        SearchSpace {
            kinds: vec![kind],
            ineligible: Vec::new(),
            m_grid: opts.m_candidates.clone(),
            batch_per_device: opts.batch_per_device,
            device_orders: vec![(0..cluster.len()).collect()],
            notes: Vec::new(),
        }
    }

    /// PipeDream's per-device batch candidates: `b, b/2, b/4, …` down to
    /// one sample (the paper halves the batch until the weight stash
    /// fits).
    pub fn pipedream_batches(batch_per_device: f64) -> Vec<f64> {
        let mut out = Vec::new();
        let mut b = batch_per_device;
        while b >= 1.0 {
            out.push(b);
            b /= 2.0;
        }
        out
    }

    /// All candidates in deterministic enumeration order (device order,
    /// then kind, then M). This order is the reduction tie-break: among
    /// equal epoch times the earliest candidate wins, matching the seed
    /// explorer's first-strictly-better sequential rule.
    pub fn candidates(&self, n_devices: usize) -> Vec<Candidate> {
        let global = self.batch_per_device * n_devices as f64;
        let mut out = Vec::with_capacity(self.device_orders.len() * self.kinds.len() * self.m_grid.len());
        for (perm, _) in self.device_orders.iter().enumerate() {
            for &kind in &self.kinds {
                for &m in &self.m_grid {
                    let micro = if m == 0 { 0.0 } else { global / m as f64 };
                    out.push(Candidate { kind, m, micro, perm });
                }
            }
        }
        out
    }
}

/// The device orderings to explore (plus construction notes): identity
/// always; on a heterogeneous cluster with permutation search enabled,
/// every *distinct* device-name sequence (permuting two identical boards
/// changes nothing), capped at [`MAX_DEVICE_ORDERS`]. A requested search
/// that is skipped or capped is reported in the notes — never dropped
/// silently.
fn device_orders(cluster: &Cluster, permute: bool) -> (Vec<Vec<usize>>, Vec<String>) {
    let n = cluster.len();
    let identity: Vec<usize> = (0..n).collect();
    if !permute {
        return (vec![identity], Vec::new());
    }
    if cluster.is_homogeneous() || n < 2 {
        return (
            vec![identity],
            vec!["device-order search: identity only (homogeneous cluster)".to_string()],
        );
    }
    if n > 8 {
        return (
            vec![identity],
            vec![format!(
                "device-order search SKIPPED: {n} devices exceed the {}-device permutation limit",
                8
            )],
        );
    }
    let mut seen: BTreeSet<Vec<String>> = BTreeSet::new();
    let mut out = Vec::new();
    let mut capped = false;
    let mut perm = identity;
    loop {
        let names: Vec<String> =
            perm.iter().map(|&i| cluster.devices[i].name.clone()).collect();
        if seen.insert(names) {
            out.push(perm.clone());
            if out.len() >= MAX_DEVICE_ORDERS {
                capped = true;
                break;
            }
        }
        if !next_permutation(&mut perm) {
            break;
        }
    }
    let mut notes = vec![format!("device-order search: {} distinct orderings", out.len())];
    if capped {
        notes.push(format!(
            "device-order search TRUNCATED at {MAX_DEVICE_ORDERS} orderings (lexicographically \
             first; more distinct layouts exist)"
        ));
    }
    (out, notes)
}

/// Advance `a` to its next lexicographic permutation; false when `a` was
/// already the last one.
fn next_permutation(a: &mut [usize]) -> bool {
    if a.len() < 2 {
        return false;
    }
    let mut i = a.len() - 1;
    while i > 0 && a[i - 1] >= a[i] {
        i -= 1;
    }
    if i == 0 {
        return false;
    }
    let mut j = a.len() - 1;
    while a[j] <= a[i - 1] {
        j -= 1;
    }
    a.swap(i - 1, j);
    a[i..].reverse();
    true
}

/// The cluster and profile as seen when devices are laid out along the
/// chain in `order` (links are properties of the chain slots and stay
/// put; per-device profile rows travel with their device).
pub fn permuted_view(cluster: &Cluster, profile: &Profile, order: &[usize]) -> (Cluster, Profile) {
    assert_eq!(order.len(), cluster.len(), "order must cover every device");
    let devices = order.iter().map(|&i| cluster.devices[i].clone()).collect();
    let cl = Cluster::new(devices, cluster.links.clone());
    let per_device = order.iter().map(|&i| profile.per_device[i].clone()).collect();
    let prof = Profile {
        model: profile.model.clone(),
        dtype_bytes: profile.dtype_bytes,
        per_device,
    };
    (cl, prof)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    #[test]
    fn bapipe_space_splits_eligibility() {
        let gpu = presets::v100_cluster(4);
        let s = SearchSpace::bapipe(&gpu, &Options::default());
        assert_eq!(s.kinds, vec![ScheduleKind::OneFOneBSno, ScheduleKind::OneFOneBSo]);
        assert_eq!(s.ineligible, vec![ScheduleKind::OneFOneBAs, ScheduleKind::FbpAs]);
        let fpga = presets::fpga_cluster(&["VCU118"; 2]);
        let s = SearchSpace::bapipe(&fpga, &Options::default());
        assert_eq!(s.kinds, vec![ScheduleKind::OneFOneBAs, ScheduleKind::FbpAs]);
    }

    #[test]
    fn candidates_enumerate_kind_major_then_m() {
        let cl = presets::v100_cluster(2);
        let s = SearchSpace::bapipe(&cl, &Options::default());
        let cands = s.candidates(2);
        assert_eq!(cands.len(), 2 * s.m_grid.len());
        assert_eq!(cands[0].kind, ScheduleKind::OneFOneBSno);
        assert_eq!(cands[0].m, 2);
        assert_eq!(cands[0].micro, 32.0); // global 64 / m 2
        assert_eq!(cands[s.m_grid.len()].kind, ScheduleKind::OneFOneBSo);
    }

    #[test]
    fn homogeneous_cluster_has_identity_order_only() {
        let cl = presets::v100_cluster(4);
        let o = Options { permute_devices: true, ..Default::default() };
        let s = SearchSpace::bapipe(&cl, &o);
        assert_eq!(s.device_orders, vec![vec![0, 1, 2, 3]]);
        assert!(s.notes.iter().any(|n| n.contains("homogeneous")), "{:?}", s.notes);
    }

    #[test]
    fn oversized_permutation_request_is_noted_not_silent() {
        let mut boards = vec!["VCU129"; 5];
        boards.extend(vec!["VCU118"; 5]);
        let cl = presets::fpga_cluster(&boards);
        let o = Options { permute_devices: true, ..Default::default() };
        let s = SearchSpace::bapipe(&cl, &o);
        assert_eq!(s.device_orders.len(), 1, "10 devices: identity only");
        assert!(
            s.notes.iter().any(|n| n.contains("SKIPPED")),
            "a dropped search dimension must be reported: {:?}",
            s.notes
        );
    }

    #[test]
    fn mixed_cluster_orders_are_distinct_name_sequences() {
        let cl = presets::fpga_cluster(&["VCU129", "VCU129", "VCU118", "VCU118"]);
        let o = Options { permute_devices: true, ..Default::default() };
        let s = SearchSpace::bapipe(&cl, &o);
        // 4!/(2!·2!) = 6 distinct sequences, identity first.
        assert_eq!(s.device_orders.len(), 6);
        assert_eq!(s.device_orders[0], vec![0, 1, 2, 3]);
        let mut seqs = BTreeSet::new();
        for ord in &s.device_orders {
            let names: Vec<&str> = ord.iter().map(|&i| cl.devices[i].name.as_str()).collect();
            assert!(seqs.insert(names.join("|")), "duplicate ordering {ord:?}");
        }
    }

    #[test]
    fn next_permutation_walks_all() {
        let mut a = vec![0usize, 1, 2];
        let mut count = 1;
        while next_permutation(&mut a) {
            count += 1;
        }
        assert_eq!(count, 6);
        assert_eq!(a, vec![2, 1, 0]);
    }

    #[test]
    fn permuted_view_moves_profile_rows_with_devices() {
        let net = zoo::vgg16(224);
        let cl = presets::fpga_cluster(&["VCU129", "VCU118"]);
        let prof = analytical::profile(&net, &cl);
        let (cl2, prof2) = permuted_view(&cl, &prof, &[1, 0]);
        assert_eq!(cl2.devices[0].name, "VCU118");
        assert_eq!(cl2.devices[1].name, "VCU129");
        // row 0 of the view is the VCU118 row of the original
        assert_eq!(prof2.per_device[0][0].fwd, prof.per_device[1][0].fwd);
        assert_eq!(prof2.per_device[1][3].bwd, prof.per_device[0][3].bwd);
        // links unchanged
        assert_eq!(cl2.links.len(), 1);
    }

    #[test]
    fn pipedream_batches_halve_to_one() {
        assert_eq!(SearchSpace::pipedream_batches(8.0), vec![8.0, 4.0, 2.0, 1.0]);
        assert_eq!(SearchSpace::pipedream_batches(0.5), Vec::<f64>::new());
    }
}
