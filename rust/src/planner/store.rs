//! Cross-scenario plan-cache persistence: fingerprint a `(model,
//! cluster)` scenario, save the [`EvalCache`]'s seed/plan maps next to it
//! and restore them on the next CLI invocation — a warm cache answers
//! every phase-A request (balance-seed DPs *and* memory fine-tunes) from
//! memory, so `bapipe explore --plan-cache plan-cache.json` skips phase A
//! entirely when the scenario is unchanged.
//!
//! The fingerprint hashes everything the partition passes consume: the
//! full per-device per-layer profile (times, parameter/activation/stash
//! sizes, saturation points), the device specs, the link parameters and
//! the legal cut set. Any change — a different model, a resized cluster,
//! retuned device constants, even a single layer's cut-legality — changes
//! the fingerprint and the stale cache is rejected (never silently
//! reused). The device-order list is stored alongside so `perm` indices
//! keep their meaning across invocations; a run with a different
//! `--permute` setting rejects the cache the same way — and past 8
//! devices the list is the [`crate::planner::orders`] *discovered* set,
//! so a cache written with `--order-search` (or with a different probe
//! budget that discovered different layouts) is likewise rejected when
//! the current discovery differs.

use super::cache::EvalCache;
use crate::cluster::{Cluster, ExecMode};
use crate::model::Network;
use crate::profile::Profile;
use crate::util::json::Json;

/// 64-bit FNV-1a over a canonical byte stream.
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }
    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }
}

/// Fingerprint of one `(model, cluster)` scenario — the key a persisted
/// plan cache is valid for (see module docs for what it covers).
pub fn fingerprint(net: &Network, cluster: &Cluster, profile: &Profile) -> String {
    let mut h = Fnv1a::new();
    h.str(&net.name);
    h.u64(net.len() as u64);
    for c in net.legal_cuts() {
        h.u64(c as u64);
    }
    h.str(&profile.model);
    h.u64(profile.dtype_bytes);
    h.u64(profile.n_devices() as u64);
    h.u64(profile.n_layers() as u64);
    for row in &profile.per_device {
        for c in row {
            h.f64(c.fwd);
            h.f64(c.bwd);
            h.f64(c.fwd_fixed);
            h.f64(c.bwd_fixed);
            h.u64(c.params);
            h.u64(c.act_in_elems);
            h.u64(c.act_out_elems);
            h.u64(c.stash_elems);
            h.f64(c.half_sat);
        }
    }
    for d in &cluster.devices {
        h.str(&d.name);
        h.f64(d.peak_flops);
        h.f64(d.mem_bw);
        h.u64(d.mem_capacity);
        h.u64(d.onchip_capacity);
        h.f64(d.onchip_bw);
        h.u64(matches!(d.exec, ExecMode::Async) as u64);
        h.f64(d.batch_half_sat);
        h.u64(d.dsp_slices);
    }
    for l in &cluster.links {
        h.f64(l.bandwidth);
        h.f64(l.latency);
    }
    format!("{:016x}", h.0)
}

/// Outcome of [`load`]: a usable cache, or the reason to start fresh.
pub enum CacheLoad {
    /// The on-disk cache matched the scenario and was restored.
    Loaded(EvalCache),
    /// No usable cache (missing file, parse failure, or a fingerprint /
    /// device-order mismatch); carries the human-readable reason.
    Fresh(String),
}

/// Load a plan cache from `path` if it matches `fingerprint` and
/// `device_orders`. Never fails hard: any problem degrades to
/// [`CacheLoad::Fresh`] with the reason, and the exploration recomputes.
pub fn load(path: &str, fingerprint: &str, device_orders: &[Vec<usize>]) -> CacheLoad {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => return CacheLoad::Fresh(format!("no plan cache at {path}")),
    };
    let json = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => return CacheLoad::Fresh(format!("unreadable plan cache {path}: {e}")),
    };
    match EvalCache::from_json(&json, fingerprint, device_orders) {
        Ok(cache) => CacheLoad::Loaded(cache),
        Err(e) => CacheLoad::Fresh(format!("stale plan cache {path}: {e}")),
    }
}

/// Persist `cache` to `path`, keyed by `fingerprint` / `device_orders`.
pub fn save(
    path: &str,
    cache: &EvalCache,
    fingerprint: &str,
    device_orders: &[Vec<usize>],
) -> crate::Result<()> {
    let text = cache.to_json(fingerprint, device_orders).to_string_pretty();
    std::fs::write(path, text).map_err(|e| anyhow::anyhow!("writing plan cache {path}: {e}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    #[test]
    fn fingerprint_is_stable_and_discriminating() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let fp = fingerprint(&net, &cl, &prof);
        assert_eq!(fp.len(), 16);
        assert_eq!(fp, fingerprint(&net, &cl, &prof), "same inputs, same fingerprint");

        // different model
        let net2 = zoo::resnet50(224);
        let prof2 = analytical::profile(&net2, &cl);
        assert_ne!(fp, fingerprint(&net2, &cl, &prof2));

        // different cluster size
        let cl8 = presets::v100_cluster(8);
        let prof8 = analytical::profile(&net, &cl8);
        assert_ne!(fp, fingerprint(&net, &cl8, &prof8));

        // same shapes, retuned profile constant
        let mut prof3 = prof.clone();
        prof3.per_device[0][0].fwd *= 1.5;
        assert_ne!(fp, fingerprint(&net, &cl, &prof3));
    }

    #[test]
    fn changed_discovered_order_set_degrades_to_fresh() {
        // Same fingerprint, different device-order set (the neighbourhood
        // search discovering different layouts): the `perm` indices of the
        // cached entries would point at different physical layouts, so the
        // load must reject the document.
        let net = zoo::vgg16(224);
        let cl = presets::fpga_cluster(&["VCU129", "VCU118"]);
        let prof = analytical::profile(&net, &cl);
        let fp = fingerprint(&net, &cl, &prof);
        let cache = EvalCache::new();
        let saved_orders = vec![vec![0usize, 1], vec![1, 0]];

        let path = std::env::temp_dir().join("bapipe-store-order-set-test.json");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        save(&path, &cache, &fp, &saved_orders).unwrap();

        match load(&path, &fp, &[vec![0usize, 1]]) {
            CacheLoad::Fresh(reason) => {
                assert!(reason.contains("device-order"), "{reason}")
            }
            CacheLoad::Loaded(_) => panic!("a different order set must not load"),
        }
        match load(&path, &fp, &saved_orders) {
            CacheLoad::Loaded(_) => {}
            CacheLoad::Fresh(reason) => panic!("matching order set must load: {reason}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_degrades_to_fresh() {
        match load("/nonexistent/bapipe-plan-cache.json", "00", &[vec![0]]) {
            CacheLoad::Fresh(reason) => assert!(reason.contains("no plan cache"), "{reason}"),
            CacheLoad::Loaded(_) => panic!("must not load a missing file"),
        }
    }
}
