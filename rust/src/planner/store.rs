//! Cross-scenario plan-cache persistence: fingerprint a `(model,
//! cluster)` scenario, save the [`EvalCache`]'s seed/plan maps next to it
//! and restore them on the next CLI invocation — a warm cache answers
//! every phase-A request (balance-seed DPs *and* memory fine-tunes) from
//! memory, so `bapipe explore --plan-cache plan-cache.json` skips phase A
//! entirely when the scenario is unchanged.
//!
//! The fingerprint hashes everything the partition passes consume: the
//! full per-device per-layer profile (times, parameter/activation/stash
//! sizes, saturation points), the device specs, the link parameters and
//! the legal cut set. Any change — a different model, a resized cluster,
//! retuned device constants, even a single layer's cut-legality — changes
//! the fingerprint and the stale cache is rejected (never silently
//! reused). The device-order list is stored alongside so `perm` indices
//! keep their meaning across invocations; a run with a different
//! `--permute` setting rejects the cache the same way — and past 8
//! devices the list is the [`crate::planner::orders`] *discovered* set,
//! so a cache written with `--order-search` (or with a different probe
//! budget that discovered different layouts) is likewise rejected when
//! the current discovery differs.

use super::cache::EvalCache;
use crate::cluster::{Cluster, ExecMode};
use crate::model::Network;
use crate::profile::Profile;
use crate::util::json::Json;

/// 64-bit FNV-1a over a canonical byte stream.
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }
    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }
}

/// Fingerprint of one `(model, cluster)` scenario — the key a persisted
/// plan cache is valid for (see module docs for what it covers).
pub fn fingerprint(net: &Network, cluster: &Cluster, profile: &Profile) -> String {
    let mut h = Fnv1a::new();
    h.str(&net.name);
    h.u64(net.len() as u64);
    for c in net.legal_cuts() {
        h.u64(c as u64);
    }
    h.str(&profile.model);
    h.u64(profile.dtype_bytes);
    h.u64(profile.n_devices() as u64);
    h.u64(profile.n_layers() as u64);
    for row in &profile.per_device {
        for c in row {
            h.f64(c.fwd);
            h.f64(c.bwd);
            h.f64(c.fwd_fixed);
            h.f64(c.bwd_fixed);
            h.u64(c.params);
            h.u64(c.act_in_elems);
            h.u64(c.act_out_elems);
            h.u64(c.stash_elems);
            h.f64(c.half_sat);
        }
    }
    for d in &cluster.devices {
        h.str(&d.name);
        h.f64(d.peak_flops);
        h.f64(d.mem_bw);
        h.u64(d.mem_capacity);
        h.u64(d.onchip_capacity);
        h.f64(d.onchip_bw);
        h.u64(matches!(d.exec, ExecMode::Async) as u64);
        h.f64(d.batch_half_sat);
        h.u64(d.dsp_slices);
    }
    for l in &cluster.links {
        h.f64(l.bandwidth);
        h.f64(l.latency);
    }
    format!("{:016x}", h.0)
}

/// Fingerprint of one permuted *view* of a scenario — the inputs the
/// partition passes for device order `order` actually consume (profile
/// rows travel with their devices; links stay in chain slots). Two
/// orders that produce byte-identical views (e.g. swapping two identical
/// boards) share a fingerprint by construction, and a view whose
/// fingerprint survives a cluster mutation can keep its cached partition
/// entries ([`EvalCache::salvage`]) even when the scenario fingerprint
/// changed.
pub fn view_fingerprint(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    order: &[usize],
) -> String {
    let (vcl, vprof) = super::space::permuted_view(cluster, profile, order);
    fingerprint(net, &vcl, &vprof)
}

/// Outcome of [`load`]: a usable cache, or the reason to start fresh.
pub enum CacheLoad {
    /// The on-disk cache matched the scenario and was restored.
    Loaded(EvalCache),
    /// No usable cache (missing file, parse failure, or a fingerprint /
    /// device-order mismatch); carries the human-readable reason.
    Fresh(String),
}

/// Load a plan cache from `path` if it matches `fingerprint` and
/// `device_orders`. Never fails hard: any problem degrades to
/// [`CacheLoad::Fresh`] with the reason, and the exploration recomputes.
pub fn load(path: &str, fingerprint: &str, device_orders: &[Vec<usize>]) -> CacheLoad {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => return CacheLoad::Fresh(format!("no plan cache at {path}")),
    };
    let json = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => return CacheLoad::Fresh(format!("unreadable plan cache {path}: {e}")),
    };
    match EvalCache::from_json(&json, fingerprint, device_orders) {
        Ok(cache) => CacheLoad::Loaded(cache),
        Err(e) => CacheLoad::Fresh(format!("stale plan cache {path}: {e}")),
    }
}

/// [`load`] with a per-view salvage fallback: when the all-or-nothing
/// match fails (changed fingerprint or order set) but the document was
/// saved with embedded view fingerprints ([`save_with_views`]), every
/// cached view that still exists in `view_fingerprints` keeps its
/// entries, re-keyed to the current `perm` indices. Returns the load
/// outcome plus report-ready notes saying exactly what was restored,
/// salvaged or rejected — the exploration surfaces them in
/// `ExplorationReport::notes` instead of burying the reason on stdout.
pub fn load_with_views(
    path: &str,
    fingerprint: &str,
    device_orders: &[Vec<usize>],
    view_fingerprints: &[String],
) -> (CacheLoad, Vec<String>) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => {
            let reason = format!("no plan cache at {path}");
            let note = format!("plan cache: {reason}; computing from scratch");
            return (CacheLoad::Fresh(reason), vec![note]);
        }
    };
    let json = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            let reason = format!("unreadable plan cache {path}: {e}");
            let note = format!("plan cache: {reason}; computing from scratch");
            return (CacheLoad::Fresh(reason), vec![note]);
        }
    };
    match EvalCache::from_json(&json, fingerprint, device_orders) {
        Ok(cache) => {
            let note = format!("plan cache: restored {path} (fingerprint {fingerprint})");
            (CacheLoad::Loaded(cache), vec![note])
        }
        Err(e) => match EvalCache::salvage_json(&json, view_fingerprints) {
            Ok((cache, st)) if st.seeds_reused + st.plans_reused > 0 => {
                let note = format!(
                    "plan cache: partial reuse of {path} — {}/{} views matched, \
                     {} seeds + {} plans re-keyed, {} entries dropped \
                     (full restore failed: {e})",
                    st.views_matched,
                    st.views_total,
                    st.seeds_reused,
                    st.plans_reused,
                    st.entries_dropped
                );
                (CacheLoad::Loaded(cache), vec![note])
            }
            _ => {
                let reason = format!("stale plan cache {path}: {e}");
                let note = format!("plan cache: {reason}; computing from scratch");
                (CacheLoad::Fresh(reason), vec![note])
            }
        },
    }
}

/// Persist `cache` to `path`, keyed by `fingerprint` / `device_orders`.
pub fn save(
    path: &str,
    cache: &EvalCache,
    fingerprint: &str,
    device_orders: &[Vec<usize>],
) -> crate::Result<()> {
    let text = cache.to_json(fingerprint, device_orders).to_string_pretty();
    std::fs::write(path, text).map_err(|e| anyhow::anyhow!("writing plan cache {path}: {e}"))?;
    Ok(())
}

/// [`save`] with per-view fingerprints embedded, enabling the
/// [`load_with_views`] salvage path on later invocations.
pub fn save_with_views(
    path: &str,
    cache: &EvalCache,
    fingerprint: &str,
    device_orders: &[Vec<usize>],
    view_fingerprints: &[String],
) -> crate::Result<()> {
    let text = cache
        .to_json_with_views(fingerprint, device_orders, view_fingerprints)
        .to_string_pretty();
    std::fs::write(path, text).map_err(|e| anyhow::anyhow!("writing plan cache {path}: {e}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    #[test]
    fn fingerprint_is_stable_and_discriminating() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let fp = fingerprint(&net, &cl, &prof);
        assert_eq!(fp.len(), 16);
        assert_eq!(fp, fingerprint(&net, &cl, &prof), "same inputs, same fingerprint");

        // different model
        let net2 = zoo::resnet50(224);
        let prof2 = analytical::profile(&net2, &cl);
        assert_ne!(fp, fingerprint(&net2, &cl, &prof2));

        // different cluster size
        let cl8 = presets::v100_cluster(8);
        let prof8 = analytical::profile(&net, &cl8);
        assert_ne!(fp, fingerprint(&net, &cl8, &prof8));

        // same shapes, retuned profile constant
        let mut prof3 = prof.clone();
        prof3.per_device[0][0].fwd *= 1.5;
        assert_ne!(fp, fingerprint(&net, &cl, &prof3));
    }

    #[test]
    fn changed_discovered_order_set_degrades_to_fresh() {
        // Same fingerprint, different device-order set (the neighbourhood
        // search discovering different layouts): the `perm` indices of the
        // cached entries would point at different physical layouts, so the
        // load must reject the document.
        let net = zoo::vgg16(224);
        let cl = presets::fpga_cluster(&["VCU129", "VCU118"]);
        let prof = analytical::profile(&net, &cl);
        let fp = fingerprint(&net, &cl, &prof);
        let cache = EvalCache::new();
        let saved_orders = vec![vec![0usize, 1], vec![1, 0]];

        let path = std::env::temp_dir().join("bapipe-store-order-set-test.json");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        save(&path, &cache, &fp, &saved_orders).unwrap();

        match load(&path, &fp, &[vec![0usize, 1]]) {
            CacheLoad::Fresh(reason) => {
                assert!(reason.contains("device-order"), "{reason}")
            }
            CacheLoad::Loaded(_) => panic!("a different order set must not load"),
        }
        match load(&path, &fp, &saved_orders) {
            CacheLoad::Loaded(_) => {}
            CacheLoad::Fresh(reason) => panic!("matching order set must load: {reason}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_degrades_to_fresh() {
        match load("/nonexistent/bapipe-plan-cache.json", "00", &[vec![0]]) {
            CacheLoad::Fresh(reason) => assert!(reason.contains("no plan cache"), "{reason}"),
            CacheLoad::Loaded(_) => panic!("must not load a missing file"),
        }
        let (outcome, notes) =
            load_with_views("/nonexistent/bapipe-plan-cache.json", "00", &[vec![0]], &[]);
        assert!(matches!(outcome, CacheLoad::Fresh(_)));
        assert_eq!(notes.len(), 1);
        assert!(notes[0].contains("no plan cache"), "{}", notes[0]);
        assert!(notes[0].contains("computing from scratch"), "{}", notes[0]);
    }

    #[test]
    fn view_fingerprint_tracks_what_the_partition_sees() {
        let net = zoo::vgg16(224);

        // Heterogeneous pair: swapping the devices changes the view.
        let cl = presets::gpu_mixed_cluster(2);
        let prof = analytical::profile(&net, &cl);
        let identity = view_fingerprint(&net, &cl, &prof, &[0, 1]);
        let swapped = view_fingerprint(&net, &cl, &prof, &[1, 0]);
        assert_ne!(identity, swapped, "V100/P100 swap must change the view");
        // The identity view is the scenario itself.
        assert_eq!(identity, fingerprint(&net, &cl, &prof));

        // Homogeneous pair: the swap produces a byte-identical view, so
        // the fingerprints legitimately coincide (shared cache entries).
        let homo = presets::v100_cluster(2);
        let hprof = analytical::profile(&net, &homo);
        assert_eq!(
            view_fingerprint(&net, &homo, &hprof, &[0, 1]),
            view_fingerprint(&net, &homo, &hprof, &[1, 0]),
        );
    }

    #[test]
    fn load_with_views_restores_salvages_and_reports() {
        use crate::planner::space::Candidate;
        use crate::schedule::ScheduleKind;

        let net = zoo::vgg16(224);
        let cl = presets::gpu_mixed_cluster(2);
        let prof = analytical::profile(&net, &cl);
        let fp = fingerprint(&net, &cl, &prof);
        let orders = vec![vec![0usize, 1], vec![1, 0]];
        let fps: Vec<String> =
            orders.iter().map(|o| view_fingerprint(&net, &cl, &prof, o)).collect();

        let mut cache = EvalCache::new();
        for (perm, order) in orders.iter().enumerate() {
            let (vcl, vprof) = crate::planner::space::permuted_view(&cl, &prof, order);
            cache
                .partition(
                    &net,
                    &vcl,
                    &vprof,
                    &Candidate {
                        kind: ScheduleKind::OneFOneBSno,
                        m: 16,
                        micro: 8.0,
                        perm,
                        recompute: false,
                    },
                )
                .unwrap();
        }

        let path = std::env::temp_dir().join("bapipe-store-views-test.json");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        save_with_views(&path, &cache, &fp, &orders, &fps).unwrap();

        // Unchanged scenario: the full restore path reports itself.
        let (outcome, notes) = load_with_views(&path, &fp, &orders, &fps);
        assert!(matches!(outcome, CacheLoad::Loaded(_)));
        assert!(notes[0].contains("restored"), "{}", notes[0]);

        // The next run discovers only the swapped order (a shrunken
        // order set): the all-or-nothing match fails, but that view's
        // entries survive via the embedded fingerprints.
        let current_orders = vec![vec![1usize, 0]];
        let current_fps = vec![fps[1].clone()];
        let (outcome, notes) = load_with_views(&path, &fp, &current_orders, &current_fps);
        let mut salvaged = match outcome {
            CacheLoad::Loaded(c) => c,
            CacheLoad::Fresh(reason) => panic!("salvage must fire: {reason}"),
        };
        assert!(notes[0].contains("partial reuse"), "{}", notes[0]);
        assert!(notes[0].contains("1/1 views matched"), "{}", notes[0]);
        let (vcl, vprof) = crate::planner::space::permuted_view(&cl, &prof, &[1, 0]);
        salvaged
            .partition(
                &net,
                &vcl,
                &vprof,
                &Candidate {
                    kind: ScheduleKind::OneFOneBSno,
                    m: 16,
                    micro: 8.0,
                    perm: 0,
                    recompute: false,
                },
            )
            .unwrap();
        assert_eq!((salvaged.hits, salvaged.misses), (1, 0), "salvaged view must answer");

        // No surviving view at all → Fresh with the stale reason.
        let other = presets::v100_cluster(2);
        let oprof = analytical::profile(&net, &other);
        let foreign = vec![view_fingerprint(&net, &other, &oprof, &[0, 1])];
        let (outcome, notes) = load_with_views(&path, "other-fp", &[vec![0usize, 1]], &foreign);
        assert!(matches!(outcome, CacheLoad::Fresh(_)));
        assert!(notes[0].contains("stale plan cache"), "{}", notes[0]);
        let _ = std::fs::remove_file(&path);
    }
}
