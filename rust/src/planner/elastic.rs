//! Elastic clusters: failure-aware replanning with end-to-end warm
//! starts (`bapipe replan`).
//!
//! Training clusters change under a running job — a device is preempted,
//! a link degrades, a straggler appears, a repaired host rejoins. BaPipe's
//! exploration is cheap enough to re-run from scratch, but a replan is
//! latency-critical (the pipeline is stalled while it runs) and the
//! incumbent plan is a *very* strong prior: most of the mutated cluster
//! is the old cluster. This module turns one `(incumbent plan, cluster
//! event)` pair into a warm-started exploration:
//!
//! 1. **Incumbent re-evaluation** — the cached plan's candidate is
//!    evaluated *on the mutated cluster* first (one DES run). Its fresh
//!    epoch time — never the stale pre-mutation number — seeds the
//!    branch-and-bound, so provably-worse candidates are pruned from the
//!    first batch onward.
//! 2. **Superset search space** — the warm space is the cold space
//!    ([`SearchSpace::bapipe`] on the mutated cluster) plus the
//!    incumbent's M, schedule kind, recompute setting and device order
//!    (restricted to the surviving devices via [`surviving_order`]);
//!    past the 8-device wall the device-order axis comes from
//!    [`orders::discover_seeded`], which appends the incumbent-seeded
//!    climb after the unseeded prefix. Warm ⊇ cold by construction, so
//!    the warm plan is **never worse** than a cold exploration of the
//!    same mutated cluster — the warm win is latency, not quality.
//! 3. **Per-view cache salvage** — every [`EvalCache`] view whose
//!    device-name-id sequence survives the mutation keeps its balance
//!    seeds and finished partitions ([`EvalCache::salvage`] keyed by
//!    [`store::view_fingerprint`]), instead of the old all-or-nothing
//!    cache rejection.
//! 4. **Graceful degradation** — if the warm space holds no feasible
//!    pipeline (a loss can push every partition past memfit), the
//!    explorer automatically widens to the activation-recomputation and
//!    2BW axes before giving up; data parallelism is the last resort.
//!    Every widening leaves a provenance note.
//!
//! Each replan prices its own disruption: stage-boundary moves become a
//! [`MigrationReport`] — bytes of weights + optimizer state that must
//! move between physical devices
//! ([`crate::partition::memfit::movable_state_bytes`]) — next to a
//! structured [`PlanDiff`]. [`run_scenario`] replays a whole
//! [`Scenario`] (a deterministic [`ClusterEvent`] stream parsed from
//! JSON), replanning after every event and threading the salvaged cache
//! through, which is the `bapipe replan` CLI path and the
//! warm-vs-cold replan-latency bench.
//!
//! The loop is closed end to end: [`crate::cluster::detect`] synthesizes
//! the event stream from live timing samples (no script), each
//! [`mutate::ScenarioEvent`] may carry its epoch position in
//! micro-batches, the challenger's state transfers are *scheduled* into
//! the draining incumbent's bubbles
//! ([`super::migrate::schedule_migration`] — overlapped under 2BW shadow
//! weight versions, drain-and-copy otherwise), and [`amortize_switch`]
//! keeps the degraded incumbent when the migration stall cannot pay for
//! itself before the epoch boundary — a full-epoch re-cost
//! systematically over-rotates to new plans late in an epoch.

use super::diff::{self, MigrationReport, PlanDiff};
use super::migrate::{self, MigrationSchedule};
use super::orders;
use super::report::{Choice, Plan};
use super::space::{self, Candidate, SearchSpace};
use super::store;
use super::{EvalCache, Options};
use crate::cluster::mutate::{self, Scenario};
use crate::cluster::Cluster;
use crate::model::Network;
use crate::partition::memfit::MemoryModel;
use crate::profile::Profile;
use crate::schedule::ScheduleKind;
use crate::sim::engine::{epoch_from_makespan, simulate, SimSpec};
use std::collections::HashSet;

#[cfg(doc)]
use crate::cluster::mutate::ClusterEvent;

/// One warm replan: the new plan plus everything the next replan (and the
/// report) needs.
pub struct Replan {
    /// The plan selected on the mutated cluster.
    pub plan: Plan,
    /// Warm-start provenance: what was seeded, salvaged, widened or given
    /// up on — one line per decision, never silent.
    pub provenance: Vec<String>,
    /// [`store::view_fingerprint`] of every device order the exploration
    /// ran over — the salvage key carrying this replan's cache into the
    /// next event.
    pub view_fingerprints: Vec<String>,
    /// The exploration's evaluation cache (salvaged prior entries plus
    /// this replan's work).
    pub cache: EvalCache,
}

/// One event of a scenario replay: the mutation, the replanned result and
/// the migration price of switching plans.
pub struct ReplanStep {
    /// The event, as [`crate::cluster::mutate::ClusterEvent::describe`]s it.
    pub event: String,
    /// The mutated cluster ([`Cluster::describe`]).
    pub cluster: String,
    /// Warm-start provenance for this event (mutation note first).
    pub provenance: Vec<String>,
    /// Weights + optimizer state that must move between physical devices
    /// to switch from the previous plan to this one. `None` when either
    /// side is data-parallel (every device holds the full model — there
    /// is no stage state to migrate). Priced against the plan actually
    /// adopted: a kept incumbent moves nothing.
    pub migration: Option<MigrationReport>,
    /// Where the *challenger's* state transfers were placed relative to
    /// the draining incumbent ([`migrate::schedule_migration`]: overlap
    /// vs drain-and-copy, per-link slots, stall). Recorded even when the
    /// amortization keeps the incumbent — it is what the decision was
    /// based on. `None` when either side is data-parallel.
    pub schedule: Option<MigrationSchedule>,
    /// The mid-epoch switch-or-keep call — present only for positioned
    /// events ([`mutate::ScenarioEvent::at_mb`]) with a pipeline
    /// incumbent that can keep draining.
    pub decision: Option<SwitchDecision>,
    /// Structured previous-vs-new plan comparison.
    pub diff: PlanDiff,
    /// The plan selected after this event.
    pub plan: Plan,
}

/// A full scenario replay: one [`ReplanStep`] per event, in order.
pub struct ReplanRun {
    /// Scenario name (from the scenario JSON).
    pub scenario: String,
    /// Per-event results.
    pub steps: Vec<ReplanStep>,
}

impl ReplanRun {
    /// Human-readable replay transcript.
    pub fn render(&self) -> String {
        let mut lines = vec![format!("scenario: {}", self.scenario)];
        for (i, s) in self.steps.iter().enumerate() {
            lines.push(format!("event {}: {}", i + 1, s.event));
            lines.push(format!("  cluster: {}", s.cluster));
            for p in &s.provenance {
                lines.push(format!("  {p}"));
            }
            if let Some(m) = &s.migration {
                lines.push(format!("  {}", m.render()));
            }
            if let Some(sc) = &s.schedule {
                lines.push(format!("  {}", sc.render()));
            }
            if let Some(d) = &s.decision {
                lines.push(format!("  {}", d.describe()));
            }
            lines.push(format!("  plan: {}", s.plan.summary()));
        }
        lines.join("\n")
    }
}

/// Where in the epoch a cluster event lands, in micro-batches of
/// training progress under the incumbent plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EventPosition {
    /// Micro-batches already completed when the event fired.
    pub at_mb: u64,
    /// Micro-batches in the full epoch
    /// ([`epoch_micro_batches`]: mini-batches per epoch × the plan's M).
    pub total_mb: u64,
}

impl EventPosition {
    /// Fraction of the epoch still ahead, clamped to `[0, 1]` (a
    /// position at or past the boundary has nothing left to amortize).
    pub fn remaining_fraction(&self) -> f64 {
        if self.total_mb == 0 {
            return 0.0;
        }
        (1.0 - self.at_mb as f64 / self.total_mb as f64).clamp(0.0, 1.0)
    }
}

/// The switch-or-keep outcome of [`amortize_switch`].
#[derive(Debug, Clone, PartialEq)]
pub struct SwitchDecision {
    /// `true` = adopt the challenger now; `false` = keep the degraded
    /// incumbent until the epoch boundary.
    pub switched: bool,
    /// Seconds to finish the epoch on the degraded incumbent.
    pub remaining_incumbent: f64,
    /// Seconds to finish it on the challenger, migration stall included.
    pub remaining_challenger: f64,
    /// The migration stall charged to the challenger
    /// ([`MigrationSchedule::stall`]).
    pub stall: f64,
    /// Where the decision was taken.
    pub position: EventPosition,
}

impl SwitchDecision {
    /// One-line report rendering.
    pub fn describe(&self) -> String {
        format!(
            "mid-epoch at {}/{} micro-batches: {} — incumbent finishes in {:.3}s, challenger \
             in {:.3}s ({:.3}s migration stall)",
            self.position.at_mb,
            self.position.total_mb,
            if self.switched { "SWITCH" } else { "KEEP until the epoch boundary" },
            self.remaining_incumbent,
            self.remaining_challenger,
            self.stall
        )
    }
}

/// The mid-epoch amortization: compare finishing the epoch on the
/// degraded incumbent (`incumbent_epoch × remaining fraction`) against
/// paying the migration stall now and finishing on the challenger
/// (`stall + challenger_epoch × remaining fraction`). The switch happens
/// only when it strictly pays before the epoch boundary — except that an
/// incumbent that cannot run at all (non-finite epoch, e.g. its host was
/// lost) always switches. Both epochs must be full-epoch times on the
/// *mutated* cluster; a stale pre-event incumbent epoch would bias the
/// decision toward keeping.
pub fn amortize_switch(
    incumbent_epoch: f64,
    challenger_epoch: f64,
    stall: f64,
    position: EventPosition,
) -> SwitchDecision {
    let r = position.remaining_fraction();
    let remaining_incumbent = incumbent_epoch * r;
    let remaining_challenger = stall + challenger_epoch * r;
    let switched = !incumbent_epoch.is_finite() || remaining_challenger < remaining_incumbent;
    SwitchDecision { switched, remaining_incumbent, remaining_challenger, stall, position }
}

/// Micro-batches one epoch spans under `plan` on an `n_devices` cluster:
/// mini-batches per epoch × the plan's M — the `total_mb` of an
/// [`EventPosition`] (and the unit [`crate::cluster::detect`] stamps
/// detections in via `mb_per_tick`). `None` for a data-parallel plan,
/// which has no micro-batch structure.
pub fn epoch_micro_batches(plan: &Plan, n_devices: usize, opts: &Options) -> Option<u64> {
    match &plan.choice {
        Choice::Pipeline { m, .. } => {
            let global = crate::util::canonical_global_batch(opts.batch_per_device, n_devices);
            let n_mb = (opts.samples_per_epoch as f64 / global).ceil() as u64;
            Some(n_mb * *m as u64)
        }
        Choice::DataParallel => None,
    }
}

/// The incumbent's fresh DES on the mutated cluster: the drain timeline
/// the migration scheduler overlaps into, and the incumbent side of the
/// mid-epoch amortization.
struct DrainInfo {
    spec: SimSpec,
    hosts: Vec<usize>,
    makespan: f64,
    epoch: f64,
}

/// The incumbent device order carried into the mutated cluster: surviving
/// devices keep their old relative position (each old index mapped
/// through the inverted `lineage`, which reads
/// `lineage[new_idx] = Some(old_idx)`), and devices with no pre-mutation
/// lineage (joins) are appended in ascending index order. Always a
/// permutation of `0..n_new`.
pub fn surviving_order(order: &[usize], lineage: &[Option<usize>], n_new: usize) -> Vec<usize> {
    let inv = invert_lineage(lineage, order.len());
    let mut out: Vec<usize> =
        order.iter().filter_map(|&i| inv.get(i).copied().flatten()).collect();
    let present: HashSet<usize> = out.iter().copied().collect();
    for d in 0..n_new {
        if !present.contains(&d) {
            out.push(d);
        }
    }
    out
}

/// Invert a [`Mutation`](mutate::Mutation) lineage
/// (`lineage[new] = Some(old)`) into `inv[old] = Some(new)`; lost
/// devices stay `None`.
fn invert_lineage(lineage: &[Option<usize>], n_old: usize) -> Vec<Option<usize>> {
    let mut inv = vec![None; n_old];
    for (new, old) in lineage.iter().enumerate() {
        if let Some(o) = *old {
            if o < n_old {
                inv[o] = Some(new);
            }
        }
    }
    inv
}

/// Per-layer physical device assignment of a plan: layer `l` lives on the
/// device hosting its stage (`device_order[stage_of(l)]`). `None` for a
/// data-parallel plan — every device holds every layer, so there is no
/// per-layer placement to diff.
fn assign_map(plan: &Plan, n_layers: usize) -> Option<Vec<Option<usize>>> {
    match &plan.choice {
        Choice::Pipeline { partition, .. } => Some(
            (0..n_layers).map(|l| Some(plan.device_order[partition.stage_of(l)])).collect(),
        ),
        Choice::DataParallel => None,
    }
}

/// Index of `order`'s device-name sequence in the space's order axis
/// (permuting identical boards changes nothing, so lookup is by name-id
/// key, the same equivalence the enumeration dedups on).
fn order_index(space: &SearchSpace, cluster: &Cluster, order: &[usize]) -> usize {
    let ids = cluster.name_ids();
    let key = |o: &[usize]| o.iter().map(|&i| ids[i]).collect::<Vec<usize>>();
    space
        .device_orders
        .iter()
        .position(|o| key(o) == key(order))
        .expect("the warm space always contains the incumbent order")
}

/// The warm search space: the cold space of the mutated cluster widened —
/// purely additively — with the incumbent's device order, M, schedule
/// kind and recompute setting, so the incumbent candidate is always
/// evaluable and warm quality is never below cold quality.
fn warm_space(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    opts: &Options,
    incumbent_order: &[usize],
    incumbent: &Plan,
    provenance: &mut Vec<String>,
) -> SearchSpace {
    let n = cluster.len();
    let discovery_path =
        opts.permute_devices && opts.order_search && n > 8 && !cluster.is_homogeneous();
    let mut space = if discovery_path {
        // The order axis comes from the *seeded* neighbourhood search:
        // unseeded prefix first (cold-space superset guarantee), the
        // incumbent seed and its climb appended. The rest of the space is
        // built with the permutation axis off so the unseeded discovery
        // does not run a second time.
        let d = orders::discover_seeded(net, cluster, profile, opts, Some(incumbent_order));
        let mut s = SearchSpace::bapipe(
            net,
            cluster,
            profile,
            &Options { permute_devices: false, order_search: false, ..opts.clone() },
        );
        s.device_orders = d.orders;
        s.order_provenance = d.provenance;
        s.notes.extend(d.notes);
        s
    } else {
        SearchSpace::bapipe(net, cluster, profile, opts)
    };

    let ids = cluster.name_ids();
    let key = |o: &[usize]| o.iter().map(|&i| ids[i]).collect::<Vec<usize>>();
    if !space.device_orders.iter().any(|o| key(o) == key(incumbent_order)) {
        if !space.order_provenance.is_empty() {
            space.order_provenance.push("incumbent device order (elastic warm start)".to_string());
        }
        space.device_orders.push(incumbent_order.to_vec());
        space.notes.push(
            "elastic warm start: incumbent device order appended to the search axis".to_string(),
        );
        provenance.push("warm start: incumbent device order appended to the order axis".to_string());
    }

    if let Choice::Pipeline { kind, m, recompute, .. } = &incumbent.choice {
        if !space.m_grid.contains(m) {
            space.m_grid.push(*m);
            space.notes.push(format!("elastic warm start: incumbent M={m} appended to the grid"));
            provenance.push(format!("warm start: incumbent M={m} appended to the M grid"));
        }
        if !space.kinds.contains(kind) && !space.ineligible.contains(kind) {
            space.kinds.push(*kind);
            space.notes.push(format!(
                "elastic warm start: incumbent kind {} appended to the schedule axis",
                kind.label()
            ));
            provenance
                .push(format!("warm start: incumbent kind {} appended", kind.label()));
        }
        if *recompute && !space.recompute_options.contains(&true) {
            space.recompute_options.push(true);
            space.notes.push(
                "elastic warm start: incumbent uses recomputation — variants enumerated"
                    .to_string(),
            );
        }
    }
    space
}

/// One warm replan against an already-mutated `(cluster, profile)`.
///
/// `incumbent_order` is the incumbent's device order expressed in the
/// *mutated* cluster's indices ([`surviving_order`] maps it through a
/// mutation's lineage). `prior` carries the previous exploration's cache
/// and its per-view fingerprints; views whose name-id sequence survived
/// the mutation keep their entries ([`EvalCache::salvage`]). The returned
/// plan is never worse than a cold [`super::explore`] of the same mutated
/// cluster with the same `opts` (the warm space is a superset — see
/// module docs), and a degradation to wider axes or data parallelism is
/// recorded in the provenance.
pub fn replan(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    incumbent: &Plan,
    incumbent_order: &[usize],
    opts: &Options,
    prior: Option<(&EvalCache, &[String])>,
) -> Replan {
    let mut provenance = Vec::new();
    let space = warm_space(net, cluster, profile, opts, incumbent_order, incumbent, &mut provenance);
    let view_fingerprints: Vec<String> = space
        .device_orders
        .iter()
        .map(|o| store::view_fingerprint(net, cluster, profile, o))
        .collect();

    let mut cache = match prior {
        Some((prior_cache, prior_fps)) => {
            let (salvaged, st) = prior_cache.salvage(prior_fps, &view_fingerprints);
            provenance.push(format!(
                "cache salvage: {}/{} views matched, {} seeds + {} plans reused, {} entries \
                 dropped",
                st.views_matched, st.views_total, st.seeds_reused, st.plans_reused,
                st.entries_dropped
            ));
            salvaged
        }
        None => EvalCache::new(),
    };

    // Warm seed: the incumbent candidate evaluated on the *mutated*
    // cluster — one DES run whose fresh epoch (never the stale
    // pre-mutation number) primes the branch-and-bound.
    let n = cluster.len();
    let global = crate::util::canonical_global_batch(space.batch_per_device, n);
    let n_mb = (opts.samples_per_epoch as f64 / global).ceil() as usize;
    let mut seed = f64::INFINITY;
    if let Choice::Pipeline { kind, m, recompute, .. } = &incumbent.choice {
        let perm = order_index(&space, cluster, incumbent_order);
        let cand = Candidate {
            kind: *kind,
            m: *m,
            micro: global / *m as f64,
            perm,
            recompute: *recompute,
        };
        let (vcl, vprof) = space::permuted_view(cluster, profile, &space.device_orders[perm]);
        match super::eval::prepare(net, &vcl, &vprof, &mut cache, &cand, global, n_mb) {
            Ok(p) => {
                let makespan = simulate(&p.spec).makespan;
                seed = epoch_from_makespan(makespan, &p.spec, n_mb);
                provenance.push(format!(
                    "warm start: incumbent {} M={m} re-evaluated on the mutated cluster — epoch \
                     {seed:.3}s seeds the branch-and-bound",
                    kind.label()
                ));
            }
            Err(e) => {
                provenance.push(format!(
                    "warm start: incumbent {} M={m} infeasible on the mutated cluster ({e}); \
                     exploring unseeded",
                    kind.label()
                ));
            }
        }
    } else {
        provenance.push(
            "warm start: incumbent is data-parallel; exploring without a pipeline seed"
                .to_string(),
        );
    }

    let mut plan =
        super::explore_seeded_in_space(net, cluster, profile, &space, opts, &mut cache, seed);

    // Graceful degradation: no feasible pipeline in the warm space (a
    // loss can push every partition past memfit) — widen to the
    // recomputation and 2BW axes before giving up. Data parallelism (the
    // explorer's own fallback) is the last resort.
    if plan.report.best_evaluation().is_none() {
        let mut widened = space.clone();
        if !widened.kinds.contains(&ScheduleKind::TwoBW) {
            widened.kinds.push(ScheduleKind::TwoBW);
        }
        if !widened.recompute_options.contains(&true) {
            widened.recompute_options.push(true);
        }
        widened.notes.push(
            "elastic degradation: no feasible pipeline in the warm space — widened to the \
             recompute/2BW axes"
                .to_string(),
        );
        provenance.push(
            "degradation: no feasible pipeline — widened to the recompute/2BW axes".to_string(),
        );
        plan = super::explore_seeded_in_space(
            net, cluster, profile, &widened, opts, &mut cache, f64::INFINITY,
        );
        if plan.report.best_evaluation().is_none() {
            provenance.push(
                "degradation: still no feasible pipeline — data-parallel fallback".to_string(),
            );
        } else {
            provenance
                .push("degradation: widened axes recovered a feasible pipeline".to_string());
        }
    }

    Replan { plan, provenance, view_fingerprints, cache }
}

/// Replay a fault-injection [`Scenario`] against an incumbent plan:
/// apply each event through [`mutate::apply`], warm-replan
/// ([`replan`]) on the mutated cluster, *schedule* the challenger's
/// state transfers into the draining incumbent's bubbles
/// ([`migrate::schedule_migration`], old devices mapped through the
/// mutation lineage), amortize positioned events
/// ([`amortize_switch`] — a late-epoch event keeps the degraded
/// incumbent when switching cannot pay before the boundary), price the
/// adopted switch ([`diff::migration`]) and carry the mutated cluster,
/// the adopted plan and the salvaged cache into the next event. Errors
/// only on an invalid event (e.g. losing the last device); planning
/// itself always degrades gracefully. Bit-identical across `--jobs`:
/// every addition on top of the PR 8 driver is sequential arithmetic.
pub fn run_scenario(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    incumbent: &Plan,
    scenario: &Scenario,
    opts: &Options,
) -> Result<ReplanRun, String> {
    let mm = MemoryModel::default();
    let n_layers = net.len();
    let mut cl = cluster.clone();
    let mut prof = profile.clone();
    let mut plan = incumbent.clone();
    let mut carried: Option<(EvalCache, Vec<String>)> = None;
    let mut steps = Vec::new();
    for ev in &scenario.events {
        let mu = mutate::apply(net, &cl, &prof, &ev.event)?;
        let inv = invert_lineage(&mu.lineage, cl.len());
        let inc_order = surviving_order(&plan.device_order, &mu.lineage, mu.cluster.len());

        // Can the incumbent keep draining on the mutated cluster? Only
        // when it is a pipeline and every host survived (straggler /
        // link-degrade; a loss takes a host with it). Its fresh DES on
        // the *degraded* cluster — never the stale pre-event timing — is
        // both the drain the migration overlaps into and the incumbent
        // side of the amortization.
        let drain: Option<DrainInfo> = match &plan.choice {
            Choice::Pipeline { kind, m, micro, recompute, partition }
                if mu.cluster.len() == cl.len() =>
            {
                let hosts: Option<Vec<usize>> = plan
                    .device_order
                    .iter()
                    .map(|&d| inv.get(d).copied().flatten())
                    .collect();
                hosts.map(|hosts| {
                    let (vcl, vprof) = space::permuted_view(&mu.cluster, &mu.profile, &hosts);
                    let spec = super::eval::build_spec(
                        &vprof, &vcl, partition, *kind, *recompute, *micro, *m,
                    );
                    let global = crate::util::canonical_global_batch(
                        opts.batch_per_device,
                        mu.cluster.len(),
                    );
                    let n_mb = (opts.samples_per_epoch as f64 / global).ceil() as usize;
                    let makespan = simulate(&spec).makespan;
                    let epoch = epoch_from_makespan(makespan, &spec, n_mb);
                    DrainInfo { spec, hosts, makespan, epoch }
                })
            }
            _ => None,
        };

        let r = replan(
            net,
            &mu.cluster,
            &mu.profile,
            &plan,
            &inc_order,
            opts,
            carried.as_ref().map(|(c, f)| (c, f.as_slice())),
        );
        let mut provenance = vec![mu.note.clone()];
        provenance.extend(r.provenance);

        // Old placements travel through the inverted lineage into the
        // mutated cluster's index namespace: a layer whose host was lost
        // maps to `None` and is priced as a restore.
        let old_mapped: Option<Vec<Option<usize>>> = assign_map(&plan, n_layers).map(|old| {
            old.iter().map(|d| d.and_then(|i| inv.get(i).copied().flatten())).collect()
        });

        // Schedule the challenger's transfers against the drain.
        let schedule = match (&old_mapped, assign_map(&r.plan, n_layers)) {
            (Some(old), Some(new)) => Some(migrate::schedule_migration(
                &mu.profile,
                &mm,
                &mu.cluster,
                drain.as_ref().map(|d| (&d.spec, d.hosts.as_slice())),
                old,
                &new,
            )),
            _ => None,
        };

        // Mid-epoch amortization: a positioned event switches only when
        // the migration stall pays for itself before the epoch boundary.
        let mut decision = None;
        let mut adopt = true;
        if let (Some(at_mb), Some(sched)) = (ev.at_mb, schedule.as_ref()) {
            match (epoch_micro_batches(&plan, cl.len(), opts), &drain) {
                (Some(total_mb), Some(d)) => {
                    let call = amortize_switch(
                        d.epoch,
                        r.plan.epoch_time,
                        sched.stall,
                        EventPosition { at_mb, total_mb },
                    );
                    adopt = call.switched;
                    decision = Some(call);
                }
                (_, None) => provenance.push(
                    "mid-epoch: incumbent cannot continue on the mutated cluster — switching \
                     regardless of position"
                        .to_string(),
                ),
                (None, _) => provenance.push(
                    "mid-epoch: data-parallel incumbent has no micro-batch structure — \
                     switching at the event"
                        .to_string(),
                ),
            }
        }

        let adopted = if adopt {
            r.plan.clone()
        } else {
            // Keep the degraded incumbent until the epoch boundary: same
            // choice, order re-expressed in the mutated namespace, times
            // refreshed on the mutated cluster.
            let d = drain.as_ref().expect("keeping requires a draining incumbent");
            let mut kept = plan.clone();
            kept.device_order = inc_order.clone();
            kept.minibatch_time = d.makespan;
            kept.epoch_time = d.epoch;
            provenance.push(format!(
                "mid-epoch: keeping the degraded incumbent (fresh epoch {:.3}s on the mutated \
                 cluster); the challenger is reconsidered at the epoch boundary",
                d.epoch
            ));
            kept
        };

        // Price the switch actually adopted (a kept incumbent moves
        // nothing; the challenger's schedule above records what the
        // decision weighed).
        let migration = match (&old_mapped, assign_map(&adopted, n_layers)) {
            (Some(old), Some(new)) => Some(diff::migration(&mu.profile, &mm, old, &new)),
            _ => None,
        };

        steps.push(ReplanStep {
            event: ev.describe(),
            cluster: mu.cluster.describe(),
            provenance,
            migration,
            schedule,
            decision,
            diff: diff::compare(&plan, &adopted),
            plan: adopted.clone(),
        });
        cl = mu.cluster;
        prof = mu.profile;
        plan = adopted;
        carried = Some((r.cache, r.view_fingerprints));
    }
    Ok(ReplanRun { scenario: scenario.name.clone(), steps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::mutate::ClusterEvent;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    fn opts() -> Options {
        Options {
            batch_per_device: 8.0,
            samples_per_epoch: 8192,
            m_candidates: vec![4, 8, 16],
            consider_dp: false,
            ..Default::default()
        }
    }

    #[test]
    fn surviving_order_maps_losses_and_appends_joins() {
        // old order [2, 0, 1, 3], device 1 lost: lineage[new] = old is
        // [0, 2, 3] — old 2 → new 1, old 0 → new 0, old 3 → new 2
        let lineage = vec![Some(0), Some(2), Some(3)];
        assert_eq!(surviving_order(&[2, 0, 1, 3], &lineage, 3), vec![1, 0, 2]);
        // a join at position 1 of a 2-device cluster: lineage
        // [Some(0), None, Some(1)] — the joiner (new index 1) is appended
        let lineage = vec![Some(0), None, Some(1)];
        assert_eq!(surviving_order(&[1, 0], &lineage, 3), vec![2, 0, 1]);
    }

    #[test]
    fn replan_after_loss_is_feasible_and_warm_not_worse_than_cold() {
        let net = zoo::vgg16(224);
        let cl = presets::gpu_mixed_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let o = opts();
        let incumbent = super::super::explore(&net, &cl, &prof, &o);
        assert!(matches!(incumbent.choice, Choice::Pipeline { .. }));

        let mu = mutate::apply(&net, &cl, &prof, &ClusterEvent::DeviceLoss { device: 1 }).unwrap();
        let inc_order = surviving_order(&incumbent.device_order, &mu.lineage, mu.cluster.len());
        let warm = replan(&net, &mu.cluster, &mu.profile, &incumbent, &inc_order, &o, None);
        assert!(
            matches!(warm.plan.choice, Choice::Pipeline { .. }),
            "a 3-device remainder must still pipeline: {:?}",
            warm.provenance
        );
        let cold = super::super::explore(&net, &mu.cluster, &mu.profile, &o);
        assert!(
            warm.plan.epoch_time <= cold.epoch_time,
            "warm {} must not be worse than cold {}",
            warm.plan.epoch_time,
            cold.epoch_time
        );
        assert!(
            warm.provenance.iter().any(|p| p.contains("seeds the branch-and-bound")),
            "{:?}",
            warm.provenance
        );
    }

    #[test]
    fn scenario_replay_is_deterministic_across_job_counts() {
        let net = zoo::vgg16(224);
        let cl = presets::gpu_mixed_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let incumbent = super::super::explore(&net, &cl, &prof, &opts());
        let scenario = Scenario::scripted(
            "test",
            vec![
                ClusterEvent::Straggler { device: 0, slowdown: 1.5 },
                ClusterEvent::DeviceLoss { device: 3 },
                ClusterEvent::LinkDegrade { link: 0, bandwidth_factor: 0.5, latency_factor: 2.0 },
            ],
        );
        let a = run_scenario(&net, &cl, &prof, &incumbent, &scenario, &opts()).unwrap();
        let b = run_scenario(
            &net,
            &cl,
            &prof,
            &incumbent,
            &scenario,
            &Options { jobs: 8, ..opts() },
        )
        .unwrap();
        assert_eq!(a.steps.len(), 3);
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert_eq!(sa.plan.choice, sb.plan.choice, "event {}", sa.event);
            assert_eq!(sa.plan.epoch_time, sb.plan.epoch_time);
            assert_eq!(sa.plan.device_order, sb.plan.device_order);
            assert_eq!(
                sa.migration.as_ref().map(|m| m.bytes),
                sb.migration.as_ref().map(|m| m.bytes)
            );
            assert_eq!(sa.schedule, sb.schedule, "event {}", sa.event);
            assert_eq!(sa.decision, sb.decision);
        }
    }

    #[test]
    fn amortize_keeps_late_and_switches_early() {
        // incumbent 100 s/epoch, challenger 50 s/epoch, 2 s stall
        let early = amortize_switch(100.0, 50.0, 2.0, EventPosition { at_mb: 10, total_mb: 100 });
        assert!(early.switched, "{}", early.describe());
        assert!((early.remaining_incumbent - 90.0).abs() < 1e-12);
        assert!((early.remaining_challenger - 47.0).abs() < 1e-12);
        // 3% remaining: incumbent 3 s vs 2 + 1.5 = 3.5 s — keep
        let late = amortize_switch(100.0, 50.0, 2.0, EventPosition { at_mb: 97, total_mb: 100 });
        assert!(!late.switched, "{}", late.describe());
        assert!(late.describe().contains("KEEP"), "{}", late.describe());
        // an incumbent that cannot run always switches, even at the boundary
        let forced =
            amortize_switch(f64::INFINITY, 50.0, 2.0, EventPosition { at_mb: 100, total_mb: 100 });
        assert!(forced.switched);
        // equal remainders do not justify paying the stall
        let tie = amortize_switch(50.0, 50.0, 0.0, EventPosition { at_mb: 0, total_mb: 100 });
        assert!(!tie.switched, "a switch must strictly pay");
        // degenerate zero-length epoch: nothing left to amortize over
        assert!(!amortize_switch(100.0, 50.0, 2.0, EventPosition { at_mb: 0, total_mb: 0 }).switched);
    }

    #[test]
    fn positioned_events_amortize_and_keep_moves_nothing() {
        let net = zoo::vgg16(224);
        let cl = presets::gpu_mixed_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let o = opts();
        let incumbent = super::super::explore(&net, &cl, &prof, &o);
        let total = epoch_micro_batches(&incumbent, cl.len(), &o).expect("pipeline incumbent");
        let mut sc = Scenario::scripted(
            "positioned",
            vec![ClusterEvent::Straggler { device: 0, slowdown: 2.0 }],
        );
        sc.events[0].at_mb = Some(total - 1); // one micro-batch before the boundary
        let run = run_scenario(&net, &cl, &prof, &incumbent, &sc, &o).unwrap();
        let step = &run.steps[0];
        assert!(step.event.contains("at micro-batch"), "{}", step.event);
        let d = step.decision.as_ref().expect("positioned pipeline event must be amortized");
        assert_eq!(d.position, EventPosition { at_mb: total - 1, total_mb: total });
        let sched = step.schedule.as_ref().expect("pipeline-to-pipeline switch is scheduled");
        assert!(sched.stall <= sched.drain_stall + 1e-12, "{sched:?}");
        if d.switched {
            assert!(d.remaining_challenger < d.remaining_incumbent, "{}", d.describe());
        } else {
            // keeping moves nothing; the step's plan is the incumbent's
            // choice with times refreshed on the degraded cluster
            assert_eq!(step.migration.as_ref().unwrap().bytes, 0);
            assert_eq!(step.plan.choice, incumbent.choice);
            assert!(step.plan.epoch_time.is_finite());
            assert!(step.plan.epoch_time > incumbent.epoch_time, "straggler slows the epoch");
        }
        // transcript carries the schedule and the decision
        let text = run.render();
        assert!(text.contains("migration schedule:"), "{text}");
        assert!(text.contains("mid-epoch at"), "{text}");
        // an unpositioned replay of the same event is the PR 8 behavior
        let sc0 = Scenario::scripted(
            "unpositioned",
            vec![ClusterEvent::Straggler { device: 0, slowdown: 2.0 }],
        );
        let run0 = run_scenario(&net, &cl, &prof, &incumbent, &sc0, &o).unwrap();
        assert!(run0.steps[0].decision.is_none());
    }

    #[test]
    fn migration_is_priced_and_cache_salvage_reported() {
        let net = zoo::vgg16(224);
        let cl = presets::gpu_mixed_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let incumbent = super::super::explore(&net, &cl, &prof, &opts());
        let scenario = Scenario::scripted(
            "loss-then-straggler",
            vec![
                ClusterEvent::DeviceLoss { device: 1 },
                ClusterEvent::Straggler { device: 0, slowdown: 2.0 },
            ],
        );
        let run = run_scenario(&net, &cl, &prof, &incumbent, &scenario, &opts()).unwrap();
        // losing a host forces its layers elsewhere: bytes must move
        let mig = run.steps[0].migration.as_ref().expect("pipeline-to-pipeline migration");
        assert!(mig.moved_layers > 0, "a lost device's layers must move");
        assert!(mig.bytes > 0);
        assert!(mig.moved_layers <= mig.n_layers);
        // the second event threads the first's cache through salvage
        assert!(
            run.steps[1].provenance.iter().any(|p| p.contains("cache salvage")),
            "{:?}",
            run.steps[1].provenance
        );
        // the rendered transcript mentions every event
        let text = run.render();
        assert!(text.contains("device-loss"), "{text}");
        assert!(text.contains("straggler"), "{text}");
    }
}
