//! Candidate evaluation: balanced partition → `SimSpec` → feasibility →
//! discrete-event simulation. The spec builders moved here from the seed
//! `explorer` (which now re-exports them).

use super::cache::EvalCache;
use super::space::Candidate;
use super::Options;
use crate::cluster::Cluster;
use crate::model::Network;
use crate::partition::intralayer::frac_stage_costs;
use crate::partition::memfit::{stage_bytes, MemoryModel, StageBytes};
use crate::partition::{
    balanced_partition, cut_comm_time, stage_costs, Partition, PartitionPlan,
};
use crate::profile::range::CostModel;
use crate::profile::Profile;
use crate::schedule::ScheduleKind;
use crate::sim::engine::{epoch_from_makespan, simulate, SimSpec};

/// Build the SimSpec for a full balanced-partition plan, using the
/// intra-layer fractional stage costs when the flow produced them (the
/// paper's Section 3.3.2 refinement; communication stays at the integral
/// boundaries, which the fractional bounds stay within one layer of).
pub fn build_spec_plan(
    profile: &Profile,
    cluster: &Cluster,
    plan: &PartitionPlan,
    kind: ScheduleKind,
    recompute: bool,
    micro: f64,
    m: usize,
) -> SimSpec {
    let mut spec = build_spec(profile, cluster, &plan.partition, kind, recompute, micro, m);
    if let Some(fp) = &plan.frac {
        let frac = frac_stage_costs(profile, fp, micro);
        // keep any stage-level floor (FPGA weight-spill penalty) from the
        // integral costs: the fractional refinement only rebalances compute
        for (i, (f, b)) in frac.into_iter().enumerate() {
            spec.fwd[i] = f.max(1e-12);
            // recomputation replays the stage forward before its backward,
            // so the refined backward slot carries the same surcharge
            spec.bwd[i] = if recompute { (f + b).max(1e-12) } else { b.max(1e-12) };
        }
    }
    spec
}

/// Build the SimSpec for a (kind, partition, micro) candidate. Generic
/// over the cost model so the exploration (on a [`Profile`]) and the
/// order search's DES verification pass (on prebuilt
/// [`crate::profile::range::RangeCost`] prefix tables) share one builder
/// — a probe spec and a phase-B spec for the same candidate are
/// bit-identical by construction.
pub fn build_spec<C: CostModel>(
    costs_model: &C,
    cluster: &Cluster,
    part: &Partition,
    kind: ScheduleKind,
    recompute: bool,
    micro: f64,
    m: usize,
) -> SimSpec {
    let costs = stage_costs(costs_model, cluster, part, micro);
    let n = part.n_stages();
    let fwd_xfer: Vec<f64> =
        (0..n - 1).map(|i| cut_comm_time(costs_model, cluster, part, micro, i)).collect();
    SimSpec {
        kind,
        m,
        fwd: costs.iter().map(|c| c.0).collect(),
        // activation recomputation replays the stage forward from the
        // stashed boundary input before running the backward, so each
        // backward slot is priced F+B (the memory side of the trade is
        // in [`crate::partition::memfit::stage_bytes`])
        bwd: costs.iter().map(|c| if recompute { c.0 + c.1 } else { c.1 }).collect(),
        update: vec![0.0; n],
        bwd_xfer: fwd_xfer.clone(), // errors are activation-sized (Section 1)
        fwd_xfer,
        exec: cluster.devices.iter().map(|d| d.exec).collect(),
    }
}

/// Per-stage byte components of a candidate plan — the planner's handle
/// on both the worst-case feasibility bytes ([`StageBytes::peak`]) and
/// the simulated-peak derivation ([`StageBytes::at_occupancy`] at the
/// DES in-flight high-water mark).
pub fn plan_stage_bytes(
    profile: &Profile,
    kind: ScheduleKind,
    recompute: bool,
    part: &Partition,
    micro: f64,
    m: usize,
) -> Vec<StageBytes> {
    let mm = MemoryModel::default();
    let n = part.n_stages();
    (0..n)
        .map(|i| stage_bytes(profile, &mm, kind, recompute, n, i, part.stage(i), micro, m))
        .collect()
}

/// Per-stage worst-case memory of a candidate plan.
pub fn plan_memory(
    profile: &Profile,
    kind: ScheduleKind,
    recompute: bool,
    part: &Partition,
    micro: f64,
    m: usize,
) -> Vec<u64> {
    plan_stage_bytes(profile, kind, recompute, part, micro, m)
        .iter()
        .map(StageBytes::peak)
        .collect()
}

/// Does every stage of a candidate fit its device?
pub fn fits(
    profile: &Profile,
    cluster: &Cluster,
    kind: ScheduleKind,
    recompute: bool,
    part: &Partition,
    micro: f64,
    m: usize,
) -> bool {
    let mm = MemoryModel::default();
    plan_memory(profile, kind, recompute, part, micro, m)
        .iter()
        .zip(&cluster.devices)
        .all(|(&used, d)| used <= mm.usable(d.mem_capacity))
}

/// Does micro-batch count `m` evenly divide the global mini-batch? The
/// single source of truth for the planner's divisibility rule — the
/// phase-A prewarm skip-set and the per-candidate rejection in
/// [`prepare`] must always agree.
///
/// The global batch arrives as `B × N` computed in f64, which can land a
/// hair below the intended integer (7.999999999999999 × 4 =
/// 31.999999999999996); `as usize` truncation turned that into 31 and
/// silently rejected every divisor of 32, so the value is *rounded* to
/// the nearest integer instead.
pub(crate) fn divides_global(global_batch: f64, m: usize) -> bool {
    m != 0 && (global_batch.round() as usize) % m == 0
}

/// A candidate that survived phase A: its DES spec, partition and
/// analytical epoch lower bound.
#[derive(Debug)]
pub(crate) struct Prepared {
    pub spec: SimSpec,
    pub partition: Partition,
    pub lb_epoch: f64,
    /// Per-stage byte constants; phase B turns the DES in-flight
    /// high-water marks into simulated peak bytes through these.
    pub stage_bytes: Vec<StageBytes>,
}

/// Phase A of the exploration for one candidate: divisibility, balanced
/// partition (memoized through `cache`), memory feasibility, spec
/// construction and the branch-and-bound lower bound. `Err` carries the
/// human-readable infeasibility reason.
pub(crate) fn prepare(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    cache: &mut EvalCache,
    cand: &Candidate,
    global_batch: f64,
    n_minibatches: usize,
) -> Result<Prepared, String> {
    if !divides_global(global_batch, cand.m) {
        return Err(format!("M={} does not divide the global mini-batch {global_batch}", cand.m));
    }
    let plan = cache.partition(net, cluster, profile, cand)?;
    let sb =
        plan_stage_bytes(profile, cand.kind, cand.recompute, &plan.partition, cand.micro, cand.m);
    let mm = MemoryModel::default();
    if !sb
        .iter()
        .zip(&cluster.devices)
        .all(|(b, d)| b.peak() <= mm.usable(d.mem_capacity))
    {
        return Err("stage memory exceeds device capacity".to_string());
    }
    let spec =
        build_spec_plan(profile, cluster, &plan, cand.kind, cand.recompute, cand.micro, cand.m);
    let lb_epoch = super::bounds::epoch_lower_bound(&spec, n_minibatches);
    // Debug builds statically certify every candidate before it reaches
    // the DES: the generated program's dependency/transfer/deadlock/
    // staleness analysis plus the occupancy-vs-StageBytes cross-check.
    // Release builds skip this (CI runs the suite once with
    // `RUSTFLAGS="-C debug-assertions"` so the gate executes at release
    // optimization levels too).
    #[cfg(debug_assertions)]
    {
        let usable: Vec<u64> =
            cluster.devices.iter().map(|d| mm.usable(d.mem_capacity)).collect();
        let gate =
            crate::verify::check_candidate(cand.kind, spec.n(), cand.m, &sb, Some(&usable));
        debug_assert!(
            gate.violations.is_empty(),
            "planner verify gate rejected {:?}:\n{}",
            cand,
            gate.render("candidate")
        );
    }
    Ok(Prepared { spec, partition: plan.partition, lb_epoch, stage_bytes: sb })
}

/// Evaluate one fully-specified pipeline candidate (the seed explorer's
/// entry point, kept for compatibility and ad-hoc probing). Returns
/// `(minibatch_time, epoch_time, partition)` or `None` if infeasible.
pub fn evaluate_pipeline(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    kind: ScheduleKind,
    m: usize,
    opts: &Options,
) -> Option<(f64, f64, Partition)> {
    let n = cluster.len();
    let global = crate::util::canonical_global_batch(opts.batch_per_device, n);
    if !divides_global(global, m) {
        return None;
    }
    let micro = global / m as f64;
    let plan = balanced_partition(net, cluster, profile, kind, micro, m).ok()?;
    if !fits(profile, cluster, kind, false, &plan.partition, micro, m) {
        return None;
    }
    let spec = build_spec_plan(profile, cluster, &plan, kind, false, micro, m);
    let n_mb = (opts.samples_per_epoch as f64 / global).ceil() as usize;
    let makespan = simulate(&spec).makespan;
    let ep = epoch_from_makespan(makespan, &spec, n_mb);
    Some((makespan, ep, plan.partition))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;
    use crate::profile::analytical;

    #[test]
    fn divisibility_rounds_the_global_batch() {
        // 7.999999999999999 × 4 = 31.999999999999996: truncation saw 31
        // (a prime!) and rejected every divisor of the intended batch.
        let global = 7.999999999999999_f64 * 4.0;
        assert!(global < 32.0, "the premise: the f64 product lands below 32");
        assert!(divides_global(global, 32), "M=32 must survive rounding");
        assert!(divides_global(global, 8));
        assert!(!divides_global(global, 5), "rounding must not loosen the filter");
        // exact integers behave as before
        assert!(divides_global(128.0, 32));
        assert!(!divides_global(128.0, 3));
        assert!(!divides_global(128.0, 0), "M=0 never divides");
    }

    #[test]
    fn prepare_rejects_non_divisor_m() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let mut cache = EvalCache::new();
        let cand = Candidate {
            kind: ScheduleKind::OneFOneBSno,
            m: 3,
            micro: 128.0 / 3.0,
            perm: 0,
            recompute: false,
        };
        let err = prepare(&net, &cl, &prof, &mut cache, &cand, 128.0, 64).unwrap_err();
        assert!(err.contains("does not divide"), "{err}");
        assert_eq!(cache.misses, 0, "no partition work for a non-divisor M");
    }

    #[test]
    fn recompute_reprices_time_and_bytes_consistently() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let plan =
            balanced_partition(&net, &cl, &prof, ScheduleKind::OneFOneBSno, 8.0, 16).unwrap();
        let part = &plan.partition;
        // time: every backward slot absorbs the replayed forward, exactly
        let s0 = build_spec(&prof, &cl, part, ScheduleKind::OneFOneBSno, false, 8.0, 16);
        let s1 = build_spec(&prof, &cl, part, ScheduleKind::OneFOneBSno, true, 8.0, 16);
        for i in 0..s0.fwd.len() {
            assert_eq!(s1.fwd[i], s0.fwd[i]);
            assert_eq!(s1.bwd[i], s0.fwd[i] + s0.bwd[i]);
        }
        // bytes: the deepest-stashing stage trades its intermediate stash
        // for a boundary-only one and must get strictly cheaper
        let b0 = plan_stage_bytes(&prof, ScheduleKind::OneFOneBSno, false, part, 8.0, 16);
        let b1 = plan_stage_bytes(&prof, ScheduleKind::OneFOneBSno, true, part, 8.0, 16);
        assert!(b1[0].peak() < b0[0].peak(), "{} !< {}", b1[0].peak(), b0[0].peak());
        assert!(b1[0].per_mb_stash < b0[0].per_mb_stash);
        assert_eq!(b1[0].stash_depth, b0[0].stash_depth, "the schedule's depth is unchanged");
        // and plan_memory is exactly the peak view of plan_stage_bytes
        let pm = plan_memory(&prof, ScheduleKind::OneFOneBSno, true, part, 8.0, 16);
        assert_eq!(pm, b1.iter().map(StageBytes::peak).collect::<Vec<_>>());
    }

    #[test]
    fn prepare_matches_evaluate_pipeline() {
        let net = zoo::vgg16(224);
        let cl = presets::v100_cluster(4);
        let prof = analytical::profile(&net, &cl);
        let opts = Options { batch_per_device: 32.0, samples_per_epoch: 8192, ..Default::default() };
        let mut cache = EvalCache::new();
        let m = 16;
        let cand =
            Candidate { kind: ScheduleKind::OneFOneBSo, m, micro: 8.0, perm: 0, recompute: false };
        let p = prepare(&net, &cl, &prof, &mut cache, &cand, 128.0, 64).unwrap();
        let (mb, ep, part) =
            evaluate_pipeline(&net, &cl, &prof, ScheduleKind::OneFOneBSo, m, &opts).unwrap();
        assert_eq!(p.partition, part);
        let makespan = simulate(&p.spec).makespan;
        assert_eq!(makespan, mb);
        assert_eq!(epoch_from_makespan(makespan, &p.spec, 64), ep);
        // the lower bound must hold on its own spec
        assert!(p.lb_epoch <= ep * (1.0 + 1e-9), "lb {} vs epoch {ep}", p.lb_epoch);
    }
}
