//! Markov-chain corpus generator.
//!
//! Each token has `branch` likely successors (a deterministic pseudo-random
//! set per token) receiving `1 - noise` of the probability mass; the rest
//! is uniform. Conditional entropy ≈ `(1-noise)·ln(branch) + noise·ln(V)`
//! — a learnable structure with a computable loss floor, which the e2e
//! example reports next to the measured curve.

use crate::util::rng::Rng;

/// Seeded Markov token stream.
#[derive(Debug, Clone)]
pub struct MarkovCorpus {
    /// Vocabulary size.
    pub vocab: usize,
    /// Likely successors per token.
    pub branch: usize,
    /// Probability mass on the uniform tail.
    pub noise: f64,
    rng: Rng,
    state: usize,
    seed: u64,
}

impl MarkovCorpus {
    /// New corpus; `branch` must be ≤ `vocab`.
    pub fn new(vocab: usize, branch: usize, noise: f64, seed: u64) -> MarkovCorpus {
        assert!(branch >= 1 && branch <= vocab);
        assert!((0.0..=1.0).contains(&noise));
        MarkovCorpus { vocab, branch, noise, rng: Rng::new(seed), state: 0, seed }
    }

    /// The j-th likely successor of token `t` (deterministic).
    fn successor(&self, t: usize, j: usize) -> usize {
        // SplitMix-style hash of (t, j) — stable across runs.
        let mut z = (t as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(j as u64)
            .wrapping_add(self.seed);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        (z ^ (z >> 31)) as usize % self.vocab
    }

    /// Next token.
    pub fn next_token(&mut self) -> usize {
        let t = if self.rng.f64() < self.noise {
            self.rng.below(self.vocab as u64) as usize
        } else {
            let j = self.rng.below(self.branch as u64) as usize;
            self.successor(self.state, j)
        };
        self.state = t;
        t
    }

    /// A batch of (inputs, targets): `b` sequences of length `s`, targets
    /// shifted by one (next-token prediction). Tokens as i32 for the i32
    /// HLO inputs.
    pub fn batch(&mut self, b: usize, s: usize) -> (Vec<i32>, Vec<i32>) {
        let mut inputs = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        for _ in 0..b {
            let mut prev = self.next_token();
            for _ in 0..s {
                let nxt = self.next_token();
                inputs.push(prev as i32);
                targets.push(nxt as i32);
                prev = nxt;
            }
        }
        (inputs, targets)
    }

    /// Theoretical conditional-entropy floor in nats (the best possible
    /// mean cross-entropy a model can reach on this stream).
    pub fn entropy_floor(&self) -> f64 {
        // Likely successors may collide; treat branch as distinct (upper
        // bound) — close enough for a reference line on the loss plot.
        let p_likely = (1.0 - self.noise) / self.branch as f64;
        let p_tail = self.noise / self.vocab as f64;
        // per-successor mass: branch tokens get p_likely + p_tail, the
        // rest get p_tail.
        let mut h = 0.0;
        let p1 = p_likely + p_tail;
        h -= self.branch as f64 * p1 * p1.ln();
        let rest = self.vocab - self.branch;
        if rest > 0 && p_tail > 0.0 {
            h -= rest as f64 * p_tail * p_tail.ln();
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = MarkovCorpus::new(512, 8, 0.1, 7);
        let mut b = MarkovCorpus::new(512, 8, 0.1, 7);
        assert_eq!(a.batch(2, 16), b.batch(2, 16));
    }

    #[test]
    fn batch_shapes_and_shift() {
        let mut c = MarkovCorpus::new(64, 4, 0.0, 1);
        let (x, y) = c.batch(3, 10);
        assert_eq!(x.len(), 30);
        assert_eq!(y.len(), 30);
        // within a row, targets are inputs shifted by one
        for row in 0..3 {
            for i in 0..9 {
                assert_eq!(x[row * 10 + i + 1], y[row * 10 + i]);
            }
        }
    }

    #[test]
    fn tokens_in_range() {
        let mut c = MarkovCorpus::new(100, 5, 0.3, 3);
        let (x, y) = c.batch(4, 64);
        assert!(x.iter().chain(&y).all(|&t| (0..100).contains(&t)));
    }

    #[test]
    fn entropy_floor_below_log_v() {
        let c = MarkovCorpus::new(4096, 8, 0.1, 0);
        let h = c.entropy_floor();
        assert!(h < (4096f64).ln(), "floor {h}");
        assert!(h > (8f64).ln() * 0.8, "floor {h} not absurdly low");
    }

    #[test]
    fn structure_is_learnable_bigram() {
        // Empirical successor distribution of a fixed token should be
        // concentrated: the top-8 successors should hold ~90% of mass.
        let mut c = MarkovCorpus::new(256, 8, 0.1, 11);
        let mut counts = vec![0u32; 256];
        let mut total = 0u32;
        let mut prev = c.next_token();
        for _ in 0..400_000 {
            let t = c.next_token();
            if prev == 42 {
                counts[t] += 1;
                total += 1;
            }
            prev = t;
        }
        let mut v: Vec<u32> = counts.clone();
        v.sort_unstable_by(|a, b| b.cmp(a));
        let top8: u32 = v[..8].iter().sum();
        assert!(total > 500, "not enough samples ({total})");
        let frac = top8 as f64 / total as f64;
        assert!(frac > 0.8, "top-8 successor mass {frac}");
    }
}
