//! Synthetic training data: a seeded Markov-chain token stream with a
//! known entropy floor, so the e2e loss curve has a meaningful target
//! (initial loss ≈ ln V, floor ≈ the chain's conditional entropy).

pub mod synth;

pub use synth::MarkovCorpus;
