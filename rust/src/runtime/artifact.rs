//! Artifact manifest: the JSON contract between `python/compile/aot.py`
//! and the rust engine (parsed with the in-repo `util::json` — no serde
//! offline).

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One parameter's metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamMeta {
    /// Name (`blk0.wqkv`, ...).
    pub name: String,
    /// Shape.
    pub shape: Vec<usize>,
}

impl ParamMeta {
    /// Element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One stage's metadata.
#[derive(Debug, Clone)]
pub struct StageMeta {
    /// `first` / `mid` / `last`.
    pub kind: String,
    /// Transformer blocks in this stage.
    pub blocks: usize,
    /// Artifact file per program (`init`/`fwd`/`bwd`/`opt`).
    pub files: std::collections::BTreeMap<String, String>,
    /// Parameter list in positional order.
    pub params: Vec<ParamMeta>,
    /// Input activation shape.
    pub in_shape: Vec<usize>,
    /// `i32` (tokens) or `f32`.
    pub in_dtype: String,
}

impl StageMeta {
    /// Total parameter elements of this stage.
    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|p| p.elems()).sum()
    }
}

/// The whole artifact bundle's manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model name (`lm10m`, ...).
    pub model: String,
    /// Model dim.
    pub d_model: usize,
    /// Total transformer blocks.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Vocabulary.
    pub vocab: usize,
    /// Sequence length.
    pub seq: usize,
    /// Static micro-batch size the programs were lowered at.
    pub micro_batch: usize,
    /// Pipeline stages.
    pub n_stages: usize,
    /// Were the Pallas kernels used (vs pure-jnp ops)?
    pub use_pallas: bool,
    /// Per-stage metadata.
    pub stages: Vec<StageMeta>,
    /// Directory the artifacts live in.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing manifest: {e}"))?;
        let stages = j
            .req_arr("stages")?
            .iter()
            .map(|s| -> crate::Result<StageMeta> {
                let files = s
                    .req("files")?
                    .as_obj()
                    .ok_or_else(|| anyhow::anyhow!("files not an object"))?
                    .iter()
                    .map(|(k, v)| (k.clone(), v.as_str().unwrap_or_default().to_string()))
                    .collect();
                let params = s
                    .req_arr("params")?
                    .iter()
                    .map(|p| -> crate::Result<ParamMeta> {
                        Ok(ParamMeta {
                            name: p.req_str("name")?.to_string(),
                            shape: p
                                .req_arr("shape")?
                                .iter()
                                .map(|d| d.as_usize().unwrap_or(0))
                                .collect(),
                        })
                    })
                    .collect::<crate::Result<Vec<_>>>()?;
                Ok(StageMeta {
                    kind: s.req_str("kind")?.to_string(),
                    blocks: s.req_usize("blocks")?,
                    files,
                    params,
                    in_shape: s
                        .req_arr("in_shape")?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    in_dtype: s.req_str("in_dtype")?.to_string(),
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(Manifest {
            model: j.req_str("model")?.to_string(),
            d_model: j.req_usize("d_model")?,
            n_layers: j.req_usize("n_layers")?,
            n_heads: j.req_usize("n_heads")?,
            vocab: j.req_usize("vocab")?,
            seq: j.req_usize("seq")?,
            micro_batch: j.req_usize("micro_batch")?,
            n_stages: j.req_usize("n_stages")?,
            use_pallas: j.get("use_pallas").and_then(|v| v.as_bool()).unwrap_or(false),
            stages,
            dir,
        })
    }

    /// Activation shape between stages: `[micro, seq, d_model]`.
    pub fn act_shape(&self) -> Vec<usize> {
        vec![self.micro_batch, self.seq, self.d_model]
    }

    /// Total parameters across stages.
    pub fn total_params(&self) -> usize {
        self.stages.iter().map(|s| s.param_elems()).sum()
    }

    /// Cross-check against the rust cost-model zoo (the L2/L3 contract):
    /// same parameter count as `model::zoo::transformer_lm` for the same
    /// config.
    pub fn crosscheck_zoo(&self) -> crate::Result<()> {
        let cfg = crate::model::zoo::TransformerCfg {
            d_model: self.d_model as u64,
            n_layers: self.n_layers as u64,
            n_heads: self.n_heads as u64,
            vocab: self.vocab as u64,
            seq: self.seq as u64,
        };
        // python model unties the head and has no pos-emb asymmetries:
        // zoo counts tok+pos emb and an untied head = vocab*d.
        let zoo = cfg.param_count() as i64 + (self.vocab * self.d_model) as i64;
        let ours = self.total_params() as i64;
        let rel = (zoo - ours).abs() as f64 / ours as f64;
        anyhow::ensure!(
            rel < 0.02,
            "manifest params {ours} vs zoo {zoo} differ by {rel:.3}"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/lm1m-s2-b2-jnp");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn load_manifest_if_built() {
        let Some(dir) = artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model, "lm1m");
        assert_eq!(m.n_stages, 2);
        assert_eq!(m.stages.len(), 2);
        assert_eq!(m.stages[0].kind, "first");
        assert_eq!(m.stages[1].kind, "last");
        assert_eq!(m.act_shape(), vec![2, 32, 128]);
        assert!(m.stages[0].params[0].name == "tok_emb");
        m.crosscheck_zoo().unwrap();
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
