//! XLA/PJRT runtime: loads the AOT artifacts `make artifacts` produced
//! (`artifacts/<cfg>/stage<k>_{init,fwd,bwd,opt}.hlo.txt` + manifest) and
//! executes them on the PJRT CPU client. HLO **text** is the interchange
//! format — see `python/compile/aot.py` and DESIGN.md.

pub mod artifact;
pub mod stage;

pub use artifact::{Manifest, StageMeta};
pub use stage::{Runtime, StageExe};

/// Build an f32 literal of the given shape filled with `v`.
pub fn f32_literal(dims: &[usize], v: f32) -> crate::Result<xla::Literal> {
    let count: usize = dims.iter().product::<usize>().max(1);
    let flat = vec![v; count];
    let lit = xla::Literal::vec1(&flat);
    if dims.is_empty() {
        // scalar
        Ok(xla::Literal::scalar(v))
    } else {
        Ok(lit.reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>())?)
    }
}

/// Build an i32 literal from data + shape.
pub fn i32_literal(data: &[i32], dims: &[usize]) -> crate::Result<xla::Literal> {
    anyhow::ensure!(data.len() == dims.iter().product::<usize>(), "shape/data mismatch");
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>())?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_builders() {
        let l = f32_literal(&[2, 3], 0.5).unwrap();
        assert_eq!(l.element_count(), 6);
        let v = l.to_vec::<f32>().unwrap();
        assert!(v.iter().all(|&x| x == 0.5));
        let s = f32_literal(&[], 2.0).unwrap();
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![2.0]);
        let i = i32_literal(&[1, 2, 3, 4], &[2, 2]).unwrap();
        assert_eq!(i.element_count(), 4);
        assert!(i32_literal(&[1, 2], &[3]).is_err());
    }
}
