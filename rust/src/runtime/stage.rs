//! Compiled per-stage executables and the typed call wrappers the
//! pipeline engine uses on its hot path.

use super::artifact::{Manifest, StageMeta};
use super::f32_literal;
use std::path::Path;
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// One pipeline stage's four compiled programs.
pub struct StageExe {
    /// Stage index.
    pub idx: usize,
    /// Manifest metadata.
    pub meta: StageMeta,
    init: PjRtLoadedExecutable,
    fwd: PjRtLoadedExecutable,
    bwd: PjRtLoadedExecutable,
    opt: PjRtLoadedExecutable,
}

fn compile(client: &PjRtClient, path: &Path) -> crate::Result<PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

/// Execute and unpack the (return_tuple=True) result into leaf literals.
fn run(exe: &PjRtLoadedExecutable, args: &[&Literal]) -> crate::Result<Vec<Literal>> {
    let result = exe.execute::<&Literal>(args)?;
    let lit = result[0][0].to_literal_sync()?;
    Ok(lit.to_tuple()?)
}

impl StageExe {
    /// Initialize parameters from a seed.
    pub fn init(&self, seed: i32) -> crate::Result<Vec<Literal>> {
        let s = Literal::scalar(seed);
        let out = run(&self.init, &[&s])?;
        anyhow::ensure!(
            out.len() == self.meta.params.len(),
            "init returned {} arrays, manifest says {}",
            out.len(),
            self.meta.params.len()
        );
        Ok(out)
    }

    /// Forward: params + input (+ targets on the last stage).
    /// Returns activations (or the scalar loss literal on the last stage).
    pub fn fwd(
        &self,
        params: &[Literal],
        x: &Literal,
        targets: Option<&Literal>,
    ) -> crate::Result<Literal> {
        let mut args: Vec<&Literal> = params.iter().collect();
        args.push(x);
        if self.meta.kind == "last" {
            args.push(targets.ok_or_else(|| anyhow::anyhow!("last stage needs targets"))?);
        }
        let mut out = run(&self.fwd, &args)?;
        anyhow::ensure!(out.len() == 1, "fwd returned {} outputs", out.len());
        Ok(out.pop().unwrap())
    }

    /// Backward with gradient accumulation: returns `(acc', Some(gx))` —
    /// `gx` is `None` on the first stage (tokens carry no gradient).
    /// `gy_or_targets` is the upstream gradient (mid) or targets (last).
    pub fn bwd(
        &self,
        params: &[Literal],
        acc: &[Literal],
        x: &Literal,
        gy_or_targets: &Literal,
    ) -> crate::Result<(Vec<Literal>, Option<Literal>)> {
        let mut args: Vec<&Literal> = params.iter().chain(acc.iter()).collect();
        args.push(x);
        args.push(gy_or_targets);
        let mut out = run(&self.bwd, &args)?;
        let p = self.meta.params.len();
        if self.meta.kind == "first" {
            anyhow::ensure!(out.len() == p, "first-stage bwd arity {}", out.len());
            Ok((out, None))
        } else {
            anyhow::ensure!(out.len() == p + 1, "bwd arity {}", out.len());
            let gx = out.pop().unwrap();
            Ok((out, Some(gx)))
        }
    }

    /// Adam step: returns `(params', m', v')`.
    #[allow(clippy::too_many_arguments)]
    pub fn opt(
        &self,
        params: &[Literal],
        acc: &[Literal],
        m: &[Literal],
        v: &[Literal],
        step: f32,
        lr: f32,
        grad_scale: f32,
    ) -> crate::Result<(Vec<Literal>, Vec<Literal>, Vec<Literal>)> {
        let st = Literal::scalar(step);
        let lrl = Literal::scalar(lr);
        let gs = Literal::scalar(grad_scale);
        let mut args: Vec<&Literal> =
            params.iter().chain(acc.iter()).chain(m.iter()).chain(v.iter()).collect();
        args.push(&st);
        args.push(&lrl);
        args.push(&gs);
        let out = run(&self.opt, &args)?;
        let p = self.meta.params.len();
        anyhow::ensure!(out.len() == 3 * p, "opt arity {}", out.len());
        let mut it = out.into_iter();
        let params: Vec<Literal> = it.by_ref().take(p).collect();
        let m: Vec<Literal> = it.by_ref().take(p).collect();
        let v: Vec<Literal> = it.collect();
        Ok((params, m, v))
    }

    /// Zero-filled gradient accumulators matching this stage's params.
    pub fn zero_acc(&self) -> crate::Result<Vec<Literal>> {
        self.meta.params.iter().map(|p| f32_literal(&p.shape, 0.0)).collect()
    }
}

/// The loaded runtime: PJRT client + manifest + all stage executables.
pub struct Runtime {
    /// PJRT CPU client (one per process; stages share it).
    pub client: PjRtClient,
    /// The artifact manifest.
    pub manifest: Manifest,
    /// Stage executables in pipeline order.
    pub stages: Vec<StageExe>,
}

impl StageExe {
    /// Compile one stage's programs on a given client. Worker threads call
    /// this with a **thread-local** client: `PjRtClient` is `Rc`-based, so
    /// clients must never be shared across threads.
    pub fn load(client: &PjRtClient, manifest: &Manifest, idx: usize) -> crate::Result<StageExe> {
        let meta = manifest.stages[idx].clone();
        let f = |name: &str| -> crate::Result<PjRtLoadedExecutable> {
            let file = meta
                .files
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("stage {idx} missing `{name}` artifact"))?;
            compile(client, &manifest.dir.join(file))
        };
        let (init, fwd, bwd, opt) = (f("init")?, f("fwd")?, f("bwd")?, f("opt")?);
        Ok(StageExe { idx, meta, init, fwd, bwd, opt })
    }
}

impl Runtime {
    /// Load + compile every stage program from an artifact directory
    /// (single-threaded use: tests, measured profiling, DP chains).
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Runtime> {
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu()?;
        let stages = (0..manifest.n_stages)
            .map(|idx| StageExe::load(&client, &manifest, idx))
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(Runtime { client, manifest, stages })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifact_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/lm1m-s2-b2-jnp");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn load_and_roundtrip_if_built() {
        let Some(dir) = artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.stages.len(), 2);
        // init → param shapes match manifest
        let p0 = rt.stages[0].init(42).unwrap();
        for (lit, meta) in p0.iter().zip(&rt.stages[0].meta.params) {
            assert_eq!(lit.element_count(), meta.elems(), "{}", meta.name);
        }
        // fwd chain produces finite loss near ln(V)
        let man = &rt.manifest;
        let toks = vec![1i32; man.micro_batch * man.seq];
        let x = super::super::i32_literal(&toks, &[man.micro_batch, man.seq]).unwrap();
        let y = rt.stages[0].fwd(&p0, &x, None).unwrap();
        assert_eq!(y.element_count(), man.micro_batch * man.seq * man.d_model);
        let p1 = rt.stages[1].init(43).unwrap();
        let tgt = super::super::i32_literal(&toks, &[man.micro_batch, man.seq]).unwrap();
        let loss = rt.stages[1].fwd(&p1, &y, Some(&tgt)).unwrap();
        let l = loss.to_vec::<f32>().unwrap()[0];
        let ln_v = (man.vocab as f32).ln();
        assert!(l.is_finite() && (l - ln_v).abs() < 1.0, "loss {l} vs ln V {ln_v}");
        // bwd arities
        let acc1 = rt.stages[1].zero_acc().unwrap();
        let (g1, gx) = rt.stages[1].bwd(&p1, &acc1, &y, &tgt).unwrap();
        assert_eq!(g1.len(), p1.len());
        let gx = gx.expect("last stage returns gx");
        let acc0 = rt.stages[0].zero_acc().unwrap();
        let (g0, none) = rt.stages[0].bwd(&p0, &acc0, &x, &gx).unwrap();
        assert_eq!(g0.len(), p0.len());
        assert!(none.is_none());
        // opt runs and changes params
        let m = rt.stages[1].zero_acc().unwrap();
        let v = rt.stages[1].zero_acc().unwrap();
        let (p1b, _, _) = rt.stages[1].opt(&p1, &g1, &m, &v, 1.0, 1e-3, 1.0).unwrap();
        let before = p1[0].to_vec::<f32>().unwrap();
        let after = p1b[0].to_vec::<f32>().unwrap();
        assert!(before.iter().zip(&after).any(|(a, b)| a != b), "params unchanged");
    }
}
