//! Point-to-point interconnect links (PCIe between GPUs, GTY/GTM
//! transceiver links between FPGAs).

/// A duplex link between adjacent accelerators in the daisy chain.
#[derive(Debug, Clone)]
pub struct Link {
    /// Effective bandwidth, bytes/s.
    pub bandwidth: f64,
    /// Per-message latency, seconds.
    pub latency: f64,
}

impl Link {
    /// New link.
    pub fn new(bandwidth: f64, latency: f64) -> Link {
        assert!(bandwidth > 0.0, "link bandwidth must be positive");
        Link { bandwidth, latency }
    }

    /// Transfer time for `bytes` bytes.
    pub fn xfer_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.bandwidth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xfer_time_includes_latency() {
        let l = Link::new(1e9, 1e-5);
        let t = l.xfer_time(1e6);
        assert!((t - (1e-5 + 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn zero_bytes_costs_latency_only() {
        let l = Link::new(1e9, 5e-6);
        assert_eq!(l.xfer_time(0.0), 5e-6);
    }

    #[test]
    #[should_panic(expected = "bandwidth must be positive")]
    fn zero_bandwidth_rejected() {
        Link::new(0.0, 0.0);
    }
}
