//! Fault-injection cluster mutations — the elastic-cluster event layer.
//!
//! Real fleets are not static: devices die or join mid-run, links
//! degrade, and stragglers appear. This module models those facts as a
//! deterministic [`ClusterEvent`] stream (parsed from a scenario JSON by
//! [`Scenario::from_json`]) and applies each event to a
//! `(Cluster, Profile)` pair, producing a [`Mutation`] that carries the
//! mutated cluster, the matching mutated profile, and a **lineage** map
//! from post-event device indices back to pre-event ones — the piece
//! `planner::elastic` needs to restrict an incumbent device order to the
//! survivors when warm-starting a replan.
//!
//! Scenario parsing is *validating*: factors that are NaN/non-finite,
//! zero or negative, duplicated device losses, and out-of-chronological-
//! order `at_mb` positions are rejected at parse time with the typed
//! [`ScenarioError`]/[`EventError`] — a silently mis-mutated cluster is
//! strictly worse than a refused scenario. Each event may carry an
//! optional `"at_mb"` position (micro-batches of the incumbent's epoch
//! already completed when the event fired), which
//! `planner::elastic::run_scenario` uses to amortize a mid-epoch plan
//! switch over only the *remaining* micro-batches.
//!
//! Invariants preserved by every event:
//! * the chain shape (`links.len() == devices.len() - 1`) — an interior
//!   device loss *merges* its two adjacent links (bandwidth = min,
//!   latency = sum: the surviving route crosses both hops);
//! * `Link::new`'s bandwidth > 0 — degradation factors must be positive;
//! * `Profile` size fields — a [`ClusterEvent::Straggler`] slows only the
//!   *time* fields of a device's rows, never `params`/`act_*`/`stash`
//!   (row 0 of the profile is the source of truth for byte sizes).

use crate::cluster::{Cluster, Device, Link};
use crate::model::Network;
use crate::profile::{analytical, Profile};
use crate::util::json::Json;

/// A typed parse/validation error for one scenario event object.
#[derive(Debug, Clone, PartialEq)]
pub enum EventError {
    /// A required field is missing or has the wrong JSON type.
    Field(String),
    /// The `event` discriminator names no known kind.
    UnknownKind(String),
    /// A numeric factor is NaN/non-finite, or outside its valid range
    /// (slowdowns and bandwidth factors must be strictly positive,
    /// latency factors non-negative).
    BadFactor {
        /// Field name of the offending factor.
        field: &'static str,
        /// The rejected value.
        value: f64,
    },
    /// The optional `at_mb` position is not a non-negative integer.
    BadPosition(String),
}

impl std::fmt::Display for EventError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EventError::Field(e) => write!(f, "{e}"),
            EventError::UnknownKind(k) => write!(
                f,
                "unknown event `{k}` (expected device-loss | device-join | \
                 link-degrade | straggler)"
            ),
            EventError::BadFactor { field, value } => write!(
                f,
                "`{field}` = {value} is invalid: factors must be finite \
                 (slowdown/bandwidth strictly positive, latency >= 0)"
            ),
            EventError::BadPosition(e) => {
                write!(f, "`at_mb` must be a non-negative integer ({e})")
            }
        }
    }
}

impl std::error::Error for EventError {}

/// A typed scenario-document parse/validation error
/// ([`Scenario::from_json`]).
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioError {
    /// A scenario-level field is missing or mistyped.
    Doc(String),
    /// One event failed to parse or validate.
    Event {
        /// Index into the `events` array.
        index: usize,
        /// The underlying event error.
        error: EventError,
    },
    /// The same `device-loss` appears twice at the same position — a
    /// copy-paste error, not a plan. Indices shift after each loss, so
    /// repeated losses of a recurring index are legitimate only when the
    /// events carry distinct `at_mb` positions.
    DuplicateLoss {
        /// Device index named by both loss events.
        device: usize,
        /// Index of the first occurrence in the `events` array.
        first: usize,
        /// Index of the duplicate.
        second: usize,
    },
    /// `at_mb` positions must be non-decreasing in array order — events
    /// replay chronologically.
    OutOfOrder {
        /// Index of the offending event.
        index: usize,
        /// Its (earlier) position.
        at_mb: u64,
        /// The largest position seen before it.
        prev: u64,
    },
}

impl std::fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScenarioError::Doc(e) => write!(f, "{e}"),
            ScenarioError::Event { index, error } => write!(f, "event {index}: {error}"),
            ScenarioError::DuplicateLoss { device, first, second } => write!(
                f,
                "event {second}: duplicate device-loss @{device} (already event {first}); \
                 repeated losses of a shifting index must carry distinct at_mb positions"
            ),
            ScenarioError::OutOfOrder { index, at_mb, prev } => write!(
                f,
                "event {index}: at_mb {at_mb} precedes the {prev} of an earlier event — \
                 scenario events must be chronological"
            ),
        }
    }
}

impl std::error::Error for ScenarioError {}

/// `Ok(v)` iff `v` is finite and strictly positive.
fn positive(field: &'static str, v: f64) -> Result<f64, EventError> {
    if v.is_finite() && v > 0.0 {
        Ok(v)
    } else {
        Err(EventError::BadFactor { field, value: v })
    }
}

/// `Ok(v)` iff `v` is finite and non-negative.
fn non_negative(field: &'static str, v: f64) -> Result<f64, EventError> {
    if v.is_finite() && v >= 0.0 {
        Ok(v)
    } else {
        Err(EventError::BadFactor { field, value: v })
    }
}

/// One mutation of the cluster, in the order fields are read from the
/// scenario JSON.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterEvent {
    /// Device at chain slot `device` fails and leaves the chain.
    DeviceLoss {
        /// Pre-event chain index of the lost device.
        device: usize,
    },
    /// A device of preset type `device_name` joins at chain slot
    /// `position` (0 ..= current length).
    DeviceJoin {
        /// Preset device name (`"V100"`, `"P100"`, `"VCU118"`,
        /// `"VCU129"`, `"cpu-host"`).
        device_name: String,
        /// Insertion slot in the chain.
        position: usize,
        /// Link bandwidth (bytes/s) for the new adjacency; when absent
        /// the nearest existing link is cloned.
        link_bandwidth: Option<f64>,
        /// Link latency (s) for the new adjacency.
        link_latency: Option<f64>,
    },
    /// Link at chain slot `link` degrades: bandwidth is multiplied by
    /// `bandwidth_factor` (0 < f), latency by `latency_factor` (f >= 0).
    LinkDegrade {
        /// Link index (between devices `link` and `link + 1`).
        link: usize,
        /// Multiplier on bandwidth (e.g. 0.5 = half the bandwidth).
        bandwidth_factor: f64,
        /// Multiplier on latency (e.g. 2.0 = double the latency).
        latency_factor: f64,
    },
    /// Device at chain slot `device` becomes `slowdown`x slower: all four
    /// time fields of its profile rows are multiplied by `slowdown`.
    Straggler {
        /// Chain index of the straggling device.
        device: usize,
        /// Time multiplier (> 0; 1.5 = 50% slower).
        slowdown: f64,
    },
}

impl ClusterEvent {
    /// One-line description for reports and provenance notes.
    pub fn describe(&self) -> String {
        match self {
            ClusterEvent::DeviceLoss { device } => format!("device-loss @{device}"),
            ClusterEvent::DeviceJoin { device_name, position, .. } => {
                format!("device-join {device_name} @{position}")
            }
            ClusterEvent::LinkDegrade { link, bandwidth_factor, latency_factor } => format!(
                "link-degrade @{link} (bandwidth x{bandwidth_factor}, latency x{latency_factor})"
            ),
            ClusterEvent::Straggler { device, slowdown } => {
                format!("straggler @{device} (x{slowdown})")
            }
        }
    }

    /// Parse **and validate** one event object (`{"event": "...", ...}`).
    /// Factors that are NaN/non-finite, zero or negative where positivity
    /// is required are rejected here, not at apply time — a scenario file
    /// fails loudly before it can mis-mutate anything.
    pub fn from_json(doc: &Json) -> Result<ClusterEvent, EventError> {
        let field = |e: crate::util::json::JsonError| EventError::Field(e.to_string());
        let kind = doc.req_str("event").map_err(field)?;
        match kind {
            "device-loss" => {
                Ok(ClusterEvent::DeviceLoss { device: doc.req_usize("device").map_err(field)? })
            }
            "device-join" => Ok(ClusterEvent::DeviceJoin {
                device_name: doc.req_str("device_name").map_err(field)?.to_string(),
                position: doc.req_usize("position").map_err(field)?,
                link_bandwidth: doc
                    .get("link_bandwidth")
                    .and_then(Json::as_f64)
                    .map(|v| positive("link_bandwidth", v))
                    .transpose()?,
                link_latency: doc
                    .get("link_latency")
                    .and_then(Json::as_f64)
                    .map(|v| non_negative("link_latency", v))
                    .transpose()?,
            }),
            "link-degrade" => Ok(ClusterEvent::LinkDegrade {
                link: doc.req_usize("link").map_err(field)?,
                bandwidth_factor: positive(
                    "bandwidth_factor",
                    doc.req_f64("bandwidth_factor").map_err(field)?,
                )?,
                latency_factor: non_negative(
                    "latency_factor",
                    doc.req_f64("latency_factor").map_err(field)?,
                )?,
            }),
            "straggler" => Ok(ClusterEvent::Straggler {
                device: doc.req_usize("device").map_err(field)?,
                slowdown: positive("slowdown", doc.req_f64("slowdown").map_err(field)?)?,
            }),
            other => Err(EventError::UnknownKind(other.to_string())),
        }
    }

    /// Serialize back to the scenario-JSON event object.
    pub fn to_json(&self) -> Json {
        use crate::util::json::obj;
        match self {
            ClusterEvent::DeviceLoss { device } => {
                obj(vec![("event", "device-loss".into()), ("device", (*device).into())])
            }
            ClusterEvent::DeviceJoin { device_name, position, link_bandwidth, link_latency } => {
                let mut fields = vec![
                    ("event", Json::from("device-join")),
                    ("device_name", device_name.clone().into()),
                    ("position", (*position).into()),
                ];
                if let Some(b) = link_bandwidth {
                    fields.push(("link_bandwidth", (*b).into()));
                }
                if let Some(l) = link_latency {
                    fields.push(("link_latency", (*l).into()));
                }
                obj(fields)
            }
            ClusterEvent::LinkDegrade { link, bandwidth_factor, latency_factor } => obj(vec![
                ("event", "link-degrade".into()),
                ("link", (*link).into()),
                ("bandwidth_factor", (*bandwidth_factor).into()),
                ("latency_factor", (*latency_factor).into()),
            ]),
            ClusterEvent::Straggler { device, slowdown } => obj(vec![
                ("event", "straggler".into()),
                ("device", (*device).into()),
                ("slowdown", (*slowdown).into()),
            ]),
        }
    }
}

/// One scenario entry: a [`ClusterEvent`] plus the optional epoch
/// position that drives mid-epoch switch amortization in
/// `planner::elastic`.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioEvent {
    /// The cluster mutation.
    pub event: ClusterEvent,
    /// Micro-batches of the incumbent's epoch already completed when the
    /// event fired. `None` replans at the epoch boundary (full-epoch
    /// amortization — the scripted-scenario behavior).
    pub at_mb: Option<u64>,
}

impl ScenarioEvent {
    /// One-line description: the event, plus its position when present.
    pub fn describe(&self) -> String {
        match self.at_mb {
            Some(p) => format!("{} at micro-batch {p}", self.event.describe()),
            None => self.event.describe(),
        }
    }
}

/// A named, ordered fault-injection scenario: the event stream the
/// elastic replanner replays against an incumbent plan.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Scenario name (for reports and bench lines).
    pub name: String,
    /// Events, applied in order.
    pub events: Vec<ScenarioEvent>,
}

impl Scenario {
    /// Build a scenario from bare events with no positions — the scripted
    /// form: every replan amortizes a full epoch.
    pub fn scripted(name: &str, events: Vec<ClusterEvent>) -> Scenario {
        Scenario {
            name: name.to_string(),
            events: events.into_iter().map(|event| ScenarioEvent { event, at_mb: None }).collect(),
        }
    }

    /// Parse **and validate** a scenario document:
    /// `{"name": "...", "events": [{"event": "device-loss", "device": 3,
    /// "at_mb": 12}, ...]}` (`at_mb` optional). Beyond per-event factor
    /// validation, two scenario-level rejections apply: a `device-loss`
    /// repeated at the same device index *and* position is a duplicate
    /// ([`ScenarioError::DuplicateLoss`]), and `at_mb` positions must be
    /// non-decreasing ([`ScenarioError::OutOfOrder`]).
    pub fn from_json(doc: &Json) -> Result<Scenario, ScenarioError> {
        let name = doc.req_str("name").map_err(|e| ScenarioError::Doc(e.to_string()))?.to_string();
        let arr = doc.req_arr("events").map_err(|e| ScenarioError::Doc(e.to_string()))?;
        let mut events: Vec<ScenarioEvent> = Vec::new();
        let mut last_pos: Option<u64> = None;
        // (device, at_mb, event index) of every loss seen so far
        let mut losses: Vec<(usize, Option<u64>, usize)> = Vec::new();
        for (i, e) in arr.iter().enumerate() {
            let event = ClusterEvent::from_json(e)
                .map_err(|error| ScenarioError::Event { index: i, error })?;
            let at_mb = match e.get("at_mb") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_usize().map(|u| u as u64).ok_or_else(|| {
                    ScenarioError::Event {
                        index: i,
                        error: EventError::BadPosition(format!("got {v:?}")),
                    }
                })?),
            };
            if let Some(p) = at_mb {
                if let Some(prev) = last_pos {
                    if p < prev {
                        return Err(ScenarioError::OutOfOrder { index: i, at_mb: p, prev });
                    }
                }
                last_pos = Some(p);
            }
            if let ClusterEvent::DeviceLoss { device } = event {
                if let Some(&(_, _, first)) =
                    losses.iter().find(|&&(d, a, _)| d == device && a == at_mb)
                {
                    return Err(ScenarioError::DuplicateLoss { device, first, second: i });
                }
                losses.push((device, at_mb, i));
            }
            events.push(ScenarioEvent { event, at_mb });
        }
        Ok(Scenario { name, events })
    }

    /// Serialize to the scenario-JSON document (`at_mb` emitted only when
    /// present — byte-identical round-trip for positionless scenarios).
    pub fn to_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut j = e.event.to_json();
                if let (Some(p), Json::Obj(map)) = (e.at_mb, &mut j) {
                    map.insert("at_mb".to_string(), Json::from(p as usize));
                }
                j
            })
            .collect();
        crate::util::json::obj(vec![
            ("name", self.name.clone().into()),
            ("events", Json::Arr(events)),
        ])
    }
}

/// The result of applying one event: the mutated cluster + profile pair,
/// a survivor lineage map, and a human-readable note.
#[derive(Debug, Clone)]
pub struct Mutation {
    /// The cluster after the event.
    pub cluster: Cluster,
    /// The profile after the event (rows travel with their devices).
    pub profile: Profile,
    /// `lineage[new_idx] = Some(old_idx)` for surviving devices, `None`
    /// for a freshly joined device.
    pub lineage: Vec<Option<usize>>,
    /// What happened, for provenance notes.
    pub note: String,
}

/// Resolve a preset device spec by name (the scenario JSON's
/// `device_name` field for joins).
pub fn device_by_name(name: &str) -> Result<Device, String> {
    use super::presets;
    match name {
        "V100" | "v100" => Ok(presets::v100()),
        "P100" | "p100" => Ok(presets::p100()),
        "VCU118" | "vcu118" => Ok(presets::vcu118()),
        "VCU129" | "vcu129" => Ok(presets::vcu129()),
        "cpu-host" | "cpu" => Ok(presets::cpu_host()),
        other => Err(format!("unknown device preset `{other}`")),
    }
}

/// Apply one event to `(cluster, profile)`; `net` is needed to profile a
/// joining device. Errors (bad index, last-device loss, non-positive
/// factor) leave the inputs untouched.
pub fn apply(
    net: &Network,
    cluster: &Cluster,
    profile: &Profile,
    event: &ClusterEvent,
) -> Result<Mutation, String> {
    let n = cluster.len();
    match event {
        ClusterEvent::DeviceLoss { device } => {
            let d = *device;
            if d >= n {
                return Err(format!("device-loss index {d} out of range (cluster has {n})"));
            }
            if n == 1 {
                return Err("device-loss would empty the cluster".to_string());
            }
            let mut devices = cluster.devices.clone();
            let lost = devices.remove(d);
            let mut links = cluster.links.clone();
            if d == 0 {
                links.remove(0);
            } else if d == n - 1 {
                links.remove(n - 2);
            } else {
                // Interior loss: the surviving route crosses both former
                // hops — merged bandwidth is the bottleneck, latency adds.
                let left = links.remove(d - 1);
                let right = links.remove(d - 1);
                links.insert(
                    d - 1,
                    Link::new(left.bandwidth.min(right.bandwidth), left.latency + right.latency),
                );
            }
            let mut per_device = profile.per_device.clone();
            per_device.remove(d);
            let lineage = (0..n).filter(|&i| i != d).map(Some).collect();
            Ok(Mutation {
                cluster: Cluster::new(devices, links),
                profile: Profile {
                    model: profile.model.clone(),
                    dtype_bytes: profile.dtype_bytes,
                    per_device,
                },
                lineage,
                note: format!("device-loss: {} @{d} removed, {} devices remain", lost.name, n - 1),
            })
        }
        ClusterEvent::DeviceJoin { device_name, position, link_bandwidth, link_latency } => {
            let p = *position;
            if p > n {
                return Err(format!("device-join position {p} out of range (cluster has {n})"));
            }
            let dev = device_by_name(device_name)?;
            // Profile the joiner in isolation; rows are per-device so a
            // single-device profiling pass yields exactly its row set.
            let solo = Cluster::new(vec![dev.clone()], vec![]);
            let solo_prof = analytical::profile(net, &solo);
            if solo_prof.dtype_bytes != profile.dtype_bytes {
                return Err(format!(
                    "device-join {device_name} would change training precision \
                     ({} vs {} bytes/elem)",
                    solo_prof.dtype_bytes, profile.dtype_bytes
                ));
            }
            let new_link = match (link_bandwidth, link_latency) {
                (Some(b), Some(l)) => {
                    // NaN compares false against every threshold, so the
                    // range checks must be phrased positively.
                    if !(b.is_finite() && *b > 0.0 && l.is_finite() && *l >= 0.0) {
                        return Err(format!(
                            "device-join link parameters invalid (bandwidth {b}, latency {l})"
                        ));
                    }
                    Link::new(*b, *l)
                }
                _ => {
                    // Clone the nearest existing link; a 1-device cluster
                    // has none, so fall back to the board-class preset.
                    let near = if p == 0 { 0 } else { p - 1 };
                    match cluster.links.get(near.min(cluster.links.len().saturating_sub(1))) {
                        Some(l) if !cluster.links.is_empty() => l.clone(),
                        _ => {
                            if dev.exec == super::ExecMode::Async {
                                super::presets::gty_link()
                            } else {
                                super::presets::pcie_gen3_x16()
                            }
                        }
                    }
                }
            };
            let mut devices = cluster.devices.clone();
            devices.insert(p, dev);
            let mut links = cluster.links.clone();
            // Inserting a device adds exactly one adjacency to the chain.
            links.insert(p.min(links.len()), new_link);
            let mut per_device = profile.per_device.clone();
            per_device.insert(p, solo_prof.per_device[0].clone());
            let mut lineage: Vec<Option<usize>> = (0..n).map(Some).collect();
            lineage.insert(p, None);
            Ok(Mutation {
                cluster: Cluster::new(devices, links),
                profile: Profile {
                    model: profile.model.clone(),
                    dtype_bytes: profile.dtype_bytes,
                    per_device,
                },
                lineage,
                note: format!("device-join: {device_name} @{p}, {} devices now", n + 1),
            })
        }
        ClusterEvent::LinkDegrade { link, bandwidth_factor, latency_factor } => {
            let l = *link;
            if l >= cluster.links.len() {
                return Err(format!(
                    "link-degrade index {l} out of range (cluster has {} links)",
                    cluster.links.len()
                ));
            }
            // Phrased positively so NaN (which compares false both ways)
            // cannot slip through and poison every downstream transfer time.
            if !(bandwidth_factor.is_finite()
                && *bandwidth_factor > 0.0
                && latency_factor.is_finite()
                && *latency_factor >= 0.0)
            {
                return Err(format!(
                    "link-degrade factors invalid (bandwidth x{bandwidth_factor}, \
                     latency x{latency_factor})"
                ));
            }
            let mut links = cluster.links.clone();
            let old = &cluster.links[l];
            links[l] = Link::new(old.bandwidth * bandwidth_factor, old.latency * latency_factor);
            Ok(Mutation {
                cluster: Cluster::new(cluster.devices.clone(), links),
                profile: profile.clone(),
                lineage: (0..n).map(Some).collect(),
                note: format!(
                    "link-degrade @{l}: bandwidth x{bandwidth_factor}, latency x{latency_factor}"
                ),
            })
        }
        ClusterEvent::Straggler { device, slowdown } => {
            let d = *device;
            if d >= n {
                return Err(format!("straggler index {d} out of range (cluster has {n})"));
            }
            if !(slowdown.is_finite() && *slowdown > 0.0) {
                return Err(format!(
                    "straggler slowdown must be finite and positive (got {slowdown})"
                ));
            }
            let mut per_device = profile.per_device.clone();
            for row in &mut per_device[d] {
                // Only the time fields: byte sizes are read from row 0 and
                // must stay identical across devices.
                row.fwd *= slowdown;
                row.bwd *= slowdown;
                row.fwd_fixed *= slowdown;
                row.bwd_fixed *= slowdown;
            }
            Ok(Mutation {
                cluster: cluster.clone(),
                profile: Profile {
                    model: profile.model.clone(),
                    dtype_bytes: profile.dtype_bytes,
                    per_device,
                },
                lineage: (0..n).map(Some).collect(),
                note: format!("straggler @{d}: x{slowdown} slower"),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::model::zoo;

    fn setup(n: usize) -> (Network, Cluster, Profile) {
        let net = zoo::vgg16(224);
        let cl = presets::gpu_mixed_cluster(n);
        let prof = analytical::profile(&net, &cl);
        (net, cl, prof)
    }

    #[test]
    fn interior_loss_merges_links() {
        let (net, cl, prof) = setup(4);
        let m = apply(&net, &cl, &prof, &ClusterEvent::DeviceLoss { device: 1 }).unwrap();
        assert_eq!(m.cluster.len(), 3);
        assert_eq!(m.cluster.links.len(), 2);
        // merged link: bandwidth = min of the two PCIe hops, latency = sum
        let merged = &m.cluster.links[0];
        let pcie = presets::pcie_gen3_x16();
        assert_eq!(merged.bandwidth, pcie.bandwidth);
        assert!((merged.latency - 2.0 * pcie.latency).abs() < 1e-18);
        assert_eq!(m.lineage, vec![Some(0), Some(2), Some(3)]);
        // survivors keep their own rows: slot 1 is now the old device 2 (V100)
        assert_eq!(m.cluster.devices[1].name, "V100");
        assert_eq!(m.profile.n_devices(), 3);
        m.profile.validate(&m.cluster).unwrap();
    }

    #[test]
    fn edge_loss_drops_one_link() {
        let (net, cl, prof) = setup(4);
        let m = apply(&net, &cl, &prof, &ClusterEvent::DeviceLoss { device: 0 }).unwrap();
        assert_eq!(m.cluster.len(), 3);
        assert_eq!(m.cluster.links.len(), 2);
        assert_eq!(m.lineage, vec![Some(1), Some(2), Some(3)]);
        let m2 = apply(&net, &cl, &prof, &ClusterEvent::DeviceLoss { device: 3 }).unwrap();
        assert_eq!(m2.lineage, vec![Some(0), Some(1), Some(2)]);
    }

    #[test]
    fn loss_errors() {
        let (net, cl, prof) = setup(2);
        assert!(apply(&net, &cl, &prof, &ClusterEvent::DeviceLoss { device: 5 }).is_err());
        let solo = presets::v100_cluster(1);
        let sp = analytical::profile(&net, &solo);
        assert!(apply(&net, &solo, &sp, &ClusterEvent::DeviceLoss { device: 0 }).is_err());
    }

    #[test]
    fn join_inserts_device_and_profile_row() {
        let (net, cl, prof) = setup(3);
        let ev = ClusterEvent::DeviceJoin {
            device_name: "P100".into(),
            position: 1,
            link_bandwidth: None,
            link_latency: None,
        };
        let m = apply(&net, &cl, &prof, &ev).unwrap();
        assert_eq!(m.cluster.len(), 4);
        assert_eq!(m.cluster.links.len(), 3);
        assert_eq!(m.cluster.devices[1].name, "P100");
        assert_eq!(m.lineage, vec![Some(0), None, Some(1), Some(2)]);
        m.profile.validate(&m.cluster).unwrap();
        // the joiner's row matches a fresh solo profiling pass
        let solo = Cluster::new(vec![presets::p100()], vec![]);
        let sp = analytical::profile(&net, &solo);
        assert_eq!(m.profile.per_device[1].len(), sp.per_device[0].len());
        assert_eq!(m.profile.per_device[1][0].fwd, sp.per_device[0][0].fwd);
    }

    #[test]
    fn join_rejects_precision_change_and_bad_preset() {
        let (net, cl, prof) = setup(2);
        let ev = ClusterEvent::DeviceJoin {
            device_name: "VCU118".into(), // fp16 board into an fp32 cluster
            position: 0,
            link_bandwidth: None,
            link_latency: None,
        };
        assert!(apply(&net, &cl, &prof, &ev).unwrap_err().contains("precision"));
        let bad = ClusterEvent::DeviceJoin {
            device_name: "TPUv9".into(),
            position: 0,
            link_bandwidth: None,
            link_latency: None,
        };
        assert!(apply(&net, &cl, &prof, &bad).is_err());
    }

    #[test]
    fn degrade_and_straggler_mutate_in_place() {
        let (net, cl, prof) = setup(3);
        let m = apply(
            &net,
            &cl,
            &prof,
            &ClusterEvent::LinkDegrade { link: 1, bandwidth_factor: 0.5, latency_factor: 2.0 },
        )
        .unwrap();
        assert_eq!(m.cluster.links[1].bandwidth, cl.links[1].bandwidth * 0.5);
        assert_eq!(m.cluster.links[1].latency, cl.links[1].latency * 2.0);
        assert_eq!(m.cluster.links[0].bandwidth, cl.links[0].bandwidth);
        assert_eq!(m.lineage, vec![Some(0), Some(1), Some(2)]);

        let s =
            apply(&net, &cl, &prof, &ClusterEvent::Straggler { device: 2, slowdown: 1.5 }).unwrap();
        let before = &prof.per_device[2][0];
        let after = &s.profile.per_device[2][0];
        assert!((after.fwd - before.fwd * 1.5).abs() < 1e-18);
        assert!((after.bwd - before.bwd * 1.5).abs() < 1e-18);
        // size fields untouched
        assert_eq!(after.params, before.params);
        assert_eq!(after.act_out_elems, before.act_out_elems);
        // other devices untouched
        assert_eq!(s.profile.per_device[0][0].fwd, prof.per_device[0][0].fwd);
        // factors validated
        assert!(apply(
            &net,
            &cl,
            &prof,
            &ClusterEvent::LinkDegrade { link: 0, bandwidth_factor: 0.0, latency_factor: 1.0 }
        )
        .is_err());
        assert!(
            apply(&net, &cl, &prof, &ClusterEvent::Straggler { device: 0, slowdown: 0.0 }).is_err()
        );
    }

    #[test]
    fn scenario_json_roundtrip() {
        let mut s = Scenario::scripted(
            "loss-degrade-straggle",
            vec![
                ClusterEvent::DeviceLoss { device: 3 },
                ClusterEvent::DeviceJoin {
                    device_name: "V100".into(),
                    position: 2,
                    link_bandwidth: Some(2e9),
                    link_latency: Some(1e-5),
                },
                ClusterEvent::LinkDegrade { link: 1, bandwidth_factor: 0.5, latency_factor: 2.0 },
                ClusterEvent::Straggler { device: 0, slowdown: 1.5 },
            ],
        );
        // positions survive the round-trip too
        s.events[3].at_mb = Some(12);
        let doc = s.to_json();
        let back = Scenario::from_json(&doc).unwrap();
        assert_eq!(s, back);
        // parse from raw text too
        let parsed = Json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(Scenario::from_json(&parsed).unwrap(), s);
        // unknown event kind rejected with the index in the message
        let bad = Json::parse(
            r#"{"name":"x","events":[{"event":"meteor-strike","device":0}]}"#,
        )
        .unwrap();
        assert!(Scenario::from_json(&bad).unwrap_err().to_string().contains("event 0"));
    }

    /// Satellite hardening: every malformed-scenario class is rejected at
    /// *parse* time with the matching typed error — nothing reaches
    /// `apply`.
    #[test]
    fn parse_rejects_bad_factors() {
        // zero straggler slowdown
        let zero = Json::parse(
            r#"{"name":"x","events":[{"event":"straggler","device":0,"slowdown":0.0}]}"#,
        )
        .unwrap();
        assert!(matches!(
            Scenario::from_json(&zero),
            Err(ScenarioError::Event { index: 0, error: EventError::BadFactor { field: "slowdown", .. } })
        ));
        // negative bandwidth factor
        let neg = Json::parse(
            r#"{"name":"x","events":[{"event":"link-degrade","link":0,
                "bandwidth_factor":-0.5,"latency_factor":1.0}]}"#,
        )
        .unwrap();
        assert!(matches!(
            Scenario::from_json(&neg),
            Err(ScenarioError::Event {
                error: EventError::BadFactor { field: "bandwidth_factor", .. },
                ..
            })
        ));
        // negative join latency
        let lat = Json::parse(
            r#"{"name":"x","events":[{"event":"device-join","device_name":"V100",
                "position":0,"link_bandwidth":1e9,"link_latency":-1e-6}]}"#,
        )
        .unwrap();
        assert!(matches!(
            Scenario::from_json(&lat),
            Err(ScenarioError::Event {
                error: EventError::BadFactor { field: "link_latency", .. },
                ..
            })
        ));
    }

    #[test]
    fn parse_rejects_nan_factors() {
        // JSON text cannot spell NaN, but programmatic documents can —
        // and NaN passes naive `<= 0.0` range checks.
        use crate::util::json::obj;
        let doc = obj(vec![
            ("name", "x".into()),
            (
                "events",
                Json::Arr(vec![obj(vec![
                    ("event", "straggler".into()),
                    ("device", 0usize.into()),
                    ("slowdown", f64::NAN.into()),
                ])]),
            ),
        ]);
        assert!(matches!(
            Scenario::from_json(&doc),
            Err(ScenarioError::Event { error: EventError::BadFactor { .. }, .. })
        ));
        // and apply() itself is NaN-proof for programmatically built events
        let (net, cl, prof) = setup(2);
        assert!(apply(
            &net,
            &cl,
            &prof,
            &ClusterEvent::Straggler { device: 0, slowdown: f64::NAN }
        )
        .is_err());
        assert!(apply(
            &net,
            &cl,
            &prof,
            &ClusterEvent::LinkDegrade {
                link: 0,
                bandwidth_factor: f64::NAN,
                latency_factor: 1.0
            }
        )
        .is_err());
    }

    #[test]
    fn parse_rejects_duplicate_loss() {
        let dup = Json::parse(
            r#"{"name":"x","events":[
                {"event":"device-loss","device":2},
                {"event":"straggler","device":0,"slowdown":1.5},
                {"event":"device-loss","device":2}]}"#,
        )
        .unwrap();
        assert!(matches!(
            Scenario::from_json(&dup),
            Err(ScenarioError::DuplicateLoss { device: 2, first: 0, second: 2 })
        ));
        // distinct positions disambiguate a legitimately recurring index
        let ok = Json::parse(
            r#"{"name":"x","events":[
                {"event":"device-loss","device":0,"at_mb":2},
                {"event":"device-loss","device":0,"at_mb":9}]}"#,
        )
        .unwrap();
        assert_eq!(Scenario::from_json(&ok).unwrap().events.len(), 2);
    }

    #[test]
    fn parse_rejects_out_of_order_and_bad_positions() {
        let ooo = Json::parse(
            r#"{"name":"x","events":[
                {"event":"straggler","device":0,"slowdown":1.5,"at_mb":10},
                {"event":"device-loss","device":1,"at_mb":3}]}"#,
        )
        .unwrap();
        assert!(matches!(
            Scenario::from_json(&ooo),
            Err(ScenarioError::OutOfOrder { index: 1, at_mb: 3, prev: 10 })
        ));
        // fractional and negative positions are not micro-batch counts
        let frac = Json::parse(
            r#"{"name":"x","events":[{"event":"device-loss","device":0,"at_mb":1.5}]}"#,
        )
        .unwrap();
        assert!(matches!(
            Scenario::from_json(&frac),
            Err(ScenarioError::Event { error: EventError::BadPosition(_), .. })
        ));
        let neg = Json::parse(
            r#"{"name":"x","events":[{"event":"device-loss","device":0,"at_mb":-4}]}"#,
        )
        .unwrap();
        assert!(matches!(
            Scenario::from_json(&neg),
            Err(ScenarioError::Event { error: EventError::BadPosition(_), .. })
        ));
    }
}
