//! [`Cluster`]: N accelerators in the 1-D daisy-chain topology BaPipe
//! targets (Section 2.3), possibly heterogeneous. `links[i]` connects
//! device `i` to device `i+1`; a closing link is assumed equal to
//! `links[0]` for ring all-reduce in the DP baseline.

use super::device::{Device, ExecMode};
use super::link::Link;

/// An accelerator cluster.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// Devices in chain order.
    pub devices: Vec<Device>,
    /// `links[i]` connects device i ↔ i+1 (`len == devices.len()-1`;
    /// empty for a single device).
    pub links: Vec<Link>,
}

impl Cluster {
    /// Build a cluster; validates link count.
    pub fn new(devices: Vec<Device>, links: Vec<Link>) -> Cluster {
        assert!(!devices.is_empty(), "cluster needs at least one device");
        assert_eq!(
            links.len(),
            devices.len().saturating_sub(1),
            "need exactly N-1 links for N devices"
        );
        Cluster { devices, links }
    }

    /// Homogeneous cluster: `n` copies of `dev` joined by copies of `link`.
    pub fn homogeneous(dev: Device, link: Link, n: usize) -> Cluster {
        assert!(n >= 1);
        Cluster::new(vec![dev; n], vec![link; n.saturating_sub(1)])
    }

    /// Number of accelerators.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// True when there are no devices (constructor forbids it).
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Is every device the same model?
    pub fn is_homogeneous(&self) -> bool {
        self.devices.windows(2).all(|w| w[0].name == w[1].name)
    }

    /// Dense device-name ids in first-appearance order along the chain:
    /// `ids[i] == ids[j]` iff devices `i` and `j` are the same model. The
    /// planner keys device-order dedup and probe memos on these, so the
    /// equivalence ("permuting two identical boards changes nothing") is
    /// defined in exactly one place.
    pub fn name_ids(&self) -> Vec<usize> {
        let mut names: Vec<&str> = Vec::new();
        self.devices
            .iter()
            .map(|d| match names.iter().position(|&n| n == d.name) {
                Some(i) => i,
                None => {
                    names.push(&d.name);
                    names.len() - 1
                }
            })
            .collect()
    }

    /// Can this cluster run asynchronous schedules (all devices Async)?
    pub fn all_async(&self) -> bool {
        self.devices.iter().all(|d| d.exec == ExecMode::Async)
    }

    /// Can this cluster run synchronous schedules? (always true — sync is
    /// the lowest common denominator.)
    pub fn supports_sync(&self) -> bool {
        true
    }

    /// Link used between pipeline stage `i` and `i+1`.
    pub fn link(&self, i: usize) -> &Link {
        &self.links[i]
    }

    /// The slowest link bandwidth (bytes/s) — bounds all-reduce rings.
    pub fn min_link_bandwidth(&self) -> f64 {
        self.links
            .iter()
            .map(|l| l.bandwidth)
            .fold(f64::INFINITY, f64::min)
            .min(if self.links.is_empty() { f64::INFINITY } else { f64::INFINITY })
    }

    /// Short description, e.g. `4x V100` or `2x VCU129 + 2x VCU118`.
    pub fn describe(&self) -> String {
        let mut parts: Vec<(String, usize)> = Vec::new();
        for d in &self.devices {
            if let Some(last) = parts.last_mut() {
                if last.0 == d.name {
                    last.1 += 1;
                    continue;
                }
            }
            parts.push((d.name.clone(), 1));
        }
        parts
            .into_iter()
            .map(|(n, c)| format!("{c}x {n}"))
            .collect::<Vec<_>>()
            .join(" + ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;

    #[test]
    fn homogeneous_build_and_describe() {
        let c = presets::v100_cluster(4);
        assert_eq!(c.len(), 4);
        assert!(c.is_homogeneous());
        assert_eq!(c.describe(), "4x V100");
        assert_eq!(c.links.len(), 3);
    }

    #[test]
    fn heterogeneous_describe() {
        let c = presets::fpga_cluster(&["VCU129", "VCU129", "VCU118", "VCU118"]);
        assert!(!c.is_homogeneous());
        assert_eq!(c.describe(), "2x VCU129 + 2x VCU118");
        assert!(c.all_async());
    }

    #[test]
    fn gpu_cluster_not_async() {
        assert!(!presets::v100_cluster(2).all_async());
    }

    #[test]
    #[should_panic(expected = "N-1 links")]
    fn wrong_link_count() {
        let d = presets::v100();
        Cluster::new(vec![d.clone(), d], vec![]);
    }

    #[test]
    fn name_ids_are_first_appearance_dense() {
        let c = presets::fpga_cluster(&["VCU129", "VCU118", "VCU129", "VCU118"]);
        assert_eq!(c.name_ids(), vec![0, 1, 0, 1]);
        assert_eq!(presets::v100_cluster(3).name_ids(), vec![0, 0, 0]);
    }

    #[test]
    fn single_device_cluster() {
        let c = presets::v100_cluster(1);
        assert_eq!(c.len(), 1);
        assert!(c.links.is_empty());
    }
}
