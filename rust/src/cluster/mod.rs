//! Accelerator-cluster model: device specifications ([`device`]),
//! interconnect links ([`link`]), the 1-D daisy-chain topology BaPipe
//! targets ([`topology`]), presets for the paper's testbeds
//! ([`presets`]: NVIDIA V100, Xilinx VCU118/VCU129, CPU host), and the
//! fault-injection mutation layer ([`mutate`]: device loss/join, link
//! degradation, stragglers — the elastic-replanning event stream), and
//! the online drift detector ([`detect`]) that synthesizes those events
//! from live timing samples instead of a script.

pub mod detect;
pub mod device;
pub mod link;
pub mod mutate;
pub mod presets;
pub mod topology;

pub use device::{Device, ExecMode};
pub use link::Link;
pub use topology::Cluster;
