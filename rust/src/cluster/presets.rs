//! Presets for the paper's testbeds.
//!
//! * **V100** — 8× NVIDIA V100 16 GB, PCIe gen3 x16 (Section 4.1).
//! * **VCU118 / VCU129** — Table 5's Xilinx boards; peak compute derived
//!   FPDeep-style from DSP slices (1 fp16 MAC/DSP/cycle @ 250 MHz).
//! * **cpu_host** — the machine the *real* engine runs on (measured
//!   profiles; capacities read generously since we simulate the cluster).

use super::device::{Device, ExecMode};
use super::link::Link;
use super::topology::Cluster;

const GIB: u64 = 1024 * 1024 * 1024;

/// NVIDIA V100 (16 GB), fp32 training.
pub fn v100() -> Device {
    Device {
        name: "V100".into(),
        peak_flops: 15.7e12,          // fp32 CUDA-core peak
        mem_bw: 900e9,                // HBM2
        mem_capacity: 16 * GIB,
        onchip_capacity: 0,
        onchip_bw: 0.0,
        exec: ExecMode::Sync,
        batch_half_sat: 4.0,          // ~89% utilization at micro-batch 32
        dsp_slices: 0,
    }
}

/// PCIe gen3 x16 between adjacent GPUs, at the ~2 GB/s a GLOO-mediated
/// tensor transfer actually achieves (device→host→device staging with
/// CPU copies; the paper's communication backend is GLOO for all modes —
/// Section 4.2.1). Raw PCIe peak is ~12 GB/s; GLOO reaches a fraction.
pub fn pcie_gen3_x16() -> Link {
    Link::new(2e9, 10e-6)
}

/// Homogeneous V100 cluster of `n` GPUs on PCIe gen3 x16.
pub fn v100_cluster(n: usize) -> Cluster {
    Cluster::homogeneous(v100(), pcie_gen3_x16(), n)
}

/// NVIDIA P100 (16 GB), fp32 training — the previous-generation board of
/// the heterogeneous GPU mixes (the §4.3 placement axis on GPU racks:
/// mixed-generation clusters are the common datacenter reality).
pub fn p100() -> Device {
    Device {
        name: "P100".into(),
        peak_flops: 9.5e12,           // fp32 CUDA-core peak (GP100)
        mem_bw: 720e9,                // HBM2, first generation
        mem_capacity: 16 * GIB,
        onchip_capacity: 0,
        onchip_bw: 0.0,
        exec: ExecMode::Sync,
        batch_half_sat: 4.0,
        dsp_slices: 0,
    }
}

/// Heterogeneous GPU chain alternating V100 (even slots) and P100 (odd
/// slots) on PCIe gen3 x16 — the ≥16-device scenario class the
/// device-order neighbourhood search targets: the alternating identity
/// layout interleaves fast and slow boards, so sorted layouts beat it.
pub fn gpu_mixed_cluster(n: usize) -> Cluster {
    let devices: Vec<Device> =
        (0..n).map(|i| if i % 2 == 0 { v100() } else { p100() }).collect();
    let links = vec![pcie_gen3_x16(); n.saturating_sub(1)];
    Cluster::new(devices, links)
}

/// FPDeep-style FPGA compute peak: `dsp` MACs/cycle at `mhz` MHz, 2 FLOPs
/// per MAC (fp16 DSP packing).
fn fpga_peak(dsp: u64, mhz: f64) -> f64 {
    dsp as f64 * 2.0 * mhz * 1e6
}

/// Xilinx VCU118 (Table 5): 6840 DSP, 345.9 Mb on-chip RAM, ~40 GB/s DDR4.
pub fn vcu118() -> Device {
    Device {
        name: "VCU118".into(),
        peak_flops: fpga_peak(6840, 250.0), // 3.42 TFLOPS fp16
        mem_bw: 40e9,                       // DDR4
        mem_capacity: 8 * GIB,              // DDR4 DIMM on the board
        onchip_capacity: (345.9e6 / 8.0) as u64, // 345.9 Mb → ~43.2 MB
        onchip_bw: 4e12,                    // aggregate BRAM/URAM bandwidth
        exec: ExecMode::Async,
        batch_half_sat: 0.0,                // fine-grained pipeline: full DSP
        dsp_slices: 6840,                   //   utilization at micro-batch 1
    }
}

/// Xilinx VCU129 (Table 5): 12288 DSP, 454.9 Mb on-chip RAM, ~40 GB/s DDR4.
pub fn vcu129() -> Device {
    Device {
        name: "VCU129".into(),
        peak_flops: fpga_peak(12288, 250.0), // 6.14 TFLOPS fp16
        mem_bw: 40e9,
        mem_capacity: 8 * GIB,
        onchip_capacity: (454.9e6 / 8.0) as u64, // ~56.9 MB
        onchip_bw: 4e12,
        exec: ExecMode::Async,
        batch_half_sat: 0.0,
        dsp_slices: 12288,
    }
}

/// Inter-FPGA serial link: 4 bonded GTY lanes @ 25 Gb/s ≈ 12.5 GB/s.
pub fn gty_link() -> Link {
    Link::new(12.5e9, 2e-6)
}

/// FPGA cluster from board names (`"VCU118"` / `"VCU129"`), daisy-chained.
pub fn fpga_cluster(boards: &[&str]) -> Cluster {
    let devices: Vec<Device> = boards
        .iter()
        .map(|b| match *b {
            "VCU118" => vcu118(),
            "VCU129" => vcu129(),
            other => panic!("unknown FPGA board `{other}`"),
        })
        .collect();
    let links = vec![gty_link(); devices.len().saturating_sub(1)];
    Cluster::new(devices, links)
}

/// The host CPU as a device — used when the *measured* profiler times the
/// real per-stage HLO executables, and by the real pipeline engine.
pub fn cpu_host() -> Device {
    Device {
        name: "cpu-host".into(),
        peak_flops: 5.0e10, // conservative single-core XLA-CPU gemm estimate
        mem_bw: 20e9,
        mem_capacity: 8 * GIB,
        onchip_capacity: 0,
        onchip_bw: 0.0,
        exec: ExecMode::Sync,
        batch_half_sat: 0.5,
        dsp_slices: 0,
    }
}

/// In-process "cluster" of `n` CPU pipeline workers (channels as links —
/// bandwidth set high; the real engine measures, it does not model).
pub fn cpu_cluster(n: usize) -> Cluster {
    Cluster::homogeneous(cpu_host(), Link::new(50e9, 1e-6), n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_parameters() {
        let a = vcu118();
        let b = vcu129();
        assert_eq!(a.dsp_slices, 6840);
        assert_eq!(b.dsp_slices, 12288);
        // VCU129 has ~1.8x the DSPs → 1.8x peak
        assert!((b.peak_flops / a.peak_flops - 12288.0 / 6840.0).abs() < 1e-9);
        // on-chip RAM: 345.9 Mb vs 454.9 Mb
        assert!(a.onchip_capacity < b.onchip_capacity);
        assert!((a.onchip_capacity as f64 - 43.2e6).abs() < 1e6);
    }

    #[test]
    fn v100_is_sync_16gb() {
        let d = v100();
        assert_eq!(d.exec, ExecMode::Sync);
        assert_eq!(d.mem_capacity, 16 * GIB);
    }

    #[test]
    #[should_panic(expected = "unknown FPGA board")]
    fn unknown_board_rejected() {
        fpga_cluster(&["VCU999"]);
    }

    #[test]
    fn gpu_mixed_cluster_alternates_generations() {
        let c = gpu_mixed_cluster(16);
        assert_eq!(c.len(), 16);
        assert!(!c.is_homogeneous());
        assert!(!c.all_async(), "GPU mixes stay on the sync schedules");
        for (i, d) in c.devices.iter().enumerate() {
            assert_eq!(d.name, if i % 2 == 0 { "V100" } else { "P100" }, "slot {i}");
        }
        assert!(p100().peak_flops < v100().peak_flops);
        assert_eq!(c.links.len(), 15);
    }

    #[test]
    fn mixed_cluster_table6() {
        let c = fpga_cluster(&["VCU129", "VCU129", "VCU118", "VCU118"]);
        assert_eq!(c.len(), 4);
        assert!(c.all_async());
    }
}
