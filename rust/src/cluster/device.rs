//! Device specification: the hardware constraints BaPipe's explorer
//! consumes (Fig. 3 — computing power, memory bandwidth, memory capacity)
//! plus the execution mode that decides which schedules are available
//! (Section 3.2: GPUs execute synchronously, FPGAs asynchronously).

/// Compute/communication overlap semantics of an accelerator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// GPU-style: a kernel's outputs are sent only after the whole kernel
    /// finishes; FP and BP cannot run concurrently (Section 3.2.2).
    /// Eligible schedules: 1F1B-SNO, 1F1B-SO.
    Sync,
    /// FPGA-style: communication streams out as partial results complete,
    /// and FP/BP can be computed in parallel (Section 3.2.1).
    /// Eligible schedules: 1F1B-AS, FBP-AS.
    Async,
}

/// One accelerator. All throughputs are *effective peaks*; per-layer-kind
/// efficiency factors live in the profiler.
#[derive(Debug, Clone)]
pub struct Device {
    /// Model name (`V100`, `VCU118`, ...).
    pub name: String,
    /// Peak dense-compute throughput in FLOP/s at the training precision.
    pub peak_flops: f64,
    /// Bandwidth of the memory holding weights/activations, bytes/s.
    pub mem_bw: f64,
    /// Capacity of that memory, bytes (16 GiB for the paper's V100s).
    pub mem_capacity: u64,
    /// Fast on-chip memory capacity, bytes (FPGA BRAM/URAM; 0 for GPUs —
    /// their HBM is already the "higher-bandwidth memory" of the paper).
    pub onchip_capacity: u64,
    /// On-chip memory bandwidth, bytes/s (FPGA only).
    pub onchip_bw: f64,
    /// Execution semantics.
    pub exec: ExecMode,
    /// Micro-batch size at which compute efficiency reaches 50% of peak
    /// (GPU utilization saturation; Section 3.2.2 notes throughput drops
    /// at small batch). FPGAs pipeline at micro-batch 1, so ~0.
    pub batch_half_sat: f64,
    /// DSP slices (FPGA) — drives the FPDeep-style profile. 0 for GPUs.
    pub dsp_slices: u64,
}

impl Device {
    /// Compute-efficiency factor for micro-batch size `b`:
    /// `b / (b + batch_half_sat)` — a saturating utilization curve.
    pub fn batch_efficiency(&self, b: f64) -> f64 {
        if self.batch_half_sat <= 0.0 {
            1.0
        } else {
            b / (b + self.batch_half_sat)
        }
    }

    /// Effective FLOP/s at micro-batch size `b` and kind-efficiency `eff`.
    pub fn effective_flops(&self, b: f64, eff: f64) -> f64 {
        self.peak_flops * eff * self.batch_efficiency(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;

    #[test]
    fn batch_efficiency_monotone_saturating() {
        let d = presets::v100();
        let e1 = d.batch_efficiency(1.0);
        let e8 = d.batch_efficiency(8.0);
        let e64 = d.batch_efficiency(64.0);
        assert!(e1 < e8 && e8 < e64 && e64 < 1.0);
        assert!(e64 > 0.9, "large batches near peak: {e64}");
    }

    #[test]
    fn fpga_full_efficiency_at_microbatch_1() {
        let d = presets::vcu118();
        assert_eq!(d.batch_efficiency(1.0), 1.0);
        assert_eq!(d.exec, ExecMode::Async);
    }

    #[test]
    fn effective_flops_scales() {
        let d = presets::v100();
        assert!(d.effective_flops(64.0, 0.5) < d.peak_flops);
        assert!(d.effective_flops(64.0, 0.5) > 0.4 * d.peak_flops * 0.9 * 0.5);
    }
}
