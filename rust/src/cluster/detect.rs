//! Online drift detection — the *live* half of the elastic loop.
//!
//! PR 8's replanner consumed hand-written scenario JSON. Real fleets
//! produce timing *samples*: per-device step times and per-link transfer
//! times, tick after tick ([`profile::measured`](crate::profile::measured)-
//! shaped deltas). This module turns such a stream into
//! [`ClusterEvent`]s without flapping:
//!
//! * **Robust baselines** — each channel's baseline is the median of its
//!   first [`DetectorConfig::baseline_ticks`] samples; the live level is
//!   an EWMA over a sliding-window median, so single outliers never move
//!   the estimate.
//! * **Hysteresis** — a channel enters the degraded state only after its
//!   level/baseline ratio stays at or above
//!   [`DetectorConfig::enter`] for [`DetectorConfig::min_dwell`]
//!   consecutive ticks, and leaves it only after the ratio stays at or
//!   below the lower [`DetectorConfig::exit`] for the same dwell —
//!   bounded jitter below the band provably emits **zero** events, and a
//!   persistent step change emits **exactly one**.
//!
//! The emitted factor is the windowed-median ratio at emission time (the
//! dwell has passed, so the window sits fully on the new level): a
//! device channel becomes [`ClusterEvent::Straggler`] with that
//! slowdown, a link channel becomes [`ClusterEvent::LinkDegrade`] with
//! `bandwidth_factor = 1/ratio` (transfer time on a chain link is
//! bandwidth-dominated for activation-sized messages; latency is left
//! untouched). [`Detection::to_scenario`] then feeds the events straight
//! into `planner::elastic::run_scenario`, each carrying its epoch
//! position (`tick × mb_per_tick`) so mid-epoch switch amortization
//! applies — the detect → replan → migrate loop with no script anywhere.
//!
//! Everything here is plain sequential arithmetic on an explicit sample
//! order: two runs over the same stream are bit-identical, and the
//! events are independent of the planner's `--jobs` by construction.

use crate::cluster::mutate::{ClusterEvent, Scenario, ScenarioEvent};
use crate::util::json::Json;

/// A typed sample-stream parse/validation error.
#[derive(Debug, Clone, PartialEq)]
pub enum DetectError {
    /// A document-level field is missing or mistyped.
    Doc(String),
    /// The stream has no ticks, or a tick has no device channel.
    Empty,
    /// Tick `tick`'s channel counts differ from tick 0's.
    ShapeMismatch {
        /// Offending tick index.
        tick: usize,
        /// `(devices, links)` of tick 0.
        expect: (usize, usize),
        /// `(devices, links)` found.
        got: (usize, usize),
    },
    /// A sample is NaN/non-finite, zero or negative — not a time.
    BadSample {
        /// Tick index of the offending sample.
        tick: usize,
        /// Channel, e.g. `device 3` or `link 0`.
        channel: String,
        /// The rejected value.
        value: f64,
    },
    /// Fewer ticks than the detector needs to freeze a baseline.
    ShortStream {
        /// Ticks present.
        ticks: usize,
        /// Ticks required ([`DetectorConfig::baseline_ticks`]).
        need: usize,
    },
    /// A [`DetectorConfig`] field is out of range.
    BadConfig(String),
}

impl std::fmt::Display for DetectError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DetectError::Doc(e) => write!(f, "{e}"),
            DetectError::Empty => write!(f, "sample stream has no ticks (or no device channels)"),
            DetectError::ShapeMismatch { tick, expect, got } => write!(
                f,
                "tick {tick}: {} device / {} link samples, but tick 0 has {} / {}",
                got.0, got.1, expect.0, expect.1
            ),
            DetectError::BadSample { tick, channel, value } => write!(
                f,
                "tick {tick}, {channel}: sample {value} is not a positive finite time"
            ),
            DetectError::ShortStream { ticks, need } => write!(
                f,
                "stream has {ticks} ticks but the detector needs {need} to freeze a baseline"
            ),
            DetectError::BadConfig(e) => write!(f, "detector config: {e}"),
        }
    }
}

impl std::error::Error for DetectError {}

/// One measurement tick: every channel sampled once.
#[derive(Debug, Clone, PartialEq)]
pub struct Tick {
    /// Per-device step time (s), chain order.
    pub device_times: Vec<f64>,
    /// Per-link transfer time (s), chain order (`devices - 1` entries on
    /// a chain, but any fixed count is accepted).
    pub link_times: Vec<f64>,
}

/// A deterministic, validated timing-sample stream — the detector input.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleStream {
    /// Stream name (becomes the synthesized scenario's name).
    pub name: String,
    /// Micro-batches of training progress per tick; when present, a
    /// detection at tick `t` carries epoch position `t × mb_per_tick`
    /// into the scenario (mid-epoch switch amortization).
    pub mb_per_tick: Option<u64>,
    /// The samples, chronological.
    pub ticks: Vec<Tick>,
}

impl SampleStream {
    /// Parse **and validate** a sample-stream document:
    /// `{"name": "...", "mb_per_tick": 4, "ticks": [{"device_times":
    /// [...], "link_times": [...]}, ...]}` (`mb_per_tick` optional,
    /// `link_times` may be an empty array). Every sample must be a
    /// finite, strictly positive time, and every tick must have the same
    /// channel counts as tick 0.
    pub fn from_json(doc: &Json) -> Result<SampleStream, DetectError> {
        let name = doc.req_str("name").map_err(|e| DetectError::Doc(e.to_string()))?.to_string();
        let mb_per_tick = match doc.get("mb_per_tick") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_usize().map(|u| u as u64).ok_or_else(|| {
                DetectError::Doc("`mb_per_tick` must be a non-negative integer".to_string())
            })?),
        };
        let arr = doc.req_arr("ticks").map_err(|e| DetectError::Doc(e.to_string()))?;
        let mut ticks = Vec::with_capacity(arr.len());
        for (t, tick_doc) in arr.iter().enumerate() {
            let series = |key: &str, label: &str| -> Result<Vec<f64>, DetectError> {
                let vals = tick_doc
                    .req_arr(key)
                    .map_err(|e| DetectError::Doc(format!("tick {t}: {e}")))?;
                let mut out = Vec::with_capacity(vals.len());
                for (c, v) in vals.iter().enumerate() {
                    let x = v.as_f64().ok_or_else(|| DetectError::BadSample {
                        tick: t,
                        channel: format!("{label} {c}"),
                        value: f64::NAN,
                    })?;
                    if !(x.is_finite() && x > 0.0) {
                        return Err(DetectError::BadSample {
                            tick: t,
                            channel: format!("{label} {c}"),
                            value: x,
                        });
                    }
                    out.push(x);
                }
                Ok(out)
            };
            let device_times = series("device_times", "device")?;
            let link_times = series("link_times", "link")?;
            ticks.push(Tick { device_times, link_times });
        }
        let stream = SampleStream { name, mb_per_tick, ticks };
        stream.validate_shape()?;
        Ok(stream)
    }

    /// Shape invariants shared by [`Self::from_json`] and
    /// programmatically built streams (which [`detect`] re-checks).
    pub fn validate_shape(&self) -> Result<(), DetectError> {
        let first = self.ticks.first().ok_or(DetectError::Empty)?;
        if first.device_times.is_empty() {
            return Err(DetectError::Empty);
        }
        let expect = (first.device_times.len(), first.link_times.len());
        for (t, tick) in self.ticks.iter().enumerate() {
            let got = (tick.device_times.len(), tick.link_times.len());
            if got != expect {
                return Err(DetectError::ShapeMismatch { tick: t, expect, got });
            }
        }
        Ok(())
    }
}

/// Hysteresis thresholds and smoothing of the drift detector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DetectorConfig {
    /// Sliding-window length of the per-tick median (outlier rejection).
    pub window: usize,
    /// EWMA weight of the newest window median (`0 < α <= 1`).
    pub ewma_alpha: f64,
    /// Enter the degraded state at `level/baseline >= enter` (> 1).
    pub enter: f64,
    /// Leave it again at `level/baseline <= exit` (`1 <= exit < enter` —
    /// the gap is the hysteresis band that kills flapping).
    pub exit: f64,
    /// Consecutive ticks a crossing must persist before it counts.
    pub min_dwell: usize,
    /// Ticks whose median freezes the per-channel baseline.
    pub baseline_ticks: usize,
}

impl Default for DetectorConfig {
    fn default() -> DetectorConfig {
        DetectorConfig {
            window: 5,
            ewma_alpha: 0.3,
            enter: 1.25,
            exit: 1.1,
            min_dwell: 3,
            baseline_ticks: 4,
        }
    }
}

impl DetectorConfig {
    /// Range-check every field.
    pub fn validate(&self) -> Result<(), DetectError> {
        if self.window == 0 {
            return Err(DetectError::BadConfig("window must be >= 1".to_string()));
        }
        if !(self.ewma_alpha.is_finite() && self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0) {
            return Err(DetectError::BadConfig(format!(
                "ewma_alpha {} must be in (0, 1]",
                self.ewma_alpha
            )));
        }
        if !(self.enter.is_finite() && self.enter > 1.0) {
            return Err(DetectError::BadConfig(format!("enter {} must be > 1", self.enter)));
        }
        if !(self.exit.is_finite() && self.exit >= 1.0 && self.exit < self.enter) {
            return Err(DetectError::BadConfig(format!(
                "exit {} must satisfy 1 <= exit < enter ({})",
                self.exit, self.enter
            )));
        }
        if self.min_dwell == 0 {
            return Err(DetectError::BadConfig("min_dwell must be >= 1".to_string()));
        }
        if self.baseline_ticks == 0 {
            return Err(DetectError::BadConfig("baseline_ticks must be >= 1".to_string()));
        }
        Ok(())
    }
}

/// One synthesized event, tagged with the tick that triggered it.
#[derive(Debug, Clone, PartialEq)]
pub struct DetectedEvent {
    /// Tick index at which the dwell completed.
    pub tick: usize,
    /// The synthesized cluster event.
    pub event: ClusterEvent,
}

/// Detector output: events in tick order (device channels before link
/// channels within one tick), plus human-readable notes.
#[derive(Debug, Clone, PartialEq)]
pub struct Detection {
    /// Synthesized events.
    pub events: Vec<DetectedEvent>,
    /// Baselines, recoveries and other provenance, one line each.
    pub notes: Vec<String>,
}

impl Detection {
    /// Package the detections as a [`Scenario`] for
    /// `planner::elastic::run_scenario` — the live replacement for a
    /// scripted scenario file. With [`SampleStream::mb_per_tick`] set,
    /// each event carries its epoch position.
    pub fn to_scenario(&self, stream: &SampleStream) -> Scenario {
        Scenario {
            name: stream.name.clone(),
            events: self
                .events
                .iter()
                .map(|d| ScenarioEvent {
                    event: d.event.clone(),
                    at_mb: stream.mb_per_tick.map(|k| k * d.tick as u64),
                })
                .collect(),
        }
    }
}

/// Median of a non-empty slice (sorted copy; ties resolve to the upper
/// middle, matching `profile::measured`'s `len/2` pick).
fn median(xs: &[f64]) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s[s.len() / 2]
}

/// Per-channel hysteresis state machine over one sample series; returns
/// `(tick, factor)` per emission plus recovery notes.
fn channel_drift(
    samples: &[f64],
    cfg: &DetectorConfig,
    label: &str,
    notes: &mut Vec<String>,
) -> Vec<(usize, f64)> {
    let baseline = median(&samples[..cfg.baseline_ticks.min(samples.len())]);
    let mut ewma = baseline;
    let mut degraded = false;
    let mut dwell = 0usize;
    let mut out = Vec::new();
    for (i, _) in samples.iter().enumerate() {
        let lo = (i + 1).saturating_sub(cfg.window);
        let med = median(&samples[lo..=i]);
        ewma = cfg.ewma_alpha * med + (1.0 - cfg.ewma_alpha) * ewma;
        if i < cfg.baseline_ticks {
            // Baseline window: the state machine is not armed yet.
            continue;
        }
        let ratio = ewma / baseline;
        if !degraded {
            if ratio >= cfg.enter {
                dwell += 1;
                if dwell >= cfg.min_dwell {
                    degraded = true;
                    dwell = 0;
                    // Emit the *windowed-median* ratio: after the dwell the
                    // window sits on the new level, so this is the step
                    // size itself, not the EWMA's lagged estimate.
                    out.push((i, med / baseline));
                }
            } else {
                dwell = 0;
            }
        } else if ratio <= cfg.exit {
            dwell += 1;
            if dwell >= cfg.min_dwell {
                degraded = false;
                dwell = 0;
                notes.push(format!(
                    "{label}: recovered at tick {i} (ratio {ratio:.3}); re-arming — a further \
                     excursion would emit again"
                ));
            }
        } else {
            dwell = 0;
        }
    }
    out
}

/// Run the drift detector over a validated sample stream.
///
/// Device channels synthesize [`ClusterEvent::Straggler`] (slowdown =
/// median ratio), link channels [`ClusterEvent::LinkDegrade`]
/// (`bandwidth_factor = 1/ratio`). One event per excursion per channel —
/// hysteresis plus dwell guarantee that jitter strictly inside the
/// `exit..enter` band never emits, and the notes record baselines and
/// recoveries. Deterministic: same stream + config → bit-identical
/// output, independent of any planner parallelism.
pub fn detect(stream: &SampleStream, cfg: &DetectorConfig) -> Result<Detection, DetectError> {
    cfg.validate()?;
    stream.validate_shape()?;
    let t = stream.ticks.len();
    if t < cfg.baseline_ticks {
        return Err(DetectError::ShortStream { ticks: t, need: cfg.baseline_ticks });
    }
    let n_dev = stream.ticks[0].device_times.len();
    let n_link = stream.ticks[0].link_times.len();
    let mut notes = vec![format!(
        "detector: {t} ticks, {n_dev} device + {n_link} link channels; enter x{}, exit x{}, \
         dwell {}, window {}",
        cfg.enter, cfg.exit, cfg.min_dwell, cfg.window
    )];
    // (tick, channel-kind-order, event) — sorted at the end so emissions
    // interleave chronologically across channels.
    let mut tagged: Vec<(usize, usize, ClusterEvent)> = Vec::new();
    for d in 0..n_dev {
        let series: Vec<f64> = stream.ticks.iter().map(|k| k.device_times[d]).collect();
        for (tick, ratio) in channel_drift(&series, cfg, &format!("device {d}"), &mut notes) {
            notes.push(format!(
                "device {d}: straggler x{ratio:.3} confirmed at tick {tick} (dwell complete)"
            ));
            tagged.push((tick, d, ClusterEvent::Straggler { device: d, slowdown: ratio }));
        }
    }
    for l in 0..n_link {
        let series: Vec<f64> = stream.ticks.iter().map(|k| k.link_times[l]).collect();
        for (tick, ratio) in channel_drift(&series, cfg, &format!("link {l}"), &mut notes) {
            notes.push(format!(
                "link {l}: transfer time x{ratio:.3} confirmed at tick {tick} — bandwidth \
                 factor {:.3}",
                1.0 / ratio
            ));
            tagged.push((
                tick,
                n_dev + l,
                ClusterEvent::LinkDegrade {
                    link: l,
                    bandwidth_factor: 1.0 / ratio,
                    latency_factor: 1.0,
                },
            ));
        }
    }
    tagged.sort_by_key(|&(tick, chan, _)| (tick, chan));
    let events = tagged.into_iter().map(|(tick, _, event)| DetectedEvent { tick, event }).collect();
    Ok(Detection { events, notes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure, Config};

    fn stream(n_dev: usize, n_link: usize, ticks: usize, f: impl Fn(usize, usize, bool) -> f64) -> SampleStream {
        SampleStream {
            name: "synthetic".to_string(),
            mb_per_tick: None,
            ticks: (0..ticks)
                .map(|t| Tick {
                    device_times: (0..n_dev).map(|c| f(t, c, true)).collect(),
                    link_times: (0..n_link).map(|c| f(t, c, false)).collect(),
                })
                .collect(),
        }
    }

    #[test]
    fn parse_validates_samples_and_shape() {
        let ok = Json::parse(
            r#"{"name":"rack","mb_per_tick":4,"ticks":[
                {"device_times":[1.0e-3,2.0e-3],"link_times":[1.0e-4]},
                {"device_times":[1.1e-3,2.1e-3],"link_times":[1.1e-4]}]}"#,
        )
        .unwrap();
        let s = SampleStream::from_json(&ok).unwrap();
        assert_eq!(s.ticks.len(), 2);
        assert_eq!(s.mb_per_tick, Some(4));

        // zero, negative and non-finite samples are rejected with position
        let zero = Json::parse(
            r#"{"name":"x","ticks":[{"device_times":[0.0],"link_times":[]}]}"#,
        )
        .unwrap();
        assert!(matches!(
            SampleStream::from_json(&zero),
            Err(DetectError::BadSample { tick: 0, .. })
        ));
        let neg = Json::parse(
            r#"{"name":"x","ticks":[{"device_times":[1e-3],"link_times":[-2e-4]}]}"#,
        )
        .unwrap();
        assert!(matches!(
            SampleStream::from_json(&neg),
            Err(DetectError::BadSample { tick: 0, .. })
        ));
        // programmatic NaN cannot sneak through either
        use crate::util::json::obj;
        let nan = obj(vec![
            ("name", "x".into()),
            (
                "ticks",
                Json::Arr(vec![obj(vec![
                    ("device_times", Json::Arr(vec![f64::NAN.into()])),
                    ("link_times", Json::Arr(vec![])),
                ])]),
            ),
        ]);
        assert!(matches!(
            SampleStream::from_json(&nan),
            Err(DetectError::BadSample { tick: 0, .. })
        ));
        // ragged tick widths are a shape error
        let ragged = Json::parse(
            r#"{"name":"x","ticks":[
                {"device_times":[1e-3,1e-3],"link_times":[1e-4]},
                {"device_times":[1e-3],"link_times":[1e-4]}]}"#,
        )
        .unwrap();
        assert!(matches!(
            SampleStream::from_json(&ragged),
            Err(DetectError::ShapeMismatch { tick: 1, .. })
        ));
    }

    #[test]
    fn short_stream_and_bad_config_rejected() {
        let s = stream(2, 1, 2, |_, c, _| 1e-3 * (c + 1) as f64);
        assert!(matches!(
            detect(&s, &DetectorConfig::default()),
            Err(DetectError::ShortStream { ticks: 2, need: 4 })
        ));
        let bad = DetectorConfig { exit: 1.5, enter: 1.25, ..DetectorConfig::default() };
        let s2 = stream(1, 0, 10, |_, _, _| 1e-3);
        assert!(matches!(detect(&s2, &bad), Err(DetectError::BadConfig(_))));
    }

    /// Satellite (c), part 1: constant-rate streams with bounded jitter
    /// strictly below the hysteresis band emit zero events — for any
    /// channel count, length and jitter pattern.
    #[test]
    fn prop_jitter_below_band_emits_nothing() {
        check(
            &Config { cases: 64, ..Config::default() },
            |g| {
                let n_dev = g.usize_in(1, 4);
                let n_link = n_dev - 1;
                let ticks = g.usize_in(8, 40);
                let jit: Vec<f64> =
                    (0..ticks * (n_dev + n_link)).map(|_| g.f64_in(-0.05, 0.05)).collect();
                (n_dev, n_link, ticks, jit)
            },
            |(n_dev, n_link, ticks, jit)| {
                let nd = *n_dev;
                let s = stream(nd, *n_link, *ticks, |t, c, is_dev| {
                    let chan = if is_dev { c } else { nd + c };
                    let base = 1e-3 * (chan + 1) as f64;
                    base * (1.0 + jit[t * (nd + n_link) + chan])
                });
                let d = detect(&s, &DetectorConfig::default()).unwrap();
                ensure(
                    d.events.is_empty(),
                    format!("jitter below the band must not flap: {:?}", d.events),
                )
            },
        );
    }

    /// Satellite (c), part 2: a persistent step change emits exactly one
    /// `Straggler` on exactly the stepped device — no flapping — and the
    /// detector is bit-identical across runs.
    #[test]
    fn prop_step_change_emits_exactly_one_event() {
        check(
            &Config { cases: 64, ..Config::default() },
            |g| {
                let n_dev = g.usize_in(2, 5);
                let culprit = g.usize_in(0, n_dev - 1);
                let step_at = g.usize_in(5, 12);
                let tail = g.usize_in(15, 30);
                (n_dev, culprit, step_at, tail)
            },
            |&(n_dev, culprit, step_at, tail)| {
                let s = stream(n_dev, n_dev - 1, step_at + tail, |t, c, is_dev| {
                    let base = 1e-3 * (c + 1) as f64 * if is_dev { 1.0 } else { 0.1 };
                    if is_dev && c == culprit && t >= step_at {
                        base * 1.6
                    } else {
                        base
                    }
                });
                let a = detect(&s, &DetectorConfig::default()).unwrap();
                let b = detect(&s, &DetectorConfig::default()).unwrap();
                ensure(a == b, "detector must be deterministic".to_string())?;
                ensure(
                    a.events.len() == 1,
                    format!("exactly one event, got {:?}", a.events),
                )?;
                match &a.events[0].event {
                    ClusterEvent::Straggler { device, slowdown } => {
                        ensure(*device == culprit, format!("wrong device {device}"))?;
                        ensure(
                            (slowdown - 1.6).abs() < 1e-9,
                            format!("median ratio should be the step size, got {slowdown}"),
                        )
                    }
                    other => ensure(false, format!("expected a straggler, got {other:?}")),
                }
            },
        );
    }

    #[test]
    fn link_step_becomes_bandwidth_degrade_with_position() {
        let s = SampleStream {
            mb_per_tick: Some(4),
            ..stream(2, 1, 30, |t, _, is_dev| {
                if is_dev {
                    1e-3
                } else if t >= 10 {
                    3e-4
                } else {
                    1.5e-4
                }
            })
        };
        let d = detect(&s, &DetectorConfig::default()).unwrap();
        assert_eq!(d.events.len(), 1, "{:?}", d.events);
        let ev = &d.events[0];
        match &ev.event {
            ClusterEvent::LinkDegrade { link, bandwidth_factor, latency_factor } => {
                assert_eq!(*link, 0);
                assert!((bandwidth_factor - 0.5).abs() < 1e-9, "{bandwidth_factor}");
                assert_eq!(*latency_factor, 1.0);
            }
            other => panic!("expected link-degrade, got {other:?}"),
        }
        // the scenario carries the epoch position tick × mb_per_tick
        let sc = d.to_scenario(&s);
        assert_eq!(sc.name, "synthetic");
        assert_eq!(sc.events[0].at_mb, Some(4 * ev.tick as u64));
    }

    #[test]
    fn recovery_rearms_and_second_excursion_emits_again() {
        // up at 8, down at 20, up again at 32: two excursions, two events
        let s = stream(1, 0, 50, |t, _, _| {
            if (8..20).contains(&t) || t >= 32 {
                1.8e-3
            } else {
                1e-3
            }
        });
        let d = detect(&s, &DetectorConfig::default()).unwrap();
        assert_eq!(d.events.len(), 2, "{:?}", d.events);
        assert!(d.notes.iter().any(|n| n.contains("recovered")), "{:?}", d.notes);
    }
}
