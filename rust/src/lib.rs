//! # BaPipe — balanced pipeline parallelism for DNN training
//!
//! Reproduction of *"BaPipe: Exploration of Balanced Pipeline Parallelism
//! for DNN Training"* (Zhao et al., 2020) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: automatic exploration
//!   of pipeline *scheduling* ([`schedule`], [`explorer`]) and *balanced
//!   partition* ([`partition`]), a discrete-event cluster simulator
//!   ([`sim`]), and a real multi-threaded pipeline training engine
//!   ([`pipeline`]) executing AOT-compiled XLA stage programs via
//!   [`runtime`].
//! * **L2 (python/compile/model.py)** — JAX transformer-LM stage graphs
//!   (fwd / bwd-with-recompute / adam / init), lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot spots, verified against a pure-jnp oracle.
//!
//! Python never runs on the training path: `make artifacts` produces
//! `artifacts/<model>/*.hlo.txt` + `manifest.json`, and the rust binary is
//! self-contained afterwards.
//!
//! ## Quick tour
//!
//! ```no_run
//! use bapipe::{cluster, model, profile, explorer};
//!
//! // 1. Describe the workload and the cluster.
//! let net = model::zoo::vgg16(224);
//! let cl = cluster::presets::v100_cluster(4);
//! // 2. Profile analytically (or measure real stage executables).
//! let prof = profile::analytical::profile(&net, &cl);
//! // 3. Let BaPipe explore schedule x partition x micro-batching.
//! let plan = explorer::explore(&net, &cl, &prof, &explorer::Options::default());
//! println!("{}", plan.report());
//! ```
#![deny(missing_docs)]

pub mod cluster;
pub mod collective;
pub mod config;
pub mod data;
pub mod explorer;
pub mod metrics;
pub mod model;
pub mod partition;
pub mod pipeline;
pub mod profile;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod util;

/// Crate-wide result type (thin alias over [`anyhow::Result`]).
pub type Result<T> = anyhow::Result<T>;
